"""Multi-device sharded async engine walkthrough.

Shards a 20,000-agent random geometric collaboration graph across 4
XLA host-platform devices (the same ``shard_map`` program runs unchanged
on real TPU/GPU meshes): degree-balanced agent blocks, per-shard wake
batches, and a halo exchange that ships only the start-of-slot border
rows between shards. Cross-checks the result against the single-device
batched engine — under forced wake sets the two are bit-identical; under
sampled clocks both land on the same fixed point.

Run:  PYTHONPATH=src python examples/sharded_async_simulation.py
"""

import os

# Must happen before jax initializes: split the CPU into 4 host devices.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np  # noqa: E402

from repro.core import AgentData, make_objective, random_geometric_graph  # noqa: E402
from repro.sim import (  # noqa: E402
    AsyncEngine,
    CDUpdate,
    ChurnConfig,
    Scenario,
    ShardedAsyncEngine,
)


def main():
    import jax

    rng = np.random.default_rng(0)
    n, p, m, shards = 20_000, 8, 16, 4
    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    obj = make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")
    Theta0 = np.zeros((n, p))
    update = CDUpdate(obj)

    print(f"devices: {len(jax.devices())}, shards: {shards}")
    eng = ShardedAsyncEngine(
        update, num_shards=shards, slot_wakes=1024.0, seed=1,
        scenario=Scenario(churn=ChurnConfig(leave_prob=0.005, rejoin_prob=0.2)),
    )
    part = eng.part
    print(
        f"partition: mode={part.mode} rows/shard<={part.rows_per_shard} "
        f"tile K={part.tile_width} halo fraction={part.halo_fraction():.2f}"
    )

    res = eng.run(Theta0, slots=40, record_every=10)
    print("[sharded]  Q:", " -> ".join(f"{q:.1f}" for q in res.objective))
    print(
        f"           {res.wakes_applied} wakes over {res.slots} super-ticks, "
        f"{res.messages:.0f} p-vectors broadcast, "
        f"{int((~res.active).sum())} agents currently departed"
    )

    # Forced wake sets: the sharded program IS the single-device engine.
    single = AsyncEngine(update, slot_wakes=64.0, seed=1)
    s1 = single.init_state(Theta0)
    sS = eng.init_state(Theta0)
    mask_rng = np.random.default_rng(7)
    for _ in range(3):
        mask = mask_rng.random(n) < 0.005
        s1 = single.step(s1, mask)
        sS = eng.step(sS, mask)
    exact = np.array_equal(np.asarray(s1.Theta), eng.global_theta(sS))
    print(f"[parity]   forced wake sets bit-identical to AsyncEngine: {exact}")


if __name__ == "__main__":
    main()
