"""Multi-device sharded async engine walkthrough.

Shards a 20,000-agent random geometric collaboration graph across 4
XLA host-platform devices (the same ``shard_map`` program runs unchanged
on real TPU/GPU meshes): a reverse Cuthill–McKee relabel pass co-locates
graph neighbours so the cut shrinks, agent blocks carry their own slice
of the dataset (no replicated ``obj.data``), and the halo exchange goes
point-to-point — each shard ships only the border rows its neighbour
shards actually read. One :class:`repro.sim.EngineConfig` drives both
engines through :func:`repro.sim.make_engine`; the wire format is an
:class:`repro.sim.ExchangeSpec` (here also demonstrated with bf16
payloads + error feedback, which halves the interconnect bytes).
Cross-checks the result against the single-device batched engine — under
forced wake sets the two are bit-identical; under sampled clocks both
land on the same fixed point.

Run:  PYTHONPATH=src python examples/sharded_async_simulation.py
      PYTHONPATH=src python examples/sharded_async_simulation.py --smoke   # CI-sized

Crash-safe resume (the CI checkpoint lane drives exactly this pair)::

    # write rotating checkpoints, then die mid-run (exit code 7)
    python examples/sharded_async_simulation.py --smoke \
        --checkpoint-dir ckpts --checkpoint-every 4 --kill-after 8
    # pick up from the newest valid entry and finish (parity assert included)
    python examples/sharded_async_simulation.py --smoke \
        --checkpoint-dir ckpts --checkpoint-every 4 --resume
"""

import argparse
import os

# Must happen before jax initializes: split the CPU into 4 host devices.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np  # noqa: E402

from repro.core import AgentData, make_objective, random_geometric_graph  # noqa: E402
from repro.sim import (  # noqa: E402
    CDUpdate,
    ChurnConfig,
    EngineConfig,
    ExchangeSpec,
    Scenario,
    make_engine,
    partition_graph,
)


def main(smoke: bool = False, checkpoint_dir=None, checkpoint_every=0,
         keep_last=3, resume=False, kill_after=0):
    import jax

    from repro.checkpoint import restore, save_engine_checkpoint

    rng = np.random.default_rng(0)
    n, p, m, shards = (2_000, 4, 8, 4) if smoke else (20_000, 8, 16, 4)
    slots, record_every = (12, 6) if smoke else (40, 10)
    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    obj = make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")
    Theta0 = np.zeros((n, p))
    update = CDUpdate(obj)

    print(f"devices: {len(jax.devices())}, shards: {shards}")
    # One config, both engines. Placement fields (relabel, exchange) are
    # no-ops on the single-device side, so the parity pair shares it.
    cfg = EngineConfig(
        slot_wakes=n / 20.0,
        seed=1,
        relabel="rcm",
        exchange=ExchangeSpec(method="auto"),
        scenario=Scenario(churn=ChurnConfig(leave_prob=0.005, rejoin_prob=0.2)),
    )
    # Locality matters: agent ids carry no spatial information, so plain
    # contiguous blocks read mostly remote rows; the RCM relabel shrinks
    # the cut by an order of magnitude and unlocks the p2p exchange.
    base = partition_graph(graph, shards)
    eng = make_engine(update, cfg, shards=shards)
    part = eng.part
    print(
        f"partition: mode={part.mode} rows/shard<={part.rows_per_shard} "
        f"tile K={part.tile_width}"
    )
    print(
        f"halo fraction: {base.halo_fraction():.2f} (no relabel) -> "
        f"{part.halo_fraction():.2f} (RCM); exchange={eng.exchange_method}, "
        f"{part.exchange_rows(eng.exchange_method)} rows/super-tick vs "
        f"{base.exchange_rows('all_gather')} unrelabeled all_gather"
    )

    if kill_after > 0:
        # CI crash rehearsal: checkpoint every few slots, then die hard
        # mid-run (no atexit, no cleanup — exactly like a preempted node).
        assert checkpoint_dir is not None and checkpoint_every > 0
        eng.run(Theta0, slots=min(kill_after, slots),
                checkpoint_every=checkpoint_every, checkpoint_dir=checkpoint_dir,
                checkpoint_keep_last=keep_last)
        print(f"[kill]     checkpointed through slot {min(kill_after, slots)}, dying now")
        os._exit(7)

    state0, start = None, 0
    if resume:
        state0, start = restore(eng, checkpoint_dir)
        print(f"[resume]   picked up slot {start} from {checkpoint_dir}")
    res = eng.run(
        Theta0,
        slots=slots - start,
        record_every=record_every,
        state=state0,
        checkpoint_every=checkpoint_every,
        checkpoint_dir=checkpoint_dir if checkpoint_every > 0 else None,
        checkpoint_keep_last=keep_last,
    )
    print("[sharded]  Q:", " -> ".join(f"{q:.1f}" for q in res.objective))
    print(
        f"           {res.wakes_applied} wakes over {res.slots} super-ticks, "
        f"{res.messages:.0f} p-vectors broadcast, "
        f"{int((~res.active).sum())} agents currently departed"
    )

    # Compressed halos: ship the border rows as bf16 with an error-feedback
    # accumulator — half the interconnect bytes, same fixed point (the EF
    # loop re-injects each slot's quantization residual next slot).
    wire = ExchangeSpec(method="p2p", dtype="bf16", error_feedback=True)
    ceng = make_engine(update, cfg, shards=shards, exchange=wire)
    cres = ceng.run(Theta0, slots=slots)
    drift = float(np.abs(cres.Theta - res.Theta).max())
    f32_bytes = part.exchange_rows("p2p") * ExchangeSpec().payload_bytes_per_row(p)
    bf16_bytes = part.exchange_rows("p2p") * wire.payload_bytes_per_row(p)
    print(
        f"[bf16+ef]  halo payload {f32_bytes} -> {bf16_bytes} bytes/super-tick "
        f"({f32_bytes / bf16_bytes:.1f}x less wire), |Theta - f32 Theta| "
        f"<= {drift:.1e}"
    )

    # Forced wake sets: the sharded program IS the single-device engine,
    # under any relabeling and either exchange method.
    single = make_engine(update, cfg, slot_wakes=64.0)
    s1 = single.init_state(Theta0)
    sS = eng.init_state(Theta0)
    mask_rng = np.random.default_rng(7)
    for _ in range(3):
        mask = mask_rng.random(n) < 0.005
        s1 = single.step(s1, mask)
        sS = eng.step(sS, mask)
    exact = np.array_equal(np.asarray(s1.Theta), eng.global_theta(sS))
    print(f"[parity]   forced wake sets bit-identical to AsyncEngine: {exact}")
    # CI runs this example as a check: a broken parity must fail the lane,
    # not just print False.
    assert exact, "sharded engine diverged from AsyncEngine under forced wakes"


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized problem")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="slots between rotating engine checkpoints (0 = off)")
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid entry and finish the run")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="checkpoint through this many slots then os._exit(7)")
    a = ap.parse_args()
    main(smoke=a.smoke, checkpoint_dir=a.checkpoint_dir,
         checkpoint_every=a.checkpoint_every, keep_last=a.keep_last,
         resume=a.resume, kill_after=a.kill_after)
