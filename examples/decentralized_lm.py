"""End-to-end driver (deliverable b): train a ~100M-param transformer with
the paper's P2P-DP update — personal models per agent, Laplace-perturbed
local gradients, ppermute gossip — for a few hundred steps.

    PYTHONPATH=src python examples/decentralized_lm.py                # ~25M, quick
    PYTHONPATH=src python examples/decentralized_lm.py --hundred-m    # ~100M params

On CPU the 100M variant takes a while; the default is sized to finish in a
few minutes while exercising exactly the same code path as the TPU run
(repro.launch.train). Personalization signal: each agent's token stream has
its own unigram distribution, so gossip + local steps must balance.
"""

import argparse
import sys

sys.path.insert(0, ".")
sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--eps", type=float, default=0.0, help="DP budget (0 = off)")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M params: 8 layers x d=768 x ff=3072, 32k vocab.
        argv = [
            "--arch", "llama3.2-1b", "--preset", "small", "--steps",
            str(args.steps or 200), "--batch", "2", "--seq", "129",
            "--mu", "0.5", "--alpha", "0.9", "--mesh", "1x1",
        ]
        import repro.configs.base as base
        # widen the 'small' preset to ~100M via explicit overrides
        orig = train_mod.build

        def build_100m(a):
            from repro.configs import get_reduced

            return get_reduced(
                "llama3.2-1b", num_layers=8, d_model=768, num_heads=12,
                num_kv_heads=4, d_ff=3072, vocab_size=32768, head_dim=64,
                dtype="float32",
            )

        train_mod.build = build_100m
    else:
        argv = [
            "--arch", "llama3.2-1b", "--preset", "small", "--steps",
            str(args.steps or 60), "--batch", "2", "--seq", "129",
            "--mu", "0.5", "--alpha", "0.9", "--mesh", "1x1",
        ]
    if args.eps > 0:
        argv += ["--eps", str(args.eps)]
    argv += ["--checkpoint-dir", "results/decentralized_lm_ckpt"]
    history = train_mod.main(argv)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'NO DESCENT'})")


if __name__ == "__main__":
    main()
