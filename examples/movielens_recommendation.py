"""Table-1 experiment: private P2P recommendation on the MovieLens-100K twin.

Each of 943 users keeps their ratings on-device; collaboration happens only
through DP-perturbed model broadcasts over a 10-NN similarity graph.

    PYTHONPATH=src python examples/movielens_recommendation.py [--full]
"""

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, ".")

from benchmarks import bench_movielens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="all 943 users")
    args = ap.parse_args()
    bench_movielens.run(fast=not args.full)


if __name__ == "__main__":
    main()
