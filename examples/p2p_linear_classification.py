"""Full Sec.-5.1 experiment: CD vs ADMM (Fig. 1) + privacy sweep (Fig. 2).

    PYTHONPATH=src python examples/p2p_linear_classification.py [--full]

Fast mode uses n=30 agents / p=20 dims; --full matches the paper (n=100,
p=100) and takes considerably longer on CPU.
"""

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, ".")

from benchmarks import bench_cd_vs_admm, bench_privacy_utility


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    print("=== Fig. 1: coordinate descent vs gossip ADMM ===")
    if args.full:
        bench_cd_vs_admm.run()
    else:
        bench_cd_vs_admm.run(n=30, p=20, T_cd=800, T_admm=80)

    print("\n=== Fig. 2-4: privacy/utility trade-off ===")
    bench_privacy_utility.run(fast=not args.full)


if __name__ == "__main__":
    main()
