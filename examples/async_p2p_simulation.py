"""Batched async simulation walkthrough: churn, delays, stragglers, DP.

Builds a 10,000-agent random geometric collaboration graph (CSR, no (n, n)
array anywhere), then drives the paper's algorithms through the
``repro.sim`` batched engine under increasingly hostile deployment
conditions:

1. non-private CD (Eq. 4) under ideal thinned-Poisson clocks;
2. the same under churn + per-edge message delays + stragglers;
3. DP-CD (Eq. 6) with per-agent uniform budget split and stopping.

Run:  PYTHONPATH=src python examples/async_p2p_simulation.py
"""

import numpy as np

from repro.core import AgentData, DPConfig, make_objective, random_geometric_graph
from repro.sim import (
    CDUpdate,
    ChurnConfig,
    DelayConfig,
    DPCDUpdate,
    EngineConfig,
    Scenario,
    StragglerConfig,
    make_engine,
)


def main():
    rng = np.random.default_rng(0)
    n, p, m = 10_000, 8, 64
    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    obj = make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")
    Theta0 = np.zeros((n, p))

    print(f"n={n} agents, avg degree ~{np.diff(graph.indptr).mean():.1f}")

    # 1. Ideal conditions: pure thinned Poisson clocks. One EngineConfig
    # carries the shared knobs; scenario variants are replace() overlays.
    cfg = EngineConfig(slot_wakes=512.0, seed=1)
    eng = make_engine(CDUpdate(obj), cfg)
    res = eng.run(Theta0, slots=60, record_every=20)
    print("\n[ideal]      Q:", " -> ".join(f"{q:.1f}" for q in res.objective))
    print(f"             {res.wakes_applied} wakes over {res.slots} super-ticks")

    # 2. Deployment conditions: 1%/slot churn, 1-slot edge delays, 10% stragglers.
    scenario = Scenario(
        churn=ChurnConfig(leave_prob=0.01, rejoin_prob=0.2),
        delay=DelayConfig(max_delay=2, edge_delays=1),
        straggler=StragglerConfig(drop_prob=0.1),
    )
    eng = make_engine(CDUpdate(obj), cfg, scenario=scenario)
    res = eng.run(Theta0, slots=60, record_every=20)
    print("\n[hostile]    Q:", " -> ".join(f"{q:.1f}" for q in res.objective))
    print(
        f"             {res.wakes_applied} wakes applied, "
        f"{int((~res.active).sum())} agents currently departed"
    )

    # 3. Differential privacy: each agent plans 4 wake-ups from an overall
    # (eps=1, delta=e^-5) budget, then freezes once it is spent. The
    # quadratic loss needs a gradient clip (Supp. D.2) for finite
    # sensitivity; noise scales as 2 * clip / (eps_step * m_i).
    clipped = make_objective(
        graph, data, "quadratic", mu=0.5, mix_mode="sparse", clip=0.5
    )
    upd = DPCDUpdate.plan(clipped, DPConfig(eps_bar=1.0), planned_Ti=4)
    eng = make_engine(upd, cfg)
    res = eng.run(Theta0, slots=60, record_every=20)
    eps = upd.eps_spent(res.update_state)
    counts = np.asarray(res.update_state)
    print("\n[private]    Q:", " -> ".join(f"{q:.1f}" for q in res.objective))
    print(
        f"             eps spent: max {eps.max():.3f} <= 1.0, "
        f"{int((counts >= upd.planned_Ti).sum())}/{n} agents exhausted their budget"
    )


if __name__ == "__main__":
    main()
