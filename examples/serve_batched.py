"""Serve personalized predictions while the swarm trains.

A 2,000-agent swarm trains in a background thread and publishes a
version-tagged Theta snapshot every ``snapshot_every`` slots into a
:class:`repro.serve.ServeHandle`; the foreground keeps answering
batched ``predict(agent_ids, X)`` requests against whatever version is
newest — including one *cold* id that is not in the swarm at all, whose
row is synthesized as the Eq. 16 neighbour average. At the end the
example pins the final snapshot and asserts the served rows are
bit-exact against the trainer's final Theta.

Run:  PYTHONPATH=src python examples/serve_batched.py
      PYTHONPATH=src python examples/serve_batched.py --smoke   # CI-sized
"""

import argparse
import threading
import time

import numpy as np

from repro.core import AgentData, make_objective, random_geometric_graph
from repro.serve import ServeHandle
from repro.sim import CDUpdate, EngineConfig, make_engine


def main(smoke: bool = False):
    rng = np.random.default_rng(0)
    n, p, m = (500, 4, 4) if smoke else (2_000, 8, 6)
    slots, snapshot_every = (6, 2) if smoke else (16, 4)

    graph = random_geometric_graph(n, rng, avg_degree=12.0)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    data = AgentData(X=X, y=np.einsum("nmp,np->nm", X, targets),
                     mask=np.ones((n, m)))
    obj = make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")
    engine = make_engine(CDUpdate(obj),
                         EngineConfig(slot_wakes=n / 10.0, seed=1))
    handle = ServeHandle.for_engine(engine)

    done = threading.Event()
    box = {}

    def _train():
        try:
            box["result"] = engine.run(np.zeros((n, p)), slots,
                                       snapshot_every=snapshot_every,
                                       serve=handle)
        finally:
            done.set()

    trainer = threading.Thread(target=_train)
    trainer.start()
    while not done.is_set():
        try:
            handle.version  # the run publishes version 0 as it starts
            break
        except RuntimeError:
            time.sleep(0.005)

    batch = 64
    ids = rng.integers(0, n, size=batch)
    Xq = rng.normal(size=(batch, p))
    requests = 0
    while not done.is_set():
        handle.predict(ids, Xq)
        requests += 1
    trainer.join()
    result = box["result"]

    # Pin the final version: served rows are the trainer's rows, bit-exact.
    snap = handle.snapshot()
    assert snap.version == result.slots
    rows = handle.rows(ids, at=snap)
    assert np.array_equal(rows.values, result.Theta[ids].astype(np.float32))

    # Cold start: an id outside the swarm gets the Eq. 16 average of the
    # neighbours we attach it to — the row a real arrival would warm-start
    # from at admission.
    cold_id = n + 7
    nbrs = (0, 1, 2)
    cold = handle.predict([cold_id], Xq[:1], neighbors={cold_id: nbrs})
    want = result.Theta[list(nbrs)].mean(axis=0).astype(np.float32) @ Xq[
        0
    ].astype(np.float32)
    assert cold.cold[0] and np.allclose(cold.values[0], want, rtol=1e-5)

    c = handle.counters()
    print(f"trained {result.slots} slots; served {requests} mid-training "
          f"batches of {batch} (+1 cold start)")
    print(f"versions published: {c['serve_snapshots_published']}, "
          f"final served version: {snap.version}, "
          f"worst version lag: {c['serve_version_lag_max']} slots")
    print("served rows bit-exact vs final Theta: OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    main(**vars(ap.parse_args()))
