"""Batched serving example: prefill + decode with KV caches for any of the
10 assigned architectures (reduced sizes on CPU).

    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-1.2b
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_mod.main([
        "--arch", args.arch, "--preset", "tiny", "--batch", str(args.batch),
        "--prompt-len", "32", "--decode-tokens", "16",
    ])


if __name__ == "__main__":
    main()
