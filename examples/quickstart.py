"""Quickstart: personalized + private P2P learning in ~60 lines.

10 agents with related-but-distinct linear tasks collaborate over a
similarity graph; we compare purely-local models, the paper's non-private
coordinate descent (Eq. 4), and the differentially-private variant (Eq. 6).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import DPConfig, make_objective, run_private, run_scan, train_local_models
from repro.core.objective import LOGISTIC
from repro.data.synthetic import eval_accuracy, linear_classification_problem

# 1. A network of agents with heterogeneous local datasets (Sec. 5.1 setup).
prob = linear_classification_problem(n=10, p=20, m_low=15, m_high=80, seed=0)
print(f"{prob.graph.n} agents, {prob.graph.num_edges()} edges, "
      f"{int(prob.train.num_examples.sum())} total examples")

# 2. Purely local models (the perfectly-private baseline).
theta_loc = train_local_models(
    prob.train, LOGISTIC, 1.0 / np.maximum(prob.train.num_examples, 1.0)
)
print(f"purely local accuracy:      {eval_accuracy(theta_loc, prob.test).mean():.3f}")

# 3. The paper's objective (Eq. 2) and asynchronous block coordinate descent.
obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3, clip=1.0)
res = run_scan(obj, theta_loc, T=600, rng=np.random.default_rng(1))
print(f"collaborative CD accuracy:  {eval_accuracy(res.Theta, prob.test).mean():.3f} "
      f"(objective {res.objective[0]:.2f} -> {res.objective[-1]:.2f})")

# 4. The private variant: every broadcast is (eps, delta)-DP for the agent.
priv = run_private(
    obj, theta_loc, T=50, cfg=DPConfig(eps_bar=1.0), rng=np.random.default_rng(2)
)
print(f"private CD (eps=1) accuracy: {eval_accuracy(priv.Theta, prob.test).mean():.3f} "
      f"(max eps spent: {priv.eps_spent.max():.3f})")
