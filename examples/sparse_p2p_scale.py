"""Sparse P2P learning at a scale no dense graph survives.

5,000 agents on a random geometric collaboration graph (avg degree ~12)
run the paper's asynchronous coordinate descent (Eq. 4) through the CSR
sparse backend: O(nnz) graph storage, O(deg * p) per tick. The same
script at n=100,000 is `benchmarks/bench_sparse_scale.py`; a dense
(n, n) weight matrix at that size would need 80 GB.

    PYTHONPATH=src python examples/sparse_p2p_scale.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import make_objective, random_geometric_graph, run_scan, synchronous_round
from repro.core.objective import AgentData

n, p, m = 5_000, 16, 8
rng = np.random.default_rng(0)

# 1. Sparse collaboration graph — built without ever touching (n, n).
graph = random_geometric_graph(n, rng, avg_degree=12.0)
deg = np.diff(graph.indptr)
print(f"{graph.n} agents, {graph.num_edges()} edges, "
      f"avg degree {deg.mean():.1f}, CSR bytes ~{graph.indices.nbytes + graph.data.nbytes}")

# 2. Per-agent quadratic tasks whose targets vary smoothly in space, so
#    geometric neighbours really are task-related (the paper's premise).
targets = rng.normal(size=(n, p)) / np.sqrt(p)
X = rng.normal(size=(n, m, p)) / np.sqrt(p)
y = np.einsum("nmp,np->nm", X, targets)
data = AgentData(X=X, y=y, mask=np.ones((n, m)))

# 3. mix_mode="auto" picks the sparse path above the crossover
#    (REPRO_SPARSE_CROSSOVER, default 2048) — here n=5000 routes sparse.
obj = make_objective(graph, data, "quadratic", mu=0.5)
print(f"neighbour-sum path: {obj.mix.kind}")

# 4. A burst of faithful asynchronous ticks (Eq. 4, one agent per tick)...
res = run_scan(obj, np.zeros((n, p)), T=2_000, rng=rng,
               record_every=500, record_objective=False)

# 5. ...then synchronous rounds (the SPMD scale-layer schedule: one round
#    ~ n async ticks in expectation), all through the sparse segment-sum.
Theta = jnp.asarray(res.Theta)
for _ in range(20):
    Theta = synchronous_round(obj, Theta)

def mean_err(Th):
    return float(np.linalg.norm(np.asarray(Th) - targets, axis=1).mean())

print(f"mean distance to hidden targets: {mean_err(np.zeros((n, p))):.3f} "
      f"-> {mean_err(res.Theta):.3f} (2k async ticks) "
      f"-> {mean_err(Theta):.3f} (+20 sync rounds)")
