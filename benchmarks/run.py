"""Benchmark entry point — one bench per paper table/figure + scale/roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast mode (CI-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale
    PYTHONPATH=src python -m benchmarks.run --list     # show bench names
    PYTHONPATH=src python -m benchmarks.run --only obs # run one bench

Prints ``name,us_per_call,derived`` CSV lines per bench plus per-table
summaries. Every run (fast mode included) writes the machine-readable
``results/BENCH_summary.json`` mapping name -> {us_per_call, derived} so
the perf trajectory accumulates per PR; paper-scale results additionally
land in results/*.json and EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys
import time


def _merge_summary(path, rows):
    """Shared with the report CLI so the two summary writers cannot drift."""
    try:
        from repro.obs.report import merge_bench_summary
    except ImportError:
        sys.path.insert(
            0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
        )
        from repro.obs.report import merge_bench_summary
    merge_bench_summary(path, rows)


def _subprocess_bench(module: str, cli: list, row_prefix: str) -> list:
    """Run a bench module in a subprocess with 8 forced host devices.

    Multi-device benches need host-platform devices, which XLA only
    grants before its first initialization — too late for a process that
    already imported jax. The subprocess reports back via its CSV rows;
    every ``row_prefix*`` line it prints becomes a summary row here.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH", "")])
    )
    proc = subprocess.run(
        [sys.executable, "-m", module, *cli], env=env, capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{proc.stderr[-3000:]}")
    rows = []
    for line in proc.stdout.splitlines():
        if not line.startswith(row_prefix) or line.count(",") < 2:
            continue
        name, val, note = line.split(",", 2)
        rows.append((name, float(val), note))
    if not rows:
        raise RuntimeError(
            f"{module} printed no {row_prefix}* rows; stdout was:\n"
            f"{proc.stdout[-2000:]}"
        )
    return rows


def _bench_fig1(full, rows, record):
    from benchmarks import bench_cd_vs_admm

    t0 = time.time()
    kw = {} if full else dict(n=30, p=20, T_cd=800, T_admm=80)
    r = bench_cd_vs_admm.run(out="results/fig1_cd_vs_admm.json", **kw)
    record("fig1_cd_vs_admm", t0,
           f"cd_beats_admm_per_message={r['cd_beats_admm_per_message']}")


def _bench_fig2(full, rows, record):
    from benchmarks import bench_privacy_utility

    t0 = time.time()
    r = bench_privacy_utility.run(out="results/fig2_privacy_utility.json",
                                  fast=not full)
    acc = r["fig2c"][-1]
    record("fig2_privacy_utility", t0,
           f"acc_local={acc['acc_local']:.3f},acc_nonpriv={acc['acc_nonprivate']:.3f}")


def _bench_table1(full, rows, record):
    from benchmarks import bench_movielens

    t0 = time.time()
    r = bench_movielens.run(out="results/table1_movielens_fastmode.json",
                            fast=not full)
    record("table1_movielens", t0,
           f"rmse_local={r['rmse_local']:.3f},rmse_cd={r['rmse_cd']:.3f}")


def _bench_ablations(full, rows, record):
    from benchmarks import bench_ablations

    t0 = time.time()
    r = bench_ablations.run(out="results/ablations.json", fast=not full)
    record("ablations", t0,
           f"personalized={r['personalization']['acc_personalized']:.3f},"
           f"global={r['personalization']['acc_global']:.3f}")


def _bench_kernels(full, rows, record):
    from benchmarks import bench_kernels

    t0 = time.time()
    ks = bench_kernels.run()
    # Per-kernel rows (fused_row_update etc.) join the summary alongside
    # the aggregate, so kernel-level perf has its own trajectory.
    rows.extend(ks)
    record("kernels", t0, f"{len(ks)} kernels timed")


def _bench_sparse_scale(full, rows, record):
    from benchmarks import bench_sparse_scale

    t0 = time.time()
    kw = dict(n=100_000, ticks=2_000) if full else dict(n=5_000, ticks=200)
    ss = bench_sparse_scale.run(verbose=False, **kw)
    tick_us = next(v for name, v, _ in ss if name == "sparse_cd_tick")
    record("sparse_scale", t0, f"n={kw['n']},us_per_seq_tick={tick_us:.3g}")


def _bench_async_engine(full, rows, record):
    from benchmarks import bench_async_engine

    t0 = time.time()
    kw = (
        dict(n=500_000, slots=12, slot_wakes=4096.0)
        if full
        else dict(n=20_000, slots=4, slot_wakes=512.0)
    )
    ae = bench_async_engine.run(churn=True, verbose=False, **kw)
    rate = next(v for name, v, _ in ae if name == "async_equiv_ticks_per_s")
    record("async_engine", t0, f"n={kw['n']},churn=1,equiv_ticks_per_s={rate:.4g}")


def _bench_sharded_engine(full, rows, record):
    t0 = time.time()
    kw = (
        dict(n=1_000_000, slots=8, slot_wakes=8192.0)
        if full
        else dict(n=100_000, slots=4, slot_wakes=2048.0)
    )
    # Tick rates, partition stats, the halo-fraction / exchanged-bytes
    # sweep over {no relabel, RCM} x {all_gather, p2p} — every sharded_*
    # row the subprocess prints joins the summary under its own name.
    sub = _subprocess_bench(
        "benchmarks.bench_sharded_engine",
        ["--n", str(kw["n"]), "--shards", "8",
         "--slots", str(kw["slots"]), "--slot-wakes", str(kw["slot_wakes"])],
        "sharded_",
    )
    rows.extend(sub)
    rate = next(
        (v for name, v, _ in sub if name == "sharded_equiv_ticks_per_s"), None
    )
    if rate is None:
        raise RuntimeError("sharded_engine printed no sharded_equiv_ticks_per_s row")
    record("sharded_engine", t0,
           f"n={kw['n']},shards=8,equiv_ticks_per_s={rate:.4g}")


def _bench_obs(full, rows, record):
    t0 = time.time()
    # Keep the slot loaded (>=2048 wakes) even in fast mode: the overhead
    # comparison divides a ~100us-scale metrics delta by the slot time, so
    # an under-loaded slot reads as inflated percentage (pure noise).
    kw = (
        dict(n=200_000, slots=8, slot_wakes=4096.0)
        if full
        else dict(n=50_000, slots=6, slot_wakes=2048.0)
    )
    # Telemetry overhead (metrics-on vs off, target <=5%) and the
    # obs_phase_* decomposition of the super-tick behind the
    # sharded_roofline_supertick_gap row; also writes the trace.json and
    # RunReport JSONL artifacts under results/.
    sub = _subprocess_bench(
        "benchmarks.bench_obs",
        ["--n", str(kw["n"]), "--shards", "8",
         "--slots", str(kw["slots"]), "--slot-wakes", str(kw["slot_wakes"])],
        "obs_",
    )
    rows.extend(sub)
    over = next((v for name, v, _ in sub if name == "obs_overhead"), None)
    if over is None:
        raise RuntimeError("obs bench printed no obs_overhead row")
    record("obs", t0, f"n={kw['n']},shards=8,overhead_pct={over:.3g}")


def _bench_dynamic_topology(full, rows, record):
    from benchmarks import bench_dynamic_topology

    t0 = time.time()
    kw = dict(n=200_000, shards=8) if full else dict(n=20_000, shards=8)
    dt = bench_dynamic_topology.run(verbose=False, **kw)
    # Host-side partition machinery: patch-vs-rebuild timings, the drift
    # gauge, and the (asserted) halo parity row all join the summary.
    rows.extend(dt)
    speedup = next(v for name, v, _ in dt if name == "dyntopo_patch_speedup")
    record("dynamic_topology", t0, f"n={kw['n']},patch_speedup={speedup:.3g}")


def _bench_checkpoint(full, rows, record):
    t0 = time.time()
    kw = dict(n=200_000, shards=8) if full else dict(n=20_000, shards=8)
    # Engine save/restore round trip at scale: wall seconds each way plus
    # entry bytes, all per-shard with no (n, p) host materialization.
    sub = _subprocess_bench(
        "benchmarks.bench_checkpoint",
        ["--n", str(kw["n"]), "--shards", str(kw["shards"])],
        "ckpt_",
    )
    rows.extend(sub)
    save_s = next((v for name, v, _ in sub if name == "ckpt_save_s"), None)
    nbytes = next((v for name, v, _ in sub if name == "ckpt_bytes"), None)
    if save_s is None or nbytes is None:
        raise RuntimeError("checkpoint bench printed no ckpt_save_s/ckpt_bytes rows")
    record("checkpoint", t0,
           f"n={kw['n']},shards=8,save_s={save_s:.3g},bytes={int(nbytes)}")


def _bench_serving(full, rows, record):
    t0 = time.time()
    kw = (
        dict(n=1_000_000, slots=6, slot_wakes=8192.0, batch=1024)
        if full
        else dict(n=100_000, slots=4, slot_wakes=2048.0, batch=512)
    )
    # Live read path: batched predict() against the newest published
    # snapshot while the sharded engine trains — predictions/s, p50/p99
    # batch latency, and the per-super-tick publication cost all join
    # the summary (served rows are asserted bit-exact in-bench).
    sub = _subprocess_bench(
        "benchmarks.bench_serving",
        ["--n", str(kw["n"]), "--shards", "8",
         "--slots", str(kw["slots"]), "--slot-wakes", str(kw["slot_wakes"]),
         "--batch", str(kw["batch"])],
        "serving_",
    )
    rows.extend(sub)
    rate = next(
        (v for name, v, _ in sub if name == "serving_predictions_per_s"), None
    )
    if rate is None:
        raise RuntimeError("serving bench printed no serving_predictions_per_s row")
    record("serving", t0,
           f"n={kw['n']},shards=8,batch={kw['batch']},predictions_per_s={rate:.4g}")


def _bench_roofline(full, rows, record):
    from benchmarks import bench_roofline

    t0 = time.time()
    rs = bench_roofline.run()
    if not rs:
        # No dry-run output on this backend/config: say so and record
        # nothing, instead of emitting an empty "0 dry-run rows" row
        # into BENCH_summary.json that reads like a measurement.
        print("roofline: skipped (no dry-run rows on this backend)")
        return
    record("roofline", t0, f"{len(rs)} dry-run rows")


# Registration order is execution order; roofline stays last so its
# dry-run rows print after the measured ones they contextualize.
BENCHES = {
    "fig1": _bench_fig1,
    "fig2": _bench_fig2,
    "table1": _bench_table1,
    "ablations": _bench_ablations,
    "kernels": _bench_kernels,
    "sparse_scale": _bench_sparse_scale,
    "async_engine": _bench_async_engine,
    "sharded_engine": _bench_sharded_engine,
    "obs": _bench_obs,
    "dynamic_topology": _bench_dynamic_topology,
    "checkpoint": _bench_checkpoint,
    "serving": _bench_serving,
    "roofline": _bench_roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single bench (see --list)")
    ap.add_argument("--list", action="store_true", help="list bench names and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in BENCHES:
            print(name)
        return 0
    if args.only is not None and args.only not in BENCHES:
        print(
            f"unknown bench {args.only!r}; valid names: {', '.join(BENCHES)}",
            file=sys.stderr,
        )
        return 2

    import jax

    jax.config.update("jax_enable_x64", True)  # paper-core benches need f64

    os.makedirs("results", exist_ok=True)
    rows = []

    def record(name, t0, derived):
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derived))
        print(f"{name},{us:.0f},{derived}")

    for name, bench in BENCHES.items():
        if args.only in (None, name):
            bench(args.full, rows, record)

    # Machine-readable per-PR perf trajectory (fast mode and --only runs
    # included): the stable contract is name -> {us_per_call, derived},
    # merged into the existing map so a partial --only run updates its own
    # entries without clobbering the accumulated trajectory. Written once
    # under results/ and copied byte-identical to the repo root, where the
    # perf-history tooling looks (tools/check_bench_sync.py asserts the
    # two stay in sync).
    _merge_summary("results/BENCH_summary.json", rows)
    shutil.copyfile("results/BENCH_summary.json", "BENCH_summary.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
