"""Benchmark entry point — one bench per paper table/figure + scale/roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast mode (CI-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale

Prints ``name,us_per_call,derived`` CSV lines per bench plus per-table
summaries. Every run (fast mode included) writes the machine-readable
``results/BENCH_summary.json`` mapping name -> {us_per_call, derived} so
the perf trajectory accumulates per PR; paper-scale results additionally
land in results/*.json and EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig1", "fig2", "table1", "kernels", "roofline",
                             "ablations", "sparse_scale", "async_engine"])
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)  # paper-core benches need f64

    from benchmarks import (
        bench_ablations,
        bench_async_engine,
        bench_cd_vs_admm,
        bench_kernels,
        bench_movielens,
        bench_privacy_utility,
        bench_roofline,
        bench_sparse_scale,
    )

    os.makedirs("results", exist_ok=True)
    rows = []

    def record(name, t0, derived):
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derived))
        print(f"{name},{us:.0f},{derived}")

    if args.only in (None, "fig1"):
        t0 = time.time()
        kw = {} if args.full else dict(n=30, p=20, T_cd=800, T_admm=80)
        r = bench_cd_vs_admm.run(out="results/fig1_cd_vs_admm.json", **kw)
        record("fig1_cd_vs_admm", t0,
               f"cd_beats_admm_per_message={r['cd_beats_admm_per_message']}")

    if args.only in (None, "fig2"):
        t0 = time.time()
        r = bench_privacy_utility.run(out="results/fig2_privacy_utility.json",
                                      fast=not args.full)
        acc = r["fig2c"][-1]
        record("fig2_privacy_utility", t0,
               f"acc_local={acc['acc_local']:.3f},acc_nonpriv={acc['acc_nonprivate']:.3f}")

    if args.only in (None, "table1"):
        t0 = time.time()
        r = bench_movielens.run(out="results/table1_movielens_fastmode.json",
                                fast=not args.full)
        record("table1_movielens", t0,
               f"rmse_local={r['rmse_local']:.3f},rmse_cd={r['rmse_cd']:.3f}")

    if args.only in (None, "ablations"):
        t0 = time.time()
        r = bench_ablations.run(out="results/ablations.json", fast=not args.full)
        record("ablations", t0,
               f"personalized={r['personalization']['acc_personalized']:.3f},"
               f"global={r['personalization']['acc_global']:.3f}")

    if args.only in (None, "kernels"):
        t0 = time.time()
        ks = bench_kernels.run()
        record("kernels", t0, f"{len(ks)} kernels timed")

    if args.only in (None, "sparse_scale"):
        t0 = time.time()
        kw = dict(n=100_000, ticks=2_000) if args.full else dict(n=5_000, ticks=200)
        ss = bench_sparse_scale.run(verbose=False, **kw)
        tick_us = next(v for name, v, _ in ss if name == "sparse_cd_tick")
        record("sparse_scale", t0, f"n={kw['n']},us_per_seq_tick={tick_us:.3g}")

    if args.only in (None, "async_engine"):
        t0 = time.time()
        kw = (
            dict(n=500_000, slots=12, slot_wakes=4096.0)
            if args.full
            else dict(n=20_000, slots=4, slot_wakes=512.0)
        )
        ae = bench_async_engine.run(churn=True, verbose=False, **kw)
        rate = next(v for name, v, _ in ae if name == "async_equiv_ticks_per_s")
        record("async_engine", t0, f"n={kw['n']},churn=1,equiv_ticks_per_s={rate:.4g}")

    if args.only in (None, "roofline"):
        t0 = time.time()
        rs = bench_roofline.run()
        record("roofline", t0, f"{len(rs)} dry-run rows")

    # Machine-readable per-PR perf trajectory (fast mode included): the
    # stable contract is name -> {us_per_call, derived}. Git-tracked, and
    # only written by complete sweeps — a partial --only debug run must
    # not clobber the accumulated trajectory. (This replaces the old
    # list-format bench_summary.json, whose name differed only by case.)
    if args.only is None:
        with open("results/BENCH_summary.json", "w") as f:
            json.dump(
                {n: {"us_per_call": u, "derived": d} for n, u, d in rows},
                f,
                indent=2,
                sort_keys=True,
            )


if __name__ == "__main__":
    main()
