"""Benchmark entry point — one bench per paper table/figure + scale/roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast mode (CI-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale

Prints ``name,us_per_call,derived`` CSV lines per bench plus per-table
summaries. Every run (fast mode included) writes the machine-readable
``results/BENCH_summary.json`` mapping name -> {us_per_call, derived} so
the perf trajectory accumulates per PR; paper-scale results additionally
land in results/*.json and EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _merge_summary(path: str, rows) -> None:
    """Merge this run's rows into the name -> {us_per_call, derived} map.

    Merging (not clobbering) lets ``--only`` debug runs and the
    subprocess-launched benches update their own entries without erasing
    the accumulated trajectory of everything else.
    """
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    if not isinstance(data, dict):
        data = {}
    data.update({n: {"us_per_call": u, "derived": d} for n, u, d in rows})
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig1", "fig2", "table1", "kernels", "roofline",
                             "ablations", "sparse_scale", "async_engine",
                             "sharded_engine"])
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)  # paper-core benches need f64

    from benchmarks import (
        bench_ablations,
        bench_async_engine,
        bench_cd_vs_admm,
        bench_kernels,
        bench_movielens,
        bench_privacy_utility,
        bench_roofline,
        bench_sparse_scale,
    )

    os.makedirs("results", exist_ok=True)
    rows = []

    def record(name, t0, derived):
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derived))
        print(f"{name},{us:.0f},{derived}")

    if args.only in (None, "fig1"):
        t0 = time.time()
        kw = {} if args.full else dict(n=30, p=20, T_cd=800, T_admm=80)
        r = bench_cd_vs_admm.run(out="results/fig1_cd_vs_admm.json", **kw)
        record("fig1_cd_vs_admm", t0,
               f"cd_beats_admm_per_message={r['cd_beats_admm_per_message']}")

    if args.only in (None, "fig2"):
        t0 = time.time()
        r = bench_privacy_utility.run(out="results/fig2_privacy_utility.json",
                                      fast=not args.full)
        acc = r["fig2c"][-1]
        record("fig2_privacy_utility", t0,
               f"acc_local={acc['acc_local']:.3f},acc_nonpriv={acc['acc_nonprivate']:.3f}")

    if args.only in (None, "table1"):
        t0 = time.time()
        r = bench_movielens.run(out="results/table1_movielens_fastmode.json",
                                fast=not args.full)
        record("table1_movielens", t0,
               f"rmse_local={r['rmse_local']:.3f},rmse_cd={r['rmse_cd']:.3f}")

    if args.only in (None, "ablations"):
        t0 = time.time()
        r = bench_ablations.run(out="results/ablations.json", fast=not args.full)
        record("ablations", t0,
               f"personalized={r['personalization']['acc_personalized']:.3f},"
               f"global={r['personalization']['acc_global']:.3f}")

    if args.only in (None, "kernels"):
        t0 = time.time()
        ks = bench_kernels.run()
        # Per-kernel rows (fused_row_update etc.) join the summary alongside
        # the aggregate, so kernel-level perf has its own trajectory.
        rows.extend(ks)
        record("kernels", t0, f"{len(ks)} kernels timed")

    if args.only in (None, "sparse_scale"):
        t0 = time.time()
        kw = dict(n=100_000, ticks=2_000) if args.full else dict(n=5_000, ticks=200)
        ss = bench_sparse_scale.run(verbose=False, **kw)
        tick_us = next(v for name, v, _ in ss if name == "sparse_cd_tick")
        record("sparse_scale", t0, f"n={kw['n']},us_per_seq_tick={tick_us:.3g}")

    if args.only in (None, "async_engine"):
        t0 = time.time()
        kw = (
            dict(n=500_000, slots=12, slot_wakes=4096.0)
            if args.full
            else dict(n=20_000, slots=4, slot_wakes=512.0)
        )
        ae = bench_async_engine.run(churn=True, verbose=False, **kw)
        rate = next(v for name, v, _ in ae if name == "async_equiv_ticks_per_s")
        record("async_engine", t0, f"n={kw['n']},churn=1,equiv_ticks_per_s={rate:.4g}")

    if args.only in (None, "sharded_engine"):
        # Multi-device engine: needs 8 host-platform devices, which XLA only
        # grants before its first initialization — so this bench runs in a
        # subprocess with the flag forced and reports back via its CSV rows.
        t0 = time.time()
        kw = (
            dict(n=1_000_000, slots=8, slot_wakes=8192.0)
            if args.full
            else dict(n=100_000, slots=4, slot_wakes=2048.0)
        )
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
        ).strip()
        env["PYTHONPATH"] = os.pathsep.join(
            filter(None, ["src", env.get("PYTHONPATH", "")])
        )
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_sharded_engine",
             "--n", str(kw["n"]), "--shards", "8",
             "--slots", str(kw["slots"]), "--slot-wakes", str(kw["slot_wakes"])],
            env=env, capture_output=True, text=True,
        )
        if proc.returncode != 0:
            raise RuntimeError(f"sharded_engine bench failed:\n{proc.stderr[-3000:]}")
        # Merge every sharded_* CSV row the subprocess printed (tick
        # rates, partition stats, the halo-fraction / exchanged-bytes
        # sweep over {no relabel, RCM} x {all_gather, p2p}) into the
        # summary under its own name.
        rate = None
        for line in proc.stdout.splitlines():
            if not line.startswith("sharded_") or line.count(",") < 2:
                continue
            name, val, note = line.split(",", 2)
            rows.append((name, float(val), note))
            if name == "sharded_equiv_ticks_per_s":
                rate = float(val)
        if rate is None:
            raise RuntimeError(
                "sharded_engine bench printed no sharded_equiv_ticks_per_s "
                f"row; stdout was:\n{proc.stdout[-2000:]}"
            )
        record("sharded_engine", t0,
               f"n={kw['n']},shards=8,equiv_ticks_per_s={rate:.4g}")

    if args.only in (None, "roofline"):
        t0 = time.time()
        rs = bench_roofline.run()
        record("roofline", t0, f"{len(rs)} dry-run rows")

    # Machine-readable per-PR perf trajectory (fast mode and --only runs
    # included): the stable contract is name -> {us_per_call, derived},
    # merged into the existing map so a partial --only run updates its own
    # entries without clobbering the accumulated trajectory. Written both
    # under results/ and at the repo root, where the perf-history tooling
    # looks. (This replaces the old list-format bench_summary.json, whose
    # name differed only by case.)
    _merge_summary("results/BENCH_summary.json", rows)
    _merge_summary("BENCH_summary.json", rows)


if __name__ == "__main__":
    main()
