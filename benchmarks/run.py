"""Benchmark entry point — one bench per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast mode (CI-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale

Prints ``name,us_per_call,derived`` CSV lines per bench plus per-table
summaries; paper-scale results land in results/*.json and EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig1", "fig2", "table1", "kernels", "roofline",
                             "ablations"])
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)  # paper-core benches need f64

    from benchmarks import (
        bench_ablations,
        bench_cd_vs_admm,
        bench_kernels,
        bench_movielens,
        bench_privacy_utility,
        bench_roofline,
    )

    os.makedirs("results", exist_ok=True)
    rows = []

    def record(name, t0, derived):
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derived))
        print(f"{name},{us:.0f},{derived}")

    if args.only in (None, "fig1"):
        t0 = time.time()
        kw = {} if args.full else dict(n=30, p=20, T_cd=800, T_admm=80)
        r = bench_cd_vs_admm.run(out="results/fig1_cd_vs_admm.json", **kw)
        record("fig1_cd_vs_admm", t0,
               f"cd_beats_admm_per_message={r['cd_beats_admm_per_message']}")

    if args.only in (None, "fig2"):
        t0 = time.time()
        r = bench_privacy_utility.run(out="results/fig2_privacy_utility.json",
                                      fast=not args.full)
        acc = r["fig2c"][-1]
        record("fig2_privacy_utility", t0,
               f"acc_local={acc['acc_local']:.3f},acc_nonpriv={acc['acc_nonprivate']:.3f}")

    if args.only in (None, "table1"):
        t0 = time.time()
        r = bench_movielens.run(out="results/table1_movielens_fastmode.json",
                                fast=not args.full)
        record("table1_movielens", t0,
               f"rmse_local={r['rmse_local']:.3f},rmse_cd={r['rmse_cd']:.3f}")

    if args.only in (None, "ablations"):
        t0 = time.time()
        r = bench_ablations.run(out="results/ablations.json", fast=not args.full)
        record("ablations", t0,
               f"personalized={r['personalization']['acc_personalized']:.3f},"
               f"global={r['personalization']['acc_global']:.3f}")

    if args.only in (None, "kernels"):
        t0 = time.time()
        ks = bench_kernels.run()
        record("kernels", t0, f"{len(ks)} kernels timed")

    if args.only in (None, "roofline"):
        t0 = time.time()
        rs = bench_roofline.run()
        record("roofline", t0, f"{len(rs)} dry-run rows")

    with open("results/bench_summary.json", "w") as f:
        json.dump([{"name": n, "us": u, "derived": d} for n, u, d in rows], f)


if __name__ == "__main__":
    main()
