"""Table 1 reproduction: per-user test RMSE on the MovieLens-100K twin.

Columns: purely local models | non-private CD | private CD for
eps in {1, 0.5, 0.1} — all with quadratic loss, gradient clipping C = 10,
lambda_i = 1/m_i, mu = 0.04, 10-NN cosine graph (Sec. 5.2 protocol).

MovieLens-100K itself is offline-unavailable; the twin matches its
published statistics (943 users, 1682 items, ~100k ratings, same count
distribution) — see repro/data/movielens.py and DESIGN.md §2.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DPConfig, make_objective, run_private, run_scan
from repro.data.movielens import movielens_twin, rmse


def _local_ridge(train, lambdas):
    n, _, p = train.X.shape
    theta = np.zeros((n, p))
    for u in range(n):
        sel = train.mask[u] > 0
        Xu, yu = train.X[u][sel], train.y[u][sel]
        m = max(len(yu), 1)
        theta[u] = np.linalg.solve(
            Xu.T @ Xu / m + lambdas[u] * np.eye(p), Xu.T @ yu / m
        )
    return theta


def _val_split(tw, seed):
    from repro.core.objective import AgentData

    rng = np.random.default_rng(seed)
    mask = tw.train.mask.copy()
    val_mask = np.zeros_like(mask)
    for u in range(mask.shape[0]):
        idx = np.nonzero(mask[u] > 0)[0]
        k = max(len(idx) // 5, 1)
        val = rng.choice(idx, size=k, replace=False)
        val_mask[u, val] = 1.0
    tr = AgentData(X=tw.train.X, y=tw.train.y, mask=mask - val_mask)
    va = AgentData(X=tw.train.X, y=tw.train.y, mask=val_mask)
    return tr, va


def tune_mu(tw, lambdas, theta_loc, ticks_per_user, mu_grid=(0.5, 1.0, 2.0, 4.0, 8.0), seed=0):
    """Tune mu on a held-out 20% of each user's training ratings, exactly the
    paper's 'tuned to maximize accuracy ... on a validation set' protocol."""
    tr, va = _val_split(tw, seed)
    best = (mu_grid[0], np.inf)
    n = tw.train.n
    for mu in mu_grid:
        obj = make_objective(tw.graph, tr, "quadratic", mu=mu, lambdas=lambdas, clip=10.0)
        res = run_scan(obj, theta_loc, T=ticks_per_user * n,
                       rng=np.random.default_rng(seed), record_objective=False)
        r = rmse(res.Theta, va)
        if r < best[1]:
            best = (mu, r)
    return best[0]


def tune_private_ticks(tw, lambdas, theta_loc, mu, eps, tick_grid=(3, 8, 20), seed=0):
    """Paper Sec. 5.2: 'the number of iterations per node is tuned for each
    value of eps on a validation set'."""
    tr, va = _val_split(tw, seed)
    obj = make_objective(tw.graph, tr, "quadratic", mu=mu, lambdas=lambdas, clip=10.0)
    n = tw.train.n
    best = (tick_grid[0], np.inf)
    for ticks in tick_grid:
        r = run_private(obj, theta_loc, T=ticks * n, cfg=DPConfig(eps_bar=eps),
                        rng=np.random.default_rng(seed + ticks), record_objective=False)
        v = rmse(r.Theta, va)
        if v < best[1]:
            best = (ticks, v)
    return best[0]


def run(n_users=943, n_items=1682, p=20, mu=None, ticks_per_user=40,
        eps_list=(1.0, 0.5, 0.1), seed=0, out=None, verbose=True, fast=False):
    if fast:
        n_users, n_items, ticks_per_user = 150, 400, 40
    t0 = time.time()
    tw = movielens_twin(n_users=n_users, n_items=n_items, p=p, rank=p, seed=seed)
    lambdas = 1.0 / np.maximum(tw.train.num_examples, 1.0)

    theta_loc = _local_ridge(tw.train, lambdas)
    rmse_loc = rmse(theta_loc, tw.test)

    if mu is None:
        mu = tune_mu(tw, lambdas, theta_loc, ticks_per_user, seed=seed)
        if verbose:
            print(f"[table1] tuned mu = {mu}")
    obj = make_objective(tw.graph, tw.train, "quadratic", mu=mu, lambdas=lambdas, clip=10.0)

    T = ticks_per_user * n_users
    nonpriv = run_scan(obj, theta_loc, T=T, rng=np.random.default_rng(seed),
                       record_objective=False)
    rmse_cd = rmse(nonpriv.Theta, tw.test)

    rows = {"rmse_local": float(rmse_loc), "rmse_cd": float(rmse_cd)}
    for eps in eps_list:
        ticks = tune_private_ticks(tw, lambdas, theta_loc, mu, eps, seed=seed)
        priv = run_private(obj, theta_loc, T=ticks * n_users, cfg=DPConfig(eps_bar=eps),
                           rng=np.random.default_rng(seed + 1), record_objective=False)
        rows[f"rmse_eps_{eps}"] = float(rmse(priv.Theta, tw.test))
        rows[f"ticks_eps_{eps}"] = ticks
    result = {"name": "table1_movielens", "n_users": n_users, "mu": mu,
              "ticks_per_user": ticks_per_user, **rows,
              "elapsed_s": round(time.time() - t0, 1)}
    if verbose:
        print(f"[table1] local {rmse_loc:.4f} | CD {rmse_cd:.4f} | " +
              " | ".join(f"eps={e}: {rows[f'rmse_eps_{e}']:.4f}" for e in eps_list))
    if out:
        with open(out, "w") as f:
            json.dump(result, f)
    return result


if __name__ == "__main__":
    run()
