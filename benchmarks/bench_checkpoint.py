"""Checkpoint bench: save/restore wall time and on-disk bytes, per-shard.

Times one :func:`repro.checkpoint.save_engine_checkpoint` +
:func:`repro.checkpoint.restore` round trip of the sharded engine's full
resume closure (Theta tiles, churn mask, update state, counters,
metrics) at benchmark scale, without ever materializing the (n, p) model
matrix on the host — the per-shard layout is exactly what makes the cost
O(n/S) resident memory per shard file. Rows:

* ``ckpt_save_s`` — state_dict + staged fsync'd write + atomic rename;
* ``ckpt_restore_s`` — verify hashes, re-tile shard files, rebuild state;
* ``ckpt_bytes`` — total entry size on disk;
* ``ckpt_mb_per_s`` — save throughput (bytes / save seconds).

Run standalone (8 forced host devices happen in run.py's subprocess):

    PYTHONPATH=src python -m benchmarks.bench_checkpoint --n 200000 --shards 8

``benchmarks/run.py --only checkpoint`` merges every ``ckpt_*`` row into
BENCH_summary.json.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np


def run(n=200_000, shards=8, slots=2, slot_wakes=2048.0, seed=0, verbose=True):
    import jax.numpy as jnp

    from repro.checkpoint import restore, save_engine_checkpoint
    from repro.core import AgentData, make_objective, random_geometric_graph
    from repro.sim import CDUpdate, ShardedAsyncEngine

    rng = np.random.default_rng(seed)
    p, m = 8, 4
    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    obj = make_objective(
        graph, AgentData(X=X, y=y, mask=np.ones((n, m))), "quadratic",
        mu=0.5, mix_mode="sparse",
    )
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=shards, slot_wakes=slot_wakes, seed=seed,
        relabel="rcm", metrics=True, dtype=jnp.float32,
    )
    res = eng.run(np.zeros((n, p)), slots=slots)
    state = res.state

    rows = []
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        t0 = time.time()
        entry = save_engine_checkpoint(eng, state, ck)
        save_s = time.time() - t0
        nbytes = sum(
            os.path.getsize(os.path.join(entry, f)) for f in os.listdir(entry)
        )
        fresh = ShardedAsyncEngine(
            CDUpdate(obj), num_shards=shards, slot_wakes=slot_wakes, seed=seed,
            relabel="rcm", metrics=True, dtype=jnp.float32,
        )
        t0 = time.time()
        restored, step = restore(fresh, ck)
        restore_s = time.time() - t0
        assert step == slots
        np.testing.assert_array_equal(
            np.asarray(restored.Theta), np.asarray(state.Theta)
        )
    note = f"n={n},shards={shards}"
    rows.append(("ckpt_save_s", save_s, note))
    rows.append(("ckpt_restore_s", restore_s, note))
    rows.append(("ckpt_bytes", float(nbytes), note))
    rows.append(("ckpt_mb_per_s", nbytes / save_s / 1e6, f"{note},save throughput"))
    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.4g},{note}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--slot-wakes", type=float, default=2048.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
    run(n=args.n, shards=args.shards, slots=args.slots,
        slot_wakes=args.slot_wakes, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
