"""Async-engine scale bench: batched super-ticks at n agents, churn on.

Where ``bench_sparse_scale`` drives the *sequential* Eq. 4 scan (one agent
per tick), this bench drives the ``repro.sim`` batched engine: each
jit-compiled super-tick wakes ~``slot_wakes`` agents via Poisson thinning,
mixes only the woken rows through the CSR gather path, and scatter-applies
their updates — with device churn enabled (and optionally per-edge message
delays), because the engine's whole point is surviving deployment
conditions at scale. Reports super-ticks/sec and applied wakes/sec (the
"equivalent sequential ticks" rate comparable to ``sparse_cd_tick``), and
asserts nothing materializes an (n, n) array.

    PYTHONPATH=src python -m benchmarks.bench_async_engine              # n=500k
    PYTHONPATH=src python -m benchmarks.bench_async_engine --n 50000 --delay
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run(
    n: int = 500_000,
    p: int = 8,
    m: int = 4,
    slots: int = 12,
    slot_wakes: float = 4096.0,
    seed: int = 0,
    churn: bool = True,
    delay: bool = False,
    metrics: bool = False,
    verbose: bool = True,
):
    from benchmarks.bench_sparse_scale import _make_problem
    from repro.sim import (
        AsyncEngine,
        CDUpdate,
        ChurnConfig,
        DelayConfig,
        Scenario,
    )

    rng = np.random.default_rng(seed)
    t0 = time.time()
    graph, obj = _make_problem(n, p, m, rng)
    build_s = time.time() - t0

    scenario = Scenario(
        churn=ChurnConfig(leave_prob=0.01, rejoin_prob=0.2) if churn else None,
        delay=DelayConfig(max_delay=2, edge_delays=1) if delay else None,
    )
    engine = AsyncEngine(
        CDUpdate(obj), slot_wakes=slot_wakes, scenario=scenario, seed=seed,
        metrics=metrics,
    )

    # No (n, n) array anywhere on the engine path (same guard as the
    # sparse-scale bench: O(nnz)-with-slack floor, still meaningful at
    # tiny --n debug sizes).
    mix = obj.mix
    leak_floor = max(n * n // 100, 64 * n + 256)
    for arr in (mix.idx, mix.w, mix.rows, mix.cols, mix.vals, engine._idx, engine._w):
        assert arr is None or arr.size < leak_floor, "an O(n^2) array leaked in"

    state = engine.init_state(np.zeros((n, p)))
    t0 = time.time()
    state = engine.advance(state, slots)
    state.Theta.block_until_ready()
    compile_s = time.time() - t0
    warm_applied = int(state.applied)  # warm-up half: compile + churn burn-in

    t0 = time.time()
    state = engine.advance(state, slots)
    state.Theta.block_until_ready()
    steady_s = time.time() - t0

    assert np.isfinite(np.asarray(state.Theta)).all()
    applied = int(state.applied)
    steady_applied = applied - warm_applied  # only wakes from the timed half
    assert steady_applied > 0
    ticks_per_s = steady_applied / max(steady_s, 1e-9)
    deg = np.diff(graph.indptr)
    rows = [
        ("async_graph_build", build_s * 1e6 / max(n, 1),
         f"n={n} deg~{deg.mean():.1f} us/agent"),
        ("async_super_tick", steady_s * 1e6 / slots,
         f"n={n} B={engine.batch_size} churn={int(churn)} delay={int(delay)} us/slot"),
        ("async_equiv_ticks_per_s", ticks_per_s,
         f"{applied} wakes applied, {int(state.dropped)} dropped, compile {compile_s:.1f}s"),
    ]
    if metrics:
        # In-jit telemetry totals (the timed halves ran with counters on,
        # so the super-tick row above already includes their cost).
        from repro.obs import summarize_counters

        counters, _derived = engine.metrics_snapshot(state)
        totals = summarize_counters(counters)
        for key in ("wakes_realized", "wakes_thinned", "churn_departures"):
            if key in totals:
                rows.append(
                    (f"async_metrics_{key}", float(totals[key]),
                     f"telemetry total over {2 * slots} slots")
                )
    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.4g},{note}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500_000)
    ap.add_argument("--slots", type=int, default=12)
    ap.add_argument("--slot-wakes", type=float, default=4096.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-churn", action="store_true")
    ap.add_argument("--delay", action="store_true", help="enable per-edge delays")
    ap.add_argument("--metrics", action="store_true",
                    help="run with in-jit telemetry on and report its totals")
    args = ap.parse_args(argv)
    run(
        n=args.n,
        slots=args.slots,
        slot_wakes=args.slot_wakes,
        seed=args.seed,
        churn=not args.no_churn,
        delay=args.delay,
        metrics=args.metrics,
    )


if __name__ == "__main__":
    main()
