"""Sharded async-engine scale bench: agent blocks over host-platform devices.

Where ``bench_async_engine`` drives the single-device batched engine,
this bench shards the agents across S devices via the ``shard_map``
super-tick: per-shard wake batches, a halo exchange of the start-of-slot
border rows, shard-local gather/mix/scatter over shard-resident data
tiles. This is the configuration that takes agent counts past one
device's memory — the bench asserts no O(n^2) array exists anywhere and
reports partition/communication stats alongside super-tick and
equivalent-sequential-tick rates.

Communication sweep: for {no relabel, RCM} x {all_gather, p2p} it
reports the measured halo fraction and the interconnect bytes shipped
per super-tick (rows x p x 4 bytes for the f32 engine dtype) — the
numbers behind the ``exchange="auto"`` selection. The timed run uses
``--relabel``/``--exchange`` (default: RCM + auto).

Run it with forced host devices (the flag must be set before jax loads,
so ``main`` sets it for you when possible):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_sharded_engine --n 1000000

``benchmarks/run.py --only sharded_engine`` invokes this module in a
subprocess with 8 forced host devices and merges every ``sharded_*`` CSV
row it prints into the bench summary.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def exchange_stats(graph, shards: int, p: int, partition_mode: str = "degree"):
    """Halo fraction + exchanged bytes/super-tick for the relabel x method grid.

    Pure-numpy partition analysis (no engine build): returns CSV-style
    rows ``(name, value, note)`` for {norelabel, rcm} x {all_gather, p2p},
    plus the built partitions keyed by relabel mode so the caller can
    reuse one for the engine instead of rebuilding it. Bytes assume the
    f32 engine dtype (4 bytes) and count padded rows, because static
    shapes ship them.
    """
    from repro.sim import partition_graph
    from repro.core.mixing import ExchangeSpec, sharded_mix_op

    rows, parts = [], {}
    for label, relabel in (("norelabel", None), ("rcm", "rcm")):
        t0 = time.time()
        part = partition_graph(graph, shards, mode=partition_mode, relabel=relabel)
        build_s = time.time() - t0
        parts[relabel] = part
        auto = sharded_mix_op(part).method
        rows.append(
            (f"sharded_halo_frac_{label}", part.halo_fraction(),
             f"S={shards} mode={partition_mode} auto_method={auto} "
             f"partition_build={build_s:.1f}s")
        )
        for method in ("all_gather", "p2p"):
            xrows = part.exchange_rows(method)
            for dtype in ("f32", "bf16"):
                spec = ExchangeSpec(method=method, dtype=dtype)
                nbytes = xrows * spec.payload_bytes_per_row(p)
                suffix = "" if dtype == "f32" else f"_{dtype}"
                rows.append(
                    (f"sharded_exchange_bytes_{label}_{method}{suffix}",
                     float(nbytes),
                     f"rows={xrows} p={p} {dtype} bytes/super-tick")
                )
    return rows, parts


def run(
    n: int = 1_000_000,
    p: int = 8,
    m: int = 4,
    shards: int = 8,
    slots: int = 8,
    slot_wakes: float = 8192.0,
    seed: int = 0,
    churn: bool = True,
    partition_mode: str = "degree",
    relabel: str | None = "rcm",
    exchange: str = "auto",
    fused="auto",
    metrics: bool = False,
    roofline: bool = True,
    verbose: bool = True,
):
    """Time the sharded engine at scale and report the comm sweep rows.

    ``exchange`` takes an :class:`repro.core.mixing.ExchangeSpec` or a
    spec string (``"auto"``, ``"p2p:bf16"``, ``"p2p:int8:ef"`` ...);
    ``fused`` is the EngineConfig knob (``"auto"`` engages the fused
    super-tick kernel on TPU only — forcing ``True`` on a CPU host runs
    the kernel in interpret mode, which is not a perf configuration).
    """
    import jax

    from benchmarks.bench_sparse_scale import _make_problem
    from repro.core.mixing import ExchangeSpec
    from repro.sim import CDUpdate, ChurnConfig, Scenario, ShardedAsyncEngine

    if len(jax.devices()) < shards:
        raise RuntimeError(
            f"need {shards} devices (have {len(jax.devices())}); set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} "
            "before jax is imported"
        )

    rng = np.random.default_rng(seed)
    t0 = time.time()
    graph, obj = _make_problem(n, p, m, rng)
    build_s = time.time() - t0

    # Communication sweep: {no relabel, RCM} x {all_gather, p2p}. The
    # sweep's partitions are reused for the timed engine when the config
    # matches, so the (RCM + cut + tile) build runs once, not twice.
    stats_rows, parts = exchange_stats(graph, shards, p, partition_mode)

    scenario = Scenario(
        churn=ChurnConfig(leave_prob=0.01, rejoin_prob=0.2) if churn else None
    )
    spec = exchange if isinstance(exchange, ExchangeSpec) else ExchangeSpec.from_string(exchange)
    t0 = time.time()
    engine = ShardedAsyncEngine(
        CDUpdate(obj),
        num_shards=shards,
        partition_mode=partition_mode,
        relabel=relabel,
        exchange=spec,
        partition=parts.get(relabel),
        slot_wakes=slot_wakes,
        scenario=scenario,
        seed=seed,
        fused=fused,
        metrics=metrics,
    )
    part_s = time.time() - t0
    part = engine.part

    # No (n, n) array anywhere: the shard tiles are O(nnz)-with-padding and
    # the halo/border maps O(cut); same guard floor as the sparse bench.
    mix = obj.mix
    leak_floor = max(n * n // 100, 64 * n + 256)
    for arr in (
        mix.idx, mix.w, mix.rows, mix.cols, mix.vals,
        part.idx, part.w, part.border, part.halo_src, part.owned,
    ):
        assert arr is None or arr.size < leak_floor, "an O(n^2) array leaked in"

    state = engine.init_state(np.zeros((n, p)))
    t0 = time.time()
    state = engine.advance(state, slots)
    state.Theta.block_until_ready()
    compile_s = time.time() - t0
    warm_applied = int(np.asarray(state.applied).sum())

    t0 = time.time()
    state = engine.advance(state, slots)
    state.Theta.block_until_ready()
    steady_s = time.time() - t0

    Theta = engine.global_theta(state)
    assert np.isfinite(Theta).all()
    applied = int(np.asarray(state.applied).sum())
    steady_applied = applied - warm_applied
    assert steady_applied > 0
    ticks_per_s = steady_applied / max(steady_s, 1e-9)
    deg = np.diff(graph.indptr)
    wire = engine.exchange_spec
    xbytes = part.exchange_rows(engine.exchange_method) * wire.payload_bytes_per_row(p)
    rows = [
        ("sharded_graph_build", build_s * 1e6 / max(n, 1),
         f"n={n} deg~{deg.mean():.1f} us/agent"),
        ("sharded_engine_build", part_s * 1e6 / max(n, 1),
         f"S={shards} mode={partition_mode} relabel={relabel} R={part.rows_per_shard} "
         f"halo_frac={part.halo_fraction():.3f} us/agent "
         "(partition reused from the sweep; per-config partition_build "
         "times are on the halo_frac rows)"),
        ("sharded_super_tick", steady_s * 1e6 / slots,
         f"n={n} S={shards} B={engine.batch_size} churn={int(churn)} "
         f"exchange={engine.exchange_method}:{wire.dtype}"
         f"{':ef' if wire.error_feedback else ''} fused={int(engine.fused)} "
         f"xbytes={xbytes} us/slot"),
        ("sharded_equiv_ticks_per_s", ticks_per_s,
         f"{applied} wakes applied, {int(np.asarray(state.dropped).sum())} dropped, "
         f"compile {compile_s:.1f}s"),
    ] + stats_rows
    if metrics:
        # In-jit telemetry totals (counters were live through the timed
        # halves, so the super-tick row above already includes their cost).
        from repro.obs import summarize_counters

        counters, _derived = engine.metrics_snapshot(state)
        totals = summarize_counters(counters)
        for key in ("wakes_realized", "exchange_bytes", "churn_departures"):
            if key in totals:
                rows.append(
                    (f"sharded_metrics_{key}", float(totals[key]),
                     f"telemetry total over {2 * slots} slots, summed over shards")
                )
    if roofline:
        # Place the compiled super-tick on the bandwidth roofline (the
        # program advance() just ran, fused kernel and compressed halos
        # included) and report the measured-vs-bound gap.
        from repro.roofline import supertick_report

        rows += supertick_report(
            engine, state=state, steps=slots,
            measured_s_per_tick=steady_s / slots,
            prefix="sharded_roofline_supertick",
        )
    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.4g},{note}")
    return rows


def main(argv=None):
    """CLI entry point; forces host-platform devices when still possible."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--slot-wakes", type=float, default=8192.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-churn", action="store_true")
    ap.add_argument("--mode", default="degree", choices=["degree", "contiguous"])
    ap.add_argument("--relabel", default="rcm", choices=["rcm", "none"])
    ap.add_argument("--exchange", default="auto",
                    help="ExchangeSpec string: method[:dtype[:ef]] with method "
                         "auto|all_gather|p2p and dtype f32|bf16|int8 "
                         "(e.g. p2p:bf16, p2p:int8:ef)")
    ap.add_argument("--fused", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--metrics", action="store_true",
                    help="run with in-jit telemetry on and report its totals")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args(argv)
    if "jax" not in sys.modules and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""
    ):
        # jax not loaded yet: we can still force the host devices ourselves.
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    run(
        n=args.n,
        shards=args.shards,
        slots=args.slots,
        slot_wakes=args.slot_wakes,
        seed=args.seed,
        churn=not args.no_churn,
        partition_mode=args.mode,
        relabel=None if args.relabel == "none" else args.relabel,
        exchange=args.exchange,
        fused={"auto": "auto", "on": True, "off": False}[args.fused],
        metrics=args.metrics,
        roofline=not args.no_roofline,
    )


if __name__ == "__main__":
    main()
