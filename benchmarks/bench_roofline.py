"""Roofline table formatter (deliverable g): reads the dry-run JSONL and
prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck and the MODEL_FLOPS/HLO_FLOPS usefulness ratio.

The dry-run itself must be produced by ``repro.launch.dryrun`` (512-device
process); this module only formats/aggregates, so it is safe to run in the
normal 1-device bench process.
"""

from __future__ import annotations

import json
import os


def load(path="results/dryrun_single.jsonl"):
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def advice(row):
    d = row["dominant"]
    if d == "collective":
        cb = row.get("collective_breakdown", {})
        top = max(cb, key=cb.get) if cb else "?"
        return f"cut {top} traffic (seq-parallel norms / bf16 payloads / layout)"
    if d == "memory":
        return "reduce HBM traffic (fusion, chunked attention, smaller remat set)"
    return "compute-bound: increase per-chip arithmetic intensity or accept"


def table(rows, mesh=None):
    out = []
    hdr = f"{'arch':22s} {'shape':12s} {'mesh':8s} {'dom':10s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'useful':>7s}"
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} {r['dominant']:10s} "
            f"{r['compute_s']:10.3f} {r['memory_s']:10.3f} {r['collective_s']:10.3f} "
            f"{r['useful_ratio']:7.2f}"
        )
    return "\n".join(out)


def run(path="results/dryrun_single.jsonl", verbose=True):
    rows = load(path)
    if verbose:
        if not rows:
            print(f"[roofline] no dry-run results at {path}; run "
                  "PYTHONPATH=src python -m repro.launch.dryrun --all --out " + path)
        else:
            print(table(rows))
            worst = sorted(
                (r for r in rows if r["compute_s"] > 0),
                key=lambda r: r["compute_s"] / max(r["compute_s"], r["memory_s"], r["collective_s"]),
            )[:3]
            print("\nworst roofline fraction (hillclimb candidates):")
            for r in worst:
                frac = r["compute_s"] / max(r["compute_s"], r["memory_s"], r["collective_s"])
                print(f"  {r['arch']} x {r['shape']} ({r['mesh']}): {frac:.3f} — {advice(r)}")
    return rows


if __name__ == "__main__":
    run()
