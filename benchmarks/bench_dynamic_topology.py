"""Dynamic-topology bench: patch vs rebuild, drift gauge, halo parity.

The dynamic-topology layer's performance claim is that a Dada-style edge
refresh should *not* pay for a full ``partition_graph`` rebuild every
round: while the cut drifts little, :meth:`GraphPartition.patch` rebinds
the halo tiles under frozen ownership. This bench measures that claim on
a k-NN graph churned by one :class:`repro.sim.GraphUpdate` refresh:

* ``dyntopo_refresh_s`` — the host-side edge-refresh round itself;
* ``dyntopo_drift`` — the cut-fraction drift gauge the repartition
  policy keys on (``EngineConfig.drift_threshold``);
* ``dyntopo_patch_s`` / ``dyntopo_rebuild_s`` — rebinding the standing
  partition vs cutting the new graph from scratch;
* ``dyntopo_patch_speedup`` — rebuild time over patch time (> 1 is the
  acceptance claim);
* ``dyntopo_halo_parity`` — 1.0 after asserting the patched partition's
  halo/exchange tiles equal a from-scratch cut of the new graph under
  the same frozen layout (contiguous bounds + pinned order/tile width,
  the configuration where the two are defined to coincide).

Run standalone (single process, no devices needed — this is host-side
partition machinery):

    PYTHONPATH=src python -m benchmarks.bench_dynamic_topology --n 200000

``benchmarks/run.py --only dynamic_topology`` merges every ``dyntopo_*``
row into BENCH_summary.json.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _churned_graph(csr, refresh, Theta, rounds: int = 1):
    """Apply ``rounds`` edge-refresh steps and return the final graph."""
    for r in range(rounds):
        csr = refresh.refresh(csr, Theta, round_index=r + 1)
    return csr


def _assert_halo_parity(base, patched, new_csr) -> None:
    """Patched tiles must equal a from-scratch cut under the frozen layout.

    The comparison pins everything :meth:`GraphPartition.patch` freezes by
    construction — contiguous bounds (independent of edge weights), the
    standing relabel order, and the (never-shrinking) tile width — so a
    fresh ``partition_graph`` of the new graph is defined to coincide
    field-for-field, point-to-point plan included.
    """
    from repro.sim import partition_graph

    fresh = partition_graph(
        new_csr,
        base.num_shards,
        mode="contiguous",
        relabel=base.order,
        tile_width=patched.tile_width,
    )
    pairs = [
        ("halo", patched.halo, fresh.halo),
        ("halo_sizes", patched.halo_sizes, fresh.halo_sizes),
        ("halo_owner", patched.halo_owner, fresh.halo_owner),
        ("border", patched.border, fresh.border),
        ("border_sizes", patched.border_sizes, fresh.border_sizes),
        ("halo_src", patched.halo_src, fresh.halo_src),
        ("idx", patched.idx, fresh.idx),
        ("w", patched.w, fresh.w),
    ]
    for name, a, b in pairs:
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(f"halo parity: field {name} diverged after patch()")
    for name, a, b in zip(("offsets", "sends", "dsts"), patched.p2p_plan, fresh.p2p_plan):
        eq = len(a) == len(b) and all(
            np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
        )
        if not eq:
            raise AssertionError(f"halo parity: p2p plan {name} diverged after patch()")


def run(n: int = 200_000, shards: int = 8, k: int = 10, seed: int = 0, verbose=True):
    """Measure patch-vs-rebuild on one refresh round; return CSV rows."""
    from repro.core import random_geometric_graph
    from repro.sim import GraphUpdate, partition_graph

    rng = np.random.default_rng(seed)
    t0 = time.time()
    # Random geometric graph: O(n) memory, same constructor the sharded
    # engine benches scale with (quadratic k-NN build would dominate the
    # partition timings this bench is actually about).
    csr = random_geometric_graph(n, rng, avg_degree=float(k))
    graph_s = time.time() - t0

    t0 = time.time()
    part = partition_graph(csr, shards, mode="degree", relabel="rcm")
    build_s = time.time() - t0

    refresh = GraphUpdate(every=1, k=k, candidates=4, gamma=4.0, seed=seed)
    Theta = rng.normal(size=(n, 8))
    t0 = time.time()
    new_csr = _churned_graph(csr, refresh, Theta)
    refresh_s = time.time() - t0

    drift = part.drift(new_csr)
    t0 = time.time()
    patched = part.patch(new_csr)
    patched.p2p_plan  # the plan is part of what a swap rebinds — time it
    patch_s = time.time() - t0
    t0 = time.time()
    rebuilt = partition_graph(new_csr, shards, mode="degree", relabel="rcm")
    rebuilt.p2p_plan
    rebuild_s = time.time() - t0
    assert rebuilt.n == patched.n

    # Halo parity runs on a contiguous-mode base: patch() freezes the
    # block bounds, and only contiguous bounds are weight-independent —
    # the configuration where patched and from-scratch coincide exactly.
    cbase = partition_graph(csr, shards, mode="contiguous", relabel="rcm")
    _assert_halo_parity(cbase, cbase.patch(new_csr), new_csr)

    rows = [
        ("dyntopo_graph_build", graph_s, f"random_geometric_graph n={n} deg~{k}"),
        ("dyntopo_partition_build", build_s, f"S={shards} mode=degree relabel=rcm"),
        ("dyntopo_refresh_s", refresh_s, "GraphUpdate round with 4 candidates/row"),
        ("dyntopo_drift", drift, "cut-fraction drift gauge after one refresh"),
        ("dyntopo_patch_s", patch_s, "GraphPartition.patch + p2p plan rebind"),
        ("dyntopo_rebuild_s", rebuild_s, "full partition_graph + p2p plan"),
        ("dyntopo_patch_speedup", rebuild_s / max(patch_s, 1e-9),
         "rebuild_s / patch_s (>1 = patch cheaper)"),
        ("dyntopo_halo_parity", 1.0,
         "patched tiles == from-scratch cut under frozen layout (asserted)"),
    ]
    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.4g},{note}")
    return rows


def main(argv=None):
    """CLI entry point (host-side only; no device mesh required)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(n=args.n, shards=args.shards, k=args.k, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
