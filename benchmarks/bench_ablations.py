"""Beyond-paper ablations:

1. Prop.-2 (time-decreasing) noise allocation vs the uniform split the
   paper's experiments use — the theory (Lemma 3) predicts lower utility
   loss for the decreasing schedule.
2. Gaussian (Remark 4) vs Laplace (Thm. 1) mechanism at matched (eps, delta).
3. Personalized objective vs single-global-model consensus (the mu -> 0
   extreme) under heterogeneous agents — the reason the paper's objective
   exists.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DPConfig, make_objective, run_private, run_scan
from repro.data.synthetic import eval_accuracy, linear_classification_problem


def prop2_vs_uniform(n=50, p=10, eps=1.0, T_per_agent=5, seeds=5, verbose=True):
    """Utility metric: mean final test accuracy from the purely-local init
    (the regime where private CD descends; min-objective is degenerate when
    the init already sits near the noise floor)."""
    from repro.core import train_local_models
    from repro.core.objective import LOGISTIC

    accs = {"uniform": [], "prop2": []}
    for s in range(seeds):
        prob = linear_classification_problem(n=n, p=p, seed=s)
        obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3, clip=1.0)
        theta_loc = train_local_models(
            prob.train, LOGISTIC, 1.0 / np.maximum(prob.train.num_examples, 1.0)
        )
        for schedule in accs:
            res = run_private(
                obj, theta_loc, T=T_per_agent * n,
                cfg=DPConfig(eps_bar=eps, schedule=schedule),
                rng=np.random.default_rng(100 + s), record_objective=False,
            )
            accs[schedule].append(float(eval_accuracy(res.Theta, prob.test).mean()))
    out = {k: float(np.mean(v)) for k, v in accs.items()}
    out["prop2_better"] = out["prop2"] >= out["uniform"]
    if verbose:
        print(f"[ablation] noise allocation: uniform acc {out['uniform']:.3f} "
              f"vs prop2 {out['prop2']:.3f} (prop2 better: {out['prop2_better']})")
    return out


def gaussian_vs_laplace(n=50, p=10, eps=1.0, T_per_agent=5, seeds=5, verbose=True):
    from repro.core import train_local_models
    from repro.core.objective import LOGISTIC

    accs = {"laplace": [], "gaussian": []}
    for s in range(seeds):
        prob = linear_classification_problem(n=n, p=p, seed=20 + s)
        obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3, clip=1.0)
        theta_loc = train_local_models(
            prob.train, LOGISTIC, 1.0 / np.maximum(prob.train.num_examples, 1.0)
        )
        for mech in accs:
            res = run_private(
                obj, theta_loc, T=T_per_agent * n,
                cfg=DPConfig(eps_bar=eps, mechanism=mech, delta_step=1e-6),
                rng=np.random.default_rng(7 + s), record_objective=False,
            )
            accs[mech].append(float(eval_accuracy(res.Theta, prob.test).mean()))
    out = {k: float(np.mean(v)) for k, v in accs.items()}
    if verbose:
        print(f"[ablation] mechanism: laplace acc {out['laplace']:.3f} "
              f"vs gaussian {out['gaussian']:.3f}")
    return out


def personalized_vs_global(n=40, p=20, verbose=True):
    """Heterogeneous tasks: the personalized optimum must beat the best
    single global model (this is Table-1's 'purely local vs collaborative'
    flipped around: collaboration must not collapse to consensus)."""
    prob = linear_classification_problem(n=n, p=p, seed=3)
    obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3, clip=1.0)
    rng = np.random.default_rng(0)
    res = run_scan(obj, np.zeros((n, p)), T=30 * n, rng=rng, record_objective=False)
    acc_pers = eval_accuracy(res.Theta, prob.test).mean()
    # Global model: train one model on the union of all data (upper bound on
    # any consensus method for this heterogeneous setup).
    X = prob.train.X.reshape(-1, p)
    y = prob.train.y.reshape(-1)
    mask = prob.train.mask.reshape(-1) > 0
    from repro.core.model_propagation import train_local_models
    from repro.core.objective import AgentData, LOGISTIC

    pooled = AgentData(X=X[mask][None], y=y[mask][None], mask=np.ones((1, mask.sum())))
    theta_g = train_local_models(pooled, LOGISTIC, np.array([1.0 / mask.sum()]))
    acc_glob = eval_accuracy(np.broadcast_to(theta_g, (n, p)), prob.test).mean()
    if verbose:
        print(f"[ablation] personalized acc {acc_pers:.3f} vs single global model "
              f"{acc_glob:.3f}")
    return {"acc_personalized": float(acc_pers), "acc_global": float(acc_glob)}


def run(out=None, verbose=True, fast=False):
    t0 = time.time()
    small = dict(n=20, p=10, seeds=2)
    r1 = prop2_vs_uniform(verbose=verbose, **(small if fast else {}))
    r2 = gaussian_vs_laplace(verbose=verbose, **(small if fast else {}))
    r3 = personalized_vs_global(verbose=verbose, **(dict(n=16, p=10) if fast else {}))
    result = {"name": "ablations", "noise_allocation": r1, "mechanism": r2,
              "personalization": r3, "elapsed_s": round(time.time() - t0, 1)}
    if out:
        with open(out, "w") as f:
            json.dump(result, f)
    return result


if __name__ == "__main__":
    run()
