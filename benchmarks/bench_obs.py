"""Observability bench: telemetry overhead + super-tick phase attribution.

Two questions, answered on the 8-shard engine the roofline rows describe:

* **What does telemetry cost?** The same sharded run is timed metrics-off
  and metrics-on (full :class:`repro.obs.MetricsSpec`); the ``obs_overhead``
  row reports the steady-state super-tick overhead in percent. The
  acceptance target is <= 5% — the counters only re-reduce values the slot
  already computed, so most of the "overhead" is timing noise.
* **Where does the super-tick's time go?** ``repro.obs.profile_supertick``
  times the engine's jitted phase-prefix programs and differences them,
  attributing the slot wall-clock to wake_sample / halo_publish /
  halo_collective / halo_scatter / gather_mix / row_update / scatter /
  finalize. The ``obs_phase_*`` rows decompose the measured super-tick the
  ``sharded_roofline_supertick_gap`` row compares against its bandwidth
  bound; ``obs_phase_total`` records the coverage (sum of phases vs the
  independently measured full slot — within 15% by construction).

Artifacts: a Chrome/Perfetto ``trace.json`` (host timing spans + the
synthetic per-phase track) and a :class:`repro.obs.RunReport` JSONL with
the drained counters and phase rows — render either with
``python -m repro.obs.report``. Needs 8 host devices, so ``run.py``
launches it in a subprocess:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_obs --n 50000
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def _steady_s_per_slot(engines, n: int, p: int, slots: int, repeats: int = 5):
    """Steady-state seconds per super-tick for each engine, measured
    **interleaved**: all engines are warmed (compile + burn-in) first, then
    the timed ``slots``-long advances alternate engine-by-engine across
    ``repeats`` rounds (best-of). Alternation matters for the overhead
    comparison — back-to-back blocks would let any machine-load drift land
    entirely on one side and masquerade as telemetry cost."""
    states = []
    for engine in engines:
        state = engine.init_state(np.zeros((n, p)))
        state = engine.advance(state, slots)
        state.Theta.block_until_ready()
        states.append(state)
    best = [float("inf")] * len(engines)
    for _ in range(repeats):
        for i, engine in enumerate(engines):
            t0 = time.time()
            states[i] = engine.advance(states[i], slots)
            states[i].Theta.block_until_ready()
            best[i] = min(best[i], (time.time() - t0) / slots)
    return best


def run(
    n: int = 200_000,
    p: int = 8,
    m: int = 4,
    shards: int = 8,
    slots: int = 6,
    slot_wakes: float = 2048.0,
    seed: int = 0,
    exchange: str = "auto",
    trace_out: str = "results/obs_trace.json",
    report_out: str = "results/obs_runreport.jsonl",
    verbose: bool = True,
):
    """Measure telemetry overhead and phase attribution; write the artifacts."""
    import jax

    from benchmarks.bench_sparse_scale import _make_problem
    from repro.core.mixing import ExchangeSpec
    from repro.obs import SpanRecorder, profile_supertick
    from repro.sim import CDUpdate, ShardedAsyncEngine

    if len(jax.devices()) < shards:
        raise RuntimeError(
            f"need {shards} devices (have {len(jax.devices())}); set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} "
            "before jax is imported"
        )

    rng = np.random.default_rng(seed)
    graph, obj = _make_problem(n, p, m, rng)
    spec = ExchangeSpec.from_string(exchange)
    kw = dict(
        num_shards=shards,
        relabel="rcm",
        exchange=spec,
        slot_wakes=slot_wakes,
        seed=seed,
    )
    eng_off = ShardedAsyncEngine(CDUpdate(obj), **kw)
    # Reuse the partition: identical cut, so the timed programs differ only
    # by the metrics leaves.
    eng_on = ShardedAsyncEngine(CDUpdate(obj), partition=eng_off.part, metrics=True, **kw)

    t_off, t_on = _steady_s_per_slot((eng_off, eng_on), n, p, slots)
    overhead_pct = 100.0 * (t_on - t_off) / max(t_off, 1e-12)
    rows = [
        (
            "obs_overhead",
            overhead_pct,
            f"metrics-on super-tick overhead % (on {t_on * 1e6:.4g}us, "
            f"off {t_off * 1e6:.4g}us, n={n} S={shards}; target <=5%)",
        )
    ]

    # Drained run -> RunReport; phase profile -> trace + obs_phase_* rows
    # decomposing the super-tick behind sharded_roofline_supertick_gap.
    result = eng_on.run(
        np.zeros((n, p)), slots, metrics_every=max(slots // 2, 1)
    )
    recorder = SpanRecorder()
    prof = profile_supertick(eng_on, state=result.state, recorder=recorder)
    result.report.add_phase_rows(prof.rows(prefix="obs_phase"))
    for path in (trace_out, report_out):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
    recorder.export_chrome_trace(trace_out)
    result.report.to_jsonl(report_out)
    rows += result.report.bench_rows()

    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.4g},{note}")
        print(f"# trace: {trace_out}  report: {report_out}", file=sys.stderr)
    return rows


def main(argv=None):
    """CLI entry point; forces host-platform devices when still possible."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--slot-wakes", type=float, default=2048.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--exchange", default="auto",
                    help="ExchangeSpec string: method[:dtype[:ef]]")
    ap.add_argument("--trace-out", default="results/obs_trace.json")
    ap.add_argument("--report-out", default="results/obs_runreport.jsonl")
    args = ap.parse_args(argv)
    if "jax" not in sys.modules and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""
    ):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    run(
        n=args.n,
        shards=args.shards,
        slots=args.slots,
        slot_wakes=args.slot_wakes,
        seed=args.seed,
        exchange=args.exchange,
        trace_out=args.trace_out,
        report_out=args.report_out,
    )


if __name__ == "__main__":
    main()
