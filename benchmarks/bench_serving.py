"""Serving bench: batched predict() throughput while the swarm trains.

Trains the sharded engine in a background thread with
``snapshot_every=1`` (a publication every super-tick) and hammers the
live :class:`repro.serve.ServeHandle` with batched ``predict`` calls
from the foreground — the heavy-traffic read path the paper's
millions-of-users framing implies. Rows:

* ``serving_predictions_per_s`` — rows scored per wall second, measured
  over the concurrent-with-training window;
* ``serving_p50_ms`` / ``serving_p99_ms`` — per-batch predict latency;
* ``serving_publish_us_per_tick`` — snapshot publication cost amortized
  per super-tick (zero-copy tile refs + a slot-counter sync);
* ``serving_version_lag_max`` — worst staleness any request observed,
  in slots (bounded by ``snapshot_every`` while training runs).

The final batch is verified bit-exact against the published snapshot
rows before any row is printed. Run standalone (8 forced host devices
happen in run.py's subprocess):

    PYTHONPATH=src python -m benchmarks.bench_serving --n 100000 --shards 8

``benchmarks/run.py --only serving`` merges every ``serving_*`` row into
BENCH_summary.json.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np


def run(n=100_000, shards=8, slots=4, slot_wakes=2048.0, batch=1024, seed=0,
        verbose=True):
    from repro.core import AgentData, make_objective, random_geometric_graph
    from repro.serve import ServeHandle
    from repro.sim import CDUpdate, EngineConfig, make_engine

    rng = np.random.default_rng(seed)
    p, m = 8, 4
    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    obj = make_objective(
        graph, AgentData(X=X, y=y, mask=np.ones((n, m))), "quadratic",
        mu=0.5, mix_mode="sparse",
    )
    cfg = EngineConfig(slot_wakes=slot_wakes, seed=seed, relabel="rcm")
    eng = make_engine(CDUpdate(obj), cfg, shards=shards)
    handle = ServeHandle.for_engine(eng)

    done = threading.Event()
    box = {}

    def _train():
        try:
            box["result"] = eng.run(np.zeros((n, p)), slots,
                                    snapshot_every=1, serve=handle)
        finally:
            done.set()

    ids = rng.integers(0, n, size=batch)
    Xq = rng.normal(size=(batch, p))

    trainer = threading.Thread(target=_train, name="trainer")
    trainer.start()
    while not done.is_set():
        try:
            handle.version
            break
        except RuntimeError:
            time.sleep(0.002)
    handle.predict(ids, Xq)  # compile outside the timed window

    lat = []
    while not done.is_set():
        t0 = time.perf_counter()
        handle.predict(ids, Xq)
        lat.append(time.perf_counter() - t0)
    trainer.join()
    if "result" not in box:
        raise RuntimeError("training thread died")
    result = box["result"]
    # keep a few post-training samples so tiny configs still measure
    while len(lat) < 16:
        t0 = time.perf_counter()
        handle.predict(ids, Xq)
        lat.append(time.perf_counter() - t0)

    # Served values must be the published snapshot's rows, bit-exact.
    snap = handle.snapshot()
    check = handle.rows(ids[:256], at=snap)
    if snap.version != result.slots or not np.array_equal(
        check.values, result.Theta[ids[:256]].astype(np.float32)
    ):
        raise RuntimeError("served rows diverged from the published snapshot")

    lat = np.asarray(lat)
    c = handle.counters()
    publish_us = 1e6 * c["serve_publish_s_total"] / max(result.slots, 1)
    rows = [
        ("serving_predictions_per_s", batch * lat.size / lat.sum(),
         f"n={n},shards={shards},batch={batch}"),
        ("serving_p50_ms", float(np.percentile(lat, 50) * 1e3),
         f"batch={batch}"),
        ("serving_p99_ms", float(np.percentile(lat, 99) * 1e3),
         f"batch={batch}"),
        ("serving_publish_us_per_tick", publish_us,
         f"snapshots={c['serve_snapshots_published']},slots={result.slots}"),
        ("serving_version_lag_max", float(c["serve_version_lag_max"]),
         "slots behind trainer; bound=snapshot_every=1 while training"),
    ]
    if verbose:
        for name, val, note in rows:
            print(f"{name},{val:.6g},{note}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--slot-wakes", type=float, default=2048.0)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(n=args.n, shards=args.shards, slots=args.slots,
        slot_wakes=args.slot_wakes, batch=args.batch, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
