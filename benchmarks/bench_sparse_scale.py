"""Sparse-graph scale bench: CD ticks at n agents where dense W cannot exist.

At n = 100,000 agents a dense float64 weight matrix is 80 GB — it cannot
even be allocated on this machine — while the CSR neighbour lists at
average degree ~16 are a few MB. This bench builds a random geometric
CSR graph, attaches a synthetic quadratic objective, and drives real
Eq. 4 coordinate-descent ticks through the sparse ``mix.row`` path,
asserting along the way that nothing materializes an (n, n) array.

Also reports dense-vs-sparse mixing agreement on a small graph (the
crossover-correctness check) and the per-tick rate.

    PYTHONPATH=src python -m benchmarks.bench_sparse_scale             # n=100k
    PYTHONPATH=src python -m benchmarks.bench_sparse_scale --n 10000
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _make_problem(n: int, p: int, m: int, rng: np.random.Generator):
    """Quadratic objective over a random geometric CSR graph; O(n) memory."""
    from repro.core import AgentData, make_objective, random_geometric_graph

    graph = random_geometric_graph(n, rng, avg_degree=16.0)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return graph, make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")


def parity_check(n: int = 512, seed: int = 0, tol: float = 1e-5) -> float:
    """Max-abs dense/sparse disagreement of the mix operator on n agents."""
    import jax.numpy as jnp

    from repro.core import knn_cosine_graph, mix_op

    rng = np.random.default_rng(seed)
    graph = knn_cosine_graph(rng.normal(size=(n, 16)), k=10)
    Theta = jnp.asarray(rng.normal(size=(n, 32)), jnp.float32)
    dense = mix_op(graph, mode="dense")
    sparse = mix_op(graph, mode="sparse")
    err_all = float(jnp.abs(dense.all(Theta) - sparse.all(Theta)).max())
    err_row = max(
        float(jnp.abs(dense.row(Theta, i) - sparse.row(Theta, i)).max())
        for i in range(0, n, max(n // 16, 1))
    )
    err = max(err_all, err_row)
    assert err <= tol, f"dense/sparse mixing disagree: {err} > {tol}"
    return err


def run(n: int = 100_000, p: int = 8, m: int = 4, ticks: int = 2_000,
        seed: int = 0, verbose: bool = True):
    from repro.core import run_scan
    from repro.core.mixing import MixOp

    rng = np.random.default_rng(seed)
    t0 = time.time()
    graph, obj = _make_problem(n, p, m, rng)
    build_s = time.time() - t0
    deg = np.diff(graph.indptr)
    assert deg.mean() <= 32.0, f"avg degree {deg.mean():.1f} exceeds bench spec"

    mix = obj.mix
    assert isinstance(mix, MixOp) and mix.kind == "sparse"
    # The whole point: no (n, n) array anywhere on the sparse path. (The
    # O(nnz) floor keeps the guard meaningful at bench scale without
    # false-firing on tiny --n debug runs.)
    leak_floor = max(n * n // 100, 64 * n + 256)
    for arr in (mix.idx, mix.w, mix.rows, mix.cols, mix.vals, graph.indices, graph.data):
        assert arr is None or arr.size < leak_floor, "an O(n^2) array leaked in"

    t0 = time.time()
    res = run_scan(obj, np.zeros((n, p)), T=ticks, rng=rng, record_objective=False)
    tick_s = time.time() - t0
    assert np.isfinite(res.Theta).all()

    rows = [
        ("sparse_graph_build", build_s * 1e6 / max(n, 1), f"n={n} deg~{deg.mean():.1f} us/agent"),
        ("sparse_cd_tick", tick_s * 1e6 / ticks, f"n={n} {ticks} ticks us/tick"),
        ("dense_sparse_parity_512", parity_check(), "max-abs, tol 1e-5"),
    ]
    if verbose:
        for name, v, note in rows:
            print(f"{name},{v:.3g},{note}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--ticks", type=int, default=2_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(n=args.n, ticks=args.ticks, seed=args.seed)


if __name__ == "__main__":
    main()
