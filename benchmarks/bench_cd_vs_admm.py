"""Figure 1 reproduction: Coordinate Descent (ours) vs gossip ADMM
(Vanhaesebrouck et al. 2017) on the linear classification task.

Both algorithms start from the purely-local models and are compared on the
objective value and test accuracy as functions of (i) iterations and (ii)
p-dimensional vectors transmitted — the paper's two x-axes.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import make_objective, run_admm, run_scan, train_local_models
from repro.core.objective import LOGISTIC
from repro.data.synthetic import eval_accuracy, linear_classification_problem


def run(n=100, p=100, T_cd=3000, T_admm=300, mu=0.3, seed=0, record_every=50,
        out=None, verbose=True):
    t0 = time.time()
    prob = linear_classification_problem(n=n, p=p, seed=seed)
    obj = make_objective(prob.graph, prob.train, "logistic", mu=mu)
    theta_loc = train_local_models(
        prob.train, LOGISTIC, 1.0 / np.maximum(prob.train.num_examples, 1.0)
    )
    acc_loc = eval_accuracy(theta_loc, prob.test).mean()

    rng = np.random.default_rng(seed)
    cd = run_scan(obj, theta_loc, T=T_cd, rng=rng, record_every=record_every)
    acc_cd = eval_accuracy(cd.Theta, prob.test).mean()

    admm = run_admm(obj, theta_loc, T=T_admm, rng=np.random.default_rng(seed + 1),
                    rho=1.0, local_grad_steps=10, record_every=max(record_every // 10, 1))
    acc_admm = eval_accuracy(admm.Theta, prob.test).mean()

    # Fig-1 comparison at equal communication: objective reached by each
    # algorithm after the same number of transmitted p-vectors.
    budget = admm.messages[-1]
    k = int(np.searchsorted(cd.messages, budget))
    k = min(k, len(cd.objective) - 1)

    result = {
        "name": "fig1_cd_vs_admm",
        "n": n, "p": p, "mu": mu,
        "acc_local": float(acc_loc),
        "acc_cd": float(acc_cd),
        "acc_admm": float(acc_admm),
        "obj_init": float(cd.objective[0]),
        "obj_cd_final": float(cd.objective[-1]),
        "obj_admm_final": float(admm.objective[-1]),
        "messages_admm": float(budget),
        "obj_cd_at_admm_budget": float(cd.objective[k]),
        "cd_beats_admm_per_message": bool(cd.objective[k] < admm.objective[-1]),
        "curves": {
            "cd_messages": cd.messages.tolist(),
            "cd_objective": cd.objective.tolist(),
            "admm_messages": admm.messages.tolist(),
            "admm_objective": admm.objective.tolist(),
        },
        "elapsed_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"[fig1] local acc {acc_loc:.3f} | CD acc {acc_cd:.3f} | ADMM acc {acc_admm:.3f}")
        print(f"[fig1] obj: init {result['obj_init']:.2f} -> CD {result['obj_cd_final']:.2f}, "
              f"ADMM {result['obj_admm_final']:.2f}")
        print(f"[fig1] at ADMM's message budget ({budget:.0f} vectors): "
              f"CD obj {result['obj_cd_at_admm_budget']:.2f} "
              f"(beats ADMM: {result['cd_beats_admm_per_message']})")
    if out:
        with open(out, "w") as f:
            json.dump(result, f)
    return result


if __name__ == "__main__":
    run()
