"""Figure 2 / Figure 3 / Figure 4 reproduction: the privacy/utility
trade-off on linear classification.

(a) objective along iterations under a fixed budget, constant init —
    the U-shaped "more iterations => more noise" behaviour;
(b) same with the private warm start (Supp. C);
(c) final test accuracy vs dimension p for several privacy budgets,
    against the purely-local baseline;
(fig3) accuracy improvement split by local dataset size;
(fig4) the local-DP (perturb-the-data) baseline.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (
    DPConfig,
    make_objective,
    perturb_dataset,
    private_warm_start,
    run_private,
    run_scan,
    train_local_models,
)
from repro.core.objective import LOGISTIC
from repro.data.synthetic import eval_accuracy, linear_classification_problem


def _local_models(prob):
    return train_local_models(
        prob.train, LOGISTIC, 1.0 / np.maximum(prob.train.num_examples, 1.0)
    )


def fig2a_b(n=100, p=100, eps=0.55, T=1000, mu=0.3, seed=0, record_every=20, verbose=True):
    prob = linear_classification_problem(n=n, p=p, seed=seed)
    obj = make_objective(prob.graph, prob.train, "logistic", mu=mu, clip=1.0)
    rng = np.random.default_rng(seed)
    const_init = np.ones((n, p))
    warm = private_warm_start(obj, eps_warm=0.05 * 10, rng=rng)  # eps=0.5 warm
    cfg = DPConfig(eps_bar=eps)
    r_const = run_private(obj, const_init, T=T, cfg=cfg, rng=np.random.default_rng(seed + 1),
                          record_every=record_every)
    r_warm = run_private(obj, warm, T=T, cfg=cfg, rng=np.random.default_rng(seed + 2),
                         record_every=record_every)
    out = {
        "const_objective": r_const.objective.tolist(),
        "warm_objective": r_warm.objective.tolist(),
        "acc_const": float(eval_accuracy(r_const.Theta, prob.test).mean()),
        "acc_warm": float(eval_accuracy(r_warm.Theta, prob.test).mean()),
        "warm_start_obj": float(obj.value(warm.astype(np.float64))),
        "const_init_obj": float(obj.value(const_init)),
    }
    if verbose:
        print(f"[fig2ab] const init: obj {out['const_init_obj']:.1f} -> min "
              f"{min(r_const.objective):.1f}, acc {out['acc_const']:.3f}")
        print(f"[fig2ab] warm  init: obj {out['warm_start_obj']:.1f} -> min "
              f"{min(r_warm.objective):.1f}, acc {out['acc_warm']:.3f}")
    return out


def fig2c_fig3(n=100, dims=(10, 50, 100), eps_list=(0.1, 0.5, 1.0), T_per_agent=None,
               mu=0.3, seed=0, verbose=True, tick_grid=(1, 2, 5, 10)):
    rows = []
    fig3 = None
    for p in dims:
        prob = linear_classification_problem(n=n, p=p, seed=seed + p)
        obj = make_objective(prob.graph, prob.train, "logistic", mu=mu, clip=1.0)
        theta_loc = _local_models(prob)
        acc_loc = eval_accuracy(theta_loc, prob.test)
        rng = np.random.default_rng(seed)
        nonpriv = run_scan(obj, theta_loc, T=20 * n, rng=rng, record_objective=False)
        acc_np = eval_accuracy(nonpriv.Theta, prob.test)
        row = {"p": p, "acc_local": float(acc_loc.mean()), "acc_nonprivate": float(acc_np.mean())}
        # Paper protocol: "the number of iterations per node was tuned based
        # on a validation set of random problem instances".
        val_prob = linear_classification_problem(n=n, p=p, seed=seed + p + 10_000)
        val_obj = make_objective(val_prob.graph, val_prob.train, "logistic", mu=mu, clip=1.0)
        val_loc = _local_models(val_prob)
        for eps in eps_list:
            if T_per_agent is None:
                best = (tick_grid[0], -1.0)
                for ticks in tick_grid:
                    vw = private_warm_start(val_obj, eps_warm=0.5,
                                            rng=np.random.default_rng(seed + 7))
                    vr = run_private(val_obj, vw, T=ticks * n, cfg=DPConfig(eps_bar=eps),
                                     rng=np.random.default_rng(seed + 8),
                                     record_objective=False)
                    a = float(eval_accuracy(vr.Theta, val_prob.test).mean())
                    if a > best[1]:
                        best = (ticks, a)
                ticks = best[0]
            else:
                ticks = T_per_agent
            warm = private_warm_start(obj, eps_warm=0.5, rng=np.random.default_rng(seed + 3))
            r = run_private(obj, warm, T=ticks * n, cfg=DPConfig(eps_bar=eps),
                            rng=np.random.default_rng(seed + 4), record_objective=False)
            acc = eval_accuracy(r.Theta, prob.test)
            row[f"acc_eps_{eps}"] = float(acc.mean())
            row[f"ticks_eps_{eps}"] = ticks
            if p == max(dims) and eps == eps_list[-1]:
                # Fig 3: improvement by dataset size (largest dim, largest eps)
                m = prob.train.num_examples
                small = m <= np.median(m)
                fig3 = {
                    "acc_local_small_m": float(acc_loc[small].mean()),
                    "acc_priv_small_m": float(acc[small].mean()),
                    "acc_local_large_m": float(acc_loc[~small].mean()),
                    "acc_priv_large_m": float(acc[~small].mean()),
                }
        rows.append(row)
        if verbose:
            print(f"[fig2c] p={p}: " + " ".join(f"{k}={v:.3f}" for k, v in row.items() if k != "p"))
    if verbose and fig3:
        print(f"[fig3] small-m agents: local {fig3['acc_local_small_m']:.3f} -> "
              f"private {fig3['acc_priv_small_m']:.3f}; large-m: "
              f"{fig3['acc_local_large_m']:.3f} -> {fig3['acc_priv_large_m']:.3f}")
    return rows, fig3


def fig4_local_dp(n=100, p=50, eps_list=(1.0, 5.0), mu=0.3, seed=0, verbose=True):
    prob = linear_classification_problem(n=n, p=p, seed=seed)
    theta_loc = _local_models(prob)
    acc_clean = eval_accuracy(theta_loc, prob.test).mean()
    rows = []
    for eps in eps_list:
        pert = perturb_dataset(prob.train, eps=eps, rng=np.random.default_rng(seed))
        theta_dp = train_local_models(
            pert, LOGISTIC, 1.0 / np.maximum(pert.num_examples, 1.0)
        )
        acc = eval_accuracy(theta_dp, prob.test).mean()
        rows.append({"eps": eps, "acc_local_dp": float(acc)})
        if verbose:
            print(f"[fig4] local-DP eps={eps}: acc {acc:.3f} (clean local {acc_clean:.3f})")
    return {"acc_local_clean": float(acc_clean), "rows": rows}


def run(out=None, fast=False, verbose=True):
    t0 = time.time()
    kw = dict(n=30, p=20, T=200) if fast else {}
    ab = fig2a_b(verbose=verbose, **({"n": 30, "p": 20, "T": 200} if fast else {}))
    c, f3 = fig2c_fig3(verbose=verbose, **({"n": 30, "dims": (10, 20), "T_per_agent": 5} if fast else {}))
    f4 = fig4_local_dp(verbose=verbose, **({"n": 30, "p": 20} if fast else {}))
    result = {"name": "fig2_privacy_utility", "fig2ab": ab, "fig2c": c, "fig3": f3,
              "fig4": f4, "elapsed_s": round(time.time() - t0, 1)}
    if out:
        with open(out, "w") as f:
            json.dump(result, f)
    return result


if __name__ == "__main__":
    run()
