"""Kernel micro-bench: Pallas (interpret on CPU; compiled on TPU) vs the
pure-jnp oracle. On this CPU container the numbers characterize the oracle
path (the Pallas timings are interpret-mode and not meaningful as TPU perf);
the bench exists so the same harness runs on real hardware unchanged.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = []

    grads = jnp.asarray(rng.normal(size=(256, 4096)), jnp.float32)
    noise = jnp.asarray(rng.laplace(size=(4096,)), jnp.float32)
    us_ref = _time(jax.jit(lambda g, n: ref.dp_clip_noise_ref(g, n, 1.0, 0.1)), grads, noise)
    rows.append(("dp_clip_noise_ref_256x4096", us_ref, "oracle jnp"))

    mix = jnp.asarray(rng.random((64, 64)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(64, 8192)), jnp.float32)
    us_ref = _time(jax.jit(ref.graph_mix_ref), mix, theta)
    rows.append(("graph_mix_ref_64x8192", us_ref, "oracle jnp"))

    G, Q, N, Pd = 8, 128, 64, 64
    C = jnp.asarray(rng.normal(size=(G, Q, N)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(G, Q, N)), jnp.float32)
    cum = jnp.asarray(np.cumsum(-np.abs(rng.normal(size=(G, Q)) * 0.1), 1), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(G, Q))), jnp.float32)
    x = jnp.asarray(rng.normal(size=(G, Q, Pd)), jnp.float32)
    us_ref = _time(jax.jit(ref.ssm_chunk_ref), C, B, cum, dt, x)
    rows.append(("ssm_chunk_ref_8x128", us_ref, "oracle jnp"))

    # Fused woken-row super-tick: B=256 woken rows, K=16 neighbours, m=4
    # data points, p=128 features over a 4096-row slab — the engine hot
    # path (gather + mix + Eq. 4 + scatter in one launch).
    Bf, K, m, p, nt = 256, 16, 4, 128, 4096
    frows = jnp.asarray(rng.choice(nt, size=Bf, replace=False).astype(np.int32))
    fidx = jnp.asarray(rng.integers(0, nt, size=(Bf, K)).astype(np.int32))
    fw = jnp.asarray(rng.random((Bf, K)), jnp.float32)
    coef = jnp.asarray(
        np.stack([np.full(Bf, 0.5), np.full(Bf, float(K)),
                  np.full(Bf, 0.1), np.full(Bf, 0.2)], 1), jnp.float32)
    fX = jnp.asarray(rng.normal(size=(Bf, m, p)), jnp.float32)
    fy = jnp.asarray(rng.normal(size=(Bf, m)), jnp.float32)
    fmask = jnp.ones((Bf, m), jnp.float32)
    fnoise = jnp.zeros((Bf, p), jnp.float32)
    ftheta = jnp.asarray(rng.normal(size=(nt, p)), jnp.float32)
    us_ref = _time(
        jax.jit(lambda *a: ref.fused_row_update_ref(*a, limit=nt)),
        frows, fidx, fw, coef, fX, fy, fmask, fnoise, ftheta)
    rows.append(("fused_row_update_ref_256x128", us_ref, "oracle jnp"))
    us_k = _time(
        lambda *a: ops.fused_row_update(*a, limit=nt),
        frows, fidx, fw, coef, fX, fy, fmask, fnoise, ftheta)
    rows.append(("fused_row_update_256x128", us_k,
                 "pallas (interpret-mode on CPU; TPU path is the engine hot loop)"))

    if verbose:
        for name, us, note in rows:
            print(f"{name},{us:.1f},{note}")
    return rows


if __name__ == "__main__":
    run()
