"""Poisson clocks, time-slotted: binomial thinning into jit-able super-ticks.

The paper's asynchrony model gives every agent an i.i.d. Poisson clock
(rate r_i = 1 in the paper; heterogeneous rates model device speed
classes). The faithful simulators replay the induced global clock one
wake-up at a time — an O(T) sequential scan. The batched engine instead
slices time into slots of duration tau and *thins* the superposed process:
over one slot, agent i rings at least once with probability

    p_i = 1 - exp(-r_i * tau)

independently across agents, so a slot's wake set is one Bernoulli draw
per agent and a whole slot compiles into a single super-tick.

Recorded deviation from pure Poisson semantics: within a slot an agent
updates **at most once** (the Binomial(1, p_i) thinning collapses multiple
rings), and all agents woken in the same slot read the same start-of-slot
snapshot (bounded staleness of one slot). Both effects vanish as
tau -> 0 (p_i ~ r_i * tau) and neither moves the fixed points — every
update is still an exact Eq. 4/6/16 block step from *some* recent state.
"""

from __future__ import annotations

import numpy as np


def normalize_rates(rates, n: int) -> np.ndarray:
    """Per-agent clock rates as a positive (n,) float64 vector (default 1)."""
    if rates is None:
        return np.ones(n, dtype=np.float64)
    r = np.broadcast_to(np.asarray(rates, dtype=np.float64), (n,)).copy()
    if np.any(r <= 0.0) or not np.all(np.isfinite(r)):
        raise ValueError("clock rates must be positive and finite")
    return r


def slot_duration(rates: np.ndarray, slot_wakes: float) -> float:
    """tau such that one slot carries ~``slot_wakes`` wake-ups in expectation.

    Exact for the superposed count (sum of Poissons with rate sum(r) * tau);
    the per-agent thinned expectation sum_i (1 - exp(-r_i tau)) is slightly
    below it — the collapsed-multiple-rings deviation recorded above.
    """
    if slot_wakes <= 0:
        raise ValueError("slot_wakes must be positive")
    return float(slot_wakes) / float(rates.sum())


def wake_probs(rates: np.ndarray, tau: float) -> np.ndarray:
    """p_i = 1 - exp(-r_i * tau): per-slot wake probability per agent."""
    return -np.expm1(-rates * tau)


def expected_wakes(rates: np.ndarray, tau: float) -> float:
    """Expected thinned wake count per slot: sum_i p_i."""
    return float(wake_probs(rates, tau).sum())


def default_batch_size(rates: np.ndarray, tau: float) -> int:
    """Static woken-rows batch size B with negligible overflow probability.

    The wake count is Poisson-binomial with mean mu = sum p_i and variance
    <= mu; mean + 6 sigma (+ slack for tiny mu) keeps P(overflow) ~ 1e-9.
    Overflowing wakes are dropped and counted (``SimResult.wakes_dropped``).
    """
    mu = expected_wakes(rates, tau)
    b = int(np.ceil(mu + 6.0 * np.sqrt(mu) + 8.0))
    n = len(rates)
    return int(min(max(b, 8), n))
