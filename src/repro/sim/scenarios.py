"""Deployment scenarios for the batched async engine: churn, delay, stragglers.

Real P2P deployments (P4, arXiv 2405.17697; P4L, arXiv 2302.13438) are
defined by exactly what the faithful Poisson simulator does not model:
devices joining and leaving mid-training, messages arriving late, and
slow devices whose contributions are lost. Each knob here is a small
frozen config consumed by :class:`repro.sim.AsyncEngine`; all of them are
per-slot processes so they compile into the super-tick.

Semantics (recorded deviations / modelling choices):

* **Churn** — a two-state Markov chain per agent: active agents depart
  with per-slot probability ``leave_prob`` and departed agents rejoin
  with ``rejoin_prob`` (either may be a per-agent array; a degenerate
  prob of 1.0 gives deterministic schedules for tests). Departed agents
  never wake, so their parameters freeze; neighbours keep mixing the
  departed agent's *last broadcast* model — the retained-cache semantics
  already used by ``dp_cd`` when a budget-exhausted agent stops ("it
  keeps broadcasting its last iterate implicitly since neighbours retain
  it").
* **Delay** — per-edge constant message delay measured in slots: agent i
  mixing from neighbour j reads j's model as of ``delay[i, k]`` slots ago
  (a ring-buffered history of start-of-slot snapshots). Constant per-edge
  delay makes every channel FIFO by construction — messages are applied
  in send order, never reordered. Delay 0 reads the current start-of-slot
  snapshot.
* **Stragglers** — a woken agent misses its slot with probability
  ``drop_prob`` (scalar or per-agent): the device rang but was too slow
  to complete the update, so nothing is computed, applied, or charged.
  Statistically this is equivalent to thinning that agent's effective
  clock rate by ``1 - drop_prob``; it exists as a separate knob so that
  device speed classes (``rates``) and loss processes (``drop_prob``)
  can be configured and swept independently.
* **Arrival** — agents the topology has never seen join mid-run at
  scheduled slots, attach to established peers, and (optionally) warm
  start from the Eq. 16 model-propagation step over their new
  neighbours; see :class:`ArrivalConfig`. Requires the engine's
  dynamic-topology mode (it is a structural graph change).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _prob_vector(p, n: int, name: str) -> np.ndarray:
    v = np.broadcast_to(np.asarray(p, dtype=np.float64), (n,)).copy()
    if np.any(v < 0.0) or np.any(v > 1.0):
        raise ValueError(f"{name} must lie in [0, 1]")
    return v


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Per-slot join/leave process. Scalars broadcast to all agents."""

    leave_prob: float | np.ndarray = 0.01
    rejoin_prob: float | np.ndarray = 0.2

    def leave_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot departure probabilities (scalars broadcast)."""
        return _prob_vector(self.leave_prob, n, "leave_prob")

    def rejoin_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot rejoin probabilities for departed agents."""
        return _prob_vector(self.rejoin_prob, n, "rejoin_prob")


@dataclasses.dataclass(frozen=True)
class DelayConfig:
    """Per-edge message delay in slots.

    ``edge_delays``: scalar, or an (n, K) array aligned with the engine's
    padded neighbour tiles (K = max degree; entry [i, k] delays the
    message from agent i's k-th neighbour). Values clip to
    ``[0, max_delay]``; ``max_delay`` sizes the snapshot history ring.
    """

    max_delay: int = 1
    edge_delays: int | np.ndarray = 1

    def delay_tiles(self, idx_shape: tuple[int, int]) -> np.ndarray:
        """(n, K) per-edge delays in slots, aligned with the neighbour
        tiles of shape ``idx_shape`` and clipped to ``[0, max_delay]``."""
        if self.max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        d = np.broadcast_to(
            np.asarray(self.edge_delays, dtype=np.int32), idx_shape
        ).copy()
        if np.any(d < 0):
            raise ValueError("edge delays must be >= 0")
        return np.minimum(d, self.max_delay).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    """Per-slot missed-wake process for woken agents (see module docstring)."""

    drop_prob: float | np.ndarray = 0.1

    def drop_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot missed-wake probabilities (scalars broadcast)."""
        return _prob_vector(self.drop_prob, n, "drop_prob")


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    """Agents *arriving* mid-run: they join the graph and start learning.

    Where :class:`ChurnConfig` models departure/rejoin of agents the
    graph already knows, arrival adds agents the topology has never
    seen. The engine holds the scheduled ids inactive (never woken,
    weight-0 edges) until their slot, then attaches them to the live
    graph and — with ``warm_start`` — initializes their model by the
    Eq. 16 model-propagation step with confidence ``c_i = 0``: a pure
    weighted neighbour average, iterated ``warm_rounds`` times. That is
    exactly the propagation fixed-point semantics for an agent with no
    local data yet (arXiv 1610.05202); a cold start keeps the agent's
    initial row instead.

    ``schedule``: tuple of ``(slot, ids)`` pairs in absolute slot-counter
    terms — at the *start* of that slot the listed agents join.
    ``attach``: optional explicit ``{agent id: (neighbour ids,)}`` map;
    ids without an entry attach to ``attach_k`` established agents drawn
    deterministically from ``seed``. Edge changes land at slot
    boundaries, like every topology update (see docs/DEVIATIONS.md).
    """

    schedule: tuple[tuple[int, tuple[int, ...]], ...] = ()
    attach_k: int = 4
    attach_weight: float = 1.0
    attach: dict | None = None
    warm_start: bool = True
    warm_rounds: int = 2
    seed: int = 0

    def __post_init__(self):
        seen: set[int] = set()
        for slot, ids in self.schedule:
            if slot < 1:
                raise ValueError(
                    f"arrival slots are 1-based slot counts, got {slot}"
                )
            dup = seen.intersection(ids)
            if dup:
                raise ValueError(f"agents scheduled to arrive twice: {sorted(dup)}")
            seen.update(ids)
        if self.attach_k < 1:
            raise ValueError("attach_k must be >= 1")
        if self.warm_rounds < 1:
            raise ValueError("warm_rounds must be >= 1")

    def all_ids(self) -> tuple[int, ...]:
        """Every agent id that arrives at some point, schedule order."""
        return tuple(i for _, ids in self.schedule for i in ids)

    def by_slot(self) -> dict[int, tuple[int, ...]]:
        """{slot: ids arriving at its start}, merged across schedule entries."""
        out: dict[int, tuple[int, ...]] = {}
        for slot, ids in self.schedule:
            out[slot] = out.get(slot, ()) + tuple(ids)
        return dict(sorted(out.items()))

    def neighbors_for(self, agent: int, established, rng) -> np.ndarray:
        """Attachment targets for ``agent``: explicit map or random draw.

        ``established``: (m,) candidate ids (active, already-joined
        agents). The random draw is without replacement, capped at the
        candidate count.
        """
        if self.attach and agent in self.attach:
            return np.asarray(self.attach[agent], dtype=np.int64)
        established = np.asarray(established, dtype=np.int64)
        k = min(self.attach_k, len(established))
        if k < 1:
            raise ValueError(f"no established agents for arrival of {agent}")
        return rng.choice(established, size=k, replace=False)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Bundle of deployment conditions; ``None`` disables a dimension."""

    churn: ChurnConfig | None = None
    delay: DelayConfig | None = None
    straggler: StragglerConfig | None = None
    arrival: ArrivalConfig | None = None

    @staticmethod
    def ideal() -> "Scenario":
        """No churn, no delay, no stragglers — the pure thinned-clock model."""
        return Scenario()
