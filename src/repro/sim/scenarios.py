"""Deployment scenarios for the batched async engine: churn, delay, stragglers.

Real P2P deployments (P4, arXiv 2405.17697; P4L, arXiv 2302.13438) are
defined by exactly what the faithful Poisson simulator does not model:
devices joining and leaving mid-training, messages arriving late, and
slow devices whose contributions are lost. Each knob here is a small
frozen config consumed by :class:`repro.sim.AsyncEngine`; all of them are
per-slot processes so they compile into the super-tick.

Semantics (recorded deviations / modelling choices):

* **Churn** — a two-state Markov chain per agent: active agents depart
  with per-slot probability ``leave_prob`` and departed agents rejoin
  with ``rejoin_prob`` (either may be a per-agent array; a degenerate
  prob of 1.0 gives deterministic schedules for tests). Departed agents
  never wake, so their parameters freeze; neighbours keep mixing the
  departed agent's *last broadcast* model — the retained-cache semantics
  already used by ``dp_cd`` when a budget-exhausted agent stops ("it
  keeps broadcasting its last iterate implicitly since neighbours retain
  it").
* **Delay** — per-edge constant message delay measured in slots: agent i
  mixing from neighbour j reads j's model as of ``delay[i, k]`` slots ago
  (a ring-buffered history of start-of-slot snapshots). Constant per-edge
  delay makes every channel FIFO by construction — messages are applied
  in send order, never reordered. Delay 0 reads the current start-of-slot
  snapshot.
* **Stragglers** — a woken agent misses its slot with probability
  ``drop_prob`` (scalar or per-agent): the device rang but was too slow
  to complete the update, so nothing is computed, applied, or charged.
  Statistically this is equivalent to thinning that agent's effective
  clock rate by ``1 - drop_prob``; it exists as a separate knob so that
  device speed classes (``rates``) and loss processes (``drop_prob``)
  can be configured and swept independently.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _prob_vector(p, n: int, name: str) -> np.ndarray:
    v = np.broadcast_to(np.asarray(p, dtype=np.float64), (n,)).copy()
    if np.any(v < 0.0) or np.any(v > 1.0):
        raise ValueError(f"{name} must lie in [0, 1]")
    return v


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Per-slot join/leave process. Scalars broadcast to all agents."""

    leave_prob: float | np.ndarray = 0.01
    rejoin_prob: float | np.ndarray = 0.2

    def leave_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot departure probabilities (scalars broadcast)."""
        return _prob_vector(self.leave_prob, n, "leave_prob")

    def rejoin_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot rejoin probabilities for departed agents."""
        return _prob_vector(self.rejoin_prob, n, "rejoin_prob")


@dataclasses.dataclass(frozen=True)
class DelayConfig:
    """Per-edge message delay in slots.

    ``edge_delays``: scalar, or an (n, K) array aligned with the engine's
    padded neighbour tiles (K = max degree; entry [i, k] delays the
    message from agent i's k-th neighbour). Values clip to
    ``[0, max_delay]``; ``max_delay`` sizes the snapshot history ring.
    """

    max_delay: int = 1
    edge_delays: int | np.ndarray = 1

    def delay_tiles(self, idx_shape: tuple[int, int]) -> np.ndarray:
        """(n, K) per-edge delays in slots, aligned with the neighbour
        tiles of shape ``idx_shape`` and clipped to ``[0, max_delay]``."""
        if self.max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        d = np.broadcast_to(
            np.asarray(self.edge_delays, dtype=np.int32), idx_shape
        ).copy()
        if np.any(d < 0):
            raise ValueError("edge delays must be >= 0")
        return np.minimum(d, self.max_delay).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    """Per-slot missed-wake process for woken agents (see module docstring)."""

    drop_prob: float | np.ndarray = 0.1

    def drop_vector(self, n: int) -> np.ndarray:
        """(n,) per-slot missed-wake probabilities (scalars broadcast)."""
        return _prob_vector(self.drop_prob, n, "drop_prob")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Bundle of deployment conditions; ``None`` disables a dimension."""

    churn: ChurnConfig | None = None
    delay: DelayConfig | None = None
    straggler: StragglerConfig | None = None

    @staticmethod
    def ideal() -> "Scenario":
        """No churn, no delay, no stragglers — the pure thinned-clock model."""
        return Scenario()
