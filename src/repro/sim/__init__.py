# Batched asynchronous simulation engine: Poisson-thinned super-ticks with
# churn / delay / straggler scenarios, driving CD, DP-CD, and model
# propagation through one LocalUpdate protocol. The architectural bridge
# between the faithful O(T) simulator (repro.core.coordinate_descent) and
# the synchronous SPMD scale layer (repro.core.spmd). See engine.py's
# docstring for the recorded deviations from pure Poisson semantics.
from repro.sim.clocks import (
    default_batch_size,
    expected_wakes,
    normalize_rates,
    slot_duration,
    wake_probs,
)
from repro.sim.engine import (
    AsyncEngine,
    ShardedAsyncEngine,
    ShardedSimState,
    SimResult,
    SimState,
)
from repro.sim.partition import GraphPartition, partition_graph
from repro.sim.scenarios import ChurnConfig, DelayConfig, Scenario, StragglerConfig
from repro.sim.updates import CDUpdate, DPCDUpdate, LocalUpdate, PropagationUpdate

__all__ = [
    "AsyncEngine",
    "GraphPartition",
    "ShardedAsyncEngine",
    "ShardedSimState",
    "partition_graph",
    "CDUpdate",
    "ChurnConfig",
    "DelayConfig",
    "DPCDUpdate",
    "LocalUpdate",
    "PropagationUpdate",
    "Scenario",
    "SimResult",
    "SimState",
    "StragglerConfig",
    "default_batch_size",
    "expected_wakes",
    "normalize_rates",
    "slot_duration",
    "wake_probs",
]
