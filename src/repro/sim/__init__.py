"""Batched asynchronous simulation engine.

Poisson-thinned super-ticks with churn / delay / straggler scenarios,
driving CD, DP-CD, and model propagation through one ``LocalUpdate``
protocol. The architectural bridge between the faithful O(T) simulator
(``repro.core.coordinate_descent``) and the synchronous SPMD scale layer
(``repro.core.spmd``); ``ShardedAsyncEngine`` spreads the agent blocks —
models, datasets, and theory constants alike — over a device mesh with
locality-aware partitioning (``partition.py``) and a halo exchange
(``repro.core.mixing.ShardedMixOp``). See ``docs/ARCHITECTURE.md`` for
the module map and ``docs/DEVIATIONS.md`` for the consolidated ledger of
recorded deviations from pure Poisson semantics.
"""

from repro.core.mixing import ExchangeSpec
from repro.sim.clocks import (
    default_batch_size,
    expected_wakes,
    normalize_rates,
    slot_duration,
    wake_probs,
)
from repro.sim.config import EngineConfig, make_engine
from repro.sim.engine import (
    AsyncEngine,
    ShardedAsyncEngine,
    ShardedSimState,
    SimResult,
    SimState,
)
from repro.sim.partition import (
    GraphPartition,
    hilbert_order,
    partition_graph,
    point_to_point_plan,
    rcm_order,
    sfc_order,
)
from repro.sim.scenarios import (
    ArrivalConfig,
    ChurnConfig,
    DelayConfig,
    Scenario,
    StragglerConfig,
)
from repro.sim.updates import (
    CDUpdate,
    DPCDUpdate,
    GraphUpdate,
    LocalUpdate,
    PropagationUpdate,
)

# Curated public surface: engines + their config, the update rules, the
# scenario bundles, partitioning, and the clock helpers. Everything else
# in the submodules is implementation detail.
__all__ = [
    # engines and configuration
    "AsyncEngine",
    "EngineConfig",
    "ExchangeSpec",
    "ShardedAsyncEngine",
    "ShardedSimState",
    "SimResult",
    "SimState",
    "make_engine",
    # update rules
    "CDUpdate",
    "DPCDUpdate",
    "GraphUpdate",
    "LocalUpdate",
    "PropagationUpdate",
    # scenarios
    "ArrivalConfig",
    "ChurnConfig",
    "DelayConfig",
    "Scenario",
    "StragglerConfig",
    # partitioning and relabels
    "GraphPartition",
    "hilbert_order",
    "partition_graph",
    "point_to_point_plan",
    "rcm_order",
    "sfc_order",
    # clock helpers
    "default_batch_size",
    "expected_wakes",
    "normalize_rates",
    "slot_duration",
    "wake_probs",
]
