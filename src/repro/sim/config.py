"""Typed engine configuration shared by both simulation engines.

The PR-4 constructors had grown 10–14 positional-ish kwargs each, with
the sharded engine's placement knobs (partition mode, relabel, coords,
exchange method) mixed into the same flat list as the clock/scenario
knobs. :class:`EngineConfig` collapses them into one frozen dataclass
that both :class:`repro.sim.AsyncEngine` and
:class:`repro.sim.ShardedAsyncEngine` accept (``config=...``), with the
old kwargs kept working as overrides (``AsyncEngine(update,
slot_wakes=8.0)`` merges into the default config). :func:`make_engine`
is the one-call factory: shards absent/0 builds the single-device
engine, otherwise the sharded one.

Placement fields (``partition_mode``/``relabel``/``coords``/
``exchange``/``partition``/``devices``) are no-ops on the single-device
engine, so one config can drive both sides of a parity test.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core.mixing import ExchangeSpec
from repro.sim.scenarios import Scenario


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything an engine run needs besides the update rule itself.

    Clock / batching / scenario (both engines):

    * ``slot_wakes``: expected wake-ups per super-tick (sets tau);
    * ``rates``: per-agent Poisson rates (None = all 1.0);
    * ``batch_size``: static woken-rows batch B (None = mean + 6 sigma);
    * ``scenario``: churn / delay / straggler bundle (None = none);
    * ``seed`` / ``dtype`` / ``steps_per_chunk``: PRNG seed, model dtype,
      super-ticks per jitted scan chunk;
    * ``fused``: woken-row hot-path selection — ``"auto"`` engages the
      fused Pallas kernel on TPU for f32 quadratic-loss updates at
      on-chip slab sizes (``REPRO_KERNEL_MAX_N``), ``True`` forces it
      (interpreted off-TPU; tests), ``False`` keeps the unfused
      gather/mix/update/scatter ops;
    * ``metrics``: in-jit telemetry — a
      :class:`repro.obs.MetricsSpec` selecting counter groups, ``True``
      for the default spec, ``None``/``False`` (default) for no
      collection. Metrics-on runs are bit-exact in Theta vs metrics-off.

    Placement / exchange (sharded engine only; ignored at S=1):

    * ``partition_mode``: ``"degree"`` | ``"contiguous"`` block cutting;
    * ``relabel``: ``"rcm"`` | ``"sfc"`` | ``"hilbert"`` | explicit
      permutation | None;
    * ``coords``: (n, 2) agent positions for the space-filling-curve
      relabels;
    * ``exchange``: :class:`repro.core.mixing.ExchangeSpec` (None =
      defaults; deprecated bare strings still coerce);
    * ``partition``: a prebuilt ``GraphPartition`` to reuse;
    * ``devices``: explicit device list for the mesh.

    Dynamic topology (both engines; the sharded engine adds the
    repartition policy):

    * ``graph_update``: a :class:`repro.sim.updates.GraphUpdate` firing
      a Dada-style edge refresh every ``graph_update.every`` slots
      (None = static topology, the default — and the bit-exactness
      anchor: a static-topology run is byte-identical to the
      pre-dynamic engines);
    * ``drift_threshold``: sharded repartition trigger. After each
      structural topology change the engine measures
      :meth:`repro.sim.partition.GraphPartition.drift`; at or below the
      threshold it patches the existing cut
      (:meth:`GraphPartition.patch`, ownership frozen), above it it
      pays for a full ``partition_graph`` rebuild.
    """

    slot_wakes: float = 64.0
    rates: Any = None
    batch_size: int | None = None
    scenario: Scenario | None = None
    seed: int = 0
    dtype: Any = jnp.float32
    steps_per_chunk: int = 16
    fused: Any = "auto"  # False | True | "auto"
    metrics: Any = None  # MetricsSpec | True | False | None
    partition_mode: str = "degree"
    relabel: Any = None
    coords: Any = None
    exchange: Any = None  # ExchangeSpec | deprecated str | None
    partition: Any = None
    devices: Any = None
    graph_update: Any = None  # GraphUpdate | None (None = static topology)
    drift_threshold: float = 0.25

    def __post_init__(self):
        if self.fused not in (False, True, "auto"):
            raise ValueError(f"fused must be False, True, or 'auto', got {self.fused!r}")

    def exchange_spec(self) -> ExchangeSpec:
        """The coerced exchange spec (warns on deprecated bare strings)."""
        return ExchangeSpec.coerce(self.exchange)

    def metrics_spec(self):
        """The coerced telemetry spec (None = collection off, the default)."""
        from repro.obs.metrics import MetricsSpec

        return MetricsSpec.coerce(self.metrics)

    def replace(self, **overrides) -> "EngineConfig":
        """A copy with the given fields replaced (dataclasses.replace)."""
        return dataclasses.replace(self, **overrides)


def resolve_config(config: EngineConfig | None, overrides: dict) -> EngineConfig:
    """Merge constructor ``**kwargs`` overrides into a (default) config."""
    base = config if config is not None else EngineConfig()
    if not overrides:
        return base
    try:
        return dataclasses.replace(base, **overrides)
    except TypeError as e:
        raise TypeError(f"unknown engine option(s) in {sorted(overrides)}: {e}") from None


def make_engine(update, config: EngineConfig | None = None, *, shards=None, **overrides):
    """Build the right engine for ``shards``: None/0 -> single-device
    :class:`AsyncEngine`, otherwise :class:`ShardedAsyncEngine` on that
    many mesh devices. ``overrides`` replace fields of ``config``."""
    from repro.sim.engine import AsyncEngine, ShardedAsyncEngine

    cfg = resolve_config(config, overrides)
    if not shards:
        return AsyncEngine(update, config=cfg)
    return ShardedAsyncEngine(update, num_shards=int(shards), config=cfg)
