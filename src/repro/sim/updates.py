"""The ``LocalUpdate`` protocol: one engine, three algorithms.

The batched engine (:class:`repro.sim.AsyncEngine`) owns time, wake
sampling, scenarios, and the gather/mix/scatter plumbing; what a woken
agent *does* with its neighbour sum is delegated to a ``LocalUpdate``:

* :class:`CDUpdate` — the non-private Eq. 4 block step;
* :class:`DPCDUpdate` — the Eq. 6 private step with per-agent uniform
  budget split and accountant-style stopping (a budget-exhausted agent
  wakes but applies nothing, exactly like ``dp_cd.run_private``'s
  inactive ticks);
* :class:`PropagationUpdate` — the Eq. 16 exact block minimizer of model
  propagation (Supp. C), data-free and so compatible with the private
  warm start.

All three reduce to the same contract: given the start-of-slot snapshot,
the woken row indices (padded with the sentinel n), and their raw
neighbour sums, return replacement rows plus an ``applied`` mask. The
math lives next to its sequential twin (``eq4_theta_rows_from`` in
``coordinate_descent``, ``propagation_rows_from`` in
``model_propagation``) so the two execution paths cannot drift apart.

For the sharded engine, each update also exposes ``agent_constants`` —
the pytree of per-agent arrays (datasets, theory constants, noise
scales) its row step reads. The engine tiles those along the agent
blocks and hands the row-gathered slice back through ``apply_rows``'s
``consts`` argument, so the sharded super-tick never closes over a
replicated (n, ...) array; ``consts=None`` (the single-device path)
falls back to gathering from the replicated arrays, elementwise-equal
by construction.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy
from repro.core.coordinate_descent import (
    eq4_agent_constants,
    eq4_theta_rows,
    eq4_theta_rows_from,
)
from repro.core.dp_cd import DPConfig, uniform_noise_plan
from repro.core.mixing import MixOp, mix_op
from repro.core.model_propagation import (
    propagation_objective,
    propagation_rows,
    propagation_rows_from,
)
from repro.core.objective import Objective


def _eq4_fused_slab(obj, Theta_slab, krows, cols, w, consts, noise, limit, interpret):
    """Run the fused Pallas kernel for one Eq. 4/6 woken batch.

    ``consts`` is the row-gathered :func:`eq4_agent_constants` slice
    (each leaf (B, ...)); the per-row coefficient pack mirrors the
    unfused ``eq4_theta_rows_from`` term grouping exactly —
    ``[alpha, deg, mu * conf, 2 * lam]`` — so the two paths differ only
    in f32 reduction order (recorded in docs/DEVIATIONS.md).
    """
    from repro.kernels import ops

    f32 = jnp.float32
    coef = jnp.stack(
        [
            jnp.asarray(consts["alpha"], f32),
            jnp.asarray(consts["deg"], f32),
            jnp.asarray(obj.mu, f32) * jnp.asarray(consts["conf"], f32),
            2.0 * jnp.asarray(consts["lam"], f32),
        ],
        axis=1,
    )
    return ops.fused_row_update(
        krows,
        cols,
        w,
        coef,
        jnp.asarray(consts["X"], f32),
        jnp.asarray(consts["y"], f32),
        jnp.asarray(consts["mask"], f32),
        noise,
        Theta_slab,
        limit=limit,
        clip=None if obj.clip is None else float(obj.clip),
        interpret=interpret,
    )


@runtime_checkable
class LocalUpdate(Protocol):
    """What the engine needs from an update rule.

    ``apply`` runs inside the jitted super-tick: ``rows`` is the (B,)
    woken index batch (padding sentinel n, which gathers clamp and the
    engine's scatter drops), ``valid`` its (B,) realness mask, ``neigh``
    the (B, p) raw neighbour sums from the (possibly delayed) snapshot.
    It returns ``(new_rows, applied, state)`` — only rows with
    ``applied[b]`` True are scattered back and charged messages.

    ``apply_rows`` is the same step for the sharded engine, which holds
    only its local Theta block: ``theta_rows`` is pre-gathered, ``rows``
    stays *global* (sentinel n), and the state pytree is this shard's
    slice, gathered and scattered at the local indices ``srows`` with
    sentinel ``ssize``. ``consts``, when given, is the row-gathered
    slice of :meth:`agent_constants` (each leaf (B, ...), row-aligned
    with ``theta_rows``) — the shard-resident replacement for indexing
    the replicated per-agent arrays with ``rows``. ``apply`` delegates
    to it with ``srows=rows, ssize=n, consts=None``, so the two
    execution paths cannot drift apart.
    """

    @property
    def n(self) -> int:
        """Number of agents."""
        ...

    @property
    def p(self) -> int:
        """Model dimension per agent."""
        ...

    @property
    def graph(self):
        """The collaboration graph (dense or CSR)."""
        ...

    @property
    def mix(self) -> MixOp:
        """The neighbour-sum operator over :attr:`graph`."""
        ...

    def init_state(self):
        """The initial update-state pytree (per-agent leaves, leading dim n)."""
        ...

    def agent_constants(self):
        """Per-agent constant arrays (leading dim n) the row step reads.

        The sharded engine tiles this pytree into (S, R, ...) blocks so
        dataset memory scales with the shard count; leaves keep their
        original dtypes (consumers cast after gathering).
        """
        ...

    def apply(self, Theta, rows, valid, neigh, key, state):
        """One batched update against the global (n, p) snapshot."""
        ...

    def apply_rows(
        self, theta_rows, rows, valid, neigh, key, state, srows=None, ssize=None, consts=None
    ):
        """One batched update from pre-gathered rows (see class docstring)."""
        ...


@dataclasses.dataclass(frozen=True, eq=False)
class CDUpdate:
    """Non-private Eq. 4 coordinate-descent block step."""

    obj: Objective

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.obj.n

    @property
    def p(self) -> int:
        """Model dimension per agent."""
        return self.obj.p

    @property
    def graph(self):
        """The collaboration graph of the objective."""
        return self.obj.graph

    @property
    def mix(self) -> MixOp:
        """The objective's neighbour-sum operator."""
        return self.obj.mix

    def init_state(self):
        """Stateless: the empty pytree."""
        return ()

    def agent_constants(self):
        """Eq. 4 constants + padded per-agent datasets (see ``eq4_agent_constants``)."""
        return eq4_agent_constants(self.obj)

    def apply(self, Theta, rows, valid, neigh, key, state):
        """Gather the woken rows from the global snapshot and update them."""
        return self.apply_rows(Theta[rows], rows, valid, neigh, key, state)

    def apply_rows(
        self, theta_rows, rows, valid, neigh, key, state, srows=None, ssize=None, consts=None
    ):
        """Batched Eq. 4 step; ``consts`` selects the shard-resident path."""
        if consts is None:
            new_rows = eq4_theta_rows(self.obj, theta_rows, rows, neigh)
        else:
            new_rows = eq4_theta_rows_from(self.obj, theta_rows, neigh, consts)
        return new_rows, valid, state

    @property
    def fused_supported(self) -> bool:
        """The fused kernel implements the quadratic point grad only."""
        return self.obj.loss.name == "quadratic"

    def apply_fused(
        self,
        Theta_slab,
        rows,
        valid,
        key,
        state,
        cols,
        w,
        srows=None,
        ssize=None,
        consts=None,
        interpret=None,
    ):
        """Fused-kernel Eq. 4 step over a theta slab (single launch).

        ``Theta_slab``: the (nt, p) slab the kernel gathers from and
        scatters into (single-device: the full Theta; sharded: the
        halo-extended block). ``rows``: (B,) *global* agent ids (sentinel
        n) used to gather constants on the replicated path; ``cols``/
        ``w``: (B, K) row-gathered neighbour tables addressing the slab;
        ``srows``/``ssize``: local scatter rows and their sentinel
        (default ``rows``/``n``); ``consts``: shard-resident constant
        slice as in :meth:`apply_rows`. Returns the updated slab (f32),
        the applied mask, and the state.
        """
        if not self.fused_supported:
            raise NotImplementedError(
                f"fused path supports the quadratic loss only, got {self.obj.loss.name!r}"
            )
        if srows is None:
            srows, ssize = rows, self.n
        if consts is None:
            safe = jnp.minimum(rows, self.n - 1)
            consts = jax.tree.map(lambda a: jnp.asarray(a)[safe], eq4_agent_constants(self.obj))
        krows = jnp.where(valid, srows, ssize)
        noise = jnp.zeros((srows.shape[0], Theta_slab.shape[1]), jnp.float32)
        new_slab = _eq4_fused_slab(
            self.obj, Theta_slab, krows, cols, w, consts, noise, ssize, interpret
        )
        return new_slab, valid, state

    def objective(self, Theta) -> float:
        """Q(Theta) of Eq. 2 (used by ``record_every``)."""
        return float(self.obj.value(Theta))


@dataclasses.dataclass(frozen=True, eq=False)
class DPCDUpdate:
    """Eq. 6 private step with per-agent budget stopping.

    Build via :meth:`plan`. Each agent splits ``(eps_bar, delta_bar)``
    equally over ``planned_Ti`` expected wake-ups (Thm. 1 composition
    inversion, shared with ``dp_cd.uniform_noise_plan``) and freezes once
    they are spent. State is the (n,) count of applied private updates;
    :meth:`eps_spent` composes it back into per-agent spend.

    Recorded deviation: only the uniform schedule is supported — the
    Prop. 2 decreasing schedule indexes the *global sequential* tick,
    which a batched slot does not expose (use ``dp_cd.run_private``).
    """

    obj: Objective
    cfg: DPConfig
    planned_Ti: int
    eps_step: float
    scales: np.ndarray  # (n,) per-agent constant noise scale

    @classmethod
    def plan(cls, obj: Objective, cfg: DPConfig, planned_Ti: int) -> "DPCDUpdate":
        """Plan the per-agent uniform budget split for ``planned_Ti`` wake-ups."""
        if cfg.schedule != "uniform":
            raise NotImplementedError(
                "the batched engine supports the uniform budget split only; "
                "the Prop. 2 schedule needs the sequential driver dp_cd.run_private"
            )
        eps_step, scales = uniform_noise_plan(obj, cfg, planned_Ti)
        return cls(obj=obj, cfg=cfg, planned_Ti=planned_Ti, eps_step=eps_step, scales=scales)

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.obj.n

    @property
    def p(self) -> int:
        """Model dimension per agent."""
        return self.obj.p

    @property
    def graph(self):
        """The collaboration graph of the objective."""
        return self.obj.graph

    @property
    def mix(self) -> MixOp:
        """The objective's neighbour-sum operator."""
        return self.obj.mix

    def init_state(self):
        """(n,) int32 count of applied private updates per agent."""
        return jnp.zeros(self.n, dtype=jnp.int32)

    def agent_constants(self):
        """Eq. 4 constants + the (n,) per-agent noise scales."""
        return {**eq4_agent_constants(self.obj), "scales": self.scales}

    def apply(self, Theta, rows, valid, neigh, key, state):
        """Gather the woken rows (sentinel-clamped) and privately update them."""
        return self.apply_rows(
            Theta[jnp.minimum(rows, self.n - 1)], rows, valid, neigh, key, state
        )

    def apply_rows(
        self, theta_rows, rows, valid, neigh, key, state, srows=None, ssize=None, consts=None
    ):
        """Batched Eq. 6 step with budget stopping; ``consts`` selects the
        shard-resident path (noise scales included in the pytree)."""
        n = self.n
        if srows is None:
            srows, ssize = rows, n
        dt = theta_rows.dtype
        counts = state[jnp.minimum(srows, ssize - 1)]
        applied = valid & (counts < self.planned_Ti)
        if self.cfg.mechanism == "gaussian":
            draws = jax.random.normal(key, shape=neigh.shape, dtype=dt)
        else:
            draws = jax.random.laplace(key, shape=neigh.shape, dtype=dt)
        if consts is None:
            scales_rows = jnp.asarray(self.scales, dt)[jnp.minimum(rows, n - 1)]
            noise = draws * scales_rows[:, None]
            new_rows = eq4_theta_rows(self.obj, theta_rows, rows, neigh, grad_noise=noise)
        else:
            noise = draws * jnp.asarray(consts["scales"], dt)[:, None]
            new_rows = eq4_theta_rows_from(self.obj, theta_rows, neigh, consts, grad_noise=noise)
        state = state.at[jnp.where(applied, srows, ssize)].add(1, mode="drop")
        return new_rows, applied, state

    @property
    def fused_supported(self) -> bool:
        """The fused kernel implements the quadratic point grad only."""
        return self.obj.loss.name == "quadratic"

    def apply_fused(
        self,
        Theta_slab,
        rows,
        valid,
        key,
        state,
        cols,
        w,
        srows=None,
        ssize=None,
        consts=None,
        interpret=None,
    ):
        """Fused-kernel Eq. 6 step: the budget-stopping/noise logic of
        :meth:`apply_rows` with the row math in one kernel launch —
        budget-exhausted agents become kernel sentinels, so their stale
        slab row survives exactly like the unfused drop-mode scatter."""
        if not self.fused_supported:
            raise NotImplementedError(
                f"fused path supports the quadratic loss only, got {self.obj.loss.name!r}"
            )
        n = self.n
        if srows is None:
            srows, ssize = rows, n
        counts = state[jnp.minimum(srows, ssize - 1)]
        applied = valid & (counts < self.planned_Ti)
        f32 = jnp.float32
        if self.cfg.mechanism == "gaussian":
            draws = jax.random.normal(key, (srows.shape[0], Theta_slab.shape[1]), f32)
        else:
            draws = jax.random.laplace(key, (srows.shape[0], Theta_slab.shape[1]), f32)
        if consts is None:
            safe = jnp.minimum(rows, n - 1)
            consts = jax.tree.map(
                lambda a: jnp.asarray(a)[safe],
                {**eq4_agent_constants(self.obj), "scales": self.scales},
            )
        noise = draws * jnp.asarray(consts["scales"], f32)[:, None]
        krows = jnp.where(applied, srows, ssize)
        new_slab = _eq4_fused_slab(
            self.obj, Theta_slab, krows, cols, w, consts, noise, ssize, interpret
        )
        state = state.at[jnp.where(applied, srows, ssize)].add(1, mode="drop")
        return new_slab, applied, state

    def eps_spent(self, state) -> np.ndarray:
        """(n,) composed per-agent spend for the applied-update counts."""
        return privacy.compose_uniform(
            self.eps_step, np.asarray(state), self.cfg.delta_bar
        )

    def budget_stopped(self, state) -> int:
        """Agents whose planned per-agent update budget T_i is exhausted.

        The host-side ground truth the ``dp_budget_stopped`` telemetry
        gauge is tested against (``tests/test_obs.py``).
        """
        return int((np.asarray(state) >= self.planned_Ti).sum())

    def objective(self, Theta) -> float:
        """Q(Theta) of Eq. 2 (used by ``record_every``)."""
        return float(self.obj.value(Theta))


@dataclasses.dataclass(frozen=True, eq=False)
class PropagationUpdate:
    """Eq. 16 model propagation (Supp. C) as an engine update rule."""

    graph: object
    theta_loc: np.ndarray
    mu: float
    confidences: np.ndarray
    mix_mode: str = "auto"

    @cached_property
    def mix(self) -> MixOp:
        """The neighbour-sum operator over :attr:`graph` (built lazily)."""
        return mix_op(self.graph, mode=self.mix_mode)

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.graph.n

    @property
    def p(self) -> int:
        """Model dimension per agent."""
        return self.theta_loc.shape[1]

    def init_state(self):
        """Stateless: the empty pytree."""
        return ()

    def agent_constants(self):
        """Degrees, confidences, and the (n, p) local models Eq. 16 reads."""
        return {"deg": self.graph.degrees, "conf": self.confidences, "loc": self.theta_loc}

    def apply(self, Theta, rows, valid, neigh, key, state):
        """Gather the woken rows from the global snapshot and update them."""
        return self.apply_rows(Theta[rows], rows, valid, neigh, key, state)

    def apply_rows(
        self, theta_rows, rows, valid, neigh, key, state, srows=None, ssize=None, consts=None
    ):
        """Batched Eq. 16 exact block minimizer; ``theta_rows`` is unused —
        the update reads only the neighbour sum and the local models."""
        if consts is None:
            new_rows = propagation_rows(
                self.graph.degrees, self.theta_loc, self.mu, self.confidences, rows, neigh
            )
        else:
            new_rows = propagation_rows_from(
                self.mu, consts["deg"], consts["conf"], consts["loc"], neigh
            )
        return new_rows, valid, state

    def objective(self, Theta) -> float:
        """Q_MP of Eq. 15 (used by ``record_every``)."""
        value, _ = propagation_objective(
            self.graph, np.asarray(self.theta_loc), self.mu, np.asarray(self.confidences)
        )
        return float(value(np.asarray(Theta)))


@dataclasses.dataclass(frozen=True)
class GraphUpdate:
    """Dada-style sparse similarity-driven edge refresh (arXiv 1901.08460).

    Zantedeschi et al. alternate model updates with a graph step that
    re-selects each node's edges from its current *model* similarity —
    their ``Node``/``set_edges`` alternation. This is that step as a
    host-side refresh the engines fire every ``every`` slots, at slot
    boundaries (the model super-ticks in between run on the frozen
    topology; see docs/DEVIATIONS.md):

    1. **Candidates** — every current edge plus ``candidates`` random
       never-self peers per node (the sparse stand-in for the dense all
       pairs similarity Dada's centralized variant uses).
    2. **Similarity** — ``w_ij = exp(-||Theta_i - Theta_j||^2 / gamma)``
       over candidate pairs only.
    3. **Selection** — per row keep the top-``k`` by similarity, always
       retaining the single best (so every degree stays >= 1: Eq. 4
       divides by D_ii) and dropping the rest below ``threshold``; then
       OR-symmetrize, exactly like the k-NN constructors.

    The refresh is deterministic in ``(seed, round_index)``, so a run is
    reproducible and the sharded engine can replay the identical graph
    sequence on every host.
    """

    every: int = 10
    k: int = 10
    candidates: int = 8
    gamma: float = 1.0
    threshold: float = 1e-4
    seed: int = 0

    def __post_init__(self):
        if self.every < 1:
            raise ValueError("every must be >= 1 slots")
        if self.k < 1:
            raise ValueError("k must be >= 1")
        if self.candidates < 0:
            raise ValueError("candidates must be >= 0")
        if self.gamma <= 0.0:
            raise ValueError("gamma must be > 0")

    def refresh(self, csr, Theta, round_index: int = 0, allowed=None):
        """One edge-update round: (current graph, models) -> new graph.

        ``csr``: the live :class:`repro.core.graph.CSRGraph`; ``Theta``:
        (n, p) current models; ``round_index``: which refresh this is
        (seeds the candidate draw). ``allowed``: optional (n,) bool mask —
        only edges between allowed agents are re-selected; existing edges
        touching a non-allowed agent pass through frozen at their current
        weight (how the engines keep not-yet-arrived agents detached and
        departed agents' caches mixed). Host-side numpy, O(n * (deg + c)).
        """
        from repro.core.graph import csr_from_coo

        Theta = np.asarray(Theta, dtype=np.float64)
        n = csr.n
        rows = csr.row_ids().astype(np.int64)
        cols = csr.indices.astype(np.int64)
        if allowed is not None:
            allowed = np.asarray(allowed, dtype=bool)
            live = allowed[rows] & allowed[cols]
            frozen = (rows[~live], cols[~live], np.asarray(csr.data, np.float64)[~live])
            rows, cols = rows[live], cols[live]
        else:
            frozen = None
        if self.candidates > 0 and n > 1:
            rng = np.random.default_rng((self.seed, round_index))
            c = min(self.candidates, n - 1)
            # i + U{1, .., n-1} mod n is never i — no self candidates.
            rand = (
                np.arange(n, dtype=np.int64)[:, None]
                + rng.integers(1, n, size=(n, c))
            ) % n
            crows = np.repeat(np.arange(n, dtype=np.int64), c)
            ccols = rand.ravel()
            if allowed is not None:
                # Draw for every row (stable rng stream), then filter.
                mask = allowed[crows] & allowed[ccols]
                crows, ccols = crows[mask], ccols[mask]
            rows = np.concatenate([rows, crows])
            cols = np.concatenate([cols, ccols])
        # Dedupe directed candidate pairs.
        key = rows * n + cols
        _, uniq = np.unique(key, return_index=True)
        rows, cols = rows[uniq], cols[uniq]
        d2 = ((Theta[rows] - Theta[cols]) ** 2).sum(axis=1)
        vals = np.exp(-d2 / self.gamma)
        # Per-row top-k: rank candidates within each row by -similarity.
        order = np.lexsort((-vals, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        first = np.concatenate([[True], rows[1:] != rows[:-1]])
        start = np.maximum.accumulate(np.where(first, np.arange(len(rows)), 0))
        rank = np.arange(len(rows)) - start
        # The row's best candidate always survives (D_ii > 0 for Eq. 4);
        # beyond it, keep top-k entries above the negligibility floor.
        keep = (rank == 0) | ((rank < self.k) & (vals >= self.threshold))
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
        if frozen is not None:
            rows = np.concatenate([rows, frozen[0]])
            cols = np.concatenate([cols, frozen[1]])
            vals = np.concatenate([vals, frozen[2]])
        return csr_from_coo(n, rows, cols, vals, symmetrize=True, dedupe="max")
