"""Batched asynchronous simulation engine: jit-compiled Poisson super-ticks.

The faithful simulators (``coordinate_descent.run``/``run_scan``) replay
the global Poisson clock one agent per tick — an O(T) sequential scan
that cannot reach millions of agents. This engine time-slots the n
i.i.d. clocks via binomial thinning (:mod:`repro.sim.clocks`): each
**super-tick** wakes a random *subset* of agents (per-agent rates
supported), computes their Eq. 4 / Eq. 6 / Eq. 16 updates from a
bounded-staleness snapshot through the woken-rows gather/mix/scatter
path (``MixOp.gather_rows``, backed by the ``sparse_mix`` Pallas
machinery on TPU), and scatter-applies them — collapsing the scan length from O(T) to
O(T / slot_wakes) compiled steps while keeping the same fixed points
(cross-validated against the sequential paths in ``test_sim_engine.py``,
in the style of the spmd/CD cross-checks).

Recorded deviations from pure Poisson semantics (same ledger style as
``spmd.py``):

* **slotted thinning** — an agent updates at most once per slot, with
  probability ``1 - exp(-r_i * tau)``; multiple rings within a slot
  collapse (vanishes as tau -> 0);
* **bounded staleness** — all agents woken in one slot read the same
  start-of-slot snapshot, so same-slot neighbours' updates are invisible
  to each other (staleness <= 1 slot; the sequential simulators are the
  tau -> 0 limit);
* **slot capacity** — the woken batch is a static size B (jit shapes);
  overflow beyond B is dropped and counted in ``SimResult.wakes_dropped``
  (B defaults to mean + 6 sigma, so this is ~never exercised);
* **churn caching** — departed agents freeze and neighbours keep mixing
  their last broadcast model (the ``dp_cd`` stopped-agent semantics);
* **delay** — per-edge constant delays over start-of-slot snapshots,
  FIFO by construction (:mod:`repro.sim.scenarios`).

Driver layering: this engine sits between the faithful simulator
(exact semantics, O(T)) and the SPMD scale layer (synchronous rounds on
the mesh) — asynchronous semantics at batched-execution speed.
:class:`ShardedAsyncEngine` then spreads the agent blocks over a device
mesh via ``shard_map`` + halo exchange (see its docstring for the extra
ledger entries), which is what lets agent counts grow past one device's
memory.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import TopologyState, as_csr, csr_from_coo, neighbor_counts
from repro.core.mixing import kernel_max_n, sharded_mix_op
from repro.core.model_propagation import propagation_rows_from
from repro.core.spmd_compat import shard_map
from repro.obs.metrics import ExchangeVolume, MetricsAccumulator, topology_log_init
from repro.sim import clocks
from repro.sim.config import EngineConfig, resolve_config
from repro.sim.partition import partition_graph
from repro.sim.scenarios import Scenario
from repro.sim.updates import LocalUpdate


def _resolve_fused(update, fused, slab_rows: int, dtype, has_delay: bool) -> bool:
    """Resolve the tri-state ``fused`` knob against what the kernel serves.

    ``"auto"`` engages only where the Pallas kernel is the right tool
    (same gate family as :meth:`repro.core.mixing.MixOp._kernel_auto`):
    compiled TPU lowering, f32 models, an update that implements the
    fused row math (quadratic loss), no per-edge delays, and a slab that
    fits VMEM (``REPRO_KERNEL_MAX_N``). ``True`` forces the kernel
    (interpreted off-TPU — tests and parity checks); ``False`` keeps the
    unfused ops.
    """
    supported = bool(getattr(update, "fused_supported", False)) and not has_delay
    if fused == "auto":
        return (
            supported
            and jax.default_backend() == "tpu"
            and jnp.dtype(dtype) == jnp.dtype(jnp.float32)
            and slab_rows <= kernel_max_n()
        )
    if fused:
        if not supported:
            reason = "a delay scenario" if has_delay else type(update).__name__
            raise ValueError(f"fused=True but the fused path does not serve {reason}")
        return True
    return False


class SimState(NamedTuple):
    """Engine state threaded through the jitted super-tick scan."""

    Theta: jnp.ndarray  # (n, p) current models
    hist: jnp.ndarray  # (depth, n, p) start-of-slot snapshot ring (delay only)
    ptr: jnp.ndarray  # scalar int32 slot counter
    active: jnp.ndarray  # (n,) bool churn state
    key: jnp.ndarray  # PRNG state
    ustate: object  # LocalUpdate state pytree
    applied: jnp.ndarray  # scalar int32: updates actually scattered
    dropped: jnp.ndarray  # scalar int32: wakes lost to slot capacity
    messages: jnp.ndarray  # scalar f32: cumulative p-vectors transmitted
    metrics: object = None  # telemetry pytree (None — empty — when
    # EngineConfig.metrics is off; see repro.obs.metrics)


@dataclasses.dataclass
class SimResult:
    """Outcome of an engine run (counters are totals since ``init_state``)."""

    Theta: np.ndarray  # final (n, p)
    objective: np.ndarray | None  # recorded Q values (None if not recorded)
    messages: float
    wakes_applied: int
    wakes_dropped: int
    slots: int
    active: np.ndarray  # final (n,) churn state
    update_state: object  # final LocalUpdate state (e.g. DP spend counts)
    state: SimState  # full engine state, resumable via ``run(state=...)``
    report: object = None  # repro.obs.RunReport when run(metrics_every=) drained


def _check_recordable(update, record_every: int) -> None:
    """Recording needs an objective; asking for one the update cannot
    produce is an error, not a silent no-op."""
    if record_every > 0 and not hasattr(update, "objective"):
        raise ValueError(
            f"record_every={record_every} requires the update to expose an "
            f"objective method; {type(update).__name__} has none"
        )


def _drive_slots(state, slots: int, stride: int, advance, events=()):
    """Shared chunked driver for both engines: run ``slots`` super-ticks
    through ``advance(state, steps)`` in ``stride``-sized chunks, reusing
    a length-1 scan for the tail so only two scan lengths ever compile
    (not one per remainder). ``events`` is a list of ``(every, callback)``
    pairs; each callback fires with the state whenever the completed slot
    count hits a multiple of its period (and once more at the end when
    ``slots`` is not a multiple — a run always closes with a final
    record/drain). ``stride`` must divide every period, or fire points
    fall between chunks (callers pass the gcd)."""
    events = [(int(every), cb) for every, cb in events if cb is not None and every > 0]
    done = 0
    while done < slots:
        steps = min(stride, slots - done)
        if steps == stride:
            state = advance(state, stride)
        else:
            for _ in range(steps):
                state = advance(state, 1)
        done += steps
        for every, cb in events:
            if done % every == 0 or done == slots:
                cb(state)
    return state


def _event_stride(events, default: int) -> int:
    """The chunk stride serving ``(every, cb)`` events: gcd of the periods
    (so every fire point lands on a chunk boundary), or ``default``."""
    periods = [int(every) for every, cb in events if cb is not None and every > 0]
    return math.gcd(*periods) if periods else default


def _run_driver(
    engine,
    Theta0,
    slots: int,
    *,
    record_every: int = 0,
    state=None,
    metrics_every: int = 0,
    report=None,
    checkpoint_every: int = 0,
    checkpoint_dir: str | None = None,
    checkpoint_keep_last: int = 3,
    snapshot_every: int = 0,
    serve=None,
):
    """The one run loop behind both engines' ``run()`` methods.

    Validates the periodic-side-effect arguments (identical error
    messages from either engine), registers each requested side effect
    as an ``(every, callback)`` event — objective recording, metric
    drains into a :class:`repro.obs.RunReport`, crash-safe checkpoints,
    and serving-snapshot publication into a
    :class:`repro.serve.ServeHandle` — then drives the slots through
    the static chunked driver or the dynamic segment driver. Returns
    ``(state, objective, report)``; each engine assembles its own
    :class:`SimResult` from them.

    When serving is on, the handle also publishes once *before* the
    first slot, so readers have a (version = starting slot) snapshot
    during the first ``snapshot_every`` slots of a live run.
    """
    _check_recordable(engine.update, record_every)
    if metrics_every > 0 and engine._macc is None:
        raise ValueError(
            "metrics_every requires metrics collection on; construct the "
            "engine with EngineConfig(metrics=True) (or a MetricsSpec)"
        )
    if (checkpoint_every > 0) != (checkpoint_dir is not None):
        raise ValueError(
            "checkpoint_every and checkpoint_dir come together: pass both "
            "(periodic checkpoints) or neither"
        )
    if (snapshot_every > 0) != (serve is not None):
        raise ValueError(
            "snapshot_every and serve come together: pass both (a "
            "repro.serve.ServeHandle receiving the published snapshots) "
            "or neither"
        )
    state = engine.init_state(Theta0) if state is None else state
    record = record_every > 0
    objective = [engine._objective_value(state)] if record else None
    if metrics_every > 0 and report is None:
        from repro.obs.report import RunReport

        report = RunReport(meta=engine.report_meta())
    events = []
    if record:
        events.append(
            (record_every, lambda s: objective.append(engine._objective_value(s)))
        )
    if metrics_every > 0:

        def _drain(s):
            counters, derived = engine.metrics_snapshot(s)
            report.add_snapshot(engine._ptr_of(s), counters, derived)

        events.append((metrics_every, _drain))
    if checkpoint_every > 0:
        from repro.checkpoint.engine_io import save_engine_checkpoint

        events.append(
            (
                checkpoint_every,
                lambda s: save_engine_checkpoint(
                    engine, s, checkpoint_dir, keep_last=checkpoint_keep_last
                ),
            )
        )
    if snapshot_every > 0:
        serve.publish(state)
        events.append((snapshot_every, serve.publish))
    if engine.dynamic:
        state = _drive_dynamic(engine, state, slots, events, engine.advance)
    else:
        state = _drive_slots(
            state,
            slots,
            _event_stride(events, engine.steps_per_chunk),
            engine.advance,
            events,
        )
    return state, objective, report


# ---------------------------------------------------------------------------
# Dynamic-topology host helpers (shared by both engines)
# ---------------------------------------------------------------------------


def _csr_triples(csr):
    """Directed ``(rows, cols, vals)`` triples of a CSR graph."""
    rows = csr.row_ids().astype(np.int64)
    return rows, np.asarray(csr.indices, dtype=np.int64), np.asarray(csr.data)


def _slot_capacity(csr) -> int:
    """Neighbour-slot capacity for a live topology: the max degree rounded
    up to a multiple of 8, so moderate edge churn keeps the engine tile
    shapes — and the compiled super-tick — stable between refreshes."""
    need = max(1, int(csr.max_degree()))
    return ((need + 7) // 8) * 8


def _edge_delta(old, new) -> tuple[int, int]:
    """Undirected ``(added, removed)`` edge counts between two CSR graphs."""
    ro, co, _ = _csr_triples(old)
    rn, cn, _ = _csr_triples(new)
    ko = ro * old.n + co
    kn = rn * new.n + cn
    return int(np.setdiff1d(kn, ko).size) // 2, int(np.setdiff1d(ko, kn).size) // 2


def _check_topology(n: int, new_csr, pending) -> None:
    """Validate a topology swap: same n, and no agent outside the pending
    arrival set may end up with zero neighbours (Eq. 4 / Eq. 16 divide
    by the degree the moment the agent wakes)."""
    if new_csr.n != n:
        raise ValueError(f"topology must keep n={n}, got n={new_csr.n}")
    orphans = np.setdiff1d(
        np.flatnonzero(np.diff(new_csr.indptr) == 0), sorted(pending)
    )
    if orphans.size:
        raise ValueError(
            f"agents {orphans[:8].tolist()} would have no neighbours "
            "(Eq. 4 / Eq. 16 divide by the degree)"
        )


def _detach_edges(csr, ids, *, require_connected: bool = True):
    """Drop every edge incident to ``ids`` (the not-yet-arrived agents).

    With ``require_connected`` (default) every *other* agent must keep at
    least one neighbour — Eq. 4 / Eq. 16 divide by the degree, so an
    established agent whose edges all ran through scheduled arrivals
    would wake straight into a division by zero.
    """
    rows, cols, vals = _csr_triples(csr)
    drop = np.isin(rows, ids) | np.isin(cols, ids)
    out = csr_from_coo(csr.n, rows[~drop], cols[~drop], vals[~drop], symmetrize=True)
    if require_connected:
        bad = np.setdiff1d(np.flatnonzero(np.diff(out.indptr) == 0), ids)
        if bad.size:
            raise ValueError(
                f"agents {bad[:8].tolist()} would have no neighbours until the "
                "scheduled arrivals join; established agents need edges that "
                "do not run through not-yet-arrived agents"
            )
    return out


def _attach_edges(csr, rows, cols, vals):
    """A CSR graph with the given undirected edges added (max-weight dedupe)."""
    r0, c0, v0 = _csr_triples(csr)
    return csr_from_coo(
        csr.n,
        np.concatenate([r0, np.asarray(rows, np.int64)]),
        np.concatenate([c0, np.asarray(cols, np.int64)]),
        np.concatenate([v0, np.asarray(vals, np.float64)]),
        symmetrize=True,
        dedupe="max",
    )


def _arrival_edges(arrival, ids, established, rng):
    """Attachment edges for an admission batch: ``(rows, cols, vals)``."""
    rows: list[int] = []
    cols: list[int] = []
    for i in ids:
        nbrs = arrival.neighbors_for(int(i), established, rng)
        rows.extend([int(i)] * len(nbrs))
        cols.extend(int(j) for j in nbrs)
    vals = np.full(len(rows), float(arrival.attach_weight))
    return np.asarray(rows, np.int64), np.asarray(cols, np.int64), vals


def _warm_start_rows(csr, Theta, ids, rounds: int) -> np.ndarray:
    """Eq. 16 warm start for arriving agents (host-side).

    The model-propagation step with confidence ``c_i = 0`` reduces to a
    pure weighted neighbour average — the fixed-point semantics for an
    agent with no local contribution yet. Iterated ``rounds`` times over
    the arrival rows only (established rows stay fixed), via the same
    :func:`repro.core.model_propagation.propagation_rows_from` formula
    the engines run.
    """
    Theta = np.array(Theta, dtype=np.float64, copy=True)
    ids = np.asarray(ids, dtype=np.int64)
    p = Theta.shape[1]
    for _ in range(rounds):
        neigh = np.zeros((ids.size, p))
        d = np.zeros(ids.size)
        for j, i in enumerate(ids):
            lo, hi = int(csr.indptr[i]), int(csr.indptr[i + 1])
            w = np.asarray(csr.data[lo:hi])
            neigh[j] = w @ Theta[csr.indices[lo:hi]]
            d[j] = w.sum()
        if np.any(d <= 0):
            raise ValueError("arriving agents must attach with positive-weight edges")
        rows = propagation_rows_from(
            1.0,
            jnp.asarray(d),
            jnp.zeros(ids.size),
            jnp.zeros((ids.size, p)),
            jnp.asarray(neigh),
        )
        Theta[ids] = np.asarray(rows)
    return Theta


def _drive_dynamic(engine, state, slots: int, events, advance):
    """Segment driver for dynamic-topology runs (both engines).

    Splits the run at every absolute slot where anything fires — the
    periodic ``(every, cb)`` events, a :class:`GraphUpdate` refresh, or a
    scheduled arrival — advances between the fire points with the shared
    chunked driver, and applies the topology work at the boundaries
    (graph changes land between super-ticks, never inside a scan). Order
    at a shared boundary: edge refresh, then admissions (so new agents
    attach to the refreshed graph), then the periodic callbacks.
    """
    gu = engine.config.graph_update
    arrival = engine.scenario.arrival
    start = engine._ptr_of(state)
    end = start + slots
    points = {end}
    for every, _cb in events:
        points.update(range(start + every, end, every))
    if gu is not None:
        # The refresh grid is *absolute* (multiples of gu.every in slot
        # time, not offsets from this call's start), so a run split
        # across resumes — run(k) + run(state=..., m) or a checkpoint
        # restore — fires the same refreshes at the same slots as one
        # run(k + m).
        first = (start // gu.every + 1) * gu.every
        points.update(range(first, end, gu.every))
    admissions: dict[int, tuple[int, ...]] = {}
    if arrival is not None:
        for slot, ids in arrival.by_slot().items():
            t = slot - 1  # agents join at the *start* of their slot
            pend = tuple(i for i in ids if i in engine._pending)
            if pend and start <= t < end:
                admissions[t] = pend
    points.update(admissions)
    if (
        gu is not None
        and start > 0
        and start % gu.every == 0
        and engine.topology_log["edge_refreshes"] < start // gu.every
    ):
        # Resuming exactly on a grid slot whose refresh has not fired
        # yet: the previous segment ended there (end-of-run boundaries
        # never refresh), so this segment owes the refresh before its
        # first super-tick. The edge_refreshes count disambiguates a
        # pre-refresh save (end-of-segment) from a post-refresh one
        # (interior event at the same slot).
        state = engine._refresh_topology(state, start // gu.every)
    prev = start
    for t in sorted(points):
        if t > prev:
            state = _drive_slots(state, t - prev, engine.steps_per_chunk, advance)
        prev = t
        rel = t - start
        if gu is not None and start < t < end and t % gu.every == 0:
            state = engine._refresh_topology(state, t // gu.every)
        if t in admissions:
            state = engine.admit(state, admissions[t])
        for every, cb in events:
            if rel % every == 0 or t == end:
                cb(state)
    return state


class AsyncEngine:
    """Batched event-driven driver for any :class:`LocalUpdate`.

    Configured by :class:`repro.sim.EngineConfig` (``config=...``); the
    historical keyword arguments (``slot_wakes``, ``rates``,
    ``batch_size``, ``scenario``, ``seed``, ``dtype``,
    ``steps_per_chunk``, ``fused``) still work as overrides merged into
    the config — see the ``EngineConfig`` docstring for what each knob
    means. With ``fused`` on (``"auto"`` engages it on TPU for f32
    quadratic-loss updates at on-chip n), the woken-row hot path runs as
    one ``fused_row_update`` Pallas launch instead of four XLA ops.
    """

    def __init__(self, update: LocalUpdate, *, config: EngineConfig | None = None, **kw):
        cfg = resolve_config(config, kw)
        self.config = cfg
        self.update = update
        self.n, self.p = update.n, update.p
        self.dtype = cfg.dtype
        self._seed = int(cfg.seed)
        self.steps_per_chunk = int(cfg.steps_per_chunk)
        self.rates = clocks.normalize_rates(cfg.rates, self.n)
        self.tau = clocks.slot_duration(self.rates, cfg.slot_wakes)
        self.wake_probs = clocks.wake_probs(self.rates, self.tau)
        self.batch_size = (
            int(cfg.batch_size)
            if cfg.batch_size is not None
            else clocks.default_batch_size(self.rates, self.tau)
        )
        if not (0 < self.batch_size <= self.n):
            raise ValueError("batch_size must lie in (0, n]")
        self.scenario = cfg.scenario or Scenario()
        self.dynamic = cfg.graph_update is not None or self.scenario.arrival is not None
        self.topology_log = topology_log_init()
        if self.dynamic and self.scenario.delay is not None:
            raise NotImplementedError(
                "dynamic topology and per-edge delays do not compose yet: the "
                "snapshot-ring delay tiles are baked per graph"
            )
        if self.dynamic and cfg.fused is True:
            raise ValueError(
                "fused=True is static-topology only (the Pallas slab bakes the "
                "neighbour tables); leave fused='auto' for dynamic runs"
            )

        self._deg_counts = np.asarray(neighbor_counts(update.graph), dtype=np.float32)
        churn = self.scenario.churn
        self._leave = churn.leave_vector(self.n) if churn else None
        self._rejoin = churn.rejoin_vector(self.n) if churn else None
        strag = self.scenario.straggler
        self._drop = strag.drop_vector(self.n) if strag else None

        delay = self.scenario.delay
        self.depth = (delay.max_delay + 1) if delay else 1
        if delay:
            # Delayed mixing always runs over padded neighbour tiles (the
            # sparse_mix layout), whatever the MixOp backend: the per-edge
            # (delay, neighbour) pair gather has no dense-matmul form.
            mix = update.mix
            if mix.kind == "sparse":
                self._idx, self._w = np.asarray(mix.idx), np.asarray(mix.w)
            else:
                self._idx, self._w = as_csr(update.graph).padded_neighbors()
            self._delays = delay.delay_tiles(self._idx.shape)
        else:
            self._idx = self._w = self._delays = None

        fused_knob = False if self.dynamic else cfg.fused
        self.fused = _resolve_fused(update, fused_knob, self.n, self.dtype, delay is not None)
        if self.fused:
            # The fused kernel consumes padded (n, K) neighbour tables
            # whatever the MixOp backend (same tile build as the delay
            # path above — dense graphs go through the CSR form).
            mix = update.mix
            if getattr(mix, "kind", None) == "sparse":
                self._fidx, self._fw = np.asarray(mix.idx), np.asarray(mix.w)
            else:
                self._fidx, self._fw = as_csr(update.graph).padded_neighbors()
        else:
            self._fidx = self._fw = None

        self.metrics_spec = cfg.metrics_spec()
        self._macc = (
            None
            if self.metrics_spec is None
            else MetricsAccumulator(
                self.metrics_spec,
                self.n,
                churn=self._leave is not None,
                straggler=self._drop is not None,
                dp_limit=getattr(update, "planned_Ti", None),
            )
        )
        if self.fused:
            self._phases = ("wake_sample", "fused_row_update", "finalize")
        else:
            self._phases = ("wake_sample", "gather_mix", "row_update", "scatter", "finalize")
        self._phase_cache: dict = {}

        self._chunk = jax.jit(self._chunk_impl, static_argnums=1)
        self._forced = jax.jit(self._slot_forced)

        # Dynamic topology: the graph becomes mutable state. The live CSR
        # and its slot-form TopologyState stay host-side; the super-tick
        # consumes jit-argument tiles (never closures), so a topology swap
        # between chunks re-executes the compiled program with new data.
        self._pending: set[int] = set()
        if self.dynamic:
            arrival = self.scenario.arrival
            csr = as_csr(update.graph)
            if arrival is not None:
                self._pending = {int(i) for i in arrival.all_ids()}
                bad = [i for i in self._pending if not 0 <= i < self.n]
                if bad:
                    raise ValueError(f"arrival ids {bad} outside [0, n={self.n})")
                csr = _detach_edges(csr, sorted(self._pending))
            consts_fn = getattr(update, "agent_constants", None)
            base = None if consts_fn is None else consts_fn()
            if not isinstance(base, dict) or "deg" not in base:
                raise ValueError(
                    "dynamic topology needs update.agent_constants() to return "
                    "a dict with a 'deg' entry (the graph-dependent constant "
                    "the engine re-derives from the live topology)"
                )
            self._consts_base = {
                k: jnp.asarray(v) for k, v in base.items() if k != "deg"
            }
            self._csr = csr
            self.topo = TopologyState.from_csr(csr, capacity=_slot_capacity(csr))
            self._dyn = self._dyn_tiles()
            self._chunk_dyn = jax.jit(self._chunk_dyn_impl, static_argnums=2)
            self._forced_dyn = jax.jit(self._slot_dyn_forced)
        else:
            self._csr = None
            self.topo = None
            self._dyn = None

    # -- state ------------------------------------------------------------
    def init_state(self, Theta0, seed: int | None = None) -> SimState:
        """Fresh engine state from an (n, p) initial model matrix."""
        Theta = jnp.asarray(Theta0, self.dtype)
        if Theta.shape != (self.n, self.p):
            raise ValueError(f"Theta0 must be {(self.n, self.p)}, got {Theta.shape}")
        if self._delays is not None:
            hist = jnp.broadcast_to(Theta, (self.depth, self.n, self.p))
        else:
            hist = jnp.zeros((0, 0, 0), self.dtype)  # no-delay placeholder
        active = np.ones(self.n, dtype=bool)
        if self._pending:
            # Scheduled arrivals exist in the arrays but are not part of
            # the system yet: inactive (never woken) and edge-detached
            # until their slot admits them.
            active[sorted(self._pending)] = False
        return SimState(
            Theta=Theta,
            hist=hist,
            ptr=jnp.zeros((), jnp.int32),
            active=jnp.asarray(active),
            key=jax.random.PRNGKey(self._seed if seed is None else seed),
            ustate=self.update.init_state(),
            applied=jnp.zeros((), jnp.int32),
            dropped=jnp.zeros((), jnp.int32),
            messages=jnp.zeros((), jnp.float32),
            metrics=None if self._macc is None else self._macc.init(),
        )

    def state_dict(self, state: SimState, step: int | None = None):
        """The complete resume closure as ``(files, manifest)`` — every
        state leaf plus the live topology and its host log; what
        :func:`repro.checkpoint.save_engine_checkpoint` writes."""
        from repro.checkpoint.engine_io import engine_state_dict

        return engine_state_dict(self, state, step=step)

    # -- one super-tick ----------------------------------------------------
    def _slot(self, state: SimState, wake_mask, upto: str | None = None):
        """One super-tick. ``upto`` cuts the pipeline after a named phase
        and returns that phase's live intermediates — the prefix programs
        :func:`repro.obs.profile_supertick` times; None runs the full slot."""
        n, B = self.n, self.batch_size
        with jax.named_scope("obs.wake_sample"):
            key, k_leave, k_rejoin, k_wake, k_strag, k_upd = jax.random.split(
                state.key, 6
            )

            active_prev = state.active
            active = active_prev
            if wake_mask is None:
                if self._leave is not None:
                    leave = jax.random.uniform(k_leave, (n,)) < jnp.asarray(
                        self._leave, jnp.float32
                    )
                    rejoin = jax.random.uniform(k_rejoin, (n,)) < jnp.asarray(
                        self._rejoin, jnp.float32
                    )
                    active = jnp.where(active, ~leave, rejoin)
                wake_pre = (
                    jax.random.uniform(k_wake, (n,))
                    < jnp.asarray(self.wake_probs, jnp.float32)
                ) & active
                wake = wake_pre
                if self._drop is not None:
                    wake = wake & (
                        jax.random.uniform(k_strag, (n,))
                        >= jnp.asarray(self._drop, jnp.float32)
                    )
            else:
                # Forced wake sets (tests/diagnostics): no churn transition, no
                # straggler losses — but departed agents still cannot wake.
                wake = jnp.asarray(wake_mask, bool) & active
                wake_pre = wake

            total = wake.sum().astype(jnp.int32)
            woken = jnp.nonzero(wake, size=B, fill_value=n)[0].astype(jnp.int32)
            valid = woken < n
            dropped = total - valid.sum().astype(jnp.int32)
        if upto == "wake_sample":
            return wake, woken, valid, dropped, active

        Theta = state.Theta
        if self.fused and self._delays is None:
            with jax.named_scope("obs.fused_row_update"):
                # One Pallas launch: gather + mix + Eq. 4/6 + drop-mode scatter.
                hist = state.hist
                safe = jnp.minimum(woken, n - 1)
                cols = jnp.asarray(self._fidx)[safe]  # (B, K)
                ww = jnp.asarray(self._fw, jnp.float32)[safe]  # (B, K)
                new_slab, applied, ustate = self.update.apply_fused(
                    Theta, woken, valid, k_upd, state.ustate, cols, ww
                )
                Theta = new_slab.astype(Theta.dtype)
            if upto == "fused_row_update":
                return Theta, applied
        else:
            with jax.named_scope("obs.gather_mix"):
                if self._delays is not None:
                    hist = state.hist.at[state.ptr % self.depth].set(Theta)
                    safe = jnp.minimum(woken, n - 1)
                    cols = jnp.asarray(self._idx)[safe]  # (B, K)
                    w = jnp.asarray(self._w, Theta.dtype)[safe]  # (B, K)
                    dly = jnp.asarray(self._delays)[safe]  # (B, K)
                    slots = jnp.mod(state.ptr - dly, self.depth)
                    vals = hist[slots, cols]  # (B, K, p)
                    neigh = jnp.einsum("bk,bkp->bp", w, vals)
                else:
                    hist = state.hist
                    neigh = self.update.mix.gather_rows(Theta, woken)
            if upto == "gather_mix":
                return neigh

            with jax.named_scope("obs.row_update"):
                new_rows, applied, ustate = self.update.apply(
                    Theta, woken, valid, neigh, k_upd, state.ustate
                )
            if upto == "row_update":
                return new_rows, applied

            with jax.named_scope("obs.scatter"):
                tgt = jnp.where(applied, woken, n)
                Theta = Theta.at[tgt].set(new_rows.astype(Theta.dtype), mode="drop")
            if upto == "scatter":
                return Theta

        with jax.named_scope("obs.finalize"):
            deg = jnp.asarray(self._deg_counts)[jnp.minimum(woken, n - 1)]
            messages = state.messages + jnp.sum(jnp.where(applied, deg, 0.0))
            metrics = state.metrics
            if self._macc is not None:
                metrics = self._macc.tick(
                    metrics,
                    ptr=state.ptr,
                    wake_pre=wake_pre,
                    wake=wake,
                    applied=applied,
                    woken=woken,
                    capacity_dropped=dropped,
                    active_prev=active_prev,
                    active_new=active,
                    dp_counts=ustate if self._macc.dp_limit is not None else None,
                )
            return SimState(
                Theta=Theta,
                hist=hist,
                ptr=state.ptr + 1,
                active=active,
                key=key,
                ustate=ustate,
                applied=state.applied + applied.sum().astype(jnp.int32),
                dropped=state.dropped + dropped,
                messages=messages,
                metrics=metrics,
            )

    def _slot_forced(self, state: SimState, wake_mask) -> SimState:
        return self._slot(state, wake_mask)

    def _chunk_impl(self, state: SimState, steps: int) -> SimState:
        def body(s, _):
            return self._slot(s, None), None

        out, _ = jax.lax.scan(body, state, None, length=steps)
        return out

    # -- dynamic-topology super-tick ---------------------------------------
    def _dyn_tiles(self) -> dict:
        """Jit-argument tiles of the live topology.

        ``idx``/``w`` are the capacity-padded neighbour slots (invalid
        slots point at the own row with weight 0, so the mix einsum adds
        exact zeros), ``counts`` the live |N_i| for message accounting,
        and ``consts`` the update's agent constants with the
        graph-dependent ``deg`` entry re-derived from the topology.
        Shapes are stable while the slot capacity holds, so a swap
        re-executes the compiled super-tick without retracing.
        """
        t = self.topo
        w = np.where(np.asarray(t.valid), np.asarray(t.w), 0.0)
        consts = dict(self._consts_base)
        consts["deg"] = jnp.asarray(w.sum(axis=1))
        tiles = {
            "idx": jnp.asarray(t.nbr),
            "w": jnp.asarray(w, self.dtype),
            "counts": jnp.asarray(np.asarray(t.valid).sum(axis=1), jnp.float32),
            "consts": consts,
        }
        if self._rejoin is not None:
            # Churn rejoin must not resurrect a not-yet-arrived agent:
            # pending rows are edge-detached (zero degree), so waking one
            # would divide by zero. Zeroing their rejoin probability here
            # (a jit argument, not a closure) keeps the compiled slot
            # current as admissions drain the pending set.
            rejoin = np.asarray(self._rejoin, np.float32).copy()
            if self._pending:
                rejoin[sorted(self._pending)] = 0.0
            tiles["rejoin"] = jnp.asarray(rejoin)
        return tiles

    def _slot_dyn(self, state: SimState, tiles: dict, wake_mask) -> SimState:
        """One super-tick against the live-topology tiles (no fused or
        delay variants: both bake per-graph structure into the program)."""
        n, B = self.n, self.batch_size
        with jax.named_scope("obs.wake_sample"):
            key, k_leave, k_rejoin, k_wake, k_strag, k_upd = jax.random.split(
                state.key, 6
            )

            active_prev = state.active
            active = active_prev
            if wake_mask is None:
                if self._leave is not None:
                    leave = jax.random.uniform(k_leave, (n,)) < jnp.asarray(
                        self._leave, jnp.float32
                    )
                    rejoin = jax.random.uniform(k_rejoin, (n,)) < tiles["rejoin"]
                    active = jnp.where(active, ~leave, rejoin)
                wake = (
                    jax.random.uniform(k_wake, (n,))
                    < jnp.asarray(self.wake_probs, jnp.float32)
                ) & active
                wake_pre = wake
                if self._drop is not None:
                    wake = wake & (
                        jax.random.uniform(k_strag, (n,))
                        >= jnp.asarray(self._drop, jnp.float32)
                    )
            else:
                wake = jnp.asarray(wake_mask, bool) & active
                wake_pre = wake

            total = wake.sum().astype(jnp.int32)
            woken = jnp.nonzero(wake, size=B, fill_value=n)[0].astype(jnp.int32)
            valid = woken < n
            dropped = total - valid.sum().astype(jnp.int32)

        Theta = state.Theta
        safe = jnp.minimum(woken, n - 1)
        with jax.named_scope("obs.gather_mix"):
            cols = tiles["idx"][safe]  # (B, cap)
            w = jnp.asarray(tiles["w"], Theta.dtype)[safe]  # (B, cap)
            neigh = jnp.einsum("bk,bkp->bp", w, Theta[cols])
        with jax.named_scope("obs.row_update"):
            consts_rows = jax.tree.map(lambda t: t[safe], tiles["consts"])
            new_rows, applied, ustate = self.update.apply_rows(
                Theta[safe], woken, valid, neigh, k_upd, state.ustate,
                srows=woken, ssize=n, consts=consts_rows,
            )
        with jax.named_scope("obs.scatter"):
            tgt = jnp.where(applied, woken, n)
            Theta = Theta.at[tgt].set(new_rows.astype(Theta.dtype), mode="drop")

        with jax.named_scope("obs.finalize"):
            deg = tiles["counts"][safe]
            messages = state.messages + jnp.sum(jnp.where(applied, deg, 0.0))
            metrics = state.metrics
            if self._macc is not None:
                metrics = self._macc.tick(
                    metrics,
                    ptr=state.ptr,
                    wake_pre=wake_pre,
                    wake=wake,
                    applied=applied,
                    woken=woken,
                    capacity_dropped=dropped,
                    active_prev=active_prev,
                    active_new=active,
                    dp_counts=ustate if self._macc.dp_limit is not None else None,
                )
            return SimState(
                Theta=Theta,
                hist=state.hist,
                ptr=state.ptr + 1,
                active=active,
                key=key,
                ustate=ustate,
                applied=state.applied + applied.sum().astype(jnp.int32),
                dropped=state.dropped + dropped,
                messages=messages,
                metrics=metrics,
            )

    def _slot_dyn_forced(self, state: SimState, tiles: dict, wake_mask) -> SimState:
        return self._slot_dyn(state, tiles, wake_mask)

    def _chunk_dyn_impl(self, state: SimState, tiles: dict, steps: int) -> SimState:
        def body(s, _):
            return self._slot_dyn(s, tiles, None), None

        out, _ = jax.lax.scan(body, state, None, length=steps)
        return out

    # -- topology ----------------------------------------------------------
    def _ptr_of(self, state: SimState) -> int:
        """Host value of the slot counter (dynamic-driver bookkeeping)."""
        return int(np.asarray(state.ptr))

    def set_topology(self, new_csr) -> None:
        """Swap the live collaboration graph (host-side, between slots).

        Validates the swap (same n; no *active-or-established* agent may
        end up with zero neighbours — Eq. 4 / Eq. 16 divide by degree),
        rebuilds the slot-form topology and the jit-argument tiles, and
        bumps the edge-churn counters. The compiled super-tick is reused
        as long as the new max degree fits the current slot capacity;
        outgrowing it recompiles once at the larger capacity.
        """
        if not self.dynamic:
            raise ValueError(
                "static-topology engine; construct with "
                "EngineConfig(graph_update=...) or an arrival scenario"
            )
        _check_topology(self.n, new_csr, self._pending)
        added, removed = _edge_delta(self._csr, new_csr)
        cap = max(self.topo.nbr.shape[1], _slot_capacity(new_csr))
        self.topo = TopologyState.from_csr(
            new_csr, capacity=cap, version=int(self.topo.version) + 1
        )
        self._csr = new_csr
        self._dyn = self._dyn_tiles()
        self.topology_log["edges_added"] += added
        self.topology_log["edges_removed"] += removed

    def _refresh_topology(self, state: SimState, round_index: int) -> SimState:
        """Fire one Dada edge-refresh round against the current models."""
        gu = self.config.graph_update
        allowed = None
        if self._pending:
            allowed = np.ones(self.n, dtype=bool)
            allowed[sorted(self._pending)] = False
        new_csr = gu.refresh(
            self._csr, np.asarray(state.Theta), round_index=round_index, allowed=allowed
        )
        self.set_topology(new_csr)
        self.topology_log["edge_refreshes"] += 1
        return state

    def admit(self, state: SimState, ids) -> SimState:
        """Join scheduled arrival agents now: attach, warm start, activate.

        ``ids`` must be pending (scheduled, not yet admitted) arrivals.
        Attachment targets come from the :class:`ArrivalConfig` (explicit
        map, or a draw over currently active agents seeded by
        ``(arrival.seed, slot)``); with ``warm_start`` the new rows are
        initialized by the Eq. 16 confidence-0 neighbour average before
        the agent's first wake.
        """
        arrival = self.scenario.arrival
        if arrival is None:
            raise ValueError("no arrival scenario configured")
        ids = tuple(int(i) for i in ids)
        missing = [i for i in ids if i not in self._pending]
        if missing:
            raise ValueError(f"agents {missing} are not pending arrivals")
        rng = np.random.default_rng((arrival.seed, self._ptr_of(state)))
        active_g = np.asarray(state.active).copy()
        established = np.flatnonzero(active_g)
        rows, cols, vals = _arrival_edges(arrival, ids, established, rng)
        self.set_topology(_attach_edges(self._csr, rows, cols, vals))
        Theta = np.asarray(state.Theta)
        if arrival.warm_start:
            Theta = _warm_start_rows(self._csr, Theta, ids, arrival.warm_rounds)
        active_g[list(ids)] = True
        self._pending -= set(ids)
        if self._rejoin is not None:
            # Admitted agents regain their churn rejoin probability.
            self._dyn = self._dyn_tiles()
        self.topology_log["arrivals"] += len(ids)
        return state._replace(
            Theta=jnp.asarray(Theta, self.dtype), active=jnp.asarray(active_g)
        )

    def topology_counters(self) -> dict:
        """Host-side dynamic-topology counters (all zeros when static)."""
        return dict(self.topology_log)

    # -- observability -----------------------------------------------------
    @property
    def phase_names(self) -> tuple:
        """The named super-tick phases, in pipeline order."""
        return self._phases

    def phase_program(self, upto: str | None = None):
        """The jitted sampled slot cut after phase ``upto`` (None = full).

        The prefix programs :func:`repro.obs.profile_supertick` times and
        differences to attribute the super-tick's wall-clock phase by
        phase; each returns the cut phase's live intermediates so XLA
        cannot dead-code-eliminate the prefix.
        """
        if self.dynamic:
            raise NotImplementedError(
                "phase profiling serves the static-topology path only"
            )
        if upto is not None and upto not in self._phases:
            raise ValueError(f"unknown phase {upto!r} (have {self._phases})")
        if upto not in self._phase_cache:
            self._phase_cache[upto] = jax.jit(
                lambda state: self._slot(state, None, upto=upto)
            )
        return self._phase_cache[upto]

    def metrics_snapshot(self, state: SimState) -> tuple:
        """Drain the device counters: ``(counters, derived)`` host dicts.

        ``counters`` are the accumulated leaves (numpy); ``derived`` adds
        host-computed values — the DP accountant's composed eps spend —
        that need update-rule context the device counters don't carry.
        """
        if self._macc is None:
            raise ValueError(
                "metrics collection is off; construct the engine with "
                "EngineConfig(metrics=True) (or a MetricsSpec)"
            )
        return self._macc.snapshot(state.metrics), self._derived_metrics(state.ustate)

    def _derived_metrics(self, ustate) -> dict:
        derived: dict = {}
        if self.metrics_spec.privacy and hasattr(self.update, "eps_spent"):
            eps = np.asarray(self.update.eps_spent(np.asarray(ustate)))
            derived["dp_eps_spent_mean"] = float(eps.mean())
            derived["dp_eps_spent_max"] = float(eps.max())
        if self.dynamic:
            derived.update({f"topology_{k}": v for k, v in self.topology_log.items()})
        return derived

    def report_meta(self) -> dict:
        """Run metadata stamped into a :class:`repro.obs.RunReport`."""
        return {
            "engine": type(self).__name__,
            "update": type(self.update).__name__,
            "n": self.n,
            "p": self.p,
            "slot_wakes": float(self.config.slot_wakes),
            "batch_size": int(self.batch_size),
            "fused": bool(self.fused),
            "dtype": str(jnp.dtype(self.dtype).name),
        }

    # -- drivers -----------------------------------------------------------
    def step(self, state: SimState, wake_mask) -> SimState:
        """One super-tick with an explicit wake set (tests/diagnostics)."""
        if self.dynamic:
            return self._forced_dyn(state, self._dyn, jnp.asarray(wake_mask, bool))
        return self._forced(state, jnp.asarray(wake_mask, bool))

    def advance(self, state: SimState, slots: int) -> SimState:
        """Run ``slots`` sampled super-ticks as one jitted scan chunk."""
        if self.dynamic:
            return self._chunk_dyn(state, self._dyn, int(slots))
        return self._chunk(state, int(slots))

    def _objective_value(self, state: SimState) -> float:
        """The update's objective at ``state`` (recording hook)."""
        return self.update.objective(state.Theta)

    def run(
        self,
        Theta0,
        slots: int,
        record_every: int = 0,
        state: SimState | None = None,
        metrics_every: int = 0,
        report=None,
        checkpoint_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_keep_last: int = 3,
        snapshot_every: int = 0,
        serve=None,
    ) -> SimResult:
        """Drive ``slots`` super-ticks from ``Theta0`` (or a resumed state).

        ``record_every`` > 0 records the update's objective every that
        many slots (requires the update to expose ``objective``; asking
        for a recording the update cannot produce is an error, not a
        silent no-op). ``metrics_every`` > 0 drains the device metrics
        every that many slots (requires collection on —
        ``EngineConfig(metrics=...)``) into a :class:`repro.obs.RunReport`
        returned as ``SimResult.report``; pass ``report=`` to keep
        appending to an existing one across resumed runs.
        ``checkpoint_every`` > 0 writes a crash-safe engine checkpoint
        into the ``checkpoint_dir`` rotation (newest
        ``checkpoint_keep_last`` entries kept) every that many slots and
        once at the end; resume via
        ``repro.checkpoint.restore(engine, checkpoint_dir)`` +
        ``run(..., state=...)``. ``snapshot_every`` > 0 publishes a
        version-tagged serving snapshot into the paired ``serve=``
        :class:`repro.serve.ServeHandle` every that many slots (plus
        once at the start and once at the end), so batched ``predict``
        readers lag the trainer by at most ``snapshot_every`` slots.
        All three periodic arguments share one event loop — see
        ``_run_driver``.
        """
        state, objective, report = _run_driver(
            self,
            Theta0,
            slots,
            record_every=record_every,
            state=state,
            metrics_every=metrics_every,
            report=report,
            checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir,
            checkpoint_keep_last=checkpoint_keep_last,
            snapshot_every=snapshot_every,
            serve=serve,
        )
        record = record_every > 0
        return SimResult(
            Theta=np.asarray(state.Theta),
            objective=np.asarray(objective) if record else None,
            messages=float(state.messages),
            wakes_applied=int(state.applied),
            wakes_dropped=int(state.dropped),
            slots=int(state.ptr),
            active=np.asarray(state.active),
            update_state=state.ustate,
            state=state,
            report=report,
        )


# ---------------------------------------------------------------------------
# Multi-device sharded engine
# ---------------------------------------------------------------------------


class ShardedSimState(NamedTuple):
    """Sharded engine state: every leaf is stacked (S, ...) and lives
    split across the ``shards`` mesh axis."""

    Theta: jnp.ndarray  # (S, R, p) agent blocks
    active: jnp.ndarray  # (S, R) bool churn state (padding rows: False)
    keys: jnp.ndarray  # (S, 2) per-shard PRNG keys
    ustate: object  # LocalUpdate state, leaves resharded to (S, R, ...)
    applied: jnp.ndarray  # (S,) int32
    dropped: jnp.ndarray  # (S,) int32
    messages: jnp.ndarray  # (S,) f32
    ptr: jnp.ndarray  # (S,) int32 slot counter (identical across shards)
    ef: jnp.ndarray | None = None  # (S, Bmax, p) error-feedback accumulator
    # for the compressed halo exchange (None — an empty pytree — unless
    # the ExchangeSpec threads one)
    metrics: object = None  # telemetry pytree, leaves stacked (S, ...)
    # (None — empty — when EngineConfig.metrics is off)


class _ShardStatic(NamedTuple):
    """Per-shard constant tiles, passed (never closed over — a closure
    would replicate the O(nnz) arrays onto every device) so ``shard_map``
    splits them along the leading S axis."""

    wake_probs: jnp.ndarray  # (S, R) f32, padding rows 0
    leave: jnp.ndarray  # (S, R) f32
    rejoin: jnp.ndarray  # (S, R) f32
    drop: jnp.ndarray  # (S, R) f32
    owned: jnp.ndarray  # (S, R) int32 global ids, sentinel n
    deg: jnp.ndarray  # (S, R) f32 |N_i| for message accounting
    idx: jnp.ndarray  # (S, R, K) extended-local neighbour indices
    w: jnp.ndarray  # (S, R, K) weights
    exchange: object  # pytree of stacked (S, ...) halo-exchange plan arrays
    consts: object  # pytree of (S, R, ...) per-agent constant tiles (None: update has none)
    mstatic: object  # (S, ...) exchange-volume tiles for telemetry — per-shard
    # border sizes differ, so they ride here, not as program constants
    # (None: metrics off)


class ShardedAsyncEngine:
    """Multi-device :class:`AsyncEngine`: agent blocks on a ``shard_map`` mesh.

    Each super-tick runs as one SPMD program over the ``shards`` axis:
    every shard samples its own wake set (per-shard static batch B_s),
    publishes its border rows of the start-of-slot snapshot, one
    ``all_gather`` replicates the border pool, each shard gathers its
    halo rows out of it, computes the woken updates through the same
    ``eq4``/``Eq. 6``/``Eq. 16`` row formulas as the single-device
    engine, and scatters shard-locally. Only O(n/S) model state and
    O(nnz/S) graph tiles live per device.

    Locality and communication: ``relabel="rcm"`` (or ``"sfc"`` with
    ``coords``) permutes agent *positions* before block cutting so graph
    neighbours co-locate and the cut shrinks (``partition.py``); ids
    visible to callers stay original under any relabeling —
    ``global_theta``/``SimResult`` need no unrelabel step. ``exchange``
    (an :class:`repro.core.mixing.ExchangeSpec`; deprecated bare strings
    coerce) picks the halo wire format: ``method`` chooses the
    collective (``"all_gather"`` replicated border pool / ``"p2p"``
    neighbour-shard ``ppermute`` / ``"auto"`` by the measured cut — the
    two are bit-exact interchangeable), ``dtype`` the payload precision
    (``"bf16"``/``"int8"`` compress the wire; pair with
    ``error_feedback=True`` so the quantization error re-enters the next
    slot's payload instead of biasing the fixed point — the accumulator
    rides in ``ShardedSimState.ef``). Configuration arrives as a shared
    :class:`repro.sim.EngineConfig` (``config=...``), with the old
    keyword arguments still accepted as overrides; ``fused`` collapses
    the woken-row path into the ``fused_row_update`` Pallas kernel over
    the halo-extended slab.

    Per-agent data and theory constants are **shard-resident**: the
    engine tiles ``update.agent_constants()`` (datasets X/y/mask,
    degrees, confidences, alphas, noise scales) into (S, R, ...) blocks
    passed through ``shard_map`` like the graph tiles, so the super-tick
    closes over no replicated (n, ...) array and dataset memory scales
    with S.

    Recorded deviations (extends the :class:`AsyncEngine` ledger; the
    consolidated list lives in ``docs/DEVIATIONS.md``):

    * **padded exchange volume** — both exchange methods ship
      static-shape buffers (Bmax / per-offset P_d maxima over shards),
      so uneven cuts pay the max, not their own size;
    * **per-shard clocks** — each shard draws its own wake/churn
      randomness, so sampled trajectories differ from the single-device
      engine's stream while matching in distribution; forced wake sets
      (:meth:`step`) are deterministic and reproduce the single-device
      engine bit-for-bit;
    * **no per-edge delays** — the snapshot-ring delay scenario needs a
      (delay, neighbour)-pair halo exchange per ring slot; use the
      single-device engine for delay studies (churn and stragglers are
      supported here);
    * **compressed halo rows** — with ``dtype="bf16"``/``"int8"`` the
      halo copies a shard reads are quantized (locally-owned rows stay
      full-precision), so sampled trajectories deviate from the f32 wire
      at the wire precision per hop; error feedback keeps the *fixed
      point* unbiased (recorded test: bf16+EF lands within 1e-4 of the
      f32 fixed point where plain truncation does not).
    """

    def __init__(
        self,
        update: LocalUpdate,
        *,
        num_shards: int,
        config: EngineConfig | None = None,
        **kw,
    ):
        cfg = resolve_config(config, kw)
        self.config = cfg
        self.update = update
        self.n, self.p = update.n, update.p
        self.dtype = cfg.dtype
        self._seed = int(cfg.seed)
        self.steps_per_chunk = int(cfg.steps_per_chunk)
        self.scenario = cfg.scenario or Scenario()
        if self.scenario.delay is not None:
            raise NotImplementedError(
                "per-edge delays are single-device only (the snapshot-ring "
                "gather has no halo-exchange form yet); use AsyncEngine"
            )
        self.dynamic = cfg.graph_update is not None or self.scenario.arrival is not None
        self.topology_log = topology_log_init()
        if self.dynamic and cfg.fused is True:
            raise ValueError(
                "fused=True is static-topology only (the Pallas slab bakes the "
                "neighbour tables); leave fused='auto' for dynamic runs"
            )
        self._pending: set[int] = set()
        csr = as_csr(update.graph)
        if self.dynamic:
            arrival = self.scenario.arrival
            if arrival is not None:
                self._pending = {int(i) for i in arrival.all_ids()}
                bad = [i for i in self._pending if not 0 <= i < self.n]
                if bad:
                    raise ValueError(f"arrival ids {bad} outside [0, n={self.n})")
                csr = _detach_edges(csr, sorted(self._pending))
        self._csr = csr

        devices = list(jax.devices() if cfg.devices is None else cfg.devices)
        if len(devices) < num_shards:
            raise ValueError(
                f"num_shards={num_shards} needs that many devices, "
                f"have {len(devices)}"
            )
        self.mesh = Mesh(np.asarray(devices[:num_shards]), ("shards",))
        partition = cfg.partition
        if partition is not None:
            # Reuse a prebuilt GraphPartition (e.g. one already analysed
            # for exchange stats) instead of re-running the relabel/cut/
            # tile build; it must describe the same graph and shard count.
            if self._pending:
                raise ValueError(
                    "partition reuse does not compose with arrival scenarios "
                    "(the engine detaches scheduled arrivals before cutting)"
                )
            if partition.n != self.n or partition.num_shards != num_shards:
                raise ValueError(
                    f"prebuilt partition is (n={partition.n}, S={partition.num_shards}), "
                    f"engine needs (n={self.n}, S={num_shards})"
                )
            self.part = partition
        else:
            self.part = partition_graph(
                csr,
                num_shards,
                mode=cfg.partition_mode,
                relabel=cfg.relabel,
                coords=cfg.coords,
            )
        self.exchange_spec = cfg.exchange_spec()
        self.smix = sharded_mix_op(self.part, exchange=self.exchange_spec)
        self.exchange_method = self.smix.method
        self.num_shards = self.part.num_shards

        self.rates = clocks.normalize_rates(cfg.rates, self.n)
        self.tau = clocks.slot_duration(self.rates, cfg.slot_wakes)
        self.wake_probs = clocks.wake_probs(self.rates, self.tau)
        R = self.part.rows_per_shard
        batch_size = cfg.batch_size
        if batch_size is not None:
            if not (0 < batch_size <= R):
                raise ValueError(f"batch_size must lie in (0, R={R}]")
            self.batch_size = int(batch_size)
        else:
            # Size B from each shard's *owned agents'* rates — under a
            # relabel, bounds index positions, not agent ids, so a
            # positional slice of `rates` would size the batch for the
            # wrong agents.
            per_shard = max(
                clocks.default_batch_size(
                    self.rates[self.part.owned[s, : int(self.part.sizes[s])]], self.tau
                )
                for s in range(self.num_shards)
            )
            self.batch_size = int(min(per_shard, R))

        churn = self.scenario.churn
        self._leave = churn.leave_vector(self.n) if churn else None
        self._rejoin = churn.rejoin_vector(self.n) if churn else None
        strag = self.scenario.straggler
        self._drop = strag.drop_vector(self.n) if strag else None

        self.metrics_spec = cfg.metrics_spec()
        consts_fn = getattr(self.update, "agent_constants", None)
        self._consts_base = None if consts_fn is None else consts_fn()
        if self.dynamic and not (
            isinstance(self._consts_base, dict) and "deg" in self._consts_base
        ):
            raise ValueError(
                "dynamic topology needs update.agent_constants() to return a "
                "dict with a 'deg' entry (the graph-dependent constant the "
                "engine re-derives from the live topology)"
            )
        self._rebuild_static()

        # The sharded slab is the halo-extended block (R + Hmax rows) —
        # that is what the fused kernel keeps VMEM-resident per shard.
        fused_knob = False if self.dynamic else cfg.fused
        self.fused = _resolve_fused(
            update, fused_knob, R + self.smix.halo_width, self.dtype, False
        )
        self._use_ef = self.smix.error_feedback

        halo = ("wake_sample", "halo_publish", "halo_collective", "halo_scatter")
        if self.fused:
            self._phases = halo + ("fused_row_update", "finalize")
        else:
            self._phases = halo + ("gather_mix", "row_update", "scatter", "finalize")
        self._phase_cache: dict = {}

        self._chunk = jax.jit(self._chunk_impl, static_argnums=2)
        self._forced = jax.jit(self._forced_impl)

    def _exchange_volume(self) -> ExchangeVolume:
        """Per-shard static wire volume of the configured halo exchange."""
        part, S = self.part, self.num_shards
        per_row = self.exchange_spec.payload_bytes_per_row(self.p)
        if self.smix.method == "p2p":
            widths = [int(d.shape[1]) for d in self.smix.p2p_dst]
            rows = int(sum(widths))
            if widths:
                p2p_rows = np.tile(np.asarray(widths, np.int32)[None], (S, 1))
                p2p_bytes = (p2p_rows * per_row).astype(np.float32)
            else:
                p2p_rows = p2p_bytes = None
        else:
            rows = int(self.smix.border.shape[1]) * (S - 1)
            p2p_rows = p2p_bytes = None
        rows_shipped = np.full(S, rows, np.int32)
        return ExchangeVolume(
            border_rows=np.asarray(part.border_sizes, np.int64).astype(np.int32),
            rows_shipped=rows_shipped,
            bytes_shipped=(rows_shipped * per_row).astype(np.float32),
            p2p_rows=p2p_rows,
            p2p_bytes=p2p_bytes,
        )

    def _rebuild_static(self) -> None:
        """(Re)build the per-shard jit-argument tiles from the current
        partition, exchange, and live graph.

        Called at construction and after every topology swap — everything
        graph- or cut-dependent rides in :class:`_ShardStatic`, which is a
        ``shard_map`` *input*, so a swap that preserves tile shapes
        re-executes the compiled super-tick with new data (no retrace).
        """
        part = self.part
        R = part.rows_per_shard
        deg_counts = np.asarray(neighbor_counts(self._csr), dtype=np.float32)
        zeros = np.zeros(self.n, dtype=np.float32)

        def prob_tiles(v):
            v = zeros if v is None else v.astype(np.float32)
            return jnp.asarray(part.pad_rows(v))

        # Shard-resident per-agent constants: tiled along the same agent
        # blocks as Theta and passed through shard_map (never closed
        # over), so dataset memory scales with S instead of replicating
        # obj.data onto every device. Float leaves are pre-cast to the
        # engine dtype — elementwise cast commutes with the row gather,
        # so this is bit-identical to the single-device
        # cast-then-gather while halving the tile bytes for f32 runs.
        def const_tile(a):
            a = np.asarray(a)
            if np.issubdtype(a.dtype, np.floating):
                a = a.astype(self.dtype)
            return jnp.asarray(part.pad_rows(a))

        if self.metrics_spec is None:
            self._macc = None
            mstatic = None
        else:
            vol = self._exchange_volume()
            self._macc = MetricsAccumulator(
                self.metrics_spec,
                R,
                churn=self._leave is not None,
                straggler=self._drop is not None,
                dp_limit=getattr(self.update, "planned_Ti", None),
                exchange_offsets=vol.num_offsets if self.smix.method == "p2p" else 0,
                quantized=self.smix.dtype != "f32",
            )
            mstatic = None if self._macc.exchange_offsets is None else vol.tiles()

        consts_tiles = (
            None
            if self._consts_base is None
            else jax.tree.map(const_tile, self._consts_base)
        )
        if self.dynamic and consts_tiles is not None:
            # The 'deg' constant is graph-dependent: re-derive it from the
            # live topology so Eq. 4 / Eq. 16 divide by current degrees.
            consts_tiles = dict(consts_tiles)
            consts_tiles["deg"] = const_tile(np.asarray(self._csr.degrees))
        # Churn rejoin must not resurrect a not-yet-arrived agent: pending
        # rows are edge-detached (zero degree — Eq. 4 would divide by
        # zero), so their rejoin probability is zero until admission
        # rebuilds these tiles.
        rejoin_vec = self._rejoin
        if rejoin_vec is not None and self._pending:
            rejoin_vec = rejoin_vec.astype(np.float32).copy()
            rejoin_vec[sorted(self._pending)] = 0.0
        self._static = _ShardStatic(
            wake_probs=jnp.asarray(part.pad_rows(self.wake_probs.astype(np.float32))),
            leave=prob_tiles(self._leave),
            rejoin=prob_tiles(rejoin_vec),
            drop=prob_tiles(self._drop),
            owned=jnp.asarray(part.owned),
            deg=jnp.asarray(part.pad_rows(deg_counts)),
            idx=jnp.asarray(part.idx),
            w=jnp.asarray(part.w, self.dtype),
            exchange=jax.tree.map(jnp.asarray, self.smix.exchange_inputs()),
            consts=consts_tiles,
            mstatic=mstatic,
        )

    # -- state ------------------------------------------------------------
    def init_state(self, Theta0, seed: int | None = None) -> ShardedSimState:
        """Fresh sharded state from an (n, p) initial model matrix
        (original agent order; the partition maps it to shard blocks)."""
        Theta = np.asarray(Theta0, self.dtype)
        if Theta.shape != (self.n, self.p):
            raise ValueError(f"Theta0 must be {(self.n, self.p)}, got {Theta.shape}")
        part, S = self.part, self.num_shards
        base = jax.random.PRNGKey(self._seed if seed is None else seed)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(jnp.arange(S))

        def shard_leaf(x):
            x = np.asarray(x)
            if x.ndim == 0 or x.shape[0] != self.n:
                raise ValueError(
                    "sharded engine needs per-agent update-state leaves with "
                    f"leading dim n={self.n}, got shape {x.shape}"
                )
            return jnp.asarray(part.pad_rows(x))

        active = np.ones(self.n, dtype=bool)
        if self._pending:
            # Scheduled arrivals: present in the arrays, not in the system
            # — inactive and edge-detached until their slot admits them.
            active[sorted(self._pending)] = False
        return ShardedSimState(
            Theta=jnp.asarray(part.pad_rows(Theta)),
            active=jnp.asarray(part.pad_rows(active, fill=False)),
            keys=keys,
            ustate=jax.tree.map(shard_leaf, self.update.init_state()),
            applied=jnp.zeros(S, jnp.int32),
            dropped=jnp.zeros(S, jnp.int32),
            messages=jnp.zeros(S, jnp.float32),
            ptr=jnp.zeros(S, jnp.int32),
            ef=self.smix.init_error_feedback(self.p, self.dtype),
            metrics=None
            if self._macc is None
            else jax.tree.map(
                lambda a: jnp.tile(a[None], (S,) + (1,) * a.ndim), self._macc.init()
            ),
        )

    def _blank_state(self) -> ShardedSimState:
        """An ``init_state``-shaped zero template built directly in the
        (S, R, ...) tile space — the checkpoint-restore scaffold. Unlike
        :meth:`init_state` it never assembles an (n, p) host Theta, so a
        restore stays within the per-shard no-gather contract."""
        part, S = self.part, self.num_shards
        R = part.rows_per_shard
        base = jax.random.PRNGKey(self._seed)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(jnp.arange(S))

        def shard_zeros(x):
            x = jnp.asarray(x)
            if x.ndim == 0 or x.shape[0] != self.n:
                raise ValueError(
                    "sharded engine needs per-agent update-state leaves with "
                    f"leading dim n={self.n}, got shape {x.shape}"
                )
            return jnp.zeros((S, R) + x.shape[1:], x.dtype)

        return ShardedSimState(
            Theta=jnp.zeros((S, R, self.p), self.dtype),
            active=jnp.zeros((S, R), bool),
            keys=keys,
            ustate=jax.tree.map(shard_zeros, self.update.init_state()),
            applied=jnp.zeros(S, jnp.int32),
            dropped=jnp.zeros(S, jnp.int32),
            messages=jnp.zeros(S, jnp.float32),
            ptr=jnp.zeros(S, jnp.int32),
            ef=self.smix.init_error_feedback(self.p, self.dtype),
            metrics=None
            if self._macc is None
            else jax.tree.map(
                lambda a: jnp.tile(a[None], (S,) + (1,) * a.ndim), self._macc.init()
            ),
        )

    def state_dict(self, state: ShardedSimState, step: int | None = None):
        """The complete resume closure as ``(files, manifest)`` — one file
        per shard keyed by original agent ids plus partition metadata and
        per-shard scalars; what
        :func:`repro.checkpoint.save_engine_checkpoint` writes. Theta is
        never gathered to one (n, p) host array."""
        from repro.checkpoint.engine_io import engine_state_dict

        return engine_state_dict(self, state, step=step)

    # -- one shard-local super-tick ----------------------------------------
    def _slot_local(
        self, state: ShardedSimState, static: _ShardStatic, wake_mask, upto=None
    ):
        """One slot on one shard (arrays carry the local leading dim 1).

        ``upto`` cuts the SPMD pipeline after a named phase and returns
        that phase's live intermediates (without the leading shard dim —
        :meth:`phase_program` re-wraps them); None runs the full slot.
        """
        n, R, Bs = self.n, self.part.rows_per_shard, self.batch_size
        with jax.named_scope("obs.wake_sample"):
            key, k_leave, k_rejoin, k_wake, k_strag, k_upd = jax.random.split(
                state.keys[0], 6
            )

            active_prev = state.active[0]
            active = active_prev
            if wake_mask is None:
                if self._leave is not None:
                    leave = jax.random.uniform(k_leave, (R,)) < static.leave[0]
                    rejoin = jax.random.uniform(k_rejoin, (R,)) < static.rejoin[0]
                    active = jnp.where(active, ~leave, rejoin)
                wake_pre = (
                    jax.random.uniform(k_wake, (R,)) < static.wake_probs[0]
                ) & active
                wake = wake_pre
                if self._drop is not None:
                    wake = wake & (jax.random.uniform(k_strag, (R,)) >= static.drop[0])
            else:
                # Forced wake sets: no churn transition, no straggler losses —
                # but departed agents still cannot wake (AsyncEngine semantics).
                wake = wake_mask[0] & active
                wake_pre = wake

            total = wake.sum().astype(jnp.int32)
            woken = jnp.nonzero(wake, size=Bs, fill_value=R)[0].astype(jnp.int32)
            valid = woken < R
            dropped = total - valid.sum().astype(jnp.int32)
        if upto == "wake_sample":
            return wake, woken, valid, dropped, active

        Theta = state.Theta[0]
        ex = jax.tree.map(lambda a: a[0], static.exchange)
        ef = state.ef[0] if self._use_ef else None
        collect_stats = self._macc is not None and self._macc.quantized
        if upto in ("halo_publish", "halo_collective"):
            out, _, _ = self.smix.exchange_halo(
                Theta, ex, ef, upto=upto, collect_stats=collect_stats
            )
            return out
        Theta_ext, ef_new, quant_stats = self.smix.exchange_halo(
            Theta, ex, ef, collect_stats=collect_stats
        )
        if upto == "halo_scatter":
            return Theta_ext

        safe = jnp.minimum(woken, R - 1)
        grows = jnp.where(valid, static.owned[0][safe], n)  # global ids, sentinel n
        ustate = jax.tree.map(lambda x: x[0], state.ustate)
        consts_rows = (
            None
            if static.consts is None
            else jax.tree.map(lambda t: t[0][safe], static.consts)
        )
        if self.fused:
            with jax.named_scope("obs.fused_row_update"):
                # One Pallas launch over the halo-extended slab: gather + mix
                # + Eq. 4/6 + scatter; owned rows [:R] come back updated.
                cols = static.idx[0][safe]  # (B, K) extended-local indices
                ww = jnp.asarray(static.w[0], jnp.float32)[safe]  # (B, K)
                new_ext, applied, ustate = self.update.apply_fused(
                    Theta_ext, grows, valid, k_upd, ustate, cols, ww,
                    srows=woken, ssize=R, consts=consts_rows,
                )
                Theta = new_ext[:R].astype(Theta.dtype)
            if upto == "fused_row_update":
                return Theta, applied
        else:
            with jax.named_scope("obs.gather_mix"):
                neigh = self.smix.gather_rows(
                    Theta_ext, static.idx[0], static.w[0], woken
                )
            if upto == "gather_mix":
                return neigh
            with jax.named_scope("obs.row_update"):
                new_rows, applied, ustate = self.update.apply_rows(
                    Theta[safe], grows, valid, neigh, k_upd, ustate,
                    srows=woken, ssize=R, consts=consts_rows,
                )
            if upto == "row_update":
                return new_rows, applied
            with jax.named_scope("obs.scatter"):
                tgt = jnp.where(applied, woken, R)
                Theta = Theta.at[tgt].set(new_rows.astype(Theta.dtype), mode="drop")
            if upto == "scatter":
                return Theta

        with jax.named_scope("obs.finalize"):
            messages = state.messages[0] + jnp.sum(
                jnp.where(applied, static.deg[0][safe], 0.0)
            )
            metrics = None
            if self._macc is not None:
                metrics = self._macc.tick(
                    jax.tree.map(lambda a: a[0], state.metrics),
                    ptr=state.ptr[0],
                    wake_pre=wake_pre,
                    wake=wake,
                    applied=applied,
                    woken=woken,
                    capacity_dropped=dropped,
                    active_prev=active_prev,
                    active_new=active,
                    dp_counts=ustate if self._macc.dp_limit is not None else None,
                    exchange=None
                    if static.mstatic is None
                    else jax.tree.map(lambda a: a[0], static.mstatic),
                    quant_stats=quant_stats,
                )
                metrics = jax.tree.map(lambda x: x[None], metrics)
            return ShardedSimState(
                Theta=Theta[None],
                active=active[None],
                keys=key[None],
                ustate=jax.tree.map(lambda x: x[None], ustate),
                applied=(state.applied[0] + applied.sum().astype(jnp.int32))[None],
                dropped=(state.dropped[0] + dropped)[None],
                messages=messages[None],
                ptr=(state.ptr[0] + 1)[None],
                ef=ef_new[None] if self._use_ef else None,
                metrics=metrics,
            )

    def _chunk_impl(self, state, static, steps: int):
        def local(state, static):
            def body(s, _):
                return self._slot_local(s, static, None), None

            out, _ = jax.lax.scan(body, state, None, length=steps)
            return out

        return shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P("shards"), P("shards")),
            out_specs=P("shards"),
        )(state, static)

    def _forced_impl(self, state, static, wake_mask):
        return shard_map(
            self._slot_local,
            mesh=self.mesh,
            in_specs=(P("shards"), P("shards"), P("shards")),
            out_specs=P("shards"),
        )(state, static, wake_mask)

    # -- topology ----------------------------------------------------------
    def _ptr_of(self, state: ShardedSimState) -> int:
        """Host value of the slot counter (identical across shards)."""
        return int(np.asarray(state.ptr)[0])

    def set_topology(self, state: ShardedSimState, new_csr) -> ShardedSimState:
        """Swap the live graph and rebind the sharded machinery.

        Three tiers, by how much of the standing cut survives:

        * **weight-only** (identical structure) — retile the weights via
          :meth:`GraphPartition.patch`'s fast path; the point-to-point
          plan and every index tile are reused as-is;
        * **structural, drift <= ``config.drift_threshold``** — patch the
          frozen ownership (:meth:`GraphPartition.patch`): halo/border
          tiles rebuild, agent placement and the model state stay put;
        * **drift above threshold** — pay for a full ``partition_graph``
          rebuild (fresh relabel + cut) and re-lay the state onto the new
          ownership.

        Returns the (possibly re-laid-out) state. The error-feedback
        accumulator survives weight-only patches and re-initializes on
        structural changes (border rows moved, so the standing residuals
        no longer describe the wire); device metrics re-initialize only
        if a rebuild changed the counter shapes.
        """
        if not self.dynamic:
            raise ValueError(
                "static-topology engine; construct with "
                "EngineConfig(graph_update=...) or an arrival scenario"
            )
        _check_topology(self.n, new_csr, self._pending)
        added, removed = _edge_delta(self._csr, new_csr)
        old_part = self.part
        same_structure = np.array_equal(
            old_part.csr.indptr, new_csr.indptr
        ) and np.array_equal(old_part.csr.indices, new_csr.indices)
        relayout = False
        if same_structure:
            new_part = old_part.patch(new_csr)
            self.topology_log["weight_patches"] += 1
        else:
            drift = float(old_part.drift(new_csr))
            self.topology_log["last_drift"] = drift
            if drift <= float(self.config.drift_threshold):
                new_part = old_part.patch(new_csr)
                self.topology_log["structural_patches"] += 1
            else:
                new_part = partition_graph(
                    new_csr,
                    self.num_shards,
                    mode=self.config.partition_mode,
                    relabel=self.config.relabel,
                    coords=self.config.coords,
                )
                self.topology_log["repartitions"] += 1
                relayout = True
        self._csr = new_csr
        self.topology_log["edges_added"] += added
        self.topology_log["edges_removed"] += removed

        if relayout:
            # Ownership changed: route every per-agent leaf through the
            # global order (old unpad -> new pad). (S,) scalars and the
            # per-shard keys keep their meaning — S is unchanged.
            def relay(leaf, fill=0):
                g = old_part.unpad_rows(np.asarray(leaf))
                return jnp.asarray(new_part.pad_rows(g, fill=fill))

            Theta = relay(state.Theta)
            active = relay(state.active, fill=False)
            ustate = jax.tree.map(relay, state.ustate)
        else:
            Theta, active, ustate = state.Theta, state.active, state.ustate

        self.part = new_part
        self.smix = self.smix.rebound(new_part)
        self.exchange_method = self.smix.method
        self.batch_size = int(min(self.batch_size, new_part.rows_per_shard))
        self._rebuild_static()

        if self._use_ef:
            ef = state.ef
            fresh_ef = self.smix.init_error_feedback(self.p, self.dtype)
            if relayout or not same_structure or ef is None or (
                np.shape(ef) != np.shape(fresh_ef)
            ):
                ef = fresh_ef
        else:
            ef = state.ef
        metrics = state.metrics
        if self._macc is not None:
            fresh = jax.tree.map(
                lambda a: jnp.tile(a[None], (self.num_shards,) + (1,) * a.ndim),
                self._macc.init(),
            )
            old_leaves = jax.tree.leaves(metrics)
            new_leaves = jax.tree.leaves(fresh)
            if len(old_leaves) != len(new_leaves) or any(
                np.shape(a) != np.shape(b) for a, b in zip(old_leaves, new_leaves)
            ):
                metrics = fresh
        return state._replace(
            Theta=Theta, active=active, ustate=ustate, ef=ef, metrics=metrics
        )

    def _refresh_topology(self, state: ShardedSimState, round_index: int):
        """Fire one Dada edge-refresh round against the current models."""
        gu = self.config.graph_update
        allowed = None
        if self._pending:
            allowed = np.ones(self.n, dtype=bool)
            allowed[sorted(self._pending)] = False
        new_csr = gu.refresh(
            self._csr,
            self.global_theta(state),
            round_index=round_index,
            allowed=allowed,
        )
        state = self.set_topology(state, new_csr)
        self.topology_log["edge_refreshes"] += 1
        return state

    def admit(self, state: ShardedSimState, ids) -> ShardedSimState:
        """Join scheduled arrival agents now (sharded counterpart of
        :meth:`AsyncEngine.admit`: attach, warm start, activate).

        The attach edges go through :meth:`set_topology` — so an
        admission can itself trigger a patch or a repartition — and the
        warm-started rows are re-laid onto whatever partition results.
        """
        arrival = self.scenario.arrival
        if arrival is None:
            raise ValueError("no arrival scenario configured")
        ids = tuple(int(i) for i in ids)
        missing = [i for i in ids if i not in self._pending]
        if missing:
            raise ValueError(f"agents {missing} are not pending arrivals")
        rng = np.random.default_rng((arrival.seed, self._ptr_of(state)))
        active_g = np.asarray(self.part.unpad_rows(np.asarray(state.active))).copy()
        established = np.flatnonzero(active_g)
        rows, cols, vals = _arrival_edges(arrival, ids, established, rng)
        state = self.set_topology(state, _attach_edges(self._csr, rows, cols, vals))
        Theta_g = self.global_theta(state)
        if arrival.warm_start:
            Theta_g = _warm_start_rows(self._csr, Theta_g, ids, arrival.warm_rounds)
        active_g[list(ids)] = True
        self._pending -= set(ids)
        if self._rejoin is not None:
            # Admitted agents regain their churn rejoin probability.
            self._rebuild_static()
        self.topology_log["arrivals"] += len(ids)
        return state._replace(
            Theta=jnp.asarray(self.part.pad_rows(Theta_g), self.dtype),
            active=jnp.asarray(self.part.pad_rows(active_g, fill=False)),
        )

    def topology_counters(self) -> dict:
        """Host-side dynamic-topology counters (all zeros when static)."""
        return dict(self.topology_log)

    # -- observability -----------------------------------------------------
    @property
    def phase_names(self) -> tuple:
        """The named super-tick phases, in SPMD pipeline order."""
        return self._phases

    def phase_program(self, upto: str | None = None):
        """The jitted sampled slot cut after phase ``upto`` (None = full).

        Same contract as :meth:`AsyncEngine.phase_program`; the cut runs
        as the full ``shard_map`` program (collectives included), with
        the static tiles passed as inputs — never closed over — so the
        prefix measures what the real slot pays.
        """
        if upto is not None and upto not in self._phases:
            raise ValueError(f"unknown phase {upto!r} (have {self._phases})")
        if upto not in self._phase_cache:

            def local(s, st):
                out = self._slot_local(s, st, None, upto)
                if upto is not None:
                    out = jax.tree.map(lambda a: a[None], out)
                return out

            fn = jax.jit(
                lambda state, static: shard_map(
                    local,
                    mesh=self.mesh,
                    in_specs=(P("shards"), P("shards")),
                    out_specs=P("shards"),
                )(state, static)
            )
            self._phase_cache[upto] = lambda state: fn(state, self._static)
        return self._phase_cache[upto]

    def metrics_snapshot(self, state: ShardedSimState) -> tuple:
        """Drain the device counters: ``(counters, derived)`` host dicts.

        Counter leaves keep their leading (S,) shard axis (summaries
        collapse it; per-shard burn-down stays visible); ``derived`` adds
        the DP accountant's composed eps spend over the *owned* (unpadded)
        agents.
        """
        if self._macc is None:
            raise ValueError(
                "metrics collection is off; construct the engine with "
                "EngineConfig(metrics=True) (or a MetricsSpec)"
            )
        counters = self._macc.snapshot(state.metrics)
        derived: dict = {}
        if self.metrics_spec.privacy and hasattr(self.update, "eps_spent"):
            counts = self.part.unpad_rows(np.asarray(state.ustate))
            eps = np.asarray(self.update.eps_spent(counts))
            derived["dp_eps_spent_mean"] = float(eps.mean())
            derived["dp_eps_spent_max"] = float(eps.max())
        if self.dynamic:
            derived.update({f"topology_{k}": v for k, v in self.topology_log.items()})
        return counters, derived

    def report_meta(self) -> dict:
        """Run metadata stamped into a :class:`repro.obs.RunReport`."""
        return {
            "engine": type(self).__name__,
            "update": type(self.update).__name__,
            "n": self.n,
            "p": self.p,
            "num_shards": int(self.num_shards),
            "slot_wakes": float(self.config.slot_wakes),
            "batch_size": int(self.batch_size),
            "fused": bool(self.fused),
            "dtype": str(jnp.dtype(self.dtype).name),
            "exchange_method": self.exchange_method,
            "exchange_dtype": self.smix.dtype,
            "error_feedback": bool(self._use_ef),
        }

    # -- drivers -----------------------------------------------------------
    def step(self, state: ShardedSimState, wake_mask) -> ShardedSimState:
        """One super-tick with an explicit global (n,) wake set."""
        mask = self.part.pad_rows(np.asarray(wake_mask, bool), fill=False)
        return self._forced(state, self._static, jnp.asarray(mask))

    def advance(self, state: ShardedSimState, slots: int) -> ShardedSimState:
        """Run ``slots`` sampled super-ticks as one jitted scan chunk."""
        return self._chunk(state, self._static, int(slots))

    def global_theta(self, state: ShardedSimState) -> np.ndarray:
        """Reassemble the (n, p) model matrix from the shard blocks."""
        return self.part.unpad_rows(np.asarray(state.Theta))

    def _objective_value(self, state: ShardedSimState) -> float:
        """The update's objective at ``state`` (recording hook)."""
        return self.update.objective(self.global_theta(state))

    def run(
        self,
        Theta0,
        slots: int,
        record_every: int = 0,
        state: ShardedSimState | None = None,
        metrics_every: int = 0,
        report=None,
        checkpoint_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_keep_last: int = 3,
        snapshot_every: int = 0,
        serve=None,
    ) -> SimResult:
        """Drive ``slots`` super-ticks; same contract as :meth:`AsyncEngine.run`."""
        state, objective, report = _run_driver(
            self,
            Theta0,
            slots,
            record_every=record_every,
            state=state,
            metrics_every=metrics_every,
            report=report,
            checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir,
            checkpoint_keep_last=checkpoint_keep_last,
            snapshot_every=snapshot_every,
            serve=serve,
        )
        record = record_every > 0
        part = self.part
        return SimResult(
            Theta=self.global_theta(state),
            objective=np.asarray(objective) if record else None,
            messages=float(np.asarray(state.messages).sum()),
            wakes_applied=int(np.asarray(state.applied).sum()),
            wakes_dropped=int(np.asarray(state.dropped).sum()),
            slots=int(np.asarray(state.ptr)[0]),
            active=part.unpad_rows(np.asarray(state.active)),
            update_state=jax.tree.map(
                lambda x: part.unpad_rows(np.asarray(x)), state.ustate
            ),
            state=state,
            report=report,
        )
