"""Batched asynchronous simulation engine: jit-compiled Poisson super-ticks.

The faithful simulators (``coordinate_descent.run``/``run_scan``) replay
the global Poisson clock one agent per tick — an O(T) sequential scan
that cannot reach millions of agents. This engine time-slots the n
i.i.d. clocks via binomial thinning (:mod:`repro.sim.clocks`): each
**super-tick** wakes a random *subset* of agents (per-agent rates
supported), computes their Eq. 4 / Eq. 6 / Eq. 16 updates from a
bounded-staleness snapshot through the woken-rows gather/mix/scatter
path (``MixOp.gather_rows``, backed by the ``sparse_mix`` Pallas
machinery on TPU), and scatter-applies them — collapsing the scan length from O(T) to
O(T / slot_wakes) compiled steps while keeping the same fixed points
(cross-validated against the sequential paths in ``test_sim_engine.py``,
in the style of the spmd/CD cross-checks).

Recorded deviations from pure Poisson semantics (same ledger style as
``spmd.py``):

* **slotted thinning** — an agent updates at most once per slot, with
  probability ``1 - exp(-r_i * tau)``; multiple rings within a slot
  collapse (vanishes as tau -> 0);
* **bounded staleness** — all agents woken in one slot read the same
  start-of-slot snapshot, so same-slot neighbours' updates are invisible
  to each other (staleness <= 1 slot; the sequential simulators are the
  tau -> 0 limit);
* **slot capacity** — the woken batch is a static size B (jit shapes);
  overflow beyond B is dropped and counted in ``SimResult.wakes_dropped``
  (B defaults to mean + 6 sigma, so this is ~never exercised);
* **churn caching** — departed agents freeze and neighbours keep mixing
  their last broadcast model (the ``dp_cd`` stopped-agent semantics);
* **delay** — per-edge constant delays over start-of-slot snapshots,
  FIFO by construction (:mod:`repro.sim.scenarios`).

Driver layering: this engine sits between the faithful simulator
(exact semantics, O(T)) and the SPMD scale layer (synchronous rounds on
the mesh) — asynchronous semantics at batched-execution speed.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import as_csr, neighbor_counts
from repro.sim import clocks
from repro.sim.scenarios import Scenario
from repro.sim.updates import LocalUpdate


class SimState(NamedTuple):
    """Engine state threaded through the jitted super-tick scan."""

    Theta: jnp.ndarray  # (n, p) current models
    hist: jnp.ndarray  # (depth, n, p) start-of-slot snapshot ring (delay only)
    ptr: jnp.ndarray  # scalar int32 slot counter
    active: jnp.ndarray  # (n,) bool churn state
    key: jnp.ndarray  # PRNG state
    ustate: object  # LocalUpdate state pytree
    applied: jnp.ndarray  # scalar int32: updates actually scattered
    dropped: jnp.ndarray  # scalar int32: wakes lost to slot capacity
    messages: jnp.ndarray  # scalar f32: cumulative p-vectors transmitted


@dataclasses.dataclass
class SimResult:
    """Outcome of an engine run (counters are totals since ``init_state``)."""

    Theta: np.ndarray  # final (n, p)
    objective: np.ndarray | None  # recorded Q values (None if not recorded)
    messages: float
    wakes_applied: int
    wakes_dropped: int
    slots: int
    active: np.ndarray  # final (n,) churn state
    update_state: object  # final LocalUpdate state (e.g. DP spend counts)
    state: SimState  # full engine state, resumable via ``run(state=...)``


class AsyncEngine:
    """Batched event-driven driver for any :class:`LocalUpdate`.

    Parameters
    ----------
    update: the local rule (CD / DP-CD / propagation).
    slot_wakes: expected wake-ups per super-tick; sets the slot duration
        tau = slot_wakes / sum(rates). Larger = faster wall-clock, more
        within-slot staleness.
    rates: per-agent Poisson rates (default 1.0 — the paper's model);
        heterogeneous rates model fast/slow device classes.
    batch_size: static woken-rows batch B (default mean + 6 sigma).
    scenario: churn / delay / straggler bundle (default: none).
    seed: engine PRNG seed; every run is a pure function of it.
    dtype: model dtype (f32 default; f64 for theory-grade parity checks).
    steps_per_chunk: super-ticks per jitted ``lax.scan`` chunk.
    """

    def __init__(
        self,
        update: LocalUpdate,
        *,
        slot_wakes: float = 64.0,
        rates=None,
        batch_size: int | None = None,
        scenario: Scenario | None = None,
        seed: int = 0,
        dtype=jnp.float32,
        steps_per_chunk: int = 16,
    ):
        self.update = update
        self.n, self.p = update.n, update.p
        self.dtype = dtype
        self._seed = int(seed)
        self.steps_per_chunk = int(steps_per_chunk)
        self.rates = clocks.normalize_rates(rates, self.n)
        self.tau = clocks.slot_duration(self.rates, slot_wakes)
        self.wake_probs = clocks.wake_probs(self.rates, self.tau)
        self.batch_size = (
            int(batch_size)
            if batch_size is not None
            else clocks.default_batch_size(self.rates, self.tau)
        )
        if not (0 < self.batch_size <= self.n):
            raise ValueError("batch_size must lie in (0, n]")
        self.scenario = scenario or Scenario()

        self._deg_counts = np.asarray(neighbor_counts(update.graph), dtype=np.float32)
        churn = self.scenario.churn
        self._leave = churn.leave_vector(self.n) if churn else None
        self._rejoin = churn.rejoin_vector(self.n) if churn else None
        strag = self.scenario.straggler
        self._drop = strag.drop_vector(self.n) if strag else None

        delay = self.scenario.delay
        self.depth = (delay.max_delay + 1) if delay else 1
        if delay:
            # Delayed mixing always runs over padded neighbour tiles (the
            # sparse_mix layout), whatever the MixOp backend: the per-edge
            # (delay, neighbour) pair gather has no dense-matmul form.
            mix = update.mix
            if mix.kind == "sparse":
                self._idx, self._w = np.asarray(mix.idx), np.asarray(mix.w)
            else:
                self._idx, self._w = as_csr(update.graph).padded_neighbors()
            self._delays = delay.delay_tiles(self._idx.shape)
        else:
            self._idx = self._w = self._delays = None

        self._chunk = jax.jit(self._chunk_impl, static_argnums=1)
        self._forced = jax.jit(self._slot_forced)

    # -- state ------------------------------------------------------------
    def init_state(self, Theta0, seed: int | None = None) -> SimState:
        Theta = jnp.asarray(Theta0, self.dtype)
        if Theta.shape != (self.n, self.p):
            raise ValueError(f"Theta0 must be {(self.n, self.p)}, got {Theta.shape}")
        if self._delays is not None:
            hist = jnp.broadcast_to(Theta, (self.depth, self.n, self.p))
        else:
            hist = jnp.zeros((0, 0, 0), self.dtype)  # no-delay placeholder
        return SimState(
            Theta=Theta,
            hist=hist,
            ptr=jnp.zeros((), jnp.int32),
            active=jnp.ones(self.n, bool),
            key=jax.random.PRNGKey(self._seed if seed is None else seed),
            ustate=self.update.init_state(),
            applied=jnp.zeros((), jnp.int32),
            dropped=jnp.zeros((), jnp.int32),
            messages=jnp.zeros((), jnp.float32),
        )

    # -- one super-tick ----------------------------------------------------
    def _slot(self, state: SimState, wake_mask) -> SimState:
        n, B = self.n, self.batch_size
        key, k_leave, k_rejoin, k_wake, k_strag, k_upd = jax.random.split(state.key, 6)

        active = state.active
        if wake_mask is None:
            if self._leave is not None:
                leave = jax.random.uniform(k_leave, (n,)) < jnp.asarray(
                    self._leave, jnp.float32
                )
                rejoin = jax.random.uniform(k_rejoin, (n,)) < jnp.asarray(
                    self._rejoin, jnp.float32
                )
                active = jnp.where(active, ~leave, rejoin)
            wake = (
                jax.random.uniform(k_wake, (n,))
                < jnp.asarray(self.wake_probs, jnp.float32)
            ) & active
            if self._drop is not None:
                wake &= jax.random.uniform(k_strag, (n,)) >= jnp.asarray(
                    self._drop, jnp.float32
                )
        else:
            # Forced wake sets (tests/diagnostics): no churn transition, no
            # straggler losses — but departed agents still cannot wake.
            wake = jnp.asarray(wake_mask, bool) & active

        total = wake.sum().astype(jnp.int32)
        woken = jnp.nonzero(wake, size=B, fill_value=n)[0].astype(jnp.int32)
        valid = woken < n
        dropped = total - valid.sum().astype(jnp.int32)

        Theta = state.Theta
        if self._delays is not None:
            hist = state.hist.at[state.ptr % self.depth].set(Theta)
            safe = jnp.minimum(woken, n - 1)
            cols = jnp.asarray(self._idx)[safe]  # (B, K)
            w = jnp.asarray(self._w, Theta.dtype)[safe]  # (B, K)
            dly = jnp.asarray(self._delays)[safe]  # (B, K)
            slots = jnp.mod(state.ptr - dly, self.depth)
            vals = hist[slots, cols]  # (B, K, p)
            neigh = jnp.einsum("bk,bkp->bp", w, vals)
        else:
            hist = state.hist
            neigh = self.update.mix.gather_rows(Theta, woken)

        new_rows, applied, ustate = self.update.apply(
            Theta, woken, valid, neigh, k_upd, state.ustate
        )
        tgt = jnp.where(applied, woken, n)
        Theta = Theta.at[tgt].set(new_rows.astype(Theta.dtype), mode="drop")

        deg = jnp.asarray(self._deg_counts)[jnp.minimum(woken, n - 1)]
        messages = state.messages + jnp.sum(jnp.where(applied, deg, 0.0))
        return SimState(
            Theta=Theta,
            hist=hist,
            ptr=state.ptr + 1,
            active=active,
            key=key,
            ustate=ustate,
            applied=state.applied + applied.sum().astype(jnp.int32),
            dropped=state.dropped + dropped,
            messages=messages,
        )

    def _slot_forced(self, state: SimState, wake_mask) -> SimState:
        return self._slot(state, wake_mask)

    def _chunk_impl(self, state: SimState, steps: int) -> SimState:
        def body(s, _):
            return self._slot(s, None), None

        out, _ = jax.lax.scan(body, state, None, length=steps)
        return out

    # -- drivers -----------------------------------------------------------
    def step(self, state: SimState, wake_mask) -> SimState:
        """One super-tick with an explicit wake set (tests/diagnostics)."""
        return self._forced(state, jnp.asarray(wake_mask, bool))

    def advance(self, state: SimState, slots: int) -> SimState:
        """Run ``slots`` sampled super-ticks as one jitted scan chunk."""
        return self._chunk(state, int(slots))

    def run(
        self,
        Theta0,
        slots: int,
        record_every: int = 0,
        state: SimState | None = None,
    ) -> SimResult:
        """Drive ``slots`` super-ticks from ``Theta0`` (or a resumed state).

        ``record_every`` > 0 records the update's objective every that
        many slots (requires the update to expose ``objective``).
        """
        state = self.init_state(Theta0) if state is None else state
        record = record_every > 0 and hasattr(self.update, "objective")
        objective = [self.update.objective(state.Theta)] if record else None
        stride = record_every if record else self.steps_per_chunk
        done = 0
        while done < slots:
            steps = min(stride, slots - done)
            if steps == stride:
                state = self._chunk(state, stride)
            else:
                # Tail shorter than the stride: reuse the length-1 scan so
                # only two scan lengths ever compile, not one per remainder.
                for _ in range(steps):
                    state = self._chunk(state, 1)
            done += steps
            if record:
                objective.append(self.update.objective(state.Theta))
        return SimResult(
            Theta=np.asarray(state.Theta),
            objective=np.asarray(objective) if record else None,
            messages=float(state.messages),
            wakes_applied=int(state.applied),
            wakes_dropped=int(state.dropped),
            slots=int(state.ptr),
            active=np.asarray(state.active),
            update_state=state.ustate,
            state=state,
        )
