"""Agent-block graph partitioning for the multi-device async engine.

The paper's algorithm is fully decentralized — a wake-up touches one
agent's neighbourhood only — so the natural way past one device's memory
is to shard *agents* across devices. This module cuts a :class:`CSRGraph`
into ``num_shards`` contiguous index blocks (equal-count blocks, or
degree-balanced blocks that equalize per-shard nnz) and precomputes
everything the shard-local super-tick needs as stacked ``(S, ...)``
arrays that ``shard_map`` splits along the leading axis:

* ``owned``: each shard's global agent ids, padded to the max block size
  ``R`` with the sentinel ``n``;
* per-shard **padded neighbour tiles** ``idx``/``w`` of width ``K`` (the
  global max degree), whose column indices live in the shard's *extended*
  local array ``[own rows (R) ; halo rows (Hmax)]``;
* **halo maps** for the cross-shard edges: ``halo`` lists the remote
  global ids a shard reads, ``border`` lists the local rows a shard must
  publish, and ``halo_src`` maps each halo slot to its position in the
  all-gathered ``(S * Bmax,)`` border pool.

The exchange itself (gather border rows -> ``all_gather`` -> gather halo
rows) lives in :class:`repro.core.mixing.ShardedMixOp`; this module is
pure numpy and is also used directly by the halo round-trip property
tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import CSRGraph


@dataclasses.dataclass(frozen=True, eq=False)
class GraphPartition:
    """A contiguous agent-block partition of a CSR graph with halo maps.

    Shapes: ``S = num_shards``, ``R = rows_per_shard`` (max block size),
    ``K = tile_width`` (max degree), ``Bmax``/``Hmax`` the padded border
    and halo widths. All index arrays use the conventions above.
    """

    csr: CSRGraph
    num_shards: int
    mode: str
    bounds: np.ndarray  # (S + 1,) block boundaries: shard s owns [b_s, b_{s+1})
    owned: np.ndarray  # (S, R) global agent ids, sentinel n past the block
    sizes: np.ndarray  # (S,) real rows per shard
    shard_of: np.ndarray  # (n,) owning shard per agent
    local_of: np.ndarray  # (n,) local row within the owning shard
    halo: np.ndarray  # (S, Hmax) remote global ids each shard reads, sentinel n
    halo_sizes: np.ndarray  # (S,)
    border: np.ndarray  # (S, Bmax) local rows each shard publishes, padded 0
    border_sizes: np.ndarray  # (S,)
    halo_src: np.ndarray  # (S, Hmax) flat index into the (S * Bmax,) border pool
    idx: np.ndarray  # (S, R, K) extended-local neighbour indices
    w: np.ndarray  # (S, R, K) neighbour weights (pad entries 0)

    @property
    def n(self) -> int:
        return self.csr.n

    @property
    def rows_per_shard(self) -> int:
        return self.owned.shape[1]

    @property
    def tile_width(self) -> int:
        return self.idx.shape[2]

    def halo_fraction(self) -> float:
        """Mean fraction of read rows that cross shards (comm diagnostics)."""
        reads = self.sizes + self.halo_sizes
        return float(self.halo_sizes.sum() / max(reads.sum(), 1))

    # -- row <-> shard layout conversions ---------------------------------
    def pad_rows(self, x, fill=0):
        """(n, ...) per-agent array -> (S, R, ...) shard layout, ``fill`` pads."""
        x = np.asarray(x)
        if x.shape[:1] != (self.n,):
            raise ValueError(f"expected leading dim {self.n}, got {x.shape}")
        out = np.full((self.num_shards, self.rows_per_shard) + x.shape[1:], fill, dtype=x.dtype)
        real = self.owned < self.n
        out[real] = x[self.owned[real]]
        return out

    def unpad_rows(self, x_sh):
        """(S, R, ...) shard layout -> (n, ...) per-agent array (drops padding)."""
        x_sh = np.asarray(x_sh)
        if x_sh.shape[:2] != self.owned.shape:
            raise ValueError(f"expected leading dims {self.owned.shape}, got {x_sh.shape}")
        out = np.empty((self.n,) + x_sh.shape[2:], dtype=x_sh.dtype)
        real = self.owned < self.n
        out[self.owned[real]] = x_sh[real]
        return out


def _block_bounds(csr: CSRGraph, num_shards: int, mode: str) -> np.ndarray:
    n, S = csr.n, num_shards
    if mode == "contiguous":
        return np.array([n * s // S for s in range(S + 1)], dtype=np.int64)
    if mode != "degree":
        raise ValueError(f"unknown partition mode {mode!r}")
    # Degree-balanced: put boundaries at equal cumulative-nnz quantiles so
    # every shard carries ~nnz/S edge work, whatever the degree skew.
    target = csr.nnz * np.arange(1, S, dtype=np.float64) / S
    cuts = np.searchsorted(np.asarray(csr.indptr, dtype=np.int64), target)
    bounds = np.concatenate([[0], cuts, [n]]).astype(np.int64)
    for s in range(1, S + 1):  # keep blocks non-empty and ordered
        bounds[s] = min(max(bounds[s], bounds[s - 1] + 1), n - (S - s))
    bounds[S] = n
    return bounds


def partition_graph(
    csr: CSRGraph, num_shards: int, mode: str = "degree", tile_width: int | None = None
) -> GraphPartition:
    """Cut ``csr`` into contiguous agent blocks with halo/border maps.

    ``mode``: "contiguous" (equal agent counts) or "degree" (equal nnz).
    ``tile_width`` pads the neighbour tiles to at least the global max
    degree (the default), which keeps the per-row contraction extent
    identical to the single-device padded tiles — the forced-wake parity
    guarantee rests on that.
    """
    n, S = csr.n, int(num_shards)
    if not (1 <= S <= max(n, 1)):
        raise ValueError(f"num_shards must lie in [1, n={n}], got {S}")
    bounds = _block_bounds(csr, S, mode)
    sizes = np.diff(bounds).astype(np.int64)
    R = int(sizes.max())
    K = max(csr.max_degree(), 1)
    if tile_width is not None:
        if tile_width < K:
            raise ValueError(f"tile_width={tile_width} < max degree {K}")
        K = int(tile_width)

    owned = np.full((S, R), n, dtype=np.int32)
    shard_of = np.empty(n, dtype=np.int32)
    local_of = np.empty(n, dtype=np.int32)
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        owned[s, : hi - lo] = np.arange(lo, hi, dtype=np.int32)
        shard_of[lo:hi] = s
        local_of[lo:hi] = np.arange(hi - lo, dtype=np.int32)

    indptr = np.asarray(csr.indptr, dtype=np.int64)
    halos = []
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        cols = csr.indices[indptr[lo] : indptr[hi]]
        halos.append(np.unique(cols[(cols < lo) | (cols >= hi)]).astype(np.int32))
    halo_sizes = np.array([len(h) for h in halos], dtype=np.int64)
    Hmax = max(int(halo_sizes.max(initial=0)), 1)
    halo = np.full((S, Hmax), n, dtype=np.int32)
    for s, h in enumerate(halos):
        halo[s, : len(h)] = h

    # Border of shard s = its rows referenced by any other shard's halo.
    borders = []
    all_halo = np.concatenate(halos) if halos else np.zeros(0, dtype=np.int32)
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        mine = np.unique(all_halo[(all_halo >= lo) & (all_halo < hi)])
        borders.append((mine - lo).astype(np.int32))  # sorted local rows
    border_sizes = np.array([len(b) for b in borders], dtype=np.int64)
    Bmax = max(int(border_sizes.max(initial=0)), 1)
    border = np.zeros((S, Bmax), dtype=np.int32)
    for s, b in enumerate(borders):
        border[s, : len(b)] = b

    # halo_src[s, h]: where halo id halo[s, h] lands in the all-gathered
    # (S * Bmax,) border pool — owner shard block, then position within the
    # owner's sorted border list.
    halo_src = np.zeros((S, Hmax), dtype=np.int32)
    for s, h in enumerate(halos):
        if not len(h):
            continue
        owner = shard_of[h]
        pos = np.empty(len(h), dtype=np.int64)
        for d in np.unique(owner):
            sel = owner == d
            pos[sel] = np.searchsorted(borders[d], local_of[h[sel]])
        halo_src[s, : len(h)] = owner.astype(np.int64) * Bmax + pos

    # Per-shard padded neighbour tiles in extended-local coordinates
    # ([own rows ; halo rows]), preserving CSR neighbour order so the
    # per-row reduction matches CSRGraph.padded_neighbors bit-for-bit.
    idx = np.tile(np.arange(R, dtype=np.int32)[None, :, None], (S, 1, K))
    w = np.zeros((S, R, K), dtype=np.float64)
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        size = hi - lo
        sl = slice(indptr[lo], indptr[hi])
        cols = csr.indices[sl].astype(np.int64)
        vals = csr.data[sl]
        deg = np.diff(indptr[lo : hi + 1])
        rows_local = np.repeat(np.arange(size, dtype=np.int64), deg)
        pos = np.arange(len(cols)) - np.repeat(indptr[lo:hi] - indptr[lo], deg)
        local_cols = np.where(
            (cols >= lo) & (cols < hi),
            cols - lo,
            R + np.searchsorted(halos[s], cols.astype(np.int32)),
        )
        idx[s, rows_local, pos] = local_cols.astype(np.int32)
        w[s, rows_local, pos] = vals
    return GraphPartition(
        csr=csr,
        num_shards=S,
        mode=mode,
        bounds=bounds,
        owned=owned,
        sizes=sizes,
        shard_of=shard_of,
        local_of=local_of,
        halo=halo,
        halo_sizes=halo_sizes,
        border=border,
        border_sizes=border_sizes,
        halo_src=halo_src,
        idx=idx,
        w=w,
    )
