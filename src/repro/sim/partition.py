"""Agent-block graph partitioning for the multi-device async engine.

The paper's algorithm is fully decentralized — a wake-up touches one
agent's neighbourhood only — so the natural way past one device's memory
is to shard *agents* across devices. This module cuts a :class:`CSRGraph`
into ``num_shards`` index blocks (equal-count blocks, or degree-balanced
blocks that equalize per-shard nnz), optionally after a **locality
relabel** pass (reverse Cuthill–McKee, or a Morton space-filling curve
for geometric graphs) that permutes agent positions so that graph
neighbours land in the same block and the cut — and with it the halo
traffic — shrinks. It precomputes everything the shard-local super-tick
needs as stacked ``(S, ...)`` arrays that ``shard_map`` splits along the
leading axis:

* ``owned``: each shard's global agent ids (always *original* ids,
  whatever the relabeling), padded to the max block size ``R`` with the
  sentinel ``n``;
* per-shard **padded neighbour tiles** ``idx``/``w`` of width ``K`` (the
  global max degree), whose column indices live in the shard's *extended*
  local array ``[own rows (R) ; halo rows (Hmax)]``;
* **halo maps** for the cross-shard edges: ``halo`` lists the remote
  global ids a shard reads, ``halo_owner`` the shard that owns each of
  them, ``border`` lists the local rows a shard must publish, and
  ``halo_src`` maps each halo slot to its position in the all-gathered
  ``(S * Bmax,)`` border pool;
* a **point-to-point plan** (:func:`point_to_point_plan`): per
  shard-offset ``d``, the local rows each shard ships to the shard ``d``
  hops ahead on the mesh ring and the halo slots the receiver writes them
  to — the ``ppermute`` alternative to the replicated border pool.

The exchange itself (all-gather pool or neighbour-shard ``ppermute``)
lives in :class:`repro.core.mixing.ShardedMixOp`; this module is pure
numpy and is also used directly by the halo round-trip property tests.

Relabeling never leaks into caller-visible ids: ``owned``/``halo``/
``shard_of``/``local_of`` all speak original agent ids, so
``pad_rows``/``unpad_rows`` (and the engine's ``global_theta``) are the
identity round-trip under any permutation — callers need no unrelabel
step. The permutation itself is exposed as ``order`` for diagnostics.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.graph import CSRGraph


@dataclasses.dataclass(frozen=True, eq=False)
class GraphPartition:
    """An agent-block partition of a CSR graph with halo and exchange maps.

    Shapes: ``S = num_shards``, ``R = rows_per_shard`` (max block size),
    ``K = tile_width`` (max degree), ``Bmax``/``Hmax`` the padded border
    and halo widths. Shard ``s`` owns the agents at *positions*
    ``[bounds[s], bounds[s+1])`` of the (possibly relabeled) ``order``;
    all id-valued arrays hold original agent ids.
    """

    csr: CSRGraph
    num_shards: int
    mode: str
    relabel: str | None  # None | "rcm" | "sfc" | "custom"
    order: np.ndarray  # (n,) position -> original agent id (the relabel permutation)
    bounds: np.ndarray  # (S + 1,) block boundaries in *positions* of ``order``
    owned: np.ndarray  # (S, R) original agent ids, sentinel n past the block
    sizes: np.ndarray  # (S,) real rows per shard
    shard_of: np.ndarray  # (n,) owning shard per agent (original ids)
    local_of: np.ndarray  # (n,) local row within the owning shard (original ids)
    halo: np.ndarray  # (S, Hmax) remote global ids each shard reads, sentinel n
    halo_sizes: np.ndarray  # (S,)
    halo_owner: np.ndarray  # (S, Hmax) owning shard per halo slot, sentinel S
    border: np.ndarray  # (S, Bmax) local rows each shard publishes, padded 0
    border_sizes: np.ndarray  # (S,)
    halo_src: np.ndarray  # (S, Hmax) flat index into the (S * Bmax,) border pool
    idx: np.ndarray  # (S, R, K) extended-local neighbour indices
    w: np.ndarray  # (S, R, K) neighbour weights (pad entries 0)

    @property
    def n(self) -> int:
        """Total number of agents in the partitioned graph."""
        return self.csr.n

    @property
    def rows_per_shard(self) -> int:
        """R: padded rows per shard (max block size over shards)."""
        return self.owned.shape[1]

    @property
    def tile_width(self) -> int:
        """K: padded neighbour-tile width (>= global max degree)."""
        return self.idx.shape[2]

    def halo_fraction(self) -> float:
        """Mean fraction of read rows that cross shards (comm diagnostics)."""
        reads = self.sizes + self.halo_sizes
        return float(self.halo_sizes.sum() / max(reads.sum(), 1))

    def neighbor_shards(self) -> list[np.ndarray]:
        """Per-shard sorted array of the shards whose rows this shard reads.

        Empty array for shards whose blocks have no cross-shard edge; a
        shard never lists itself. This is the communication graph the
        point-to-point exchange walks.
        """
        return [
            np.unique(self.halo_owner[s, : int(self.halo_sizes[s])]).astype(np.int64)
            for s in range(self.num_shards)
        ]

    @functools.cached_property
    def p2p_plan(self) -> tuple[tuple[int, ...], tuple[np.ndarray, ...], tuple[np.ndarray, ...]]:
        """Cached :func:`point_to_point_plan` for this partition."""
        return point_to_point_plan(self)

    def exchange_rows(self, method: str) -> int:
        """Interconnect rows moved per super-tick under an exchange method.

        ``"all_gather"``: every shard receives the other ``S - 1`` shards'
        padded ``Bmax`` border rows from the replicated pool. ``"p2p"``:
        every shard receives one padded ``P_d`` buffer per ring offset
        ``d`` in the plan. Counts are rows summed over all shards (one row
        = one ``(p,)`` model vector); padding rows are counted because
        static shapes ship them. Used by the ``method="auto"`` selection
        in :func:`repro.core.mixing.sharded_mix_op`.
        """
        S = self.num_shards
        if S <= 1:
            return 0
        if method == "all_gather":
            return S * (S - 1) * int(self.border.shape[1])
        if method != "p2p":
            raise ValueError(f"unknown exchange method {method!r}")
        _, sends, _ = self.p2p_plan
        return S * int(sum(s.shape[1] for s in sends))

    # -- dynamic topology: drift gauge + incremental rebind ----------------
    def cut_weight(self, csr: CSRGraph | None = None) -> float:
        """Total edge weight crossing shard boundaries under *this* cut.

        With ``csr`` given, the live graph is measured against the
        ownership frozen at partition time — the drift gauge input.
        """
        csr = self.csr if csr is None else csr
        if csr.n != self.n:
            raise ValueError(f"graph has {csr.n} agents, partition has {self.n}")
        rows = csr.row_ids()
        cross = self.shard_of[rows] != self.shard_of[csr.indices]
        return float(np.asarray(csr.data)[cross].sum() / 2.0)

    def cut_fraction(self, csr: CSRGraph | None = None) -> float:
        """Cut weight as a fraction of total edge weight (0 when no edges)."""
        csr = self.csr if csr is None else csr
        total = float(np.asarray(csr.data).sum() / 2.0)
        if total <= 0.0:
            return 0.0
        return self.cut_weight(csr) / total

    def drift(self, new_csr: CSRGraph) -> float:
        """Topology drift: cut fraction of the live graph minus at cut time.

        Positive drift means edge weight has migrated onto shard
        boundaries since this partition was cut — the engine's
        repartition-trigger policy compares it to
        ``EngineConfig.drift_threshold``.
        """
        return self.cut_fraction(new_csr) - self.cut_fraction()

    def patch(self, new_csr: CSRGraph) -> "GraphPartition":
        """Rebind halo rows + exchange maps to ``new_csr`` without a rebuild.

        Ownership (relabel order, block bounds, ``owned``/``shard_of``/
        ``local_of``) is kept frozen — that is the entire saving over
        :func:`partition_graph`, which would redo the relabel pass and
        the block cut. Two paths:

        * weight-only (identical ``indptr``/``indices``): only the ``w``
          tiles are regathered; every map — including the cached
          ``p2p_plan`` — carries over unchanged.
        * structural: the halo/border/exchange maps and neighbour tiles
          are rebuilt against the frozen ownership. The tile width never
          shrinks (it grows to the new max degree when needed), keeping
          downstream jit programs stable under pure edge deletion.
        """
        if new_csr.n != self.n:
            raise ValueError(f"graph has {new_csr.n} agents, partition has {self.n}")
        same_structure = np.array_equal(
            np.asarray(self.csr.indptr), np.asarray(new_csr.indptr)
        ) and np.array_equal(np.asarray(self.csr.indices), np.asarray(new_csr.indices))
        if same_structure:
            w = self.w.copy()
            for s in range(self.num_shards):
                lo, hi = int(self.bounds[s]), int(self.bounds[s + 1])
                _, vals, deg, offs = _row_gather(new_csr, self.order[lo:hi])
                rows_local = np.repeat(np.arange(hi - lo, dtype=np.int64), deg)
                w[s, rows_local, offs] = vals
            patched = dataclasses.replace(self, csr=new_csr, w=w)
            # Same structure -> identical plan; carry the cache over.
            patched.__dict__["p2p_plan"] = self.p2p_plan
            return patched
        K = max(self.tile_width, new_csr.max_degree())
        tiles = _halo_tiles(
            new_csr,
            self.num_shards,
            self.order,
            self.bounds,
            self.sizes,
            self.rows_per_shard,
            K,
            self.shard_of,
            self.local_of,
        )
        return dataclasses.replace(self, csr=new_csr, **tiles)

    # -- row <-> shard layout conversions ---------------------------------
    def pad_rows(self, x, fill=0):
        """(n, ...) per-agent array -> (S, R, ...) shard layout, ``fill`` pads."""
        x = np.asarray(x)
        if x.shape[:1] != (self.n,):
            raise ValueError(f"expected leading dim {self.n}, got {x.shape}")
        out = np.full((self.num_shards, self.rows_per_shard) + x.shape[1:], fill, dtype=x.dtype)
        real = self.owned < self.n
        out[real] = x[self.owned[real]]
        return out

    def unpad_rows(self, x_sh):
        """(S, R, ...) shard layout -> (n, ...) per-agent array (drops padding)."""
        x_sh = np.asarray(x_sh)
        if x_sh.shape[:2] != self.owned.shape:
            raise ValueError(f"expected leading dims {self.owned.shape}, got {x_sh.shape}")
        out = np.empty((self.n,) + x_sh.shape[2:], dtype=x_sh.dtype)
        real = self.owned < self.n
        out[self.owned[real]] = x_sh[real]
        return out

    def place_rows(self, out, ids, rows):
        """Scatter per-agent ``rows`` (keyed by original agent ``ids``)
        into the (S, R, ...) shard layout ``out``, in place.

        The elastic-restore primitive: a checkpoint written under one cut
        re-tiles under another by routing each owned row through this
        partition's ``shard_of``/``local_of`` maps — no (n, ...) host
        array is ever assembled, unlike ``pad_rows``/``unpad_rows``.
        """
        ids = np.asarray(ids)
        rows = np.asarray(rows)
        if out.shape[:2] != self.owned.shape:
            raise ValueError(f"expected leading dims {self.owned.shape}, got {out.shape}")
        if ids.shape[:1] != rows.shape[:1]:
            raise ValueError(f"ids/rows leading dims differ: {ids.shape} vs {rows.shape}")
        out[self.shard_of[ids], self.local_of[ids]] = rows
        return out


# ---------------------------------------------------------------------------
# Locality relabeling
# ---------------------------------------------------------------------------


def _rcm_order_numpy(csr: CSRGraph) -> np.ndarray:
    """Pure-numpy reverse Cuthill–McKee fallback (scipy unavailable).

    Per component: BFS from a minimum-degree start node, visiting each
    frontier's unvisited neighbours in ascending-degree order, then
    reverse the full visitation sequence. O(n + nnz log deg); the scipy
    path is preferred at large n.
    """
    n = csr.n
    deg = np.diff(csr.indptr)
    visited = np.zeros(n, dtype=bool)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    for start in np.argsort(deg, kind="stable"):
        if visited[start]:
            continue
        visited[start] = True
        queue = [int(start)]
        head = 0
        while head < len(queue):
            i = queue[head]
            head += 1
            out[pos] = i
            pos += 1
            nbrs = csr.neighbors(i)
            nbrs = nbrs[~visited[nbrs]]
            if len(nbrs):
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                visited[nbrs] = True
                queue.extend(int(j) for j in nbrs)
    return out[::-1].copy()


def rcm_order(csr: CSRGraph) -> np.ndarray:
    """Reverse Cuthill–McKee ordering: (n,) position -> agent id.

    A bandwidth-reducing BFS relabeling: after it, graph neighbours sit at
    nearby positions, so contiguous position blocks have O(boundary) cuts
    instead of O(volume). Uses scipy's C implementation when available and
    a pure-numpy BFS otherwise.
    """
    try:
        from scipy.sparse import csr_matrix
        from scipy.sparse.csgraph import reverse_cuthill_mckee
    except ImportError:  # pragma: no cover - exercised where scipy is absent
        return _rcm_order_numpy(csr)
    mat = csr_matrix(
        (np.asarray(csr.data), np.asarray(csr.indices), np.asarray(csr.indptr)),
        shape=(csr.n, csr.n),
    )
    return np.asarray(reverse_cuthill_mckee(mat, symmetric_mode=True), dtype=np.int64)


def sfc_order(coords: np.ndarray) -> np.ndarray:
    """Morton (Z-order) space-filling-curve ordering of 2-D coordinates.

    ``coords``: (n, 2) positions (any units; rescaled to the bounding
    box). Each point is quantized to a 16-bit grid per axis and sorted by
    the bit-interleaved Morton key, so spatially-close agents get nearby
    positions — the right relabel for ``random_geometric_graph``-style
    topologies where edges are short-range. Returns (n,) position ->
    agent id.
    """
    c = np.asarray(coords, dtype=np.float64)
    if c.ndim != 2 or c.shape[1] != 2:
        raise ValueError(f"coords must be (n, 2), got {c.shape}")
    mins = c.min(axis=0)
    span = c.max(axis=0) - mins
    span = np.where(span > 0.0, span, 1.0)
    q = ((c - mins) / span * (2**16 - 1)).astype(np.uint64)

    def spread(v):
        # 16 significant bits -> 32, a zero between every pair of bits.
        v = (v | (v << 8)) & np.uint64(0x00FF00FF)
        v = (v | (v << 4)) & np.uint64(0x0F0F0F0F)
        v = (v | (v << 2)) & np.uint64(0x33333333)
        v = (v | (v << 1)) & np.uint64(0x55555555)
        return v

    key = (spread(q[:, 0]) << np.uint64(1)) | spread(q[:, 1])
    return np.argsort(key, kind="stable").astype(np.int64)


def hilbert_order(coords: np.ndarray, bits: int = 16) -> np.ndarray:
    """Hilbert-curve space-filling ordering of 2-D coordinates.

    Same contract as :func:`sfc_order` (Morton), but sorts by the Hilbert
    curve index instead of the bit-interleaved Z-order key. The Hilbert
    curve has no diagonal jumps — consecutive curve positions are always
    grid neighbours — so block cuts along it have strictly local
    boundaries where Morton's quadrant seams put far-apart points at
    adjacent positions. That is exactly the S=16 regime the ROADMAP
    flags: more shards means more cuts landing on Morton seams. Returns
    (n,) position -> agent id.

    Vectorized transcription of the standard ``xy2d`` bit-descent: per
    quantization level ``s`` the quadrant pair (rx, ry) contributes
    ``s^2 * ((3 rx) XOR ry)`` to the curve index, then the lower-level
    coordinates are rotated/reflected into the quadrant's frame.
    """
    c = np.asarray(coords, dtype=np.float64)
    if c.ndim != 2 or c.shape[1] != 2:
        raise ValueError(f"coords must be (n, 2), got {c.shape}")
    mins = c.min(axis=0)
    span = c.max(axis=0) - mins
    span = np.where(span > 0.0, span, 1.0)
    q = ((c - mins) / span * (2**bits - 1)).astype(np.int64)
    x, y = q[:, 0].copy(), q[:, 1].copy()
    d = np.zeros(len(c), dtype=np.int64)
    s = np.int64(1) << (bits - 1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        # Rotate the sub-square: in the ry == 0 quadrants the lower bits
        # traverse a reflected/transposed copy of the curve.
        flip = (ry == 0) & (rx == 1)
        x = np.where(flip, s - 1 - x, x)
        y = np.where(flip, s - 1 - y, y)
        swap = ry == 0
        x, y = np.where(swap, y, x), np.where(swap, x, y)
        s >>= 1
    return np.argsort(d, kind="stable").astype(np.int64)


def _resolve_order(csr: CSRGraph, relabel, coords) -> tuple[str | None, np.ndarray]:
    """Resolve the ``relabel`` argument into (mode name, order array)."""
    n = csr.n
    if relabel is None:
        return None, np.arange(n, dtype=np.int64)
    if isinstance(relabel, str):
        if relabel == "rcm":
            return "rcm", rcm_order(csr)
        if relabel in ("sfc", "hilbert"):
            if coords is None:
                raise ValueError(
                    f"relabel={relabel!r} needs coords: the (n, 2) agent positions"
                )
            order = sfc_order(coords) if relabel == "sfc" else hilbert_order(coords)
            if len(order) != n:
                raise ValueError(f"coords rows ({len(order)}) != agents ({n})")
            return relabel, order
        raise ValueError(
            f"unknown relabel mode {relabel!r} (use 'rcm', 'sfc', 'hilbert', or an order)"
        )
    order = np.asarray(relabel, dtype=np.int64)
    if order.shape != (n,) or not np.array_equal(np.sort(order), np.arange(n)):
        raise ValueError("explicit relabel must be a permutation of arange(n)")
    return "custom", order


# ---------------------------------------------------------------------------
# Block cutting
# ---------------------------------------------------------------------------


def _block_bounds(csr: CSRGraph, num_shards: int, mode: str, order: np.ndarray) -> np.ndarray:
    """Cut the permuted position axis into ``num_shards`` blocks."""
    n, S = csr.n, num_shards
    if mode == "contiguous":
        return np.array([n * s // S for s in range(S + 1)], dtype=np.int64)
    if mode != "degree":
        raise ValueError(f"unknown partition mode {mode!r}")
    # Degree-balanced: put boundaries at equal cumulative-nnz quantiles of
    # the *permuted* degree sequence so every shard carries ~nnz/S edge
    # work, whatever the degree skew or relabeling.
    deg = np.diff(np.asarray(csr.indptr, dtype=np.int64))
    cum = np.concatenate([[0], np.cumsum(deg[order])])
    target = csr.nnz * np.arange(1, S, dtype=np.float64) / S
    cuts = np.searchsorted(cum, target)
    bounds = np.concatenate([[0], cuts, [n]]).astype(np.int64)
    for s in range(1, S + 1):  # keep blocks non-empty and ordered
        bounds[s] = min(max(bounds[s], bounds[s - 1] + 1), n - (S - s))
    bounds[S] = n
    return bounds


def partition_graph(
    csr: CSRGraph,
    num_shards: int,
    mode: str = "degree",
    tile_width: int | None = None,
    relabel: str | np.ndarray | None = None,
    coords: np.ndarray | None = None,
) -> GraphPartition:
    """Cut ``csr`` into agent blocks with halo/border/exchange maps.

    ``mode``: "contiguous" (equal agent counts) or "degree" (equal nnz).
    ``relabel``: None (cut original ids in index order), ``"rcm"``
    (reverse Cuthill–McKee), ``"sfc"`` (Morton curve over ``coords``,
    the (n, 2) agent positions), or an explicit (n,) permutation
    (position -> agent id). Blocks are contiguous in the relabeled
    position space; all returned id arrays stay in original ids, so
    results need no unrelabel step.
    ``tile_width`` pads the neighbour tiles to at least the global max
    degree (the default), which keeps the per-row contraction extent
    identical to the single-device padded tiles — the forced-wake parity
    guarantee rests on that, together with the tiles preserving the
    original CSR neighbour order per row under any relabeling.
    """
    n, S = csr.n, int(num_shards)
    if not (1 <= S <= max(n, 1)):
        raise ValueError(f"num_shards must lie in [1, n={n}], got {S}")
    relabel_mode, order = _resolve_order(csr, relabel, coords)
    bounds = _block_bounds(csr, S, mode, order)
    sizes = np.diff(bounds).astype(np.int64)
    R = int(sizes.max())
    K = max(csr.max_degree(), 1)
    if tile_width is not None:
        if tile_width < K:
            raise ValueError(f"tile_width={tile_width} < max degree {K}")
        K = int(tile_width)

    owned = np.full((S, R), n, dtype=np.int32)
    shard_of = np.empty(n, dtype=np.int32)
    local_of = np.empty(n, dtype=np.int32)
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        ids = order[lo:hi]
        owned[s, : hi - lo] = ids.astype(np.int32)
        shard_of[ids] = s
        local_of[ids] = np.arange(hi - lo, dtype=np.int32)

    tiles = _halo_tiles(csr, S, order, bounds, sizes, R, K, shard_of, local_of)
    return GraphPartition(
        csr=csr,
        num_shards=S,
        mode=mode,
        relabel=relabel_mode,
        order=order,
        bounds=bounds,
        owned=owned,
        sizes=sizes,
        shard_of=shard_of,
        local_of=local_of,
        **tiles,
    )


def partition_from_ownership(
    csr: CSRGraph,
    order: np.ndarray,
    bounds: np.ndarray,
    mode: str = "degree",
    relabel: str | None = None,
    tile_width: int | None = None,
) -> GraphPartition:
    """Rebuild a :class:`GraphPartition` from a frozen ownership.

    ``order``/``bounds`` are taken verbatim (no relabel pass, no block
    cut) and only the halo/border/exchange maps and neighbour tiles are
    derived from ``csr`` — the same second half :meth:`GraphPartition.patch`
    runs. This is how a checkpoint restores the *exact* partition a
    sharded run was cut on: the saved ownership may be the product of a
    patch chain that no ``partition_graph`` call reproduces, but given
    (ownership, graph, tile width) the derived maps are deterministic.
    ``mode``/``relabel`` are recorded as provenance only.
    """
    n = csr.n
    order = np.asarray(order, dtype=np.int64)
    bounds = np.asarray(bounds, dtype=np.int64)
    if order.shape != (n,) or not np.array_equal(np.sort(order), np.arange(n)):
        raise ValueError("order must be a permutation of arange(n)")
    S = len(bounds) - 1
    if S < 1 or bounds[0] != 0 or bounds[-1] != n or np.any(np.diff(bounds) <= 0):
        raise ValueError(f"bounds must cut [0, n={n}] into non-empty blocks")
    sizes = np.diff(bounds).astype(np.int64)
    R = int(sizes.max())
    K = max(csr.max_degree(), 1)
    if tile_width is not None:
        if tile_width < K:
            raise ValueError(f"tile_width={tile_width} < max degree {K}")
        K = int(tile_width)
    owned = np.full((S, R), n, dtype=np.int32)
    shard_of = np.empty(n, dtype=np.int32)
    local_of = np.empty(n, dtype=np.int32)
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        ids = order[lo:hi]
        owned[s, : hi - lo] = ids.astype(np.int32)
        shard_of[ids] = s
        local_of[ids] = np.arange(hi - lo, dtype=np.int32)
    tiles = _halo_tiles(csr, S, order, bounds, sizes, R, K, shard_of, local_of)
    return GraphPartition(
        csr=csr,
        num_shards=S,
        mode=mode,
        relabel=relabel,
        order=order,
        bounds=bounds,
        owned=owned,
        sizes=sizes,
        shard_of=shard_of,
        local_of=local_of,
        **tiles,
    )


def _row_gather(csr: CSRGraph, ids: np.ndarray):
    """Flat CSR gather of the rows ``ids`` (preserving per-row order).

    Returns ``(cols, vals, deg, offs)`` where ``offs[e]`` is edge ``e``'s
    position within its row — reused by the tile builds as the tile
    column coordinate. Reduces to the indptr slice when ``ids`` is a
    contiguous identity range.
    """
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    deg = np.diff(indptr)[ids]
    total = int(deg.sum())
    offs = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(deg) - deg, deg)
    flat = np.repeat(indptr[ids], deg) + offs
    return csr.indices[flat].astype(np.int64), csr.data[flat], deg, offs


def _halo_tiles(
    csr: CSRGraph,
    S: int,
    order: np.ndarray,
    bounds: np.ndarray,
    sizes: np.ndarray,
    R: int,
    K: int,
    shard_of: np.ndarray,
    local_of: np.ndarray,
) -> dict:
    """Halo/border/exchange maps + neighbour tiles for a frozen ownership.

    The second half of :func:`partition_graph`, split out so
    :meth:`GraphPartition.patch` can rebind a changed graph to an
    existing cut (order/bounds/ownership untouched) without paying for
    the relabel pass or the block cut again. Returns the field dict
    ``{halo, halo_sizes, halo_owner, border, border_sizes, halo_src,
    idx, w}``.
    """
    n = csr.n
    # Flat CSR row gathers per shard (reduces to the indptr slice when the
    # order is the identity): cols/vals keep the original per-row
    # neighbour order, which the bit-exactness guarantee rests on.
    shard_cols, shard_vals, shard_degs, shard_offs = [], [], [], []
    halos = []
    for s in range(S):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        cols, vals, deg, offs = _row_gather(csr, order[lo:hi])
        shard_cols.append(cols)
        shard_vals.append(vals)
        shard_degs.append(deg)
        shard_offs.append(offs)
        halos.append(np.unique(cols[shard_of[cols] != s]).astype(np.int32))
    halo_sizes = np.array([len(h) for h in halos], dtype=np.int64)
    Hmax = max(int(halo_sizes.max(initial=0)), 1)
    halo = np.full((S, Hmax), n, dtype=np.int32)
    halo_owner = np.full((S, Hmax), S, dtype=np.int32)
    for s, h in enumerate(halos):
        halo[s, : len(h)] = h
        halo_owner[s, : len(h)] = shard_of[h]

    # Border of shard s = its local rows referenced by any other shard's
    # halo, unique-sorted in local-row order.
    all_halo = np.concatenate(halos) if halos else np.zeros(0, dtype=np.int32)
    owner_all = shard_of[all_halo] if len(all_halo) else np.zeros(0, dtype=np.int32)
    borders = []
    for s in range(S):
        mine = all_halo[owner_all == s]
        borders.append(np.unique(local_of[mine]).astype(np.int32))
    border_sizes = np.array([len(b) for b in borders], dtype=np.int64)
    Bmax = max(int(border_sizes.max(initial=0)), 1)
    border = np.zeros((S, Bmax), dtype=np.int32)
    for s, b in enumerate(borders):
        border[s, : len(b)] = b

    # halo_src[s, h]: where halo id halo[s, h] lands in the all-gathered
    # (S * Bmax,) border pool — owner shard block, then position within the
    # owner's sorted border list.
    halo_src = np.zeros((S, Hmax), dtype=np.int32)
    for s, h in enumerate(halos):
        if not len(h):
            continue
        owner = shard_of[h]
        pos = np.empty(len(h), dtype=np.int64)
        for d in np.unique(owner):
            sel = owner == d
            pos[sel] = np.searchsorted(borders[d], local_of[h[sel]])
        halo_src[s, : len(h)] = owner.astype(np.int64) * Bmax + pos

    # Per-shard padded neighbour tiles in extended-local coordinates
    # ([own rows ; halo rows]), preserving the original CSR neighbour
    # order per row so the per-row reduction matches
    # CSRGraph.padded_neighbors bit-for-bit under any relabeling.
    idx = np.tile(np.arange(R, dtype=np.int32)[None, :, None], (S, 1, K))
    w = np.zeros((S, R, K), dtype=np.float64)
    for s in range(S):
        size = int(sizes[s])
        cols, vals, deg, pos = shard_cols[s], shard_vals[s], shard_degs[s], shard_offs[s]
        rows_local = np.repeat(np.arange(size, dtype=np.int64), deg)
        local_cols = np.where(
            shard_of[cols] == s,
            local_of[cols],
            R + np.searchsorted(halos[s], cols),
        )
        idx[s, rows_local, pos] = local_cols.astype(np.int32)
        w[s, rows_local, pos] = vals
    return dict(
        halo=halo,
        halo_sizes=halo_sizes,
        halo_owner=halo_owner,
        border=border,
        border_sizes=border_sizes,
        halo_src=halo_src,
        idx=idx,
        w=w,
    )


def point_to_point_plan(
    part: GraphPartition,
) -> tuple[tuple[int, ...], tuple[np.ndarray, ...], tuple[np.ndarray, ...]]:
    """Neighbour-shard exchange plan: one ``ppermute`` per ring offset.

    Returns ``(offsets, sends, dsts)``. For each mesh-ring offset
    ``d = offsets[k]`` (a distinct value of ``(reader - owner) mod S``
    over cross-shard edges):

    * ``sends[k]``: (S, P_d) int32 — the local rows shard ``t`` packs
      into the buffer it ships to shard ``(t + d) mod S`` (padded with
      row 0; padding is never referenced by the receiver);
    * ``dsts[k]``: (S, P_d) int32 — the halo slot (position in
      ``[0, Hmax)``) shard ``s`` writes each received buffer row to,
      padded with the sentinel ``Hmax`` (dropped by the scatter).

    Buffer slot ``j`` of the (t -> s) pair carries owner-local row
    ``sends[k][t, j]`` and lands in halo slot ``dsts[k][s, j]`` — both
    sides are built from the same traversal of shard ``s``'s halo list,
    so the alignment is by construction. Total shipped rows per
    super-tick are ``S * sum_d P_d``, vs ``S * (S-1) * Bmax`` for the
    replicated all-gather pool — the ``method="auto"`` selection in
    :func:`repro.core.mixing.sharded_mix_op` compares exactly these.
    """
    S, Hmax = part.halo.shape
    send_by_off: dict[int, dict[int, np.ndarray]] = {}
    dst_by_off: dict[int, dict[int, np.ndarray]] = {}
    for s in range(S):
        hs = int(part.halo_sizes[s])
        ids = part.halo[s, :hs]
        owners = part.shard_of[ids]
        for t in np.unique(owners):
            d = int((s - int(t)) % S)
            sel = np.nonzero(owners == t)[0]
            send_by_off.setdefault(d, {})[int(t)] = part.local_of[ids[sel]].astype(np.int32)
            dst_by_off.setdefault(d, {})[s] = sel.astype(np.int32)
    offsets = tuple(sorted(send_by_off))
    sends, dsts = [], []
    for d in offsets:
        P = max(max(len(v) for v in send_by_off[d].values()), 1)
        snd = np.zeros((S, P), dtype=np.int32)
        dst = np.full((S, P), Hmax, dtype=np.int32)
        for t, rows_t in send_by_off[d].items():
            snd[t, : len(rows_t)] = rows_t
        for s, slots in dst_by_off[d].items():
            dst[s, : len(slots)] = slots
        sends.append(snd)
        dsts.append(dst)
    return offsets, tuple(sends), tuple(dsts)
