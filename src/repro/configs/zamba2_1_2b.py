"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_kernel=4, chunk=128, expand=2),
    shared_attn_every=6,  # shared attn block applied every 6 mamba layers
    tie_embeddings=True,
    citation="arXiv:2411.15242",
)
