"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] [arXiv:2405.04517]."""

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,  # FFN lives inside the xLSTM blocks (proj_factor)
    vocab_size=50304,
    head_dim=512,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk=128),
    tie_embeddings=True,
    citation="arXiv:2405.04517",
)
