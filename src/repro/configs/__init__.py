"""Assigned architecture configs (+ the paper's native linear configs).

Each module defines ``CONFIG`` with the exact assigned hyperparameters and
cites its source. ``get_config(name)`` resolves by arch id.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    DECODE_32K,
    ModelConfig,
    MoEConfig,
    P2PConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    reduced,
)

ARCH_IDS = [
    "llama3.2-1b",
    "granite-moe-3b-a800m",
    "qwen1.5-4b",
    "chameleon-34b",
    "seamless-m4t-medium",
    "zamba2-1.2b",
    "qwen2.5-14b",
    "grok-1-314b",
    "xlstm-1.3b",
    "granite-3-8b",
]

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen1.5-4b": "qwen1_5_4b",
    "chameleon-34b": "chameleon_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2.5-14b": "qwen2_5_14b",
    "grok-1-314b": "grok_1_314b",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-3-8b": "granite_3_8b",
}

# P2P agent-mode per arch (DESIGN.md §5): memory-bound giants run in "silo"
# mode (agent = pod, FSDP+TP within), everything else gets 16/32 personal
# replicas ("full").
AGENT_MODES = {
    "llama3.2-1b": "full",
    "granite-moe-3b-a800m": "full",
    "qwen1.5-4b": "full",
    "chameleon-34b": "silo",
    "seamless-m4t-medium": "full",
    "zamba2-1.2b": "full",
    "qwen2.5-14b": "full",
    "grok-1-314b": "silo",
    "xlstm-1.3b": "full",
    "granite-3-8b": "full",
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch_id), **overrides)


__all__ = [
    "ARCH_IDS",
    "AGENT_MODES",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
    "P2PConfig",
    "RunConfig",
    "ShapeConfig",
    "get_config",
    "get_reduced",
    "reduced",
]
