"""seamless-m4t-medium [audio] — enc-dec; the mel/conv frontend is stubbed:
the encoder consumes precomputed frame embeddings [arXiv:2308.11596]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,          # decoder layers
    encoder_layers=12,      # speech-encoder transformer layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    citation="arXiv:2308.11596",
)
