"""grok-1-314b [moe] — 8 experts top-2, logit softcap [hf:xai-org/grok-1]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    logit_softcap=30.0,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25, group_size=512),
    citation="hf:xai-org/grok-1",
)
