"""chameleon-34b [vlm] — early-fusion; VQ image tokens live in the vocab
(stubbed VQ tokenizer frontend) [arXiv:2405.09818]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    # last 8192 vocab ids are VQ image codes emitted by the stub frontend
    image_vocab_offset=65536 - 8192,
    citation="arXiv:2405.09818",
)
