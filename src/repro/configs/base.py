"""Architecture + run configuration schema.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro/configs``. The P2P scale layer's settings live in ``P2PConfig``
(agent graph topology, DP budget, gossip schedule) — the paper's technique is
a first-class feature toggled per run, not per architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 256  # tokens per dispatch group (bounds dispatch memory)
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True, eq=False)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    conv_kernel: int = 4
    chunk: int = 128
    expand: int = 2


@dataclasses.dataclass(frozen=True, eq=False)
class XLSTMConfig:
    slstm_every: int = 8  # one sLSTM block per this many blocks (rest mLSTM)
    proj_factor: float = 2.0  # up-projection inside mLSTM blocks
    chunk: int = 128


@dataclasses.dataclass(frozen=True, eq=False)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None  # grok-style tanh soft-capping
    sliding_window: Optional[int] = None  # if set, self-attn is windowed
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2-style): a single shared attention block applied every
    # `shared_attn_every` backbone layers.
    shared_attn_every: Optional[int] = None
    # enc-dec (seamless-style): number of encoder layers; encoder consumes
    # precomputed frontend embeddings (the stub carve-out).
    encoder_layers: int = 0
    # VLM early-fusion: image tokens are a reserved slice of the vocab (VQ
    # codes produced by the stubbed tokenizer frontend).
    image_vocab_offset: Optional[int] = None
    dtype: str = "bfloat16"
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/lm-head
        vocab dim shards over the 16-wide model axis (MaxText-style padding;
        keeps logits vocab-sharded instead of replicated)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Rough analytic parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6 N D."""
        d, hd = self.d_model, self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" and self.xlstm is not None:
            pf = self.xlstm.proj_factor
            di = int(pf * d)
            # mLSTM block: up/gate proj d->2di, qkv di->3di, out di->d (+ norms)
            per = d * 2 * di + di * 3 * di + di * d
            return emb + self.num_layers * per
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.moe is not None:
            ff = self.moe.num_experts * 3 * d * self.d_ff + d * self.moe.num_experts
        else:
            ff = 3 * d * self.d_ff
        if self.family == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * d
            nheads = di // self.ssm.head_dim
            per = (
                d * (2 * di + 2 * nheads * self.ssm.state_dim + nheads)
                + di * d
                + di * self.ssm.conv_kernel
            )
            n_attn = self.num_layers // (self.shared_attn_every or self.num_layers)
            return emb + self.num_layers * per + attn  # attn is shared (1 copy)
        total_blocks = self.num_layers * (attn + ff)
        if self.is_encdec:
            # decoder cross-attn adds one more attention per decoder layer
            total_blocks += self.num_layers * attn
            total_blocks += self.encoder_layers * (attn + ff)
        return emb + total_blocks

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full_ff = self.moe.num_experts * 3 * d * self.d_ff
        act_ff = self.moe.top_k * 3 * d * self.d_ff
        return self.param_count() - self.num_layers * (full_ff - act_ff)


@dataclasses.dataclass(frozen=True, eq=False)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


@dataclasses.dataclass(frozen=True, eq=False)
class P2PConfig:
    """The paper's technique at datacenter scale (DESIGN.md §4)."""

    enabled: bool = True
    # "full": one agent per data-axis index (personal model replicas);
    # "silo": one agent per pod (FSDP+TP within; for memory-bound giants).
    agent_mode: str = "full"
    # circulant gossip topology: neighbour offsets on the agent ring.
    neighbor_offsets: tuple = (1, 2)
    mu: float = 0.04
    # DP budget per agent (eps_bar, delta_bar); noise on local grads (Eq. 6).
    dp_enabled: bool = True
    eps_bar: float = 1.0
    delta_bar: float = float(np.exp(-5.0))
    planned_rounds: int = 100  # T_i for budget splitting
    clip: float = 10.0  # per-example grad clip C (Supp. D.2)
    gossip_dtype: str = "bfloat16"  # payload dtype for Theta exchange

    def __post_init__(self):
        # The three gossip paths (ppermute / sparse / dense) carry
        # divergent legacy fallbacks for an empty ring, so reject it here
        # rather than let them silently disagree.
        if self.enabled and not self.neighbor_offsets:
            raise ValueError("neighbor_offsets must name at least one ring offset")


@dataclasses.dataclass(frozen=True, eq=False)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    p2p: P2PConfig = dataclasses.field(default_factory=P2PConfig)
    learning_rate: float = 3e-4  # local-loss step inside the CD update
    remat: bool = True  # activation checkpointing per layer
    seed: int = 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (<=2 layers, d<=512)."""
    defaults = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 128),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        d_ff=min(cfg.d_ff, 256),
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=32,
    )
    if cfg.moe is not None:
        defaults["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            group_size=32,
        )
    if cfg.ssm is not None:
        defaults["ssm"] = SSMConfig(state_dim=16, head_dim=16, chunk=16)
    if cfg.xlstm is not None:
        defaults["xlstm"] = XLSTMConfig(slstm_every=2, chunk=16)
    if cfg.shared_attn_every is not None:
        defaults["shared_attn_every"] = 2
    if cfg.encoder_layers > 0:
        defaults["encoder_layers"] = 2
    if cfg.num_kv_heads == cfg.num_heads:  # MHA archs keep MHA in reduced form
        defaults["num_kv_heads"] = defaults["num_heads"]
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
