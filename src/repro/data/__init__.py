from repro.data.synthetic import linear_classification_problem
from repro.data.movielens import movielens_twin

__all__ = ["linear_classification_problem", "movielens_twin"]
