"""Synthetic tasks matching the paper's experimental setups.

``linear_classification_problem`` reproduces Sec. 5.1 exactly:
* n agents, each with a hidden target linear separator in R^p;
* W_ij = exp((cos(phi_ij) - 1) / gamma), gamma = 0.1, small weights dropped;
* m_i ~ U{10..100} training points per agent, drawn uniformly around the
  origin, labeled by the target model, labels flipped w.p. 0.05;
* a held-out test set of 100 points per agent;
* lambda_i = 1 / m_i.

Target models are sampled as in Vanhaesebrouck et al. (2017): two random
orthogonal base vectors; each agent's target is a random convex-ish
combination, giving a 1-D spectrum of relatedness.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import AgentGraph, angular_similarity_graph
from repro.core.objective import AgentData


@dataclasses.dataclass
class LinearProblem:
    graph: AgentGraph
    train: AgentData
    test: AgentData
    targets: np.ndarray  # (n, p) hidden target separators


def _sample_targets(n: int, p: int, rng: np.random.Generator) -> np.ndarray:
    u = rng.normal(size=p)
    u /= np.linalg.norm(u)
    v = rng.normal(size=p)
    v -= (v @ u) * u
    v /= np.linalg.norm(v)
    angles = rng.uniform(0.0, np.pi / 2.0, size=n)
    return np.cos(angles)[:, None] * u[None, :] + np.sin(angles)[:, None] * v[None, :]


def _label(points: np.ndarray, target: np.ndarray, noise: float, rng) -> np.ndarray:
    y = np.sign(points @ target)
    y[y == 0] = 1.0
    flips = rng.random(len(y)) < noise
    return np.where(flips, -y, y)


def linear_classification_problem(
    n: int = 100,
    p: int = 100,
    m_low: int = 10,
    m_high: int = 100,
    test_points: int = 100,
    label_noise: float = 0.05,
    gamma: float = 0.1,
    feature_scale: float = 1.0,
    seed: int = 0,
) -> LinearProblem:
    rng = np.random.default_rng(seed)
    targets = _sample_targets(n, p, rng)
    graph = angular_similarity_graph(targets, gamma=gamma)

    ms = rng.integers(m_low, m_high + 1, size=n)
    m_max = int(ms.max())
    X = np.zeros((n, m_max, p))
    y = np.zeros((n, m_max))
    mask = np.zeros((n, m_max))
    Xt = np.zeros((n, test_points, p))
    yt = np.zeros((n, test_points))
    for i in range(n):
        m = int(ms[i])
        # "drawn uniformly around the origin": uniform in [-s, s]^p, normalized
        # to keep the logistic loss 1-Lipschitz as in the paper.
        pts = rng.uniform(-feature_scale, feature_scale, size=(m, p))
        pts /= np.maximum(np.linalg.norm(pts, axis=1, keepdims=True), 1e-12)
        X[i, :m] = pts
        y[i, :m] = _label(pts, targets[i], label_noise, rng)
        mask[i, :m] = 1.0
        tp = rng.uniform(-feature_scale, feature_scale, size=(test_points, p))
        tp /= np.maximum(np.linalg.norm(tp, axis=1, keepdims=True), 1e-12)
        Xt[i] = tp
        yt[i] = _label(tp, targets[i], 0.0, rng)

    return LinearProblem(
        graph=graph,
        train=AgentData(X=X, y=y, mask=mask),
        test=AgentData(X=Xt, y=yt, mask=np.ones((n, test_points))),
        targets=targets,
    )


def eval_accuracy(Theta: np.ndarray, test: AgentData) -> np.ndarray:
    """Per-agent accuracy of sign(theta_i^T x) on the test set."""
    scores = np.einsum("nmp,np->nm", test.X, Theta)
    pred = np.sign(scores)
    pred[pred == 0] = 1.0
    correct = (pred == test.y) * test.mask
    return correct.sum(axis=1) / np.maximum(test.mask.sum(axis=1), 1.0)


def token_stream(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    n_agents: int = 1,
):
    """Per-agent heterogeneous token streams for the LM-scale layer.

    Each agent gets a distinct unigram distribution (Dirichlet-sampled) so the
    personalization signal exists at the data level; used by examples and
    integration tests (not by the dry-run, which uses ShapeDtypeStructs).
    """
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.full(vocab_size, 0.5), size=n_agents)
    while True:
        toks = np.stack(
            [
                rng.choice(vocab_size, size=(batch // n_agents, seq_len), p=probs[a])
                for a in range(n_agents)
            ]
        )
        yield toks.reshape(batch, seq_len).astype(np.int32)
