"""A statistically matched synthetic twin of MovieLens-100K (Sec. 5.2).

MovieLens-100K is not available in this offline container, so we generate a
dataset with the same published statistics and generative structure:

* 943 users, 1682 items, ~100k ratings in {1..5};
* per-user rating counts with mean ~106, std ~100, min 20, max 737 — we draw
  counts from a truncated log-normal fitted to those moments;
* ratings follow a low-rank user/item factor model (rank 20) plus user bias,
  item bias and Gaussian noise, quantized to the 1..5 star scale — the
  standard generative assumption underlying the ALS features the paper uses;
* item features phi_j in R^20 are recovered from the *training* ratings via
  alternating least squares (Zhou et al., 2008), exactly as the paper does.

The experiment protocol then matches Sec. 5.2: 80/20 per-user train/test
split, user-mean normalization, 10-NN cosine graph on training ratings,
quadratic loss with gradient clipping C = 10, lambda_i = 1/m_i, mu = 0.04.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import AgentGraph, knn_cosine_graph
from repro.core.objective import AgentData


@dataclasses.dataclass
class MovieLensTwin:
    train: AgentData  # X = item features of rated movies, y = normalized rating
    test: AgentData
    graph: AgentGraph
    item_features: np.ndarray  # (n_items, p) ALS features
    user_means: np.ndarray  # (n_users,)


def _sample_counts(n_users: int, rng: np.random.Generator) -> np.ndarray:
    """Truncated log-normal matched to MovieLens-100K count stats."""
    # mean 106, std 100, min 20, max 737 -> lognormal(mu=4.35, sigma=0.8), clipped.
    c = rng.lognormal(mean=4.35, sigma=0.8, size=n_users)
    c = np.clip(c, 20, 737)
    return c.astype(int)


def _als(ratings: list[dict[int, float]], n_items: int, p: int, iters: int, reg: float, rng):
    """Alternating least squares on the sparse training ratings."""
    n_users = len(ratings)
    U = 0.1 * rng.normal(size=(n_users, p))
    V = 0.1 * rng.normal(size=(n_items, p))
    by_item: list[list[tuple[int, float]]] = [[] for _ in range(n_items)]
    for u, rd in enumerate(ratings):
        for j, r in rd.items():
            by_item[j].append((u, r))
    eye = reg * np.eye(p)
    for _ in range(iters):
        for u, rd in enumerate(ratings):
            if not rd:
                continue
            idx = np.fromiter(rd.keys(), int)
            r = np.fromiter(rd.values(), float)
            Vj = V[idx]
            U[u] = np.linalg.solve(Vj.T @ Vj + len(idx) * eye, Vj.T @ r)
        for j, lst in enumerate(by_item):
            if not lst:
                continue
            idx = np.array([u for u, _ in lst])
            r = np.array([x for _, x in lst])
            Uu = U[idx]
            V[j] = np.linalg.solve(Uu.T @ Uu + len(lst) * eye, Uu.T @ r)
    return U, V


def movielens_twin(
    n_users: int = 943,
    n_items: int = 1682,
    p: int = 20,
    rank: int = 20,
    noise: float = 1.2,
    train_frac: float = 0.8,
    als_iters: int = 6,
    seed: int = 0,
    n_clusters: int = 25,
    cluster_spread: float = 0.25,
) -> MovieLensTwin:
    rng = np.random.default_rng(seed)
    # Ground-truth low-rank structure. User factors are CLUSTERED (taste
    # communities), matching the strong user-similarity structure of the
    # real dataset — this is what the paper's graph regularizer exploits.
    centers = rng.normal(scale=0.6, size=(n_clusters, rank))
    assign = rng.integers(0, n_clusters, size=n_users)
    Utrue = centers[assign] + rng.normal(scale=0.6 * cluster_spread, size=(n_users, rank))
    Vtrue = rng.normal(scale=0.6, size=(n_items, rank))
    user_bias = rng.normal(scale=0.4, size=n_users)
    item_pop = rng.dirichlet(np.full(n_items, 0.3))  # popularity skew
    counts = _sample_counts(n_users, rng)

    train_ratings: list[dict[int, float]] = []
    test_ratings: list[dict[int, float]] = []
    for u in range(n_users):
        k = int(counts[u])
        items = rng.choice(n_items, size=min(k, n_items), replace=False, p=item_pop)
        raw = Utrue[u] @ Vtrue[items].T + user_bias[u] + rng.normal(scale=noise, size=len(items))
        stars = np.clip(np.round(3.0 + raw), 1, 5)
        n_train = max(int(train_frac * len(items)), 1)
        perm = rng.permutation(len(items))
        tr = {int(items[i]): float(stars[i]) for i in perm[:n_train]}
        te = {int(items[i]): float(stars[i]) for i in perm[n_train:]}
        train_ratings.append(tr)
        test_ratings.append(te)

    # Per-user mean normalization (computed on train only).
    user_means = np.array(
        [np.mean(list(r.values())) if r else 3.0 for r in train_ratings]
    )

    # ALS item features from the (normalized) training ratings.
    norm_train = [
        {j: r - user_means[u] for j, r in rd.items()} for u, rd in enumerate(train_ratings)
    ]
    _, V = _als(norm_train, n_items, p, als_iters, reg=0.05, rng=rng)

    # Build per-agent padded regression datasets: x = phi_j, y = r_uj - mean_u.
    def pack(ratings_list):
        m_max = max(max((len(r) for r in ratings_list), default=1), 1)
        X = np.zeros((n_users, m_max, p))
        y = np.zeros((n_users, m_max))
        mask = np.zeros((n_users, m_max))
        for u, rd in enumerate(ratings_list):
            for k, (j, r) in enumerate(rd.items()):
                X[u, k] = V[j]
                y[u, k] = r - user_means[u]
                mask[u, k] = 1.0
        return AgentData(X=X, y=y, mask=mask)

    train = pack(train_ratings)
    test = pack(test_ratings)

    # 10-NN cosine graph on raw training rating vectors (sparse, as the paper).
    vecs = np.zeros((n_users, n_items))
    for u, rd in enumerate(train_ratings):
        for j, r in rd.items():
            vecs[u, j] = r
    graph = knn_cosine_graph(vecs, k=10)

    return MovieLensTwin(
        train=train, test=test, graph=graph, item_features=V, user_means=user_means
    )


def rmse(Theta: np.ndarray, data: AgentData) -> float:
    """Per-user test RMSE averaged over users (Table 1 metric)."""
    pred = np.einsum("nmp,np->nm", data.X, Theta)
    err = (pred - data.y) ** 2 * data.mask
    m = np.maximum(data.mask.sum(axis=1), 1.0)
    per_user = np.sqrt(err.sum(axis=1) / m)
    valid = data.mask.sum(axis=1) > 0
    return float(per_user[valid].mean())
