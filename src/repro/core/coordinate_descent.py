"""The paper's non-private algorithm (Sec. 2.3): asynchronous decentralized
block coordinate descent under the Poisson-clock / broadcast model.

Faithful semantics: at each global tick t, one uniformly-random agent i wakes
up, performs the Eq. 4 update

    Theta_i <- (1 - alpha_i) Theta_i
               + alpha_i ( sum_j (W_ij / D_ii) Theta_j - mu c_i grad L_i(Theta_i) )

with alpha_i = 1 / (1 + mu c_i L_i^loc), and broadcasts Theta_i to its
neighbourhood (cost: one p-dimensional vector per neighbour under the
broadcast model of Aysal et al. — we account messages as |N_i| edge-vectors
so the comparison with gossip ADMM in Fig. 1 is fair on the same axis).

Two execution paths share the same math:
* ``run``            — python loop, arbitrary wake sequences, full history.
* ``run_scan``       — lax.scan over a pre-sampled wake sequence (jit, fast).

Both are used by tests to cross-validate each other.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import neighbor_counts
from repro.core.objective import Objective


@dataclasses.dataclass
class CDResult:
    Theta: np.ndarray  # final (n, p)
    objective: np.ndarray  # (T+1,) Q at every tick (0 = init)
    messages: np.ndarray  # (T+1,) cumulative p-vectors transmitted
    wake_sequence: np.ndarray  # (T,)


def sample_wake_sequence(n: int, T: int, rng: np.random.Generator) -> np.ndarray:
    """Global-clock view of n i.i.d. rate-1 Poisson clocks: uniform agent per tick."""
    return rng.integers(0, n, size=T)


def cd_update(obj: Objective, Theta, i):
    """One Eq. 4 update for agent ``i``. jit-able; ``i`` may be traced."""
    d = jnp.asarray(obj.degrees)
    c = jnp.asarray(obj.confidences)
    alphas = jnp.asarray(obj.alphas())
    theta_i = Theta[i]
    neigh = obj.mix.row(Theta, i) / d[i]  # sum_j W_ij Theta_j / D_ii
    grad_i = obj.local_grad(Theta)[i]
    new_i = (1.0 - alphas[i]) * theta_i + alphas[i] * (neigh - obj.mu * c[i] * grad_i)
    return Theta.at[i].set(new_i)


def _agent_grad_from_data(obj: Objective, theta_i, X_i, y_i, mask_i, lam):
    """grad L_i at theta_i from one agent's already-gathered data rows.

    ``X_i``: (m, p), ``y_i``/``mask_i``: (m,), ``lam``: scalar — all in
    ``theta_i``'s dtype. The one gradient formula every execution path
    (sequential scans, both engines, sharded constants) reduces to.
    """
    m = jnp.maximum(mask_i.sum(), 1.0)
    g = obj._point_grads(theta_i, X_i, y_i)
    return jnp.sum(g * mask_i[:, None], axis=0) / m + 2.0 * lam * theta_i


def _single_agent_grad(obj: Objective, theta_i, i):
    """grad L_i at theta_i for (possibly traced) agent index i."""
    dt = theta_i.dtype
    return _agent_grad_from_data(
        obj,
        theta_i,
        jnp.asarray(obj.data.X, dt)[i],
        jnp.asarray(obj.data.y, dt)[i],
        jnp.asarray(obj.data.mask, dt)[i],
        jnp.asarray(obj.lambdas, dt)[i],
    )


def batched_agent_grads(obj: Objective, theta_rows, rows):
    """grad L_i at theta_rows[b] for each (possibly traced) index rows[b].

    The woken-rows counterpart of :func:`_single_agent_grad`: only the B
    gathered agents' data enters, never the full (n, m, p) stack.
    """
    return jax.vmap(lambda th, i: _single_agent_grad(obj, th, i))(theta_rows, rows)


def eq4_agent_constants(obj: Objective) -> dict:
    """The per-agent constants (leading dim n) the Eq. 4/6 row step reads.

    This is the pytree the sharded engine tiles into (S, R, ...) blocks
    so the super-tick never closes over an (n, ...) array: ``deg``/
    ``conf``/``alpha``/``lam`` are (n,) theory constants and ``X``
    (n, m, p) / ``y`` / ``mask`` (n, m) the padded per-agent datasets.
    Arrays keep their original (f64) dtypes; consumers cast elementwise
    after gathering, which commutes with the gather — the bit-exactness
    bridge between the replicated and shard-resident paths.
    """
    return {
        "deg": obj.degrees,
        "conf": obj.confidences,
        "alpha": obj.alphas(),
        "lam": obj.lambdas,
        "X": obj.data.X,
        "y": obj.data.y,
        "mask": obj.data.mask,
    }


def eq4_theta_rows_from(obj: Objective, theta, neigh, consts, grad_noise=None):
    """Batched Eq. 4 update from pre-gathered per-agent constants.

    ``theta``/``neigh``: (B, p) current rows and their raw neighbour sums
    ``sum_j W_ij Theta_j``. ``consts``: the row-gathered slice of
    :func:`eq4_agent_constants` — each leaf is (B, ...) and row-aligned
    with ``theta``. ``grad_noise``: optional (B, p) perturbation added to
    the local gradient (the Eq. 6 private update); None recovers the
    non-private algorithm. Returns the (B, p) replacement rows.
    """
    dt = theta.dtype
    d = jnp.asarray(consts["deg"], dt)
    c = jnp.asarray(consts["conf"], dt)
    a = jnp.asarray(consts["alpha"], dt)
    grads = jax.vmap(lambda th, Xi, yi, mi, l: _agent_grad_from_data(obj, th, Xi, yi, mi, l))(
        theta,
        jnp.asarray(consts["X"], dt),
        jnp.asarray(consts["y"], dt),
        jnp.asarray(consts["mask"], dt),
        jnp.asarray(consts["lam"], dt),
    )
    if grad_noise is not None:
        grads = grads + grad_noise
    return (1.0 - a[:, None]) * theta + a[:, None] * (
        neigh / d[:, None] - obj.mu * c[:, None] * grads
    )


def eq4_theta_rows(obj: Objective, theta, rows, neigh, grad_noise=None):
    """Batched Eq. 4 update for already-gathered rows — the one formula
    shared by the sequential simulators and both ``repro.sim`` engines.

    ``theta``: (B, p) current parameter rows (the sharded engine gathers
    them from its local block; :func:`eq4_rows` gathers from the global
    Theta). ``rows``: (B,) *global* agent indices, used to gather the
    per-agent constants and data (may be traced; out-of-range padding
    sentinels clamp on gather — callers drop those rows on scatter).
    ``neigh``: (B, p) raw neighbour sums ``sum_j W_ij Theta_j`` for those
    rows. ``grad_noise``: optional (B, p) perturbation added to the local
    gradient — passing the Laplace/Gaussian draw makes this the Eq. 6
    private update; None (or zeros) recovers the non-private algorithm.
    Returns the (B, p) replacement rows.

    The gathers here read the *replicated* (n, ...) arrays; the sharded
    engine instead gathers from its (R, ...) shard-resident tiles and
    calls :func:`eq4_theta_rows_from` directly with the result.
    """
    consts = jax.tree.map(lambda arr: jnp.asarray(arr)[rows], eq4_agent_constants(obj))
    return eq4_theta_rows_from(obj, theta, neigh, consts, grad_noise=grad_noise)


def eq4_rows(obj: Objective, Theta, rows, neigh, grad_noise=None):
    """:func:`eq4_theta_rows` with the row gather from the global (n, p)
    Theta (padding sentinels clamp on the gather)."""
    return eq4_theta_rows(obj, Theta[rows], rows, neigh, grad_noise=grad_noise)


def run(
    obj: Objective,
    Theta0: np.ndarray,
    T: int,
    rng: np.random.Generator,
    record_every: int = 1,
    wake_sequence: np.ndarray | None = None,
) -> CDResult:
    """Python-loop reference implementation (exact Eq. 4 semantics)."""
    n = obj.n
    if wake_sequence is None:
        wake_sequence = sample_wake_sequence(n, T, rng)
    Theta = jnp.asarray(Theta0, dtype=jnp.float32)
    deg_counts = neighbor_counts(obj.graph)
    objective = [float(obj.value(Theta))]
    messages = [0.0]
    msg = 0.0
    update = jax.jit(lambda Th, i: _cd_step(obj, Th, i))
    for t in range(T):
        i = int(wake_sequence[t])
        Theta = update(Theta, i)
        msg += float(deg_counts[i])
        if (t + 1) % record_every == 0 or t == T - 1:
            objective.append(float(obj.value(Theta)))
            messages.append(msg)
    return CDResult(
        Theta=np.asarray(Theta),
        objective=np.asarray(objective),
        messages=np.asarray(messages),
        wake_sequence=np.asarray(wake_sequence),
    )


def _cd_step(obj: Objective, Theta, i):
    d = jnp.asarray(obj.degrees, dtype=Theta.dtype)
    c = jnp.asarray(obj.confidences, dtype=Theta.dtype)
    alphas = jnp.asarray(obj.alphas(), dtype=Theta.dtype)
    theta_i = Theta[i]
    neigh = obj.mix.row(Theta, i) / d[i]
    grad_i = _single_agent_grad(obj, theta_i, i)
    new_i = (1.0 - alphas[i]) * theta_i + alphas[i] * (neigh - obj.mu * c[i] * grad_i)
    return Theta.at[i].set(new_i)


def run_scan(
    obj: Objective,
    Theta0: np.ndarray,
    T: int,
    rng: np.random.Generator,
    record_every: int = 1,
    wake_sequence: np.ndarray | None = None,
    noise_scales: np.ndarray | None = None,
    noise_key=None,
    record_objective: bool = True,
) -> CDResult:
    """lax.scan fast path. Optionally adds Laplace noise to the local gradient
    with per-(tick) scale ``noise_scales[t]`` for the waking agent (this is the
    Eq. 6 private update; scale 0 recovers the non-private algorithm).
    """
    n, p = obj.n, obj.p
    if wake_sequence is None:
        wake_sequence = sample_wake_sequence(n, T, rng)
    wake = jnp.asarray(wake_sequence, dtype=jnp.int32)
    if noise_scales is None:
        noise = jnp.zeros((T, p), dtype=jnp.float32)
    else:
        if noise_key is None:
            noise_key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        lap = jax.random.laplace(noise_key, shape=(T, p), dtype=jnp.float32)
        noise = lap * jnp.asarray(noise_scales, dtype=jnp.float32)[:, None]

    mix = obj.mix
    d = jnp.asarray(obj.degrees, dtype=jnp.float32)
    c = jnp.asarray(obj.confidences, dtype=jnp.float32)
    alphas = jnp.asarray(obj.alphas(), dtype=jnp.float32)
    deg_counts = jnp.asarray(neighbor_counts(obj.graph), dtype=jnp.float32)

    def step(carry, inp):
        Theta, msg = carry
        i, eta = inp
        theta_i = Theta[i]
        neigh = mix.row(Theta, i) / d[i]
        grad_i = _single_agent_grad(obj, theta_i, i) + eta
        new_i = (1.0 - alphas[i]) * theta_i + alphas[i] * (neigh - obj.mu * c[i] * grad_i)
        Theta = Theta.at[i].set(new_i)
        msg = msg + deg_counts[i]
        val = obj.value(Theta) if record_objective else jnp.zeros(())
        return (Theta, msg), (val, msg)

    (ThetaT, _), (objs, msgs) = jax.lax.scan(
        step, (jnp.asarray(Theta0, dtype=jnp.float32), jnp.zeros(())), (wake, noise)
    )
    q0 = float(obj.value(jnp.asarray(Theta0, jnp.float32))) if record_objective else 0.0
    objective = np.concatenate([[q0], np.asarray(objs)])
    messages = np.concatenate([[0.0], np.asarray(msgs)])
    if record_every > 1:
        idx = np.unique(np.concatenate([[0], np.arange(record_every, T + 1, record_every), [T]]))
        objective = objective[idx]
        messages = messages[idx]
    return CDResult(
        Theta=np.asarray(ThetaT),
        objective=objective,
        messages=messages,
        wake_sequence=np.asarray(wake_sequence),
    )


def synchronous_round(obj: Objective, Theta):
    """All agents apply Eq. 4 simultaneously from the same snapshot.

    This is the SPMD scale-layer schedule (DESIGN.md §4.2): one round = n
    async ticks in expectation. Fixed points coincide with Eq. 4's: a round
    is ``Theta <- Theta - diag(1/L_i) grad Q(Theta)`` blockwise.
    """
    d = jnp.asarray(obj.degrees, dtype=Theta.dtype)
    c = jnp.asarray(obj.confidences, dtype=Theta.dtype)
    alphas = jnp.asarray(obj.alphas(), dtype=Theta.dtype)
    neigh = obj.mix.all(Theta) / d[:, None]
    grads = obj.local_grad(Theta)
    return (1.0 - alphas[:, None]) * Theta + alphas[:, None] * (
        neigh - obj.mu * c[:, None] * grads
    )


def proposition1_bound(obj: Objective, gap0: float, T: int) -> np.ndarray:
    """E[Q(T)] - Q* <= (1 - sigma/(n L_max))^T (Q(0) - Q*)."""
    C = obj.contraction()
    return gap0 * (C ** np.arange(T + 1))
