"""Asynchronous gossip ADMM baseline of Vanhaesebrouck et al. (2017).

The paper's Fig. 1 compares its coordinate-descent algorithm against this
ADMM. Following the description in Sec. 4 / Sec. 5.1:

* the objective (Eq. 2) is cast as partial consensus by duplicating each
  node variable once per incident edge: for edge e = (i, j) the copies
  Theta_i^e, Theta_j^e carry the smoothness term, with consensus
  constraints Theta_i^e = Theta_i. This yields **4 auxiliary variables per
  edge** (two primal copies + two scaled duals), exactly as the paper notes;
* communication is gossip-based: at each tick one *edge* (i, j) is activated
  and the two endpoints exchange; auxiliary variables of an edge are updated
  only when that edge is activated (the inefficiency the paper blames for
  ADMM's slowness);
* each primal update runs ``local_grad_steps`` gradient steps (10 in the
  paper's experiment) on the local augmented Lagrangian.

Message accounting matches Fig. 1's x-axis: each edge activation transmits
2 p-dimensional vectors (one each way).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import Objective


@dataclasses.dataclass
class ADMMResult:
    Theta: np.ndarray
    objective: np.ndarray
    messages: np.ndarray


def run_admm(
    obj: Objective,
    Theta0: np.ndarray,
    T: int,
    rng: np.random.Generator,
    rho: float = 1.0,
    local_grad_steps: int = 10,
    local_lr: float | None = None,
    record_every: int = 1,
) -> ADMMResult:
    n, p = obj.n, obj.p
    erows, ecols, evals = obj.graph.edge_list()
    edges = list(zip(erows.tolist(), ecols.tolist()))
    E = len(edges)
    incident: list[list[int]] = [[] for _ in range(n)]
    for e, (i, j) in enumerate(edges):
        incident[i].append(e)
        incident[j].append(e)

    d = obj.degrees
    c = obj.confidences
    mu = obj.mu
    # f_i(theta) = mu D_ii c_i L_i(theta) — the separable part.
    if local_lr is None:
        # Safe step size: smoothness of f_i + rho * deg_i.
        L_f = mu * d * c * obj.local_smoothness()
        local_lr = float(1.0 / (L_f.max() + rho * max(len(ic) for ic in incident)))

    Theta = np.array(Theta0, dtype=np.float64, copy=True)
    # Edge copies z[e, 0] ~ node i's copy, z[e, 1] ~ node j's copy; duals u likewise.
    z = np.zeros((E, 2, p))
    for e, (i, j) in enumerate(edges):
        z[e, 0] = Theta[i]
        z[e, 1] = Theta[j]
    u = np.zeros((E, 2, p))

    X = jnp.asarray(obj.data.X, jnp.float32)
    Y = jnp.asarray(obj.data.y, jnp.float32)
    M = jnp.asarray(obj.data.mask, jnp.float32)
    lam = jnp.asarray(obj.lambdas, jnp.float32)

    dc = jnp.asarray(mu * d * c, jnp.float32)

    @jax.jit
    def node_update_jit(i, theta_i, zs, us, deg_mask):
        """local_grad_steps GD steps on f_i + (rho/2) sum_{e in i} ||theta - z_e^i + u_e^i||^2."""
        Xi, yi, mi = X[i], Y[i], M[i]
        m = jnp.maximum(mi.sum(), 1.0)

        def f_grad(theta):
            g = jax.vmap(lambda x, yy: obj.loss.point_grad(theta, x, yy))(Xi, yi)
            g = jnp.sum(g * mi[:, None], axis=0) / m + 2.0 * lam[i] * theta
            return dc[i] * g

        def body(th, _):
            g = f_grad(th) + rho * jnp.sum(
                (th[None, :] - zs + us) * deg_mask[:, None], axis=0
            )
            return th - local_lr * g, None

        th, _ = jax.lax.scan(body, theta_i, None, length=local_grad_steps)
        return th

    max_deg = max(len(ic) for ic in incident)

    def node_update(i, theta_i):
        ic = incident[i]
        zs = np.zeros((max_deg, p), np.float32)
        us = np.zeros((max_deg, p), np.float32)
        mask = np.zeros(max_deg, np.float32)
        for k, e in enumerate(ic):
            side = 0 if edges[e][0] == i else 1
            zs[k] = z[e, side]
            us[k] = u[e, side]
            mask[k] = 1.0
        th = node_update_jit(
            jnp.int32(i), jnp.asarray(theta_i, jnp.float32), jnp.asarray(zs),
            jnp.asarray(us), jnp.asarray(mask)
        )
        return np.asarray(th, dtype=np.float64)

    objective = [float(obj.value(jnp.asarray(Theta, jnp.float32)))]
    messages = [0.0]
    msg = 0.0
    for t in range(T):
        e = int(rng.integers(E))
        i, j = edges[e]
        # Primal node updates (each endpoint uses current copies/duals).
        Theta[i] = node_update(i, Theta[i])
        Theta[j] = node_update(j, Theta[j])
        # Edge (z) update: minimize the edge smoothness + proximity to the
        # broadcasted node variables: closed form for
        #   (W_ij/2)||z_i - z_j||^2 + rho/2 (||z_i - a||^2 + ||z_j - b||^2)
        a = Theta[i] + u[e, 0]
        b = Theta[j] + u[e, 1]
        w = evals[e]
        denom = rho * (rho + 2.0 * w)
        z[e, 0] = ((rho + w) * rho * a + w * rho * b) / denom
        z[e, 1] = (w * rho * a + (rho + w) * rho * b) / denom
        # Dual ascent.
        u[e, 0] += Theta[i] - z[e, 0]
        u[e, 1] += Theta[j] - z[e, 1]
        msg += 2.0
        if (t + 1) % record_every == 0 or t == T - 1:
            objective.append(float(obj.value(jnp.asarray(Theta, jnp.float32))))
            messages.append(msg)
    return ADMMResult(Theta=Theta, objective=np.asarray(objective), messages=np.asarray(messages))
