"""Agent graphs for peer-to-peer personalized learning.

The paper (Sec. 2.1) models the collaboration network as a weighted connected
graph G = ([n], E, W) whose weights encode task relatedness.  This module
builds the weight matrices used throughout:

* ``angular_similarity_graph`` — the synthetic linear-classification setup of
  Sec. 5.1: ``W_ij = exp((cos(phi_ij) - 1) / gamma)`` from the angles between
  the agents' (hidden) target models, with negligible weights dropped.
* ``knn_cosine_graph`` — the MovieLens setup of Sec. 5.2: ``W_ij = 1`` iff i
  is in the 10-NN of j (or vice versa) under cosine similarity of the raw
  per-agent data vectors.
* ``ring_graph`` / ``circulant_graph`` — collective-friendly topologies used
  by the SPMD scale layer (a union of ring permutations lowers to
  ``lax.ppermute``).
* ``erdos_renyi_graph`` — random sparse topology for robustness tests.

All constructors return an :class:`AgentGraph` with the degree vector
``D_ii = sum_j W_ij`` precomputed (Eq. 2 normalization).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class AgentGraph:
    """Symmetric non-negative weight matrix with zero diagonal."""

    weights: np.ndarray  # (n, n) float64

    def __post_init__(self):
        w = self.weights
        if w.ndim != 2 or w.shape[0] != w.shape[1]:
            raise ValueError(f"weights must be square, got {w.shape}")
        if not np.allclose(w, w.T, atol=1e-10):
            raise ValueError("weights must be symmetric")
        if np.any(np.diag(w) != 0.0):
            raise ValueError("weights must have zero diagonal")
        if np.any(w < 0.0):
            raise ValueError("weights must be non-negative")

    @property
    def n(self) -> int:
        return self.weights.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """D_ii = sum_j W_ij."""
        return self.weights.sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.weights[i] > 0.0)[0]

    def laplacian(self) -> np.ndarray:
        return np.diag(self.degrees) - self.weights

    def is_connected(self) -> bool:
        n = self.n
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            i = stack.pop()
            for j in np.nonzero(self.weights[i] > 0.0)[0]:
                if not seen[j]:
                    seen[j] = True
                    stack.append(int(j))
        return bool(seen.all())

    def num_edges(self) -> int:
        return int(np.count_nonzero(np.triu(self.weights, 1)))


def angular_similarity_graph(
    target_models: np.ndarray, gamma: float = 0.1, threshold: float = 1e-2
) -> AgentGraph:
    """Paper Sec. 5.1: W_ij = exp((cos(phi_ij) - 1) / gamma), thresholded.

    ``target_models``: (n, p) array of the agents' target separators.
    Weights below ``threshold`` are considered negligible and dropped.
    """
    t = np.asarray(target_models, dtype=np.float64)
    norms = np.linalg.norm(t, axis=1, keepdims=True)
    norms = np.where(norms == 0.0, 1.0, norms)
    unit = t / norms
    cos = np.clip(unit @ unit.T, -1.0, 1.0)
    w = np.exp((cos - 1.0) / gamma)
    np.fill_diagonal(w, 0.0)
    w[w < threshold] = 0.0
    # Symmetrize against numerical asymmetry.
    w = 0.5 * (w + w.T)
    return AgentGraph(w)


def knn_cosine_graph(features: np.ndarray, k: int = 10) -> AgentGraph:
    """Paper Sec. 5.2: unit weight iff i in kNN(j) or j in kNN(i), cosine sim."""
    f = np.asarray(features, dtype=np.float64)
    norms = np.linalg.norm(f, axis=1, keepdims=True)
    norms = np.where(norms == 0.0, 1.0, norms)
    unit = f / norms
    sim = unit @ unit.T
    np.fill_diagonal(sim, -np.inf)
    n = f.shape[0]
    w = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        nn = np.argpartition(-sim[i], k)[:k]
        w[i, nn] = 1.0
    w = np.maximum(w, w.T)  # i in kNN(j) OR j in kNN(i)
    np.fill_diagonal(w, 0.0)
    return AgentGraph(w)


def ring_graph(n: int, weight: float = 1.0) -> AgentGraph:
    w = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        w[i, (i + 1) % n] = weight
        w[(i + 1) % n, i] = weight
    return AgentGraph(w)


def circulant_graph(n: int, offsets: tuple[int, ...], weights=None) -> AgentGraph:
    """Union of ring permutations: agent i connects to i +/- o for o in offsets.

    This is the collective-friendly family: the neighbour sum
    ``sum_j W_ij Theta_j`` decomposes into |offsets| * 2 ``ppermute`` calls on
    the agent mesh axis (see repro.core.spmd).
    """
    if weights is None:
        weights = [1.0] * len(offsets)
    w = np.zeros((n, n), dtype=np.float64)
    for o, wt in zip(offsets, weights):
        o = o % n
        if o == 0:
            continue
        for i in range(n):
            j = (i + o) % n
            w[i, j] = max(w[i, j], wt)
            w[j, i] = max(w[j, i], wt)
    return AgentGraph(w)


def erdos_renyi_graph(n: int, prob: float, rng: np.random.Generator, weight: float = 1.0) -> AgentGraph:
    while True:
        upper = rng.random((n, n)) < prob
        w = np.triu(upper, 1).astype(np.float64) * weight
        w = w + w.T
        g = AgentGraph(w)
        if g.is_connected():
            return g


def complete_graph(n: int, weight: float = 1.0) -> AgentGraph:
    w = np.full((n, n), weight, dtype=np.float64)
    np.fill_diagonal(w, 0.0)
    return AgentGraph(w)


def confidences(num_examples: np.ndarray, floor: float = 1e-3) -> np.ndarray:
    """Paper footnote 2: c_i = m_i / max_j m_j (plus small constant if m_i=0)."""
    m = np.asarray(num_examples, dtype=np.float64)
    mx = m.max()
    if mx <= 0:
        return np.full_like(m, floor)
    c = m / mx
    return np.clip(c, floor, 1.0)
