"""Agent graphs for peer-to-peer personalized learning.

The paper (Sec. 2.1) models the collaboration network as a weighted connected
graph G = ([n], E, W) whose weights encode task relatedness.  This module
builds the weight matrices used throughout:

* ``angular_similarity_graph`` — the synthetic linear-classification setup of
  Sec. 5.1: ``W_ij = exp((cos(phi_ij) - 1) / gamma)`` from the angles between
  the agents' (hidden) target models, with negligible weights dropped.
* ``knn_cosine_graph`` — the MovieLens setup of Sec. 5.2: ``W_ij = 1`` iff i
  is in the 10-NN of j (or vice versa) under cosine similarity of the raw
  per-agent data vectors.
* ``ring_graph`` / ``circulant_graph`` — collective-friendly topologies used
  by the SPMD scale layer (a union of ring permutations lowers to
  ``lax.ppermute``).
* ``erdos_renyi_graph`` — random sparse topology for robustness tests.

Dense constructors return an :class:`AgentGraph` with the degree vector
``D_ii = sum_j W_ij`` precomputed (Eq. 2 normalization).

Scale layer: the algorithm only ever touches an agent's neighbourhood
``N_i``, so storing W as a dense (n, n) matrix is an O(n^2) wall.
:class:`CSRGraph` stores the same symmetric weighted graph as CSR
neighbour lists (indptr/indices/data) and is a drop-in replacement for
:class:`AgentGraph` everywhere in ``repro.core``; ``knn_graph`` and
``random_geometric_graph`` build it without ever materializing (n, n).
:func:`mix_op` dispatches the neighbour-sum operator ``sum_j W_ij Theta_j``
to a dense matmul below :data:`sparse_crossover` agents (MXU fast path)
and to gather/segment-sum kernels above it.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import os

import numpy as np

try:  # TopologyState's in-jit ops need jax; everything else is numpy-only.
    import jax as _jax
    import jax.numpy as _jnp
except ImportError:  # pragma: no cover - the container always has jax
    _jax = None
    _jnp = None


@dataclasses.dataclass(frozen=True, eq=False)
class AgentGraph:
    """Symmetric non-negative weight matrix with zero diagonal."""

    weights: np.ndarray  # (n, n) float64

    def __post_init__(self):
        w = self.weights
        if w.ndim != 2 or w.shape[0] != w.shape[1]:
            raise ValueError(f"weights must be square, got {w.shape}")
        if not np.allclose(w, w.T, atol=1e-10):
            raise ValueError("weights must be symmetric")
        if np.any(np.diag(w) != 0.0):
            raise ValueError("weights must have zero diagonal")
        if np.any(w < 0.0):
            raise ValueError("weights must be non-negative")

    @property
    def n(self) -> int:
        return self.weights.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """D_ii = sum_j W_ij."""
        return self.weights.sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.weights[i] > 0.0)[0]

    def laplacian(self) -> np.ndarray:
        return np.diag(self.degrees) - self.weights

    def is_connected(self) -> bool:
        n = self.n
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            i = stack.pop()
            for j in np.nonzero(self.weights[i] > 0.0)[0]:
                if not seen[j]:
                    seen[j] = True
                    stack.append(int(j))
        return bool(seen.all())

    def num_edges(self) -> int:
        return int(np.count_nonzero(np.triu(self.weights, 1)))

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(neighbour indices, weights) of agent i — the CSR-compatible view."""
        cols = self.neighbors(i)
        return cols, self.weights[i, cols]

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, weights) over undirected edges, one entry per i < j."""
        rows, cols = np.nonzero(np.triu(self.weights, 1))
        return rows, cols, self.weights[rows, cols]

    def max_degree(self) -> int:
        return int(np.count_nonzero(self.weights > 0.0, axis=1).max(initial=0))

    def to_csr(self) -> "CSRGraph":
        rows, cols = np.nonzero(self.weights > 0.0)
        return csr_from_coo(self.n, rows, cols, self.weights[rows, cols])


def angular_similarity_graph(
    target_models: np.ndarray, gamma: float = 0.1, threshold: float = 1e-2
) -> AgentGraph:
    """Paper Sec. 5.1: W_ij = exp((cos(phi_ij) - 1) / gamma), thresholded.

    ``target_models``: (n, p) array of the agents' target separators.
    Weights below ``threshold`` are considered negligible and dropped.
    """
    t = np.asarray(target_models, dtype=np.float64)
    norms = np.linalg.norm(t, axis=1, keepdims=True)
    norms = np.where(norms == 0.0, 1.0, norms)
    unit = t / norms
    cos = np.clip(unit @ unit.T, -1.0, 1.0)
    w = np.exp((cos - 1.0) / gamma)
    np.fill_diagonal(w, 0.0)
    w[w < threshold] = 0.0
    # Symmetrize against numerical asymmetry.
    w = 0.5 * (w + w.T)
    return AgentGraph(w)


def knn_cosine_graph(
    features: np.ndarray,
    k: int = 10,
    block_rows: int | None = None,
    sparse: bool = False,
) -> AgentGraph | "CSRGraph":
    """Paper Sec. 5.2: unit weight iff i in kNN(j) or j in kNN(i), cosine sim.

    The similarity computation streams in (block_rows, n) slabs — the
    dense (n, n) cosine matrix is never materialized, so the top-k
    selection scales past ~50k agents. The default return type is the
    historical dense :class:`AgentGraph` (itself (n, n) — fine for the
    small-n paper experiments); pass ``sparse=True`` to get the same
    graph as a :class:`CSRGraph` with O(n * k) storage end to end.

    ``k`` is clamped to ``n - 1``: with fewer than k candidate peers,
    everyone is a neighbour (the paper's semantics), instead of
    ``np.argpartition`` crashing on an out-of-range kth.
    """
    f = np.asarray(features, dtype=np.float64)
    n = f.shape[0]
    k = min(k, n - 1)
    if k <= 0:
        if sparse:
            return csr_from_coo(n, [], [], [])
        return AgentGraph(np.zeros((n, n), dtype=np.float64))
    norms = np.linalg.norm(f, axis=1, keepdims=True)
    norms = np.where(norms == 0.0, 1.0, norms)
    unit = f / norms
    if block_rows is None:
        block_rows = max(1, min(4096, (1 << 25) // max(n, 1)))
    rows = np.empty(n * k, dtype=np.int64)
    cols = np.empty(n * k, dtype=np.int64)
    for lo in range(0, n, block_rows):
        hi = min(lo + block_rows, n)
        sim = unit[lo:hi] @ unit.T  # (b, n) slab
        sim[np.arange(hi - lo), np.arange(lo, hi)] = -np.inf
        nn = np.argpartition(-sim, k, axis=1)[:, :k]
        rows[lo * k : hi * k] = np.repeat(np.arange(lo, hi), k)
        cols[lo * k : hi * k] = nn.ravel()
    if sparse:
        return csr_from_coo(n, rows, cols, np.ones(n * k), symmetrize=True)
    w = np.zeros((n, n), dtype=np.float64)
    w[rows, cols] = 1.0
    w = np.maximum(w, w.T)  # i in kNN(j) OR j in kNN(i)
    np.fill_diagonal(w, 0.0)
    return AgentGraph(w)


def ring_graph(n: int, weight: float = 1.0) -> AgentGraph:
    w = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        w[i, (i + 1) % n] = weight
        w[(i + 1) % n, i] = weight
    return AgentGraph(w)


def circulant_graph(n: int, offsets: tuple[int, ...], weights=None) -> AgentGraph:
    """Union of ring permutations: agent i connects to i +/- o for o in offsets.

    This is the collective-friendly family: the neighbour sum
    ``sum_j W_ij Theta_j`` decomposes into |offsets| * 2 ``ppermute`` calls on
    the agent mesh axis (see repro.core.spmd).
    """
    if weights is None:
        weights = [1.0] * len(offsets)
    w = np.zeros((n, n), dtype=np.float64)
    for o, wt in zip(offsets, weights):
        o = o % n
        if o == 0:
            continue
        for i in range(n):
            j = (i + o) % n
            w[i, j] = max(w[i, j], wt)
            w[j, i] = max(w[j, i], wt)
    return AgentGraph(w)


def erdos_renyi_graph(n: int, prob: float, rng: np.random.Generator, weight: float = 1.0) -> AgentGraph:
    while True:
        upper = rng.random((n, n)) < prob
        w = np.triu(upper, 1).astype(np.float64) * weight
        w = w + w.T
        g = AgentGraph(w)
        if g.is_connected():
            return g


def complete_graph(n: int, weight: float = 1.0) -> AgentGraph:
    w = np.full((n, n), weight, dtype=np.float64)
    np.fill_diagonal(w, 0.0)
    return AgentGraph(w)


# ---------------------------------------------------------------------------
# Sparse (CSR) representation
# ---------------------------------------------------------------------------

_DEFAULT_SPARSE_CROSSOVER = 2048


def int_env_knob(name: str, default: int) -> int:
    """Integer agent-count knob from the environment (shared parse/raise)."""
    raw = os.environ.get(name, default)
    try:
        return int(raw)
    except ValueError as e:
        raise ValueError(
            f"{name} must be an integer agent count, got {raw!r}"
        ) from e


def sparse_crossover() -> int:
    """Agent count at which the neighbour-sum switches dense -> sparse.

    Below this, the (n, n) mixing matrix fits comfortably on chip and the
    MXU matmul wins; above it, gather/segment-sum over CSR neighbour lists
    is the only representation that scales. Override with the
    ``REPRO_SPARSE_CROSSOVER`` environment variable.
    """
    return int_env_knob("REPRO_SPARSE_CROSSOVER", _DEFAULT_SPARSE_CROSSOVER)


@dataclasses.dataclass(frozen=True, eq=False)
class CSRGraph:
    """Symmetric non-negative weighted graph in CSR neighbour-list form.

    Same invariants as :class:`AgentGraph` (symmetric, zero diagonal,
    non-negative) but O(nnz) storage: ``indices[indptr[i]:indptr[i+1]]`` are
    agent i's neighbours and ``data[...]`` the matching weights. Column
    indices are sorted within each row; every undirected edge is stored
    twice (once per direction), so ``nnz == 2 * num_edges``.
    """

    indptr: np.ndarray  # (n + 1,) int64
    indices: np.ndarray  # (nnz,) int32
    data: np.ndarray  # (nnz,) float64

    def __post_init__(self):
        indptr = np.asarray(self.indptr)
        indices = np.asarray(self.indices)
        data = np.asarray(self.data)
        if indptr.ndim != 1 or indices.shape != data.shape or indices.ndim != 1:
            raise ValueError("malformed CSR arrays")
        if indptr[0] != 0 or indptr[-1] != len(indices) or np.any(np.diff(indptr) < 0):
            raise ValueError("malformed indptr")
        if np.any(data < 0.0):
            raise ValueError("weights must be non-negative")
        n = len(indptr) - 1
        if len(indices) and (indices.min() < 0 or indices.max() >= n):
            raise ValueError("column index out of range")
        rows = self.row_ids()
        if np.any(indices == rows):
            raise ValueError("weights must have zero diagonal")
        # Symmetry: the transpose has the same sorted (row, col, val) triples.
        order_t = np.lexsort((rows, indices))
        if not (
            np.array_equal(indices[order_t], rows)
            and np.array_equal(rows[order_t], indices)
            and np.allclose(data[order_t], data, atol=1e-10)
        ):
            raise ValueError("weights must be symmetric")

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return len(self.indices)

    @functools.cached_property
    def degrees(self) -> np.ndarray:
        """D_ii = sum_j W_ij. Cached — the async tick loop reads it per tick."""
        return np.bincount(self.row_ids(), weights=self.data, minlength=self.n)

    def row_ids(self) -> np.ndarray:
        """(nnz,) row index of every stored entry (COO row vector)."""
        return np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i] : self.indptr[i + 1]]

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        sl = slice(self.indptr[i], self.indptr[i + 1])
        return self.indices[sl], self.data[sl]

    def num_edges(self) -> int:
        return self.nnz // 2

    def max_degree(self) -> int:
        return int(np.diff(self.indptr).max(initial=0))

    def digest(self) -> str:
        """sha256 over the exact CSR contents (indptr, indices, data).

        The checkpoint fingerprint: two graphs digest equal iff every
        stored edge, weight, and the row layout are byte-identical.
        """
        h = hashlib.sha256()
        for a in (self.indptr, self.indices, self.data):
            a = np.ascontiguousarray(a)
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        return h.hexdigest()

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, weights) over undirected edges, one entry per i < j."""
        rows = self.row_ids()
        keep = rows < self.indices
        return rows[keep], self.indices[keep], self.data[keep]

    def is_connected(self) -> bool:
        n = self.n
        if n == 0:
            return True
        seen = np.zeros(n, dtype=bool)
        seen[0] = True
        frontier = np.array([0])
        while len(frontier):
            nxt = np.concatenate([self.neighbors(int(i)) for i in frontier])
            nxt = np.unique(nxt)
            nxt = nxt[~seen[nxt]]
            seen[nxt] = True
            frontier = nxt
        return bool(seen.all())

    def padded_neighbors(self, pad_to: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """Dense (n, K) neighbour tiles for the gather kernels.

        Rows shorter than K = max degree are padded with the agent's own
        index (in-bounds gather) at weight 0, which contributes nothing to
        the neighbour sum.
        """
        n = self.n
        K = max(self.max_degree(), 1)
        if pad_to is not None:
            if pad_to < K:
                raise ValueError(f"pad_to={pad_to} < max degree {K}")
            K = pad_to
        idx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, K))
        w = np.zeros((n, K), dtype=np.float64)
        deg = np.diff(self.indptr)
        cols = (np.arange(K)[None, :] < deg[:, None]).nonzero()
        idx[cols] = self.indices
        w[cols] = self.data
        return idx, w

    def to_dense(self) -> AgentGraph:
        w = np.zeros((self.n, self.n), dtype=np.float64)
        w[self.row_ids(), self.indices] = self.data
        return AgentGraph(w)

    def laplacian(self) -> np.ndarray:
        return self.to_dense().laplacian()


def csr_from_coo(
    n: int, rows, cols, vals, symmetrize: bool = False, dedupe: str = "max"
) -> CSRGraph:
    """Build a :class:`CSRGraph` from COO triples.

    Entries with zero weight and duplicate (i, j) pairs are collapsed
    (``dedupe``: "max" or "sum"). With ``symmetrize`` the union with the
    transpose is taken, so callers may pass directed picks (e.g. raw k-NN
    lists) and get the paper's OR-symmetrized graph back.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    if np.any(vals < 0.0):
        raise ValueError("weights must be non-negative")
    if symmetrize:
        rows, cols, vals = (
            np.concatenate([rows, cols]),
            np.concatenate([cols, rows]),
            np.concatenate([vals, vals]),
        )
    keep = (vals > 0.0) & (rows != cols)
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if len(rows):
        key = rows * n + cols
        first = np.concatenate([[True], key[1:] != key[:-1]])
        group = np.cumsum(first) - 1
        if dedupe == "sum":
            merged = np.bincount(group, weights=vals)
        else:
            merged = np.full(group[-1] + 1, -np.inf)
            np.maximum.at(merged, group, vals)
        rows, cols, vals = rows[first], cols[first], merged
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=cols.astype(np.int32), data=vals)


# ---------------------------------------------------------------------------
# Mutable, versioned topology (capacity-padded slot form)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class TopologyState:
    """Mutable, versioned topology backing a :class:`CSRGraph`.

    Each row holds ``capacity`` neighbour *slots*: ``nbr[i, s]`` is the
    neighbour id (the row's own index where the slot is free — always an
    in-bounds gather), ``w[i, s]`` its weight (0 where invalid) and
    ``valid[i, s]`` whether the slot holds a live edge. Because every
    array keeps a static (n, capacity) shape, edge *weight* updates and
    edge activate/deactivate are pure jnp scatters — usable inside jit
    with traced operands and no retrace. Structural changes that exceed a
    row's capacity go through the host-side :meth:`apply_edge_updates`,
    which rebuilds (and, if needed, grows) the slot arrays.

    ``version`` is a 0-d int32 *array* (not a Python int) so functional
    in-jit updates can bump it without leaving the traced world; it is
    the cheap "did topology change" probe engines key their re-tile /
    re-partition decisions on.

    Instances are registered as a jax pytree (children: nbr, w, valid,
    version) and are functionally updated — every mutator returns a new
    ``TopologyState``. Symmetry is maintained by construction: all three
    in-jit mutators apply each (i, j) pair in both directions. Batches
    must not repeat a row within one :meth:`activate_edges` call (two
    activations racing for the same free slot collide); the host path
    has no such restriction.
    """

    nbr: np.ndarray  # (n, capacity) int32, own index where invalid
    w: np.ndarray  # (n, capacity) float, 0 where invalid
    valid: np.ndarray  # (n, capacity) bool
    version: np.ndarray  # () int32

    @property
    def n(self) -> int:
        return self.nbr.shape[0]

    @property
    def capacity(self) -> int:
        return self.nbr.shape[1]

    @classmethod
    def from_csr(
        cls,
        csr: CSRGraph,
        capacity: int | None = None,
        slack: int = 0,
        version: int = 0,
    ) -> "TopologyState":
        """Slot form of ``csr``; ``capacity`` defaults to max degree + slack."""
        need = max(csr.max_degree(), 1)
        if capacity is None:
            capacity = need + max(slack, 0)
        if capacity < need:
            raise ValueError(f"capacity={capacity} < max degree {need}")
        idx, w = csr.padded_neighbors(pad_to=capacity)
        deg = np.diff(csr.indptr)
        valid = np.arange(capacity)[None, :] < deg[:, None]
        return cls(
            nbr=idx,
            w=w,
            valid=valid,
            version=np.asarray(version, dtype=np.int32),
        )

    def to_csr(self) -> CSRGraph:
        """Host-side CSR snapshot of the live edge set."""
        nbr = np.asarray(self.nbr)
        w = np.asarray(self.w)
        valid = np.asarray(self.valid)
        r, s = np.nonzero(valid)
        return csr_from_coo(self.n, r, nbr[r, s], w[r, s], symmetrize=True)

    def degrees(self):
        """Weighted degrees D_ii = sum_j W_ij (w is 0 at invalid slots)."""
        return self.w.sum(axis=1)

    def neighbor_counts(self):
        """|N_i| per row — live slots only."""
        return self.valid.sum(axis=1)

    def _directed(self, rows, cols, fn):
        """Apply ``fn(state, rows, cols) -> (nbr, w, valid)`` both ways."""
        nbr = _jnp.asarray(self.nbr)
        w = _jnp.asarray(self.w)
        valid = _jnp.asarray(self.valid)
        nbr, w, valid = fn(nbr, w, valid, rows, cols)
        nbr, w, valid = fn(nbr, w, valid, cols, rows)
        return dataclasses.replace(
            self, nbr=nbr, w=w, valid=valid, version=self.version + 1
        )

    def _find_slot(self, nbr, valid, rows, cols):
        """(slot, found) of the live slot holding cols in rows' lists."""
        hit = (nbr[rows] == cols[:, None]) & valid[rows]
        return _jnp.argmax(hit, axis=1), hit.any(axis=1)

    def _flat(self, rows, slot, ok):
        """Flat (n * capacity) scatter index; sentinel (dropped) where !ok."""
        cap = self.capacity
        return _jnp.where(ok, rows * cap + slot, self.n * cap)

    def with_edge_weights(self, rows, cols, vals) -> "TopologyState":
        """Set weights of existing edges (i, j) — in-jit, shape-preserving.

        Pairs that are not currently live edges are ignored (no
        activation happens here); weights are applied symmetrically.
        """
        rows = _jnp.asarray(rows, _jnp.int32)
        cols = _jnp.asarray(cols, _jnp.int32)
        vals = _jnp.asarray(vals, self.w.dtype)

        def set_w(nbr, w, valid, r, c):
            slot, found = self._find_slot(nbr, valid, r, c)
            flat = self._flat(r, slot, found)
            w = w.ravel().at[flat].set(vals, mode="drop").reshape(w.shape)
            return nbr, w, valid

        return self._directed(rows, cols, set_w)

    def deactivate_edges(self, rows, cols) -> "TopologyState":
        """Remove edges (i, j) — in-jit; slots free for later activation."""
        rows = _jnp.asarray(rows, _jnp.int32)
        cols = _jnp.asarray(cols, _jnp.int32)

        def drop(nbr, w, valid, r, c):
            slot, found = self._find_slot(nbr, valid, r, c)
            flat = self._flat(r, slot, found)
            w = w.ravel().at[flat].set(0.0, mode="drop").reshape(w.shape)
            valid = (
                valid.ravel().at[flat].set(False, mode="drop").reshape(valid.shape)
            )
            return nbr, w, valid

        return self._directed(rows, cols, drop)

    def activate_edges(self, rows, cols, vals) -> "TopologyState":
        """Add (or reweight) edges (i, j) — in-jit, within row capacity.

        An existing slot already holding j (live or freed) is reused;
        otherwise the first free slot is claimed. Rows with no free slot
        silently drop the activation — capacity growth is the host-side
        :meth:`apply_edge_updates` path. At most one activation per row
        per call (including the mirrored direction).
        """
        rows = _jnp.asarray(rows, _jnp.int32)
        cols = _jnp.asarray(cols, _jnp.int32)
        vals = _jnp.asarray(vals, self.w.dtype)

        def add(nbr, w, valid, r, c):
            hit = nbr[r] == c[:, None]  # reuse a matching slot, even freed
            slot_hit = _jnp.argmax(hit, axis=1)
            found = hit.any(axis=1)
            free = ~valid[r]
            slot_free = _jnp.argmax(free, axis=1)
            has_free = free.any(axis=1)
            slot = _jnp.where(found, slot_hit, slot_free)
            ok = found | has_free
            flat = self._flat(r, slot, ok)
            nbr = nbr.ravel().at[flat].set(c, mode="drop").reshape(nbr.shape)
            w = w.ravel().at[flat].set(vals, mode="drop").reshape(w.shape)
            valid = (
                valid.ravel().at[flat].set(True, mode="drop").reshape(valid.shape)
            )
            return nbr, w, valid

        return self._directed(rows, cols, add)

    def apply_edge_updates(
        self,
        add_rows=(),
        add_cols=(),
        add_vals=(),
        remove_rows=(),
        remove_cols=(),
        slack: int = 0,
    ) -> "TopologyState":
        """Host-side structural update — handles beyond-capacity growth.

        Removes then adds the given (i, j) pairs (symmetrically, duplicates
        collapse by max weight) and rebuilds the slot arrays. When the new
        max degree exceeds the current capacity, capacity grows to the
        next multiple of 8 (so repeated growth retraces downstream jit
        programs a bounded number of times); it never shrinks. The version
        counter advances by one.
        """
        nbr = np.asarray(self.nbr)
        wts = np.asarray(self.w)
        valid = np.asarray(self.valid)
        r, s = np.nonzero(valid)
        rows, cols, vals = r, nbr[r, s], wts[r, s]
        if len(np.asarray(remove_rows)):
            rr = np.asarray(remove_rows, dtype=np.int64)
            rc = np.asarray(remove_cols, dtype=np.int64)
            drop_keys = np.concatenate([rr * self.n + rc, rc * self.n + rr])
            keep = ~np.isin(rows * self.n + cols, drop_keys)
            rows, cols, vals = rows[keep], cols[keep], vals[keep]
        if len(np.asarray(add_rows)):
            rows = np.concatenate([rows, np.asarray(add_rows, dtype=np.int64)])
            cols = np.concatenate([cols, np.asarray(add_cols, dtype=np.int64)])
            vals = np.concatenate([vals, np.asarray(add_vals, dtype=np.float64)])
        csr = csr_from_coo(self.n, rows, cols, vals, symmetrize=True, dedupe="max")
        need = max(csr.max_degree(), 1) + max(slack, 0)
        capacity = self.capacity
        if need > capacity:
            capacity = ((need + 7) // 8) * 8
        return type(self).from_csr(
            csr, capacity=capacity, version=int(np.asarray(self.version)) + 1
        )


if _jax is not None:

    def _topology_flatten(t: TopologyState):
        return (t.nbr, t.w, t.valid, t.version), None

    def _topology_unflatten(_, children):
        nbr, w, valid, version = children
        return TopologyState(nbr=nbr, w=w, valid=valid, version=version)

    _jax.tree_util.register_pytree_node(
        TopologyState, _topology_flatten, _topology_unflatten
    )


def neighbor_counts(graph) -> np.ndarray:
    """|N_i| per agent (message accounting), vectorized for either backend."""
    if isinstance(graph, CSRGraph):
        return np.diff(graph.indptr)
    return np.count_nonzero(graph.weights > 0.0, axis=1)


def as_csr(graph) -> CSRGraph:
    return graph if isinstance(graph, CSRGraph) else graph.to_csr()


def as_dense(graph) -> AgentGraph:
    return graph.to_dense() if isinstance(graph, CSRGraph) else graph


def dense_weights(graph) -> np.ndarray:
    """(n, n) weight matrix of either representation. O(n^2) — small n only."""
    return as_dense(graph).weights


# ---------------------------------------------------------------------------
# Sparse constructors (never materialize (n, n))
# ---------------------------------------------------------------------------


def knn_graph(
    features: np.ndarray, k: int = 10, block_rows: int | None = None
) -> CSRGraph:
    """Sparse OR-symmetrized cosine k-NN graph (Sec. 5.2 semantics).

    Streams the similarity computation in (block_rows, n) slabs so peak
    memory is O(block_rows * n), never (n, n). Matches
    :func:`knn_cosine_graph` exactly on the same input.
    """
    f = np.asarray(features, dtype=np.float64)
    n = f.shape[0]
    # Clamp like knn_cosine_graph: k >= n means everyone is a neighbour.
    k = min(k, n - 1)
    if k <= 0:
        return csr_from_coo(n, [], [], [])
    norms = np.linalg.norm(f, axis=1, keepdims=True)
    unit = f / np.where(norms == 0.0, 1.0, norms)
    if block_rows is None:
        block_rows = max(1, min(4096, (1 << 25) // max(n, 1)))
    rows = np.empty(n * k, dtype=np.int64)
    cols = np.empty(n * k, dtype=np.int64)
    for lo in range(0, n, block_rows):
        hi = min(lo + block_rows, n)
        sim = unit[lo:hi] @ unit.T  # (b, n) slab
        sim[np.arange(hi - lo), np.arange(lo, hi)] = -np.inf
        nn = np.argpartition(-sim, k, axis=1)[:, :k]
        rows[lo * k : hi * k] = np.repeat(np.arange(lo, hi), k)
        cols[lo * k : hi * k] = nn.ravel()
    return csr_from_coo(n, rows, cols, np.ones(n * k), symmetrize=True)


def random_geometric_graph(
    n: int,
    rng: np.random.Generator,
    avg_degree: float = 16.0,
    radius: float | None = None,
    weight: float = 1.0,
    min_degree: int = 1,
    return_pos: bool = False,
) -> CSRGraph | tuple[CSRGraph, np.ndarray]:
    """Random geometric graph on [0, 1]^2 via grid-cell bucketing: O(n * deg).

    Agents are uniform points; i ~ j iff ||x_i - x_j|| <= radius (default
    radius targets ``avg_degree`` via E[deg] = n pi r^2). Isolated agents are
    linked to their nearest peer so every D_ii > 0 (Eq. 4 divides by it).
    With ``return_pos`` the (n, 2) agent positions are returned alongside
    the graph — the coordinates a space-filling-curve relabel pass
    (``repro.sim.partition.sfc_order``) sorts by.
    """
    pos = rng.random((n, 2))
    if radius is None:
        radius = float(np.sqrt(avg_degree / (np.pi * max(n - 1, 1))))
    cell = np.floor(pos / radius).astype(np.int64)
    ncells = int(np.ceil(1.0 / radius)) + 1
    cell_id = cell[:, 0] * ncells + cell[:, 1]
    order = np.argsort(cell_id, kind="stable")
    sorted_ids = cell_id[order]
    uniq, starts = np.unique(sorted_ids, return_index=True)
    starts = np.append(starts, n)
    bucket = {int(u): order[s:e] for u, s, e in zip(uniq, starts[:-1], starts[1:])}

    rows_acc, cols_acc = [], []
    r2 = radius * radius
    # Half-neighbourhood offsets so each cell pair is visited once.
    half = [(0, 0), (0, 1), (1, -1), (1, 0), (1, 1)]
    for u, members in bucket.items():
        cx, cy = divmod(u, ncells)
        for dx, dy in half:
            other = bucket.get((cx + dx) * ncells + (cy + dy))
            if other is None:
                continue
            d2 = ((pos[members][:, None, :] - pos[other][None, :, :]) ** 2).sum(-1)
            a, b = np.nonzero(d2 <= r2)
            if dx == 0 and dy == 0:
                keep = a < b  # dedupe within-cell pairs
                a, b = a[keep], b[keep]
            rows_acc.append(members[a])
            cols_acc.append(other[b])
    rows = np.concatenate(rows_acc) if rows_acc else np.zeros(0, dtype=np.int64)
    cols = np.concatenate(cols_acc) if cols_acc else np.zeros(0, dtype=np.int64)

    if min_degree > 0 and n > 1:
        deg = np.bincount(np.concatenate([rows, cols]), minlength=n)
        need = min(min_degree, n - 1)
        for i in np.nonzero(deg < need)[0]:
            # Link to the (need) nearest peers; existing radius edges to
            # them dedupe away in csr_from_coo, so post-union degree >= need.
            d2 = ((pos - pos[i]) ** 2).sum(-1)
            d2[i] = np.inf
            nearest = np.argpartition(d2, need)[:need]
            rows = np.append(rows, np.full(need, i))
            cols = np.append(cols, nearest)
    csr = csr_from_coo(n, rows, cols, np.full(len(rows), weight), symmetrize=True)
    return (csr, pos) if return_pos else csr


def confidences(num_examples: np.ndarray, floor: float = 1e-3) -> np.ndarray:
    """Paper footnote 2: c_i = m_i / max_j m_j (plus small constant if m_i=0)."""
    m = np.asarray(num_examples, dtype=np.float64)
    mx = m.max()
    if mx <= 0:
        return np.full_like(m, floor)
    c = m / mx
    return np.clip(c, floor, 1.0)
