"""The differentially-private algorithm (Sec. 3.2, Eq. 6).

Each agent i replaces its Eq. 4 update with

    Theta_i <- (1-a) Theta_i + a ( sum_j (W_ij/D_ii) Theta_j
                                   - mu c_i ( grad L_i(Theta_i) + eta_i(t) ) )

with eta_i(t) ~ Laplace(0, s_i(t))^p, s_i(t) = 2 L0 / (eps_i(t) m_i).

Driver semantics follow the experiments in Sec. 5: every agent gets an
overall budget (eps_bar, delta_bar), splits it over its expected T_i = T/n
wake-ups (equal split via composition inversion, or the Prop.-2 decreasing
schedule), and *stops updating* once its budget is spent (it keeps
broadcasting its last iterate implicitly since neighbours retain it).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy
from repro.core.coordinate_descent import CDResult, sample_wake_sequence, _single_agent_grad
from repro.core.graph import neighbor_counts
from repro.core.objective import Objective


@dataclasses.dataclass
class DPConfig:
    eps_bar: float  # overall per-agent budget
    delta_bar: float = np.exp(-5.0)  # paper Sec. 5: delta = exp(-5)
    schedule: str = "uniform"  # "uniform" | "prop2"
    T_total: int = 0  # planned global ticks (agents plan for T_i = T/n)
    mechanism: str = "laplace"  # "laplace" (Thm. 1) | "gaussian" (Remark 4)
    delta_step: float = 1e-6  # per-step delta for the Gaussian mechanism

    def per_step_eps(self, obj: Objective, wake_ticks: np.ndarray) -> np.ndarray:
        """Per-wake-up epsilon for one agent given its wake ticks."""
        T_i = len(wake_ticks)
        if T_i == 0:
            return np.zeros(0)
        if self.schedule == "uniform":
            eps = privacy.invert_uniform_budget(self.eps_bar, T_i, self.delta_bar)
            return np.full(T_i, eps)
        elif self.schedule == "prop2":
            C = obj.contraction()
            full = privacy.proposition2_allocation(self.eps_bar, self.T_total, C)
            lam = privacy.schedule_renormalization(wake_ticks, self.T_total, C)
            return full[np.asarray(wake_ticks)] / max(lam, 1e-12)
        raise ValueError(f"unknown schedule {self.schedule}")


def mechanism_scale(cfg: DPConfig, l0: float, eps_step: float, m_i: float) -> float:
    """Per-step noise scale for the configured mechanism (Thm. 1 / Remark 4)."""
    if cfg.mechanism == "gaussian":
        # Remark 4: L2 sensitivity; l0 doubles as the L2 bound here.
        return privacy.gaussian_scale(l0, eps_step, cfg.delta_step, m_i)
    return privacy.laplace_scale(l0, eps_step, m_i)


def mechanism_scales(cfg: DPConfig, l0: float, eps_steps, m) -> np.ndarray:
    """Vectorized :func:`mechanism_scale` over per-agent epsilons/samples.

    Same formulas, element-wise (the expressions mirror the scalar code
    exactly so the two paths agree bitwise) — this is what keeps planning
    O(distinct epsilons) instead of an O(n) python loop.
    """
    eps = np.asarray(eps_steps, dtype=np.float64)
    mm = np.maximum(np.asarray(m, dtype=np.float64), 1.0)
    if np.any(eps <= 0):
        raise ValueError("eps_step must be positive")
    if cfg.mechanism == "gaussian":
        if not (0 < cfg.delta_step < 1):
            raise ValueError("need 0 < delta < 1")
        return 2.0 * l0 * np.sqrt(2.0 * np.log(2.0 / cfg.delta_step)) / (eps * mm)
    return 2.0 * l0 / (eps * mm)


def uniform_noise_plan(obj: Objective, cfg: DPConfig, planned_Ti: int):
    """Per-agent uniform-split plan: (eps_step, (n,) noise scales).

    Each agent plans for ``planned_Ti`` wake-ups, splits its overall
    ``(eps_bar, delta_bar)`` budget equally over them via composition
    inversion (Thm. 1), and uses the resulting constant per-step noise
    scale until the budget is spent. Shared by :func:`run_private`'s
    per-tick schedule and the batched ``repro.sim`` engine: agents that
    realize at least ``planned_Ti`` wake-ups stop at identical spend in
    both drivers. (For agents that wake fewer times, :func:`run_private`
    re-splits over the *realized* count — larger per-step eps — while the
    engine keeps the planned scale and under-spends; both stay within
    budget.)
    """
    if planned_Ti <= 0:
        raise ValueError("planned_Ti must be positive")
    l0 = obj.lipschitz_l1()
    if not np.isfinite(l0):
        raise ValueError(
            "loss has unbounded gradient; set Objective.clip (Supp. D.2) "
            "to get a finite sensitivity"
        )
    eps_step = privacy.invert_uniform_budget(cfg.eps_bar, planned_Ti, cfg.delta_bar)
    m = np.maximum(obj.data.num_examples, 1.0)
    return eps_step, mechanism_scales(cfg, l0, eps_step, m)


def _uniform_tick_schedule(obj, cfg, wake, m, l0, planned_Ti):
    """Vectorized uniform-split accounting for :func:`run_private`.

    Replaces the O(T) python pre-compute loop (per-tick
    ``PrivacyAccountant.spend`` plus a dict of per-agent eps arrays) with
    array passes: per-tick noise scales and active flags plus the
    composed per-agent spend. Semantics are unchanged — each agent plans
    ``planned_Ti`` wake-ups via :func:`uniform_noise_plan`, an agent that
    realizes fewer re-splits its budget over the realized count (one
    budget inversion per *distinct* realized count, not per agent), and
    every agent freezes once its planned steps are spent; spend composes
    through :func:`privacy.compose_uniform`.
    """
    n, T = obj.n, len(wake)
    total = np.bincount(wake, minlength=n)
    spent = np.minimum(total, planned_Ti)
    eps_step, scale_i = uniform_noise_plan(obj, cfg, planned_Ti)
    eps_i = np.full(n, eps_step)
    for k in np.unique(spent[spent < planned_Ti]):
        if k == 0:
            continue  # never woke: nothing spent, eps_i irrelevant
        sel = spent == k
        eps_k = privacy.invert_uniform_budget(cfg.eps_bar, int(k), cfg.delta_bar)
        eps_i[sel] = eps_k
        scale_i[sel] = mechanism_scales(cfg, l0, eps_k, m[sel])
    # Occurrence index of each tick within its agent's wake sequence.
    order = np.argsort(wake, kind="stable")
    starts = np.concatenate([[0], np.cumsum(total)[:-1]])
    occ = np.empty(T, dtype=np.int64)
    occ[order] = np.arange(T) - np.repeat(starts, total)
    active = occ < planned_Ti
    noise_scales = np.where(active, scale_i[wake], 0.0)
    eps_spent = privacy.compose_uniform(eps_i, spent, cfg.delta_bar)
    return noise_scales, active, eps_spent


@dataclasses.dataclass
class DPCDResult(CDResult):
    eps_spent: np.ndarray  # (n,) composed eps per agent
    noise_scales: np.ndarray  # (T,) Laplace scale used at each tick (0 if agent stopped)


def run_private(
    obj: Objective,
    Theta0: np.ndarray,
    T: int,
    cfg: DPConfig,
    rng: np.random.Generator,
    record_every: int = 1,
    wake_sequence: np.ndarray | None = None,
    record_objective: bool = True,
) -> DPCDResult:
    """Private CD, scan-based. Faithful per-agent budgeting + stopping."""
    n, p = obj.n, obj.p
    if wake_sequence is None:
        wake_sequence = sample_wake_sequence(n, T, rng)
    wake = np.asarray(wake_sequence)
    l0 = obj.lipschitz_l1()
    if not np.isfinite(l0):
        raise ValueError(
            "loss has unbounded gradient; set Objective.clip (Supp. D.2) "
            "to get a finite sensitivity"
        )
    m = np.maximum(obj.data.num_examples, 1.0)

    # Plan: each agent expects T_i = T/n wake-ups and allocates eps for them.
    planned_Ti = max(T // n, 1)
    cfg = dataclasses.replace(cfg, T_total=T)
    if cfg.schedule == "uniform":
        # Vectorized accounting: O(distinct realized counts) inversions
        # and array passes instead of the O(T) per-tick accountant loop.
        noise_scales, active, eps_spent = _uniform_tick_schedule(
            obj, cfg, wake, m, l0, planned_Ti
        )
    else:
        # Prop. 2 decreasing schedule: per-step epsilons index the global
        # sequential tick, so this stays on the per-tick accountant path.
        accountants = [privacy.PrivacyAccountant(cfg.delta_bar) for _ in range(n)]
        noise_scales = np.zeros(T)
        active = np.ones(T, dtype=bool)
        wake_count = np.zeros(n, dtype=int)
        per_agent_eps: dict[int, np.ndarray] = {}
        for i in range(n):
            ticks = np.nonzero(wake == i)[0][:planned_Ti]
            per_agent_eps[i] = cfg.per_step_eps(obj, ticks)
        for t in range(T):
            i = int(wake[t])
            k = wake_count[i]
            if k >= len(per_agent_eps[i]):
                active[t] = False  # budget exhausted: agent skips its update
                continue
            eps_t = per_agent_eps[i][k]
            noise_scales[t] = mechanism_scale(cfg, l0, eps_t, m[i])
            accountants[i].spend(eps_t)
            wake_count[i] += 1
        eps_spent = np.array([a.eps_bar for a in accountants])

    # Scan with per-tick scales; inactive ticks are identity updates.
    mix = obj.mix
    d = jnp.asarray(obj.degrees, dtype=jnp.float32)
    c = jnp.asarray(obj.confidences, dtype=jnp.float32)
    alphas = jnp.asarray(obj.alphas(), dtype=jnp.float32)
    key = jax.random.PRNGKey(int(rng.integers(2**31 - 1)))
    if cfg.mechanism == "gaussian":
        draws = jax.random.normal(key, shape=(T, p), dtype=jnp.float32)
    else:
        draws = jax.random.laplace(key, shape=(T, p), dtype=jnp.float32)
    noise = draws * jnp.asarray(noise_scales, dtype=jnp.float32)[:, None]
    act = jnp.asarray(active.astype(np.float32))

    def step(Theta, inp):
        i, eta, a_t = inp
        theta_i = Theta[i]
        neigh = mix.row(Theta, i) / d[i]
        grad_i = _single_agent_grad(obj, theta_i, i) + eta
        new_i = (1.0 - alphas[i]) * theta_i + alphas[i] * (neigh - obj.mu * c[i] * grad_i)
        new_i = a_t * new_i + (1.0 - a_t) * theta_i
        Theta = Theta.at[i].set(new_i)
        val = obj.value(Theta) if record_objective else jnp.zeros(())
        return Theta, val

    ThetaT, objs = jax.lax.scan(
        step,
        jnp.asarray(Theta0, dtype=jnp.float32),
        (jnp.asarray(wake, dtype=jnp.int32), noise, act),
    )
    deg_counts = neighbor_counts(obj.graph)
    messages = np.concatenate([[0.0], np.cumsum(deg_counts[wake] * active)])
    q0 = float(obj.value(jnp.asarray(Theta0, jnp.float32))) if record_objective else 0.0
    objective = np.concatenate([[q0], np.asarray(objs)])
    if record_every > 1:
        idx = np.unique(np.concatenate([[0], np.arange(record_every, T + 1, record_every), [T]]))
        objective = objective[idx]
        messages = messages[idx]
    return DPCDResult(
        Theta=np.asarray(ThetaT),
        objective=objective,
        messages=messages,
        wake_sequence=wake,
        eps_spent=eps_spent,
        noise_scales=noise_scales,
    )
