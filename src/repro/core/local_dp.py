"""Local differential privacy baseline (Supp. D.1).

Perturbs each data point itself before any learning: features get Laplace
noise calibrated to the feature-space L1 diameter, labels are flipped via
randomized response. The total per-point budget eps is split
``feature_frac`` / ``1 - feature_frac`` between the two. The perturbed
dataset is then (eps, 0)-locally-DP and can be released; purely local models
trained on it form the baseline of Fig. 4.
"""

from __future__ import annotations

import numpy as np

from repro.core.objective import AgentData


def perturb_dataset(
    data: AgentData,
    eps: float,
    rng: np.random.Generator,
    feature_bound: float | None = None,
    feature_frac: float = 0.8,
) -> AgentData:
    if eps <= 0:
        raise ValueError("eps must be positive")
    X, y, mask = data.X.copy(), data.y.copy(), data.mask.copy()
    eps_x = feature_frac * eps
    eps_y = (1.0 - feature_frac) * eps
    if feature_bound is None:
        feature_bound = float(np.abs(X[mask > 0]).max()) if mask.any() else 1.0
    # L1 sensitivity of the feature vector: replacing a point moves each
    # coordinate by at most 2B -> Delta_1 = 2 B p.
    p = X.shape[-1]
    delta1 = 2.0 * feature_bound * p
    X = X + rng.laplace(0.0, delta1 / eps_x, size=X.shape)
    # Randomized response on binary labels {-1, +1}.
    flip_prob = 1.0 / (1.0 + np.exp(min(eps_y, 50.0)))
    flips = rng.random(y.shape) < flip_prob
    y = np.where(flips, -y, y)
    X = X * mask[..., None]
    y = y * mask
    return AgentData(X=X, y=y, mask=mask)
