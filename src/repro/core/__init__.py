# The paper's primary contribution: personalized, private, fully decentralized
# learning via asynchronous block coordinate descent over an agent graph
# (Bellet, Guerraoui, Taziki, Tommasi, 2017).
from repro.core.graph import (
    AgentGraph,
    CSRGraph,
    angular_similarity_graph,
    as_csr,
    as_dense,
    circulant_graph,
    complete_graph,
    confidences,
    csr_from_coo,
    dense_weights,
    erdos_renyi_graph,
    knn_cosine_graph,
    knn_graph,
    neighbor_counts,
    random_geometric_graph,
    ring_graph,
    sparse_crossover,
    TopologyState,
)
from repro.core.mixing import MixOp, mix_op
from repro.core.objective import (
    LOGISTIC,
    LOSSES,
    QUADRATIC,
    AgentData,
    Loss,
    Objective,
    make_objective,
)
from repro.core.coordinate_descent import (
    CDResult,
    proposition1_bound,
    run,
    run_scan,
    sample_wake_sequence,
    synchronous_round,
)
from repro.core.dp_cd import DPCDResult, DPConfig, run_private
from repro.core.privacy import (
    PrivacyAccountant,
    compose_kairouz,
    gaussian_scale,
    invert_uniform_budget,
    laplace_scale,
    proposition2_allocation,
    theorem2_bound,
)
from repro.core.model_propagation import (
    private_local_models,
    private_warm_start,
    run_propagation,
    train_local_models,
)
from repro.core.admm_baseline import ADMMResult, run_admm
from repro.core.local_dp import perturb_dataset

__all__ = [k for k in dir() if not k.startswith("_")]
