"""The paper's objective (Eq. 2) and its block structure (Eq. 3).

``Q(Theta) = 1/2 sum_{i<j} W_ij ||Theta_i - Theta_j||^2
           + mu * sum_i D_ii c_i L_i(Theta_i; S_i)``

with ``L_i(theta) = (1/m_i) sum_k loss(theta; x_k, y_k) + lambda_i ||theta||^2``.

Everything here operates on the *stacked* representation ``Theta`` of shape
``(n, p)`` and on padded per-agent datasets (``X: (n, m_max, p)``,
``y: (n, m_max)``, ``mask: (n, m_max)``) so that the whole objective and all
block gradients are jit-able and vmap-able.

The module exposes the constants driving the theory:

* block Lipschitz constants ``L_i = D_ii (1 + mu c_i L_i^loc)`` (Sec. 2.2),
* the strong-convexity lower bound ``sigma >= mu min_i D_ii c_i sigma_i^loc``,
* the contraction factor ``C = 1 - sigma / (n L_max)`` of Prop. 1 / Prop. 2.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import AgentGraph, CSRGraph
from repro.core.mixing import mix_op

# ---------------------------------------------------------------------------
# Loss zoo
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Loss:
    """A pointwise convex loss ell(theta; x, y) with the constants the theory needs.

    ``lipschitz_l1(X)``: L0 s.t. ||grad ell||_1 <= L0 for all points in X
    (Thm. 1).  ``smoothness(X)``: upper bound on the largest eigenvalue of the
    pointwise Hessian over X (used for L_i^loc).
    """

    name: str
    point_loss: callable  # (theta, x, y) -> scalar
    point_grad: callable  # (theta, x, y) -> (p,)
    lipschitz_l1: callable  # (X, mask) -> float
    smoothness: callable  # (X, mask) -> float


def _logistic_point_loss(theta, x, y):
    margin = y * jnp.dot(x, theta)
    # log(1 + exp(-m)) computed stably.
    return jnp.logaddexp(0.0, -margin)


def _logistic_point_grad(theta, x, y):
    margin = y * jnp.dot(x, theta)
    return -y * jax.nn.sigmoid(-margin) * x


def _logistic_lip_l1(X, mask):
    # ||grad||_1 = sigmoid(.) * ||x||_1 <= max ||x||_1  (paper uses 1-Lipschitz
    # after normalizing features; we compute the data-dependent bound).
    norms = np.abs(np.asarray(X)).sum(axis=-1) * np.asarray(mask)
    return float(norms.max())


def _logistic_smoothness(X, mask):
    # Hessian = sigmoid'(m) x x^T with sigmoid' <= 1/4.
    sq = (np.asarray(X) ** 2).sum(axis=-1) * np.asarray(mask)
    return float(0.25 * sq.max())


def _quadratic_point_loss(theta, x, y):
    return jnp.square(jnp.dot(x, theta) - y)


def _quadratic_point_grad(theta, x, y):
    return 2.0 * (jnp.dot(x, theta) - y) * x


def _quadratic_lip_l1(X, mask):
    # Unbounded in general; callers should clip (paper Supp. D.2, C = 10).
    return float("inf")


def _quadratic_smoothness(X, mask):
    sq = (np.asarray(X) ** 2).sum(axis=-1) * np.asarray(mask)
    return float(2.0 * sq.max())


LOGISTIC = Loss(
    "logistic",
    _logistic_point_loss,
    _logistic_point_grad,
    _logistic_lip_l1,
    _logistic_smoothness,
)
QUADRATIC = Loss(
    "quadratic",
    _quadratic_point_loss,
    _quadratic_point_grad,
    _quadratic_lip_l1,
    _quadratic_smoothness,
)

LOSSES = {"logistic": LOGISTIC, "quadratic": QUADRATIC}


# ---------------------------------------------------------------------------
# Per-agent datasets (padded)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class AgentData:
    """Padded per-agent datasets.

    X: (n, m_max, p), y: (n, m_max), mask: (n, m_max) in {0,1}.
    """

    X: np.ndarray
    y: np.ndarray
    mask: np.ndarray

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[2]

    @property
    def num_examples(self) -> np.ndarray:
        return self.mask.sum(axis=1)

    @staticmethod
    def from_lists(Xs, ys, p=None):
        n = len(Xs)
        m_max = max(max((len(x) for x in Xs), default=1), 1)
        p = p if p is not None else Xs[0].shape[1]
        X = np.zeros((n, m_max, p))
        y = np.zeros((n, m_max))
        mask = np.zeros((n, m_max))
        for i, (xi, yi) in enumerate(zip(Xs, ys)):
            m = len(xi)
            if m:
                X[i, :m] = xi
                y[i, :m] = yi
                mask[i, :m] = 1.0
        return AgentData(X, y, mask)


# ---------------------------------------------------------------------------
# The objective
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Objective:
    """Q(Theta) of Eq. 2, fully specified.

    Construct via :func:`make_objective`. All jnp methods are jit-able; the
    arrays stored here are treated as constants (closed over by jit).
    """

    graph: AgentGraph | CSRGraph
    data: AgentData
    loss: Loss
    mu: float
    lambdas: np.ndarray  # (n,) L2 regularization per agent
    confidences: np.ndarray  # (n,) c_i in (0, 1]
    clip: float | None = None  # per-point gradient clip (Supp. D.2); None = off
    mix_mode: str = "auto"  # neighbour-sum path: "auto" | "dense" | "sparse"

    @cached_property
    def mix(self):
        """The neighbour-sum operator sum_j W_ij Theta_j (dense or sparse)."""
        return mix_op(self.graph, mode=self.mix_mode)

    # --- constants -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def p(self) -> int:
        return self.data.p

    @property
    def degrees(self) -> np.ndarray:
        return self.graph.degrees

    def local_smoothness(self) -> np.ndarray:
        """L_i^loc per agent: smoothness of L_i = emp. loss + lambda_i ||.||^2."""
        base = self.loss.smoothness(self.data.X, self.data.mask)
        return base + 2.0 * self.lambdas

    def local_strong_convexity(self) -> np.ndarray:
        """sigma_i^loc >= 2 lambda_i (L2 regularizer)."""
        return 2.0 * self.lambdas

    def block_lipschitz(self) -> np.ndarray:
        """L_i = D_ii (1 + mu c_i L_i^loc)."""
        return self.degrees * (1.0 + self.mu * self.confidences * self.local_smoothness())

    def strong_convexity(self) -> float:
        """sigma >= mu min_i [D_ii c_i sigma_i^loc]."""
        return float(
            self.mu
            * np.min(self.degrees * self.confidences * self.local_strong_convexity())
        )

    def contraction(self) -> float:
        """C = 1 - sigma / (n L_max) of Prop. 1."""
        return 1.0 - self.strong_convexity() / (self.n * float(self.block_lipschitz().max()))

    def alphas(self) -> np.ndarray:
        """alpha_i = 1 / (1 + mu c_i L_i^loc) — the Eq. 4 mixing coefficient."""
        return 1.0 / (1.0 + self.mu * self.confidences * self.local_smoothness())

    def lipschitz_l1(self) -> float:
        """L0 for Thm. 1 (possibly clipped per Supp. D.2)."""
        l0 = self.loss.lipschitz_l1(self.data.X, self.data.mask)
        if self.clip is not None:
            return min(l0, float(self.clip))
        return l0

    # --- values and gradients (jit-able) ----------------------------------
    def _point_grads(self, theta_i, X_i, y_i):
        g = jax.vmap(lambda x, y: self.loss.point_grad(theta_i, x, y))(X_i, y_i)
        if self.clip is not None:
            # L1-norm clipping to C, matching the Laplace/L1 sensitivity story.
            norms = jnp.sum(jnp.abs(g), axis=-1, keepdims=True)
            g = g * jnp.minimum(1.0, self.clip / jnp.maximum(norms, 1e-12))
        return g

    @partial(jax.jit, static_argnums=0)
    def local_loss(self, Theta):
        """L_i(Theta_i; S_i) for all agents: (n,) vector."""

        def one(theta_i, X_i, y_i, mask_i, lam):
            m = jnp.maximum(mask_i.sum(), 1.0)
            vals = jax.vmap(lambda x, y: self.loss.point_loss(theta_i, x, y))(X_i, y_i)
            return jnp.sum(vals * mask_i) / m + lam * jnp.sum(theta_i**2)

        return jax.vmap(one)(
            Theta,
            jnp.asarray(self.data.X),
            jnp.asarray(self.data.y),
            jnp.asarray(self.data.mask),
            jnp.asarray(self.lambdas),
        )

    @partial(jax.jit, static_argnums=0)
    def local_grad(self, Theta):
        """grad L_i(Theta_i; S_i) for all agents: (n, p)."""

        def one(theta_i, X_i, y_i, mask_i, lam):
            m = jnp.maximum(mask_i.sum(), 1.0)
            g = self._point_grads(theta_i, X_i, y_i)
            return jnp.sum(g * mask_i[:, None], axis=0) / m + 2.0 * lam * theta_i

        return jax.vmap(one)(
            Theta,
            jnp.asarray(self.data.X),
            jnp.asarray(self.data.y),
            jnp.asarray(self.data.mask),
            jnp.asarray(self.lambdas),
        )

    @partial(jax.jit, static_argnums=0)
    def value(self, Theta):
        smooth = self.mix.pairwise_smoothness(Theta)
        d = jnp.asarray(self.degrees)
        c = jnp.asarray(self.confidences)
        return smooth + self.mu * jnp.sum(d * c * self.local_loss(Theta))

    @partial(jax.jit, static_argnums=0)
    def block_grad(self, Theta):
        """[grad Q]_i for all i (Eq. 3), stacked into (n, p)."""
        d = jnp.asarray(self.degrees)
        c = jnp.asarray(self.confidences)
        neigh = self.mix.all(Theta)  # (n, p): sum_j W_ij Theta_j
        return d[:, None] * (Theta + self.mu * c[:, None] * self.local_grad(Theta)) - neigh

    def grad_check(self, Theta, eps=1e-5):
        """Finite-difference check of block_grad; returns max abs error."""
        Theta = np.asarray(Theta, dtype=np.float64)
        g = np.asarray(self.block_grad(jnp.asarray(Theta)))
        err = 0.0
        rng = np.random.default_rng(0)
        for _ in range(10):
            i = rng.integers(self.n)
            k = rng.integers(self.p)
            tp = Theta.copy()
            tp[i, k] += eps
            tm = Theta.copy()
            tm[i, k] -= eps
            fd = (float(self.value(jnp.asarray(tp))) - float(self.value(jnp.asarray(tm)))) / (
                2 * eps
            )
            err = max(err, abs(fd - g[i, k]))
        return err

    def solve_exact(self) -> np.ndarray:
        """Closed-form minimizer when the loss is quadratic-in-theta.

        Only valid for QUADRATIC loss (and the model-propagation special
        case); used by tests to verify convergence to the true optimum.
        """
        if self.loss.name != "quadratic":
            raise ValueError("closed form only available for quadratic loss")
        n, p = self.n, self.p
        d = self.degrees
        c = self.confidences
        X, y, mask = self.data.X, self.data.y, self.data.mask
        m = np.maximum(mask.sum(axis=1), 1.0)
        A = np.zeros((n * p, n * p))
        b = np.zeros(n * p)
        for i in range(n):
            sl = slice(i * p, (i + 1) * p)
            Xi = X[i] * mask[i][:, None]
            H = 2.0 * Xi.T @ Xi / m[i] + 2.0 * self.lambdas[i] * np.eye(p)
            g0 = -2.0 * Xi.T @ (y[i] * mask[i]) / m[i]
            A[sl, sl] += d[i] * np.eye(p) + self.mu * d[i] * c[i] * H
            b[sl] += -self.mu * d[i] * c[i] * g0
            for j, wij in zip(*self.graph.row(i)):
                A[sl, j * p : (j + 1) * p] += -wij * np.eye(p)
        sol = np.linalg.solve(A, b)
        return sol.reshape(n, p)


def make_objective(
    graph: AgentGraph | CSRGraph,
    data: AgentData,
    loss: Loss | str,
    mu: float,
    lambdas=None,
    confidences=None,
    clip: float | None = None,
    mix_mode: str = "auto",
) -> Objective:
    if isinstance(loss, str):
        loss = LOSSES[loss]
    m = data.num_examples
    if lambdas is None:
        # Paper Sec. 5: lambda_i = 1 / m_i ensures overall strong convexity.
        lambdas = 1.0 / np.maximum(m, 1.0)
    if confidences is None:
        from repro.core.graph import confidences as conf_fn

        confidences = conf_fn(m)
    return Objective(
        graph=graph,
        data=data,
        loss=loss,
        mu=float(mu),
        lambdas=np.asarray(lambdas, dtype=np.float64),
        confidences=np.asarray(confidences, dtype=np.float64),
        clip=clip,
        mix_mode=mix_mode,
    )
