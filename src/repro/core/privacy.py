"""Differential-privacy machinery (Sec. 3, Thm. 1, Prop. 2, Remark 4).

Implements:

* Laplace noise scales ``s_i(t) = 2 L0 / (eps_i(t) m_i)`` (Thm. 1) and the
  Gaussian variant (Remark 4).
* The Kairouz–Oh–Viswanath composition of Thm. 1: the three-term min giving
  the overall ``(eps_bar, delta_bar)`` for a sequence of per-step epsilons.
* Budget *inversion*: given an overall budget, find the per-step epsilon
  under equal splitting (bisection on the composition formula) — this is how
  the paper's experiments split budgets ("splits its privacy budget equally
  across T_i iterations using Theorem 1").
* The utility-optimal time-decreasing allocation of Prop. 2 / Lemma 3:
  ``eps_i*(t) ∝ C^{t/3}``.
* A per-agent :class:`PrivacyAccountant` that tracks spend and enforces
  stopping.
* The Thm. 2 utility-loss bound for plotting against empirical curves.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


def laplace_scale(l0: float, eps_step: float, m_i: int | float) -> float:
    """Thm. 1: s_i(t) = 2 L0 / (eps_i(t) m_i)."""
    if eps_step <= 0:
        raise ValueError("eps_step must be positive")
    return 2.0 * l0 / (eps_step * max(float(m_i), 1.0))


def gaussian_scale(l0_l2: float, eps_step: float, delta_step: float, m_i: int | float) -> float:
    """Remark 4: s_i(t) >= 2 L0* sqrt(2 ln(2/delta)) / eps (per-example L2)."""
    if eps_step <= 0 or not (0 < delta_step < 1):
        raise ValueError("need eps > 0 and 0 < delta < 1")
    return (
        2.0
        * l0_l2
        * math.sqrt(2.0 * math.log(2.0 / delta_step))
        / (eps_step * max(float(m_i), 1.0))
    )


def compose_kairouz(eps_steps: np.ndarray, delta_bar: float) -> float:
    """Overall eps_bar of Thm. 1 for per-step eps list and slack delta_bar.

    eps_bar = min( sum eps_t,
                   sum (e^e - 1) e / (e^e + 1) + sqrt( sum 2 e^2 log(e + sqrt(sum e^2)/delta) ),
                   sum (e^e - 1) e / (e^e + 1) + sqrt( sum 2 e^2 log(1/delta) ) )
    """
    e = np.asarray(eps_steps, dtype=np.float64)
    if np.any(e < 0):
        raise ValueError("per-step epsilons must be non-negative")
    basic = e.sum()
    if delta_bar <= 0:
        return float(basic)
    kl = np.sum((np.expm1(e)) * e / (np.exp(e) + 1.0))
    sq = np.sum(e**2)
    adv1 = kl + math.sqrt(2.0 * sq * math.log(math.e + math.sqrt(sq) / delta_bar))
    adv2 = kl + math.sqrt(2.0 * sq * math.log(1.0 / delta_bar))
    return float(min(basic, adv1, adv2))


def compose_uniform(eps_step, counts: np.ndarray, delta_bar: float) -> np.ndarray:
    """Vectorized :func:`compose_kairouz` for k equal per-step epsilons.

    ``counts``: (n,) number of spent steps per agent, each spent at that
    agent's constant ``eps_step`` (a scalar, or an array broadcastable
    against ``counts`` — the re-split schedules give under-waking agents a
    larger per-step epsilon). Returns the (n,) composed eps_bar — what n
    separate ``compose_kairouz(np.full(k, eps_step), delta_bar)`` calls
    would give, without the per-agent python loop (the batched engine's
    and ``dp_cd.run_private``'s accounting at large n).
    """
    k = np.asarray(counts, dtype=np.float64)
    e = np.asarray(eps_step, dtype=np.float64)
    basic = k * e
    if delta_bar <= 0:
        return basic
    kl = k * (np.expm1(e) * e / (np.exp(e) + 1.0))
    sq = k * e * e
    adv1 = kl + np.sqrt(2.0 * sq * np.log(math.e + np.sqrt(sq) / delta_bar))
    adv2 = kl + np.sqrt(2.0 * sq * math.log(1.0 / delta_bar))
    return np.where(k > 0, np.minimum(basic, np.minimum(adv1, adv2)), 0.0)


def invert_uniform_budget(eps_bar: float, T_i: int, delta_bar: float) -> float:
    """Largest per-step eps s.t. T_i equal steps compose to <= eps_bar.

    Monotone in eps -> bisection. This is what "split the budget equally
    across T_i iterations using Theorem 1" means operationally.
    """
    if T_i <= 0:
        raise ValueError("T_i must be positive")
    if eps_bar <= 0:
        raise ValueError("eps_bar must be positive")

    def total(eps_step):
        return compose_kairouz(np.full(T_i, eps_step), delta_bar)

    lo, hi = 0.0, eps_bar  # eps_step = eps_bar always overshoots for T_i > 1
    if total(hi) <= eps_bar:
        return hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if total(mid) <= eps_bar:
            lo = mid
        else:
            hi = mid
    return lo


def proposition2_allocation(eps_bar: float, T: int, C: float) -> np.ndarray:
    """Lemma 3: eps*(t) = (C^{1/3} - 1) / (C^{T/3} - 1) * C^{t/3} * eps_bar.

    Returns the (T,) schedule over *global* iterations 0..T-1; it sums to
    eps_bar. C = 1 - sigma / (n L_max) in (0, 1).
    """
    if not (0.0 < C < 1.0):
        raise ValueError("contraction factor must be in (0,1)")
    r = C ** (1.0 / 3.0)
    t = np.arange(T, dtype=np.float64)
    coef = (r - 1.0) / (r**T - 1.0)
    return coef * (r**t) * eps_bar


def schedule_renormalization(schedule_t: np.ndarray, T: int, C: float) -> float:
    """lambda_{T_i}(i) of Prop. 2: sum over the agent's wake ticks of the
    Lemma-3 coefficients. Dividing eps*(t) by it makes the realized spend
    exactly eps_bar for this schedule."""
    r = C ** (1.0 / 3.0)
    coef = (r - 1.0) / (r**T - 1.0)
    return float(np.sum(coef * (r ** np.asarray(schedule_t, dtype=np.float64))))


@dataclasses.dataclass
class PrivacyAccountant:
    """Tracks one agent's per-step epsilons and reports the composed spend."""

    delta_bar: float
    steps: list = dataclasses.field(default_factory=list)

    def spend(self, eps_step: float):
        if eps_step < 0:
            raise ValueError("eps must be >= 0")
        self.steps.append(float(eps_step))

    @property
    def eps_bar(self) -> float:
        if not self.steps:
            return 0.0
        return compose_kairouz(np.asarray(self.steps), self.delta_bar)

    def can_spend(self, eps_step: float, budget: float) -> bool:
        trial = np.asarray(self.steps + [float(eps_step)])
        return compose_kairouz(trial, self.delta_bar) <= budget + 1e-12


def theorem2_bound(
    gap0: float,
    T: int,
    n: int,
    L_max: float,
    L_min: float,
    sigma: float,
    noise_sq_per_tick: np.ndarray,
) -> np.ndarray:
    """Thm. 2 upper bound on E[Q(t)] - Q* for t = 0..T.

    ``noise_sq_per_tick[t] = sum_i (mu D_ii c_i s_i(t))^2`` — the expected
    squared scaled-noise magnitude injected at tick t (2x for Laplace
    variance is folded in by the caller via ``2 * s^2`` if desired; we follow
    the theorem statement and take the (mu D c s)^2 terms directly).
    """
    rho = sigma / (n * L_max)
    C = 1.0 - rho
    bound = np.empty(T + 1)
    bound[0] = gap0
    acc = 0.0
    for t in range(1, T + 1):
        acc = C * acc + noise_sq_per_tick[t - 1] / (n * L_min)
        bound[t] = gap0 * (C**t) + acc
    return bound


def uniform_noise_limit(a: float, rho: float) -> float:
    """Supp. B: additive loss a/rho as T -> inf under uniform noise scales."""
    return a / rho
