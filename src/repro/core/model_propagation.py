"""Model propagation (Supp. C) and the private warm start.

With L_i(Theta_i) = 1/2 ||Theta_i - Theta_i^loc||^2, the Eq. 4 update becomes
the *exact* block minimizer (Eq. 16):

    Theta_i <- ( sum_j (W_ij/D_ii) Theta_j + mu c_i Theta_i^loc ) / (1 + mu c_i)

which recovers Vanhaesebrouck et al. (2017)'s model propagation. Since the
data only enters through Theta_i^loc, a DP version of Theta_i^loc makes the
whole propagation private at no per-iteration cost — this is the paper's
private warm start (Remark 3).

DP local models use output perturbation (Chaudhuri et al., 2011): L_i is
(2 lambda_i)-strongly convex and swapping one data point moves its gradient
by at most 2 L0 / m_i, so the minimizer moves by at most
(2 L0 / m_i) / (2 lambda_i) = L0 / (lambda_i m_i); Laplace noise with scale
L0 / (lambda_i m_i eps) gives (eps, 0)-DP.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import AgentGraph, CSRGraph, dense_weights
from repro.core.objective import AgentData, Objective, make_objective


def propagation_objective(
    graph: AgentGraph | CSRGraph, theta_loc: np.ndarray, mu: float, confidences: np.ndarray
):
    """Q_MP of Eq. 15 as closures (value, exact solve, one sync round)."""
    rows, cols, vals = graph.edge_list()
    d = graph.degrees
    n, p = theta_loc.shape

    def value(Theta):
        d2 = np.sum((Theta[rows] - Theta[cols]) ** 2, axis=-1)
        smooth = 0.5 * np.sum(vals * d2)
        local = 0.5 * mu * np.sum(d * confidences * np.sum((Theta - theta_loc) ** 2, axis=-1))
        return smooth + local

    def solve():
        # (diag(D)(I + mu C) - W) Theta = mu diag(D) C theta_loc, per dimension.
        A = np.diag(d * (1.0 + mu * confidences)) - dense_weights(graph)
        B = mu * (d * confidences)[:, None] * theta_loc
        return np.linalg.solve(A, B)

    return value, solve


def propagation_update(graph: AgentGraph | CSRGraph, Theta, theta_loc, mu, confidences, i):
    """Eq. 16 for one agent (exact block minimizer)."""
    cols, w = graph.row(i)
    neigh = w @ Theta[cols] / graph.degrees[i]
    return (neigh + mu * confidences[i] * theta_loc[i]) / (1.0 + mu * confidences[i])


def propagation_rows_from(mu, d, c, loc, neigh):
    """Batched Eq. 16 from pre-gathered per-agent constants.

    ``d``/``c``: (B,) degrees and confidences, ``loc``: (B, p) local
    models, ``neigh``: (B, p) raw neighbour sums — all row-aligned. The
    sharded engine gathers these from its shard-resident tiles;
    :func:`propagation_rows` gathers them from the replicated arrays.
    """
    dt = neigh.dtype
    d = jnp.asarray(d, dt)
    c = jnp.asarray(c, dt)
    loc = jnp.asarray(loc, dt)
    return (neigh / d[:, None] + mu * c[:, None] * loc) / (1.0 + mu * c[:, None])


def propagation_rows(degrees, theta_loc, mu, confidences, rows, neigh):
    """Batched Eq. 16 for a gathered row set (jit-able, traced ``rows``).

    ``neigh``: (B, p) raw neighbour sums ``sum_j W_ij Theta_j`` for the
    rows. The exact block minimizer needs no gradient, so this is the
    whole update — the ``repro.sim`` engine drives it through the same
    gather/mix/scatter path as Eq. 4.
    """
    dt = neigh.dtype
    return propagation_rows_from(
        mu,
        jnp.asarray(degrees, dt)[rows],
        jnp.asarray(confidences, dt)[rows],
        jnp.asarray(theta_loc, dt)[rows],
        neigh,
    )


def run_propagation(
    graph: AgentGraph,
    theta_loc: np.ndarray,
    mu: float,
    confidences: np.ndarray,
    T: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Asynchronous model propagation; returns final Theta."""
    n = graph.n
    Theta = theta_loc.copy()
    for t in range(T):
        i = int(rng.integers(n))
        Theta[i] = propagation_update(graph, Theta, theta_loc, mu, confidences, i)
    return Theta


def train_local_models(data: AgentData, loss, lambdas, steps: int = 300, lr: float = 0.5):
    """Theta_i^loc per Eq. 1 via jit-scanned full-batch gradient descent."""
    X = jnp.asarray(data.X, jnp.float32)
    y = jnp.asarray(data.y, jnp.float32)
    mask = jnp.asarray(data.mask, jnp.float32)
    lam = jnp.asarray(lambdas, jnp.float32)
    n, _, p = data.X.shape

    def agent_loss(theta, Xi, yi, mi, l):
        m = jnp.maximum(mi.sum(), 1.0)
        vals = jax.vmap(lambda x, yy: loss.point_loss(theta, x, yy))(Xi, yi)
        return jnp.sum(vals * mi) / m + l * jnp.sum(theta**2)

    grad = jax.grad(agent_loss)

    def step(Theta, _):
        g = jax.vmap(grad)(Theta, X, y, mask, lam)
        return Theta - lr * g, None

    Theta0 = jnp.zeros((n, p), jnp.float32)
    ThetaT, _ = jax.lax.scan(step, Theta0, None, length=steps)
    return np.asarray(ThetaT)


def private_local_models(
    theta_loc: np.ndarray,
    l0: float,
    lambdas: np.ndarray,
    num_examples: np.ndarray,
    eps: float,
    rng: np.random.Generator,
) -> np.ndarray:
    """Output perturbation: Theta~_i^loc = Theta_i^loc + Lap(0, L0/(lam_i m_i eps))^p."""
    n, p = theta_loc.shape
    m = np.maximum(num_examples, 1.0)
    scales = l0 / (lambdas * m * eps)
    noise = rng.laplace(0.0, 1.0, size=(n, p)) * scales[:, None]
    return theta_loc + noise


def private_warm_start(
    obj: Objective,
    eps_warm: float,
    rng: np.random.Generator,
    propagation_ticks: int | None = None,
) -> np.ndarray:
    """Remark 3 / Supp. C: DP local models + (data-free) model propagation."""
    theta_loc = train_local_models(obj.data, obj.loss, obj.lambdas)
    l0 = obj.lipschitz_l1()
    theta_priv = private_local_models(
        theta_loc, l0, obj.lambdas, obj.data.num_examples, eps_warm, rng
    )
    T = propagation_ticks if propagation_ticks is not None else 10 * obj.n
    return run_propagation(obj.graph, theta_priv, obj.mu, obj.confidences, T, rng)
