"""Version-compat ``shard_map`` shim shared by the SPMD layers.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` (0.4.x) to the
top-level ``jax`` namespace (>= 0.6) and renamed the replication-check
keyword from ``check_rep`` to ``check_vma`` along the way. Both the
synchronous scale layer (:mod:`repro.core.spmd`) and the sharded async
engine (:mod:`repro.sim.engine`) need the same wrapper, so it lives here
once instead of being copy-pasted per caller.
"""

from __future__ import annotations

try:  # jax >= 0.6: public API, replication check renamed check_vma.
    from jax import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(body, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with the replication check disabled, any jax version."""
    return _shard_map_impl(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **{_SHARD_MAP_CHECK_KW: False},
    )
