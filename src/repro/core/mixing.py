"""Neighbour-sum operator ``sum_j W_ij Theta_j`` with dense/sparse dispatch.

Every algorithm in ``repro.core`` reduces its graph traffic to two shapes:

* ``all``: the full neighbour sum for every agent at once (synchronous
  rounds, block gradients) — (n, p) -> (n, p);
* ``row``: one agent's neighbour sum under a traced index (the Eq. 4
  asynchronous tick inside ``lax.scan``) — (n, p), i -> (p,).

:func:`mix_op` builds a :class:`MixOp` for either graph representation.
Below :func:`repro.core.graph.sparse_crossover` agents the operator
materializes the (n, n) matrix and uses the MXU matmul fast path; at or
above it the operator stays O(nnz): padded-neighbour gathers for ``row``
and a ``segment_sum`` for ``all``. On a TPU backend, ``all`` routes
through the ``graph_mix``/``sparse_mix`` Pallas kernels for f32 at
on-chip agent counts (and through plain jnp otherwise — on this CPU
container the kernels would run interpreted, so they are test/TPU-only).
Pass ``mode="dense"``/``"sparse"`` to pin a representation explicitly
(the property tests assert both paths agree).
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (
    CSRGraph,
    as_csr,
    dense_weights,
    int_env_knob,
    sparse_crossover,
)


# The Pallas mixing kernels keep the (n, bp) Theta slab VMEM-resident, so
# they only serve the on-chip regime; past this the jnp paths take over.
_KERNEL_MAX_N = 4096


def kernel_max_n() -> int:
    """Largest agent count the Pallas mixing kernels auto-engage at.

    The kernels keep the whole (n, bp) Theta slab VMEM-resident, so the
    ceiling tracks the chip's VMEM budget, not correctness. Override with
    the ``REPRO_KERNEL_MAX_N`` environment variable (mirrors
    ``REPRO_SPARSE_CROSSOVER``); set 0 to disable the kernel auto-path.
    """
    return int_env_knob("REPRO_KERNEL_MAX_N", _KERNEL_MAX_N)


@dataclasses.dataclass(frozen=True, eq=False)
class MixOp:
    """Dense or sparse neighbour-sum operator. Arrays are jit-closure constants."""

    kind: str  # "dense" | "sparse"
    n: int
    W: np.ndarray | None = None  # (n, n) — dense only
    idx: np.ndarray | None = None  # (n, K) padded neighbour indices — sparse only
    w: np.ndarray | None = None  # (n, K) padded neighbour weights — sparse only
    rows: np.ndarray | None = None  # (nnz,) COO rows, sorted — sparse only
    cols: np.ndarray | None = None  # (nnz,)
    vals: np.ndarray | None = None  # (nnz,)

    def _kernel_auto(self, Theta) -> bool:
        # Engage the Pallas kernels only where they are the right tool:
        # compiled TPU lowering, f32 (the kernels accumulate/return f32 —
        # silently downcasting the x64 theory paths is not acceptable),
        # and an on-chip agent count whose Theta slab fits VMEM.
        return (
            jax.default_backend() == "tpu"
            and Theta.dtype == jnp.float32
            and self.n <= kernel_max_n()
        )

    def all(self, Theta, use_kernel: bool | None = None):
        """sum_j W_ij Theta_j for every agent: (n, p) -> (n, p).

        ``use_kernel``: force the Pallas kernel path on (True, interpreted
        off-TPU) or off (False); None auto-selects it on TPU for f32 at
        on-chip n.
        """
        if use_kernel is None:
            use_kernel = self._kernel_auto(Theta)
        if use_kernel:
            from repro.kernels import ops

            if self.kind == "dense":
                return ops.graph_mix(jnp.asarray(self.W, jnp.float32), Theta)
            return ops.sparse_mix(
                jnp.asarray(self.idx), jnp.asarray(self.w, jnp.float32), Theta
            )
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype) @ Theta
        contrib = jnp.asarray(self.vals, Theta.dtype)[:, None] * Theta[jnp.asarray(self.cols)]
        return jax.ops.segment_sum(
            contrib, jnp.asarray(self.rows), num_segments=self.n, indices_are_sorted=True
        )

    def row(self, Theta, i):
        """sum_j W_ij Theta_j for one (possibly traced) agent i: -> (p,)."""
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype)[i] @ Theta
        cols_i = jnp.asarray(self.idx)[i]  # (K,)
        w_i = jnp.asarray(self.w, Theta.dtype)[i]  # (K,)
        return jnp.sum(w_i[:, None] * Theta[cols_i], axis=0)

    def gather_rows(self, Theta, idx, use_kernel: bool | None = None):
        """Batched neighbour sums for a row subset: (B,) indices -> (B, p).

        The super-tick path of ``repro.sim``: gather only the woken agents'
        neighbourhoods instead of computing all n sums. Indices may be
        traced and may contain the out-of-range padding sentinel n (jit
        gathers clamp it to row n-1; callers mask those entries out when
        scattering). Sparse graphs route through the ``sparse_mix`` Pallas
        machinery on TPU under the same gate as :meth:`all`.
        """
        if use_kernel is None:
            use_kernel = self._kernel_auto(Theta)
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype)[idx] @ Theta
        cols = jnp.asarray(self.idx)[idx]  # (B, K)
        w = jnp.asarray(self.w, Theta.dtype)[idx]  # (B, K)
        if use_kernel:
            from repro.kernels import ops

            return ops.sparse_rows_mix(cols, w.astype(jnp.float32), Theta)
        return jnp.einsum("bk,bkp->bp", w, Theta[cols])

    def pairwise_smoothness(self, Theta):
        """1/2 sum_{i<j} W_ij ||Theta_i - Theta_j||^2 (Eq. 2 first term)."""
        if self.kind == "dense":
            W = jnp.asarray(self.W, Theta.dtype)
            diffs = Theta[:, None, :] - Theta[None, :, :]
            return 0.25 * jnp.sum(W * jnp.sum(diffs**2, axis=-1))
        rows, cols = jnp.asarray(self.rows), jnp.asarray(self.cols)
        d2 = jnp.sum((Theta[rows] - Theta[cols]) ** 2, axis=-1)
        return 0.25 * jnp.sum(jnp.asarray(self.vals, Theta.dtype) * d2)


_EXCHANGE_METHODS = ("all_gather", "p2p", "auto")
_EXCHANGE_DTYPES = ("f32", "bf16", "int8")

# The bare-string deprecation fires once per process, not once per engine:
# sweeps and parity tests construct dozens of engines from the same config
# and a warning per construction is noise that buries real warnings.
_warned_bare_exchange_string = False


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """Typed halo-exchange configuration for the sharded engines.

    Replaces the bare ``method`` strings: the wire format now has three
    independent axes —

    * ``method``: which collective ships the halo rows (``"all_gather"``
      replicated border pool, ``"p2p"`` per-ring-offset ``ppermute``, or
      ``"auto"`` to pick by the partition's measured cut);
    * ``dtype``: the payload element type. ``"f32"`` ships full-precision
      rows (bit-exact, the PR-4 behaviour); ``"bf16"`` halves the bytes
      per row; ``"int8"`` quarters them, shipping one f32 scale per row
      (``max|row| / 127`` symmetric quantization);
    * ``error_feedback``: carry a per-border-row residual accumulator
      (CHOCO-SGD style) in :class:`repro.sim.ShardedSimState` so the
      quantization error is re-injected into the next slot's payload
      instead of biasing the gossip fixed point.

    Old-style strings (``exchange="p2p"``) still work everywhere a spec
    is accepted, via :meth:`coerce` + ``DeprecationWarning``. The
    documented string form for CLIs is :meth:`from_string`
    (``"p2p:bf16:ef"``), which does not warn.
    """

    method: str = "auto"
    dtype: str = "f32"
    error_feedback: bool = False

    def __post_init__(self):
        if self.method not in _EXCHANGE_METHODS:
            raise ValueError(
                f"unknown exchange method {self.method!r} (use one of {_EXCHANGE_METHODS})"
            )
        if self.dtype not in _EXCHANGE_DTYPES:
            raise ValueError(
                f"unknown exchange dtype {self.dtype!r} (use one of {_EXCHANGE_DTYPES})"
            )
        if self.error_feedback and self.dtype == "f32":
            raise ValueError(
                "error_feedback has no effect on the lossless f32 wire format; "
                "pick dtype='bf16' or 'int8'"
            )

    @classmethod
    def from_string(cls, spec: str) -> "ExchangeSpec":
        """Parse the CLI form ``method[:dtype[:ef]]``, e.g. ``"p2p:bf16:ef"``."""
        parts = [s for s in str(spec).split(":") if s]
        if not parts:
            raise ValueError(f"empty exchange spec {spec!r}")
        method, rest = parts[0], parts[1:]
        ef = "ef" in rest
        dtypes = [r for r in rest if r != "ef"]
        if len(dtypes) > 1 or any(r not in _EXCHANGE_DTYPES for r in dtypes):
            raise ValueError(f"bad exchange spec {spec!r} (want method[:dtype[:ef]])")
        return cls(method=method, dtype=dtypes[0] if dtypes else "f32", error_feedback=ef)

    @classmethod
    def coerce(cls, value) -> "ExchangeSpec":
        """Accept an ExchangeSpec, None (defaults), or a deprecated string."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            global _warned_bare_exchange_string
            if not _warned_bare_exchange_string:
                _warned_bare_exchange_string = True
                warnings.warn(
                    f"passing exchange={value!r} as a bare string is deprecated; "
                    f"use ExchangeSpec (e.g. ExchangeSpec.from_string({value!r}))",
                    DeprecationWarning,
                    stacklevel=3,
                )
            return cls.from_string(value)
        raise TypeError(f"exchange must be an ExchangeSpec or string, got {type(value)!r}")

    def payload_bytes_per_row(self, p: int) -> int:
        """Wire bytes per exchanged row of width p (int8 adds its f32 scale)."""
        if self.dtype == "f32":
            return 4 * p
        if self.dtype == "bf16":
            return 2 * p
        return p + 4

    def needs_error_feedback_state(self) -> bool:
        """Whether the engine must thread a (Bmax, p) accumulator per shard."""
        return self.error_feedback and self.dtype != "f32"


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedMixOp:
    """Shard-local neighbour sums with halo exchange over an agent partition.

    The multi-device counterpart of :meth:`MixOp.gather_rows`: agents are
    position-contiguous blocks on a ``shard_map`` mesh axis, each shard
    holds its own (R, p) Theta block, and cross-shard edges are served by
    a **halo exchange** with two interchangeable wire formats:

    * ``method="all_gather"`` — every shard publishes its (Bmax,) border
      rows, one ``all_gather`` replicates the (S, Bmax, p) pool, and each
      shard gathers exactly the remote rows its tiles reference. One
      static collective, but the pool is replicated: each shard receives
      (S-1) * Bmax rows however few it needs — the right trade when the
      cut is dense (high halo fraction).
    * ``method="p2p"`` — one ``ppermute`` per mesh-ring offset in the
      partition's :func:`repro.sim.partition.point_to_point_plan`: each
      shard ships only the rows its ring-offset neighbour actually reads
      (padded to the per-offset max P_d) and scatters received rows into
      its halo slots. Each shard receives sum_d P_d rows — the right
      trade once a locality relabel has shrunk the cut to a few
      neighbour shards.

    Both formats fill the identical halo slots with identical row copies,
    so everything downstream — and therefore the two methods — is
    bit-exact-interchangeable. ``method="auto"`` in
    :func:`sharded_mix_op` picks whichever ships fewer rows per
    super-tick for the measured cut.

    **Compressed payloads** (``dtype="bf16"`` / ``"int8"`` from the
    :class:`ExchangeSpec`): each shard quantizes its border rows *once*
    per slot — every reader receives the same dequantized copy, whichever
    collective ships it — and the wire carries the narrow payload (int8
    adds one f32 scale per row, ``max|row| / 127``). With
    ``error_feedback`` the shard keeps a (Bmax, p) residual accumulator
    ``e``: it quantizes ``v = border + e`` and stores ``e' = v - dq(v)``,
    so the quantization error re-enters the next slot's payload instead
    of accumulating into a fixed-point bias. The accumulator is engine
    state (:class:`repro.sim.ShardedSimState` ``ef`` leaf), threaded
    through :meth:`exchange_halo`.

    The stacked (S, ...) plan arrays (``exchange_inputs``) and tiles are
    *inputs* to the shard_map'd caller (sliced per shard by
    ``in_specs``), never closed over — a closure would replicate the
    O(nnz) tiles onto every device, which is exactly what sharding
    exists to avoid.
    """

    n: int
    num_shards: int
    idx: np.ndarray  # (S, R, K) extended-local neighbour indices
    w: np.ndarray  # (S, R, K) weights (pad entries 0)
    border: np.ndarray  # (S, Bmax) local rows each shard publishes
    halo_src: np.ndarray  # (S, Hmax) flat index into the (S * Bmax,) border pool
    method: str = "all_gather"  # "all_gather" | "p2p"
    halo_width: int = 1  # Hmax: halo slots per shard in the extended array
    p2p_offsets: tuple[int, ...] = ()  # static ring offsets, one ppermute each
    p2p_send: tuple[np.ndarray, ...] = ()  # per offset: (S, P_d) local rows to ship
    p2p_dst: tuple[np.ndarray, ...] = ()  # per offset: (S, P_d) halo slots, sentinel Hmax
    p2p_bpos: tuple[np.ndarray, ...] = ()  # per offset: (S, P_d) border-pool positions of sends
    dtype: str = "f32"  # wire format: "f32" | "bf16" | "int8"
    error_feedback: bool = False  # thread a (Bmax, p) residual accumulator
    axis: str = "shards"

    @property
    def rows_per_shard(self) -> int:
        """R: padded rows per shard."""
        return self.idx.shape[1]

    def rebound(self, partition) -> "ShardedMixOp":
        """This operator rebuilt against a patched/repartitioned partition.

        The exchange *method* is pinned to this operator's already
        resolved choice (never re-run through ``"auto"``), so a
        dynamic-topology engine keeps a stable program structure across
        :meth:`repro.sim.partition.GraphPartition.patch` rebinds — only
        the plan arrays change. Wire dtype and error-feedback threading
        carry over unchanged.
        """
        return sharded_mix_op(
            partition,
            axis=self.axis,
            exchange=ExchangeSpec(
                method=self.method,
                dtype=self.dtype,
                error_feedback=self.error_feedback,
            ),
        )

    def exchange_inputs(self):
        """The stacked (S, ...) plan arrays the chosen method consumes.

        Pass this pytree through ``shard_map`` with a leading-axis spec
        (never close over it) and hand the per-shard slice to
        :meth:`exchange_halo`.
        """
        if self.method == "p2p":
            if self.dtype != "f32":
                # Compressed p2p quantizes the border pool once, then ships
                # per-offset *slices* of it: sends are re-addressed as
                # border-pool positions and the border table rides along.
                return {"border": self.border, "bpos": self.p2p_bpos, "dst": self.p2p_dst}
            return {"send": self.p2p_send, "dst": self.p2p_dst}
        return {"border": self.border, "halo_src": self.halo_src}

    def init_error_feedback(self, p: int, dtype=jnp.float32):
        """Zero (S, Bmax, p) residual accumulator (None when not threaded)."""
        if not (self.error_feedback and self.dtype != "f32"):
            return None
        return jnp.zeros((self.num_shards, self.border.shape[1], p), dtype)

    def _quantize(self, v):
        """Quantize border rows v (Bmax, p) -> (payload dict, dequantized)."""
        if self.dtype == "bf16":
            q = v.astype(jnp.bfloat16)
            return {"q": q}, q.astype(v.dtype)
        # int8 with per-row symmetric scales: scale = max|row| / 127.
        scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, jnp.asarray(1e-30, v.dtype))
        q = jnp.clip(jnp.round(v / scale), -127.0, 127.0).astype(jnp.int8)
        return {"q": q, "scale": scale}, q.astype(v.dtype) * scale

    def exchange_halo(self, Theta_local, ex, ef=None, *, upto=None, collect_stats=False):
        """Extend this shard's (R, p) block with its halo rows.

        Runs inside ``shard_map``. ``ex`` is this shard's slice of
        :meth:`exchange_inputs` (leading S axis already consumed); ``ef``
        is this shard's (Bmax, p) error-feedback accumulator slice (None
        when not threaded). Returns ``(out, ef_new, stats)`` where ``out``
        for the full exchange (``upto=None``) is the (R + Hmax, p)
        extended array the tiles index — halo slots past this shard's
        real halo size are unreferenced by the tiles — and ``ef_new`` is
        the updated accumulator (unchanged/None without error feedback).

        The exchange decomposes into three ``jax.named_scope`` phases the
        super-tick profiler can cut at (``upto``):

        * ``"halo_publish"`` — gather/quantize/pack this shard's border
          rows into the send payload (``out`` = the packed payload);
        * ``"halo_collective"`` — the ``ppermute``s / ``all_gather`` that
          ship it (``out`` = the received raw buffers);
        * ``None`` (``"halo_scatter"``) — dequantize and place received
          rows into the halo slots (``out`` = ``Theta_ext``).

        ``collect_stats=True`` on a compressed wire reports the
        telemetry dict ``{"quant_err_sq", "ef_residual_sq"}`` computed
        from values the exchange already produced (stats is None
        otherwise) — collection never perturbs the payload.
        """
        S = self.num_shards
        stats = None

        # -- publish: pack (and on compressed wires, quantize) the border.
        with jax.named_scope("obs.halo_publish"):
            scales = None
            ef_new = ef
            if self.dtype == "f32":
                if self.method == "p2p":
                    send = tuple(Theta_local[snd] for snd in ex["send"])  # (P_d, p) each
                else:
                    send = Theta_local[ex["border"]]  # (Bmax, p)
            else:
                # Compressed wire: quantize the border pool once per slot —
                # every reader receives the same dequantized copy — and ship
                # the narrow payload through whichever collective the plan
                # chose.
                v = Theta_local[ex["border"]]  # (Bmax, p)
                if ef is not None:
                    v = v + ef.astype(v.dtype)
                payload, dq = self._quantize(v)
                ef_new = (v - dq) if ef is not None else ef
                if collect_stats:
                    err = (v - dq).astype(jnp.float32)
                    res = err if ef is not None else jnp.zeros_like(err)
                    stats = {
                        "quant_err_sq": jnp.sum(jnp.square(err)),
                        "ef_residual_sq": jnp.sum(jnp.square(res)),
                    }
                if self.method == "p2p":
                    send = tuple(payload["q"][bpos] for bpos in ex["bpos"])
                    if "scale" in payload:
                        scales = tuple(payload["scale"][bpos] for bpos in ex["bpos"])
                else:
                    send = payload["q"]
                    scales = payload.get("scale")
        if upto == "halo_publish":
            return (send, scales), ef_new, stats

        # -- collective: ship the payload.
        with jax.named_scope("obs.halo_collective"):
            if self.method == "p2p":
                recv, recv_s = [], []
                for k, off in enumerate(self.p2p_offsets):
                    perm = [(s, (s + off) % S) for s in range(S)]
                    recv.append(jax.lax.ppermute(send[k], self.axis, perm))  # (P_d, ...)
                    if scales is not None:
                        recv_s.append(jax.lax.ppermute(scales[k], self.axis, perm))
                got = (tuple(recv), tuple(recv_s) if scales is not None else None)
            else:
                pool = jax.lax.all_gather(send, self.axis)  # (S, Bmax, ...)
                pool_s = (
                    jax.lax.all_gather(scales, self.axis) if scales is not None else None
                )
                got = (pool, pool_s)
        if upto == "halo_collective":
            return got, ef_new, stats

        # -- scatter: dequantize received rows into the halo slots.
        with jax.named_scope("obs.halo_scatter"):
            if self.method == "p2p":
                bufs, sbufs = got
                halo = jnp.zeros(
                    (self.halo_width,) + Theta_local.shape[1:], Theta_local.dtype
                )
                for k in range(len(self.p2p_offsets)):
                    rows = bufs[k].astype(Theta_local.dtype)
                    if sbufs is not None:
                        rows = rows * sbufs[k].astype(Theta_local.dtype)
                    # Sentinel dst Hmax drops padding rows.
                    halo = halo.at[ex["dst"][k]].set(rows, mode="drop")
            else:
                pool, pool_s = got
                flat = pool.reshape((-1,) + pool.shape[2:])[ex["halo_src"]]  # (Hmax, ...)
                halo = flat.astype(Theta_local.dtype)
                if pool_s is not None:
                    halo = halo * pool_s.reshape((-1, 1))[ex["halo_src"]].astype(
                        Theta_local.dtype
                    )
            Theta_ext = jnp.concatenate([Theta_local, halo], axis=0)
        return Theta_ext, ef_new, stats

    def gather_rows(self, Theta_ext, idx_s, w_s, rows):
        """Neighbour sums for local ``rows`` from the extended array.

        ``rows`` may be traced and may carry the out-of-range sentinel R
        (clamped here; callers mask those entries when scattering), same
        contract as :meth:`MixOp.gather_rows`.
        """
        safe = jnp.minimum(rows, idx_s.shape[0] - 1)
        cols = idx_s[safe]  # (B, K)
        ww = jnp.asarray(w_s, Theta_ext.dtype)[safe]  # (B, K)
        return jnp.einsum("bk,bkp->bp", ww, Theta_ext[cols])


def sharded_mix_op(
    partition, axis: str = "shards", exchange: "ExchangeSpec | str | None" = None
) -> ShardedMixOp:
    """Build the halo-exchange operator for a :class:`GraphPartition`.

    ``exchange`` is an :class:`ExchangeSpec` (None = defaults: auto
    method, f32 wire). ``method="auto"`` goes point-to-point only when
    it ships at most 3/4 of the all_gather rows on this partition's
    measured cut (``GraphPartition.exchange_rows``): a dense cut (high
    halo fraction, e.g. unrelabeled shuffled labels) pays S-1 ppermutes
    for barely less volume, so it falls back to the single fused
    collective; a locality-relabeled cut ships a small fraction and
    wins outright. Bare strings (``"p2p"``, ``"p2p:bf16"``) are accepted
    as a deprecated shim.
    """
    spec = ExchangeSpec.coerce(exchange)
    method = spec.method
    if method == "auto":
        method = (
            "p2p"
            if 4 * partition.exchange_rows("p2p") <= 3 * partition.exchange_rows("all_gather")
            else "all_gather"
        )
    offsets, sends, dsts = partition.p2p_plan if method == "p2p" else ((), (), ())
    bpos: tuple[np.ndarray, ...] = ()
    if method == "p2p" and spec.dtype != "f32":
        # Re-address each offset's send rows as positions in the (sorted,
        # unique) border list, so compressed sends slice the
        # quantized-once border pool instead of Theta itself.
        border = np.asarray(partition.border)
        bsizes = np.asarray(partition.border_sizes)
        bpos = tuple(
            np.stack(
                [
                    # Only the valid prefix of the border row is sorted; the
                    # zero padding past border_sizes[t] would break the
                    # search. Padding send entries (row 0) may land on an
                    # arbitrary position — the receiver's sentinel dst
                    # drops them.
                    np.searchsorted(
                        border[t, : int(bsizes[t])], np.asarray(snd)[t]
                    ).astype(np.int32)
                    for t in range(partition.num_shards)
                ]
            )
            for snd in sends
        )
    return ShardedMixOp(
        n=partition.n,
        num_shards=partition.num_shards,
        idx=partition.idx,
        w=partition.w,
        border=partition.border,
        halo_src=partition.halo_src,
        method=method,
        halo_width=partition.halo.shape[1],
        p2p_offsets=offsets,
        p2p_send=sends,
        p2p_dst=dsts,
        p2p_bpos=bpos,
        dtype=spec.dtype,
        error_feedback=spec.needs_error_feedback_state(),
        axis=axis,
    )


def mix_op(graph, mode: str = "auto") -> MixOp:
    """Build the neighbour-sum operator for a dense or CSR graph.

    ``mode="auto"`` picks dense below the crossover (small graphs pay the
    O(n^2) matrix gladly for the MXU matmul) and sparse at or above it —
    regardless of which representation the caller holds.
    """
    if mode == "auto":
        mode = "sparse" if graph.n >= sparse_crossover() else "dense"
    if mode == "dense":
        return MixOp(kind="dense", n=graph.n, W=dense_weights(graph))
    if mode != "sparse":
        raise ValueError(f"unknown mix mode {mode!r}")
    csr = as_csr(graph)
    idx, w = csr.padded_neighbors()
    return MixOp(
        kind="sparse",
        n=csr.n,
        idx=idx,
        w=w,
        rows=csr.row_ids(),
        cols=csr.indices,
        vals=csr.data,
    )
