"""Neighbour-sum operator ``sum_j W_ij Theta_j`` with dense/sparse dispatch.

Every algorithm in ``repro.core`` reduces its graph traffic to two shapes:

* ``all``: the full neighbour sum for every agent at once (synchronous
  rounds, block gradients) — (n, p) -> (n, p);
* ``row``: one agent's neighbour sum under a traced index (the Eq. 4
  asynchronous tick inside ``lax.scan``) — (n, p), i -> (p,).

:func:`mix_op` builds a :class:`MixOp` for either graph representation.
Below :func:`repro.core.graph.sparse_crossover` agents the operator
materializes the (n, n) matrix and uses the MXU matmul fast path; at or
above it the operator stays O(nnz): padded-neighbour gathers for ``row``
and a ``segment_sum`` for ``all``. On a TPU backend, ``all`` routes
through the ``graph_mix``/``sparse_mix`` Pallas kernels for f32 at
on-chip agent counts (and through plain jnp otherwise — on this CPU
container the kernels would run interpreted, so they are test/TPU-only).
Pass ``mode="dense"``/``"sparse"`` to pin a representation explicitly
(the property tests assert both paths agree).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (
    CSRGraph,
    as_csr,
    dense_weights,
    int_env_knob,
    sparse_crossover,
)


# The Pallas mixing kernels keep the (n, bp) Theta slab VMEM-resident, so
# they only serve the on-chip regime; past this the jnp paths take over.
_KERNEL_MAX_N = 4096


def kernel_max_n() -> int:
    """Largest agent count the Pallas mixing kernels auto-engage at.

    The kernels keep the whole (n, bp) Theta slab VMEM-resident, so the
    ceiling tracks the chip's VMEM budget, not correctness. Override with
    the ``REPRO_KERNEL_MAX_N`` environment variable (mirrors
    ``REPRO_SPARSE_CROSSOVER``); set 0 to disable the kernel auto-path.
    """
    return int_env_knob("REPRO_KERNEL_MAX_N", _KERNEL_MAX_N)


@dataclasses.dataclass(frozen=True, eq=False)
class MixOp:
    """Dense or sparse neighbour-sum operator. Arrays are jit-closure constants."""

    kind: str  # "dense" | "sparse"
    n: int
    W: np.ndarray | None = None  # (n, n) — dense only
    idx: np.ndarray | None = None  # (n, K) padded neighbour indices — sparse only
    w: np.ndarray | None = None  # (n, K) padded neighbour weights — sparse only
    rows: np.ndarray | None = None  # (nnz,) COO rows, sorted — sparse only
    cols: np.ndarray | None = None  # (nnz,)
    vals: np.ndarray | None = None  # (nnz,)

    def _kernel_auto(self, Theta) -> bool:
        # Engage the Pallas kernels only where they are the right tool:
        # compiled TPU lowering, f32 (the kernels accumulate/return f32 —
        # silently downcasting the x64 theory paths is not acceptable),
        # and an on-chip agent count whose Theta slab fits VMEM.
        return (
            jax.default_backend() == "tpu"
            and Theta.dtype == jnp.float32
            and self.n <= kernel_max_n()
        )

    def all(self, Theta, use_kernel: bool | None = None):
        """sum_j W_ij Theta_j for every agent: (n, p) -> (n, p).

        ``use_kernel``: force the Pallas kernel path on (True, interpreted
        off-TPU) or off (False); None auto-selects it on TPU for f32 at
        on-chip n.
        """
        if use_kernel is None:
            use_kernel = self._kernel_auto(Theta)
        if use_kernel:
            from repro.kernels import ops

            if self.kind == "dense":
                return ops.graph_mix(jnp.asarray(self.W, jnp.float32), Theta)
            return ops.sparse_mix(
                jnp.asarray(self.idx), jnp.asarray(self.w, jnp.float32), Theta
            )
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype) @ Theta
        contrib = jnp.asarray(self.vals, Theta.dtype)[:, None] * Theta[jnp.asarray(self.cols)]
        return jax.ops.segment_sum(
            contrib, jnp.asarray(self.rows), num_segments=self.n, indices_are_sorted=True
        )

    def row(self, Theta, i):
        """sum_j W_ij Theta_j for one (possibly traced) agent i: -> (p,)."""
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype)[i] @ Theta
        cols_i = jnp.asarray(self.idx)[i]  # (K,)
        w_i = jnp.asarray(self.w, Theta.dtype)[i]  # (K,)
        return jnp.sum(w_i[:, None] * Theta[cols_i], axis=0)

    def gather_rows(self, Theta, idx, use_kernel: bool | None = None):
        """Batched neighbour sums for a row subset: (B,) indices -> (B, p).

        The super-tick path of ``repro.sim``: gather only the woken agents'
        neighbourhoods instead of computing all n sums. Indices may be
        traced and may contain the out-of-range padding sentinel n (jit
        gathers clamp it to row n-1; callers mask those entries out when
        scattering). Sparse graphs route through the ``sparse_mix`` Pallas
        machinery on TPU under the same gate as :meth:`all`.
        """
        if use_kernel is None:
            use_kernel = self._kernel_auto(Theta)
        if self.kind == "dense":
            return jnp.asarray(self.W, Theta.dtype)[idx] @ Theta
        cols = jnp.asarray(self.idx)[idx]  # (B, K)
        w = jnp.asarray(self.w, Theta.dtype)[idx]  # (B, K)
        if use_kernel:
            from repro.kernels import ops

            return ops.sparse_rows_mix(cols, w.astype(jnp.float32), Theta)
        return jnp.einsum("bk,bkp->bp", w, Theta[cols])

    def pairwise_smoothness(self, Theta):
        """1/2 sum_{i<j} W_ij ||Theta_i - Theta_j||^2 (Eq. 2 first term)."""
        if self.kind == "dense":
            W = jnp.asarray(self.W, Theta.dtype)
            diffs = Theta[:, None, :] - Theta[None, :, :]
            return 0.25 * jnp.sum(W * jnp.sum(diffs**2, axis=-1))
        rows, cols = jnp.asarray(self.rows), jnp.asarray(self.cols)
        d2 = jnp.sum((Theta[rows] - Theta[cols]) ** 2, axis=-1)
        return 0.25 * jnp.sum(jnp.asarray(self.vals, Theta.dtype) * d2)


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedMixOp:
    """Shard-local neighbour sums with halo exchange over an agent partition.

    The multi-device counterpart of :meth:`MixOp.gather_rows`: agents are
    contiguous blocks on a ``shard_map`` mesh axis, each shard holds its
    own (R, p) Theta block, and cross-shard edges are served by a halo
    exchange — every shard publishes its border rows, one ``all_gather``
    replicates the (small) border pool, and each shard gathers exactly the
    remote rows its tiles reference. Per-shard padded tiles keep the CSR
    neighbour order and the single-device tile width K, so the per-row
    reduction is bit-identical to :meth:`MixOp.gather_rows`'s sparse path.

    The stacked (S, ...) arrays here are *inputs* to the shard_map'd
    caller (sliced per shard by ``in_specs``), never closed over — a
    closure would replicate the O(nnz) tiles onto every device, which is
    exactly what sharding exists to avoid.
    """

    n: int
    num_shards: int
    idx: np.ndarray  # (S, R, K) extended-local neighbour indices
    w: np.ndarray  # (S, R, K) weights (pad entries 0)
    border: np.ndarray  # (S, Bmax) local rows each shard publishes
    halo_src: np.ndarray  # (S, Hmax) flat index into the (S * Bmax,) border pool
    axis: str = "shards"

    @property
    def rows_per_shard(self) -> int:
        return self.idx.shape[1]

    def exchange_halo(self, Theta_local, border_s, halo_src_s):
        """Extend this shard's (R, p) block with its halo rows.

        Runs inside ``shard_map``: publishes the border rows, all-gathers
        the (S, Bmax, p) pool, and gathers this shard's halo rows out of
        it. Returns the (R + Hmax, p) extended array the tiles index.
        """
        send = Theta_local[border_s]  # (Bmax, p)
        pool = jax.lax.all_gather(send, self.axis)  # (S, Bmax, p)
        halo = pool.reshape((-1,) + pool.shape[2:])[halo_src_s]  # (Hmax, p)
        return jnp.concatenate([Theta_local, halo], axis=0)

    def gather_rows(self, Theta_ext, idx_s, w_s, rows):
        """Neighbour sums for local ``rows`` from the extended array.

        ``rows`` may be traced and may carry the out-of-range sentinel R
        (clamped here; callers mask those entries when scattering), same
        contract as :meth:`MixOp.gather_rows`.
        """
        safe = jnp.minimum(rows, idx_s.shape[0] - 1)
        cols = idx_s[safe]  # (B, K)
        ww = jnp.asarray(w_s, Theta_ext.dtype)[safe]  # (B, K)
        return jnp.einsum("bk,bkp->bp", ww, Theta_ext[cols])


def sharded_mix_op(partition, axis: str = "shards") -> ShardedMixOp:
    """Build the halo-exchange operator for a :class:`GraphPartition`."""
    return ShardedMixOp(
        n=partition.n,
        num_shards=partition.num_shards,
        idx=partition.idx,
        w=partition.w,
        border=partition.border,
        halo_src=partition.halo_src,
        axis=axis,
    )


def mix_op(graph, mode: str = "auto") -> MixOp:
    """Build the neighbour-sum operator for a dense or CSR graph.

    ``mode="auto"`` picks dense below the crossover (small graphs pay the
    O(n^2) matrix gladly for the MXU matmul) and sparse at or above it —
    regardless of which representation the caller holds.
    """
    if mode == "auto":
        mode = "sparse" if graph.n >= sparse_crossover() else "dense"
    if mode == "dense":
        return MixOp(kind="dense", n=graph.n, W=dense_weights(graph))
    if mode != "sparse":
        raise ValueError(f"unknown mix mode {mode!r}")
    csr = as_csr(graph)
    idx, w = csr.padded_neighbors()
    return MixOp(
        kind="sparse",
        n=csr.n,
        idx=idx,
        w=w,
        rows=csr.row_ids(),
        cols=csr.indices,
        vals=csr.data,
    )
