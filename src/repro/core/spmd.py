"""The paper's update (Eq. 4/6) as an SPMD program on the production mesh.

This is the scale layer (DESIGN.md §4): agents are mesh slices, the
broadcast-gossip neighbour sum becomes ``lax.ppermute`` ring collectives
over the agent axis, and the DP perturbation (clip + Laplace) runs fused on
each agent's local gradient.

Semantics vs the paper (recorded deviations, DESIGN.md §9):
* synchronous rounds (all agents update from the same snapshot) instead of
  Poisson single-agent wake-ups — same fixed points; the simulator in
  ``coordinate_descent.py`` keeps the faithful async semantics and
  ``test_spmd.py`` cross-checks both against each other;
* for transformer-scale models the DP unit is the per-round *aggregated*
  local gradient, clipped to C in global L2 norm (the paper's per-example
  clipping is kept in the simulator and in the dp_clip_noise kernel, which
  serving-scale linear heads use directly);
* c_i == 1 (uniform confidence): the scale layer feeds equal-size local
  batches per agent each round.

Update per agent (leaf-wise over the param pytree):

    Theta_i <- (1 - alpha) Theta_i
               + alpha * ( sum_o w_o (Theta_{i-o} + Theta_{i+o})
                           - mu * (clip_C(grad_i) + eta_i) )

with eta_i ~ Laplace(0, s)^dim, s = 2 C / (eps_step * m_i).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.spmd_compat import shard_map
from repro.configs.base import P2PConfig
from repro.core import privacy
from repro.models.sharding import batch_specs, cache_specs, param_specs


# ---------------------------------------------------------------------------
# Gossip
# ---------------------------------------------------------------------------


def agent_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def num_agents(mesh, agent_mode: str) -> int:
    if agent_mode == "full":
        n = mesh.shape["data"]
        if "pod" in mesh.shape:
            n *= mesh.shape["pod"]
        return n
    return mesh.shape.get("pod", 1)


def _ring_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def gossip_ppermute(params, specs, mesh, offsets, agent_axes, gossip_dtype=None):
    """Circulant neighbour mean via collective_permute along the agent axes.

    Returns sum_j (W_ij / D_ii) Theta_j for the ring-union graph W with unit
    weights on the *distinct* target set {i +/- o mod n : o in offsets}.
    Offsets that collide modulo the ring size (e.g. +o and -o when
    2o ≡ 0 mod n, or duplicate offsets) contribute a single unit entry —
    exactly what the dense/sparse W constructions store — so D_ii is the
    distinct-neighbour count, not 2 |offsets|. A residual-0 offset
    (o ≡ 0 mod n) is the self-loop the dense W writes on its diagonal and
    contributes the agent's own block without a collective.
    """
    n = int(np.prod([mesh.shape[a] for a in agent_axes]))
    residues = sorted({s * int(o) % n for o in offsets for s in (1, -1)})
    w = 1.0 / len(residues)

    axis = agent_axes if len(agent_axes) > 1 else agent_axes[0]

    def body(tree):
        def mix_leaf(x):
            orig_dtype = x.dtype
            xg = x.astype(gossip_dtype) if gossip_dtype is not None else x
            acc = jnp.zeros(xg.shape, dtype=jnp.float32)
            for r in residues:
                # ppermute with shift r delivers Theta_{i-r}; the residue set
                # is closed under negation, so the union over residues is the
                # same distinct {i +/- o} target set the dense W stores.
                got = xg if r == 0 else jax.lax.ppermute(xg, axis, _ring_perm(n, r))
                acc = acc + w * got.astype(jnp.float32)
            return acc.astype(orig_dtype)

        return jax.tree.map(mix_leaf, tree)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(specs,),
        out_specs=specs,
    )(params)


def gossip_dense(params, mix_matrix):
    """Dense-W fallback: einsum over the agent dim (GSPMD emits all-gathers).

    ``mix_matrix``: (A, A) row-normalized W/D. Baseline for §Perf lever (i).
    """
    return jax.tree.map(
        lambda x: jnp.einsum(
            "ij,j...->i...", mix_matrix.astype(jnp.float32), x.astype(jnp.float32)
        ).astype(x.dtype),
        params,
    )


def gossip_gather(params, idx, w):
    """Sparse neighbour mean over the stacked agent axis: O(A * K) gathers.

    ``idx``: (A, K) padded neighbour indices; ``w``: (A, K) row-normalized
    weights (pad entries 0). The matrix-free counterpart of
    :func:`gossip_dense` — the only shape that survives past the
    dense->sparse crossover, where an (A, A) mixing matrix would not fit.
    """

    def leaf(x):
        g = jnp.take(x.astype(jnp.float32), idx, axis=0)  # (A, K, ...)
        ww = w.astype(jnp.float32).reshape(w.shape + (1,) * (g.ndim - 2))
        return jnp.sum(g * ww, axis=1).astype(x.dtype)

    return jax.tree.map(leaf, params)


# ---------------------------------------------------------------------------
# DP perturbation
# ---------------------------------------------------------------------------


def _tree_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_and_noise(grads, key, clip, noise_scale):
    """Global-L2 clip to `clip`, then add Laplace(0, noise_scale) per coord."""
    norm = _tree_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (x.astype(jnp.float32) * scale
         + noise_scale * jax.random.laplace(k, x.shape, jnp.float32)).astype(x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


# ---------------------------------------------------------------------------
# Train-step builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class P2PPlan:
    """Everything the launcher needs to pjit one training round."""

    train_step: Callable  # (params, batch, key) -> (params, metrics)
    in_specs: tuple  # (param_specs, batch_specs, None)
    out_specs: tuple
    n_agents: int
    eps_step: float
    noise_scale: float


def make_train_step(bundle, p2p: P2PConfig, mesh, local_batch_size: int,
                    alpha: float = 0.5, gossip: str = "ppermute"):
    """Build the pjit-able P2P-DP training round for a model bundle.

    ``gossip``: "ppermute" (ring collectives), "dense" ((A, A) mixing
    matrix), "sparse" (padded-neighbour gathers, no (A, A) array), or
    "matrix" (auto: dense below the sparse crossover, sparse above).
    """
    agent_mode = p2p.agent_mode
    A = num_agents(mesh, agent_mode)
    agent_axes = agent_axes_of(mesh)
    m_i = max(local_batch_size, 1)

    if p2p.dp_enabled:
        eps_step = privacy.invert_uniform_budget(p2p.eps_bar, p2p.planned_rounds, p2p.delta_bar)
        noise_scale = 2.0 * p2p.clip / (eps_step * m_i)
    else:
        eps_step, noise_scale = 0.0, 0.0

    gossip_dtype = jnp.dtype(p2p.gossip_dtype) if p2p.gossip_dtype else None
    do_gossip = p2p.enabled and A > 1
    if gossip == "matrix":
        # Explicit-W paths: "dense" below the crossover ((A, A) matmul /
        # all-gather), padded-neighbour gathers at or above it, where the
        # matrix would be O(A^2).
        from repro.core.graph import sparse_crossover

        gossip = "sparse" if A >= sparse_crossover() else "dense"
    mix_mat = mix_idx = mix_w = None
    if do_gossip and gossip == "dense":
        W = np.zeros((A, A))
        for o in p2p.neighbor_offsets:
            for i in range(A):
                W[i, (i + o) % A] = 1.0
                W[i, (i - o) % A] = 1.0
        mix_mat = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    elif do_gossip and gossip == "sparse":
        # The exact distinct-target set the dense W construction produces,
        # including the self-loop from offsets ≡ 0 (mod A), so dense and
        # sparse stay bit-identical in semantics for any neighbor_offsets.
        offs = sorted({s * o % A for o in p2p.neighbor_offsets for s in (1, -1)}) or [0]
        idx = (np.arange(A)[:, None] + np.asarray(offs)[None, :]) % A
        mix_idx = jnp.asarray(idx, jnp.int32)
        mix_w = jnp.full(idx.shape, 1.0 / len(offs), jnp.float32)

    def agent_update(params_a, grads_a, mixed_a, key_a):
        noisy = (
            clip_and_noise(grads_a, key_a, p2p.clip, noise_scale)
            if p2p.dp_enabled
            else grads_a
        )
        def leaf(theta, mix, g):
            t32 = theta.astype(jnp.float32)
            m32 = mix.astype(jnp.float32) if do_gossip else t32
            return ((1.0 - alpha) * t32 + alpha * (m32 - p2p.mu * g.astype(jnp.float32))
                    ).astype(theta.dtype)
        return jax.tree.map(leaf, params_a, mixed_a, noisy)

    # Agents are always a leading (stacked) param/batch axis; in silo mode A
    # is the pod count (1 single-pod), so the vmap is over a size-A axis and
    # gossip runs over the pod axis only.
    gossip_axes = agent_axes if agent_mode == "full" else ("pod",)
    # Pass offsets through unfiltered: gossip_ppermute reduces them to the
    # distinct residue set itself (residual-0 offsets become the same
    # self-loop the dense W writes), keeping all three gossip paths on
    # identical semantics for any neighbor_offsets.
    offsets = tuple(p2p.neighbor_offsets) or (1,)

    def train_step(params, batch, key):
        losses, grads = jax.vmap(jax.value_and_grad(bundle.loss))(params, batch)
        if do_gossip:
            if gossip == "dense":
                mixed = gossip_dense(params, mix_mat)
            elif gossip == "sparse":
                mixed = gossip_gather(params, mix_idx, mix_w)
            else:
                specs = param_specs(params, mesh, agent_mode, A)
                mixed = gossip_ppermute(
                    params, specs, mesh, offsets, gossip_axes, gossip_dtype
                )
        else:
            mixed = params
        keys = jax.random.split(key, A)
        new_params = jax.vmap(agent_update)(params, grads, mixed, keys)
        metrics = {
            "loss": losses.mean(),
            "grad_norm": jax.vmap(_tree_norm)(grads).mean(),
        }
        return new_params, metrics

    return train_step, eps_step, noise_scale


def make_fedavg_step(bundle, mesh, lr: float = 3e-4):
    """Single-global-model baseline (the paper's mu -> 0 extreme).

    Every agent slot holds the same model; gradients are averaged across the
    agent axis each round (complete-graph consensus). Used to compare the
    personalization objective against classic data-parallel training.
    """

    def train_step(params, batch, key):
        losses, grads = jax.vmap(jax.value_and_grad(bundle.loss))(params, batch)
        new_params = jax.tree.map(
            lambda p, g: (
                p.astype(jnp.float32)
                - lr * jnp.broadcast_to(
                    jnp.mean(g.astype(jnp.float32), axis=0, keepdims=True), p.shape
                )
            ).astype(p.dtype),
            params, grads,
        )
        metrics = {"loss": losses.mean(), "grad_norm": jax.vmap(_tree_norm)(grads).mean()}
        return new_params, metrics

    return train_step


# ---------------------------------------------------------------------------
# pjit wiring helpers (used by launch/ and the dry-run)
# ---------------------------------------------------------------------------


def shardings_for(tree, mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def jit_train_step(train_step, mesh, pspecs, bspecs):
    ps = shardings_for(None, mesh, pspecs)
    bs = shardings_for(None, mesh, bspecs)
    return jax.jit(
        train_step,
        in_shardings=(ps, bs, None),
        out_shardings=(ps, None),
        donate_argnums=(0,),
    )
