"""Small pytree helpers used across the framework.

These are deliberately dependency-free (no optax) — the paper's block
coordinate descent update is applied leaf-wise to parameter pytrees by the
SPMD layer, and the simulator works on dense (n, p) arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """b + s * a, leaf-wise."""
    return jax.tree.map(lambda x, y: y + s * x, a, b)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_global_norm(a):
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size(a):
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
