from repro.optim.optimizers import adamw, apply_updates, sgd

__all__ = ["sgd", "adamw", "apply_updates"]
