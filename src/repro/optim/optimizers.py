"""Minimal optax-style optimizers (dependency-free).

The paper's update IS the optimizer for P2P training (repro.core.spmd); these
exist for the centralized baselines the paper compares against (single global
model, local-only training) and for the train driver's --optimizer flag.

Each factory returns (init_fn, update_fn):
    state = init_fn(params)
    updates, state = update_fn(grads, state, params)
    params = apply_updates(params, updates)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state
        new_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads
        )
        return jax.tree.map(lambda m: -lr * m, new_state), new_state

    return init, update


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(z, params),
            "nu": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        mhat = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), mu)
        vhat = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), nu)
        upd = jax.tree.map(
            lambda m, v, p: -lr * (m / (jnp.sqrt(v) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mhat, vhat, params,
        )
        return upd, {"mu": mu, "nu": nu, "t": t}

    return init, update
