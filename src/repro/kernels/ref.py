"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dp_clip_noise_ref(grads, noise, clip, noise_scale):
    """Fused DP gradient aggregation (Eq. 6 + Supp. D.2 clipping).

    grads: (N, D) per-example gradients; noise: (D,) standard-Laplace draws;
    clip: L2 clip constant C; noise_scale: Laplace scale s (already includes
    2 L0 / (eps m)). Returns (D,) = mean_i clip(g_i) + s * noise.
    """
    g32 = grads.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(g32**2, axis=-1, keepdims=True))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    mean = jnp.mean(g32 * scale, axis=0)
    return mean + noise_scale * noise.astype(jnp.float32)


def graph_mix_ref(mix, theta):
    """Neighbour mixing: Y = A @ Theta. mix: (n, n); theta: (n, p)."""
    return (mix.astype(jnp.float32) @ theta.astype(jnp.float32)).astype(theta.dtype)


def sparse_mix_ref(idx, w, theta):
    """Padded-neighbour mixing: Y[r] = sum_k w[r,k] Theta[idx[r,k]].

    idx: (R, K) int32; w: (R, K); theta: (n, p). Pad entries carry weight 0.
    R == n is the full neighbour sum; R == B < n is the woken-rows batch
    (``sparse_rows_mix``), which shares this oracle.
    """
    gathered = theta.astype(jnp.float32)[idx]  # (R, K, p)
    return jnp.einsum("nk,nkp->np", w.astype(jnp.float32), gathered)


sparse_rows_mix_ref = sparse_mix_ref


def csr_mix_ref(rows, cols, vals, theta, n):
    """CSR neighbour mixing as a pure segment_sum (the O(nnz) oracle).

    rows/cols/vals: (nnz,) sorted COO triples of the symmetric W;
    theta: (n, p). Returns (n, p) = sum over stored entries.
    """
    contrib = vals.astype(jnp.float32)[:, None] * theta.astype(jnp.float32)[cols]
    return jax.ops.segment_sum(contrib, rows, num_segments=n, indices_are_sorted=True)


def ssm_chunk_ref(C, B, cum, dt, x):
    """Mamba2 intra-chunk SSD (single head-group block).

    C, B: (G, Q, N); cum: (G, Q) inclusive cumulative log-decay;
    dt: (G, Q); x: (G, Q, P).
    Returns:
      y:     (G, Q, P)  causal intra-chunk output
      s_loc: (G, P, N)  chunk-local end state
    """
    C = C.astype(jnp.float32)
    B = B.astype(jnp.float32)
    cum = cum.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Q = C.shape[1]
    cb = jnp.einsum("gqn,gtn->gqt", C, B)
    decay = jnp.exp(jnp.clip(cum[:, :, None] - cum[:, None, :], -60.0, 0.0))
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    scores = jnp.where(causal[None], cb * decay * dt[:, None, :], 0.0)
    y = jnp.einsum("gqt,gtp->gqp", scores, x)
    w_end = jnp.exp(jnp.clip(cum[:, -1:] - cum, -60.0, 0.0)) * dt  # (G,Q)
    s_loc = jnp.einsum("gq,gqp,gqn->gpn", w_end, x, B)
    return y, s_loc


def fused_row_update_ref(rows, idx, w, coef, X, y, mask, noise, theta, limit, clip=None):
    """Fused woken-row super-tick: gather + mix + Eq. 4 + drop-mode scatter.

    rows: (B,) slab rows (entries >= limit are sentinels, never written);
    idx/w: (B, K) row-gathered padded neighbour tables over the slab;
    coef: (B, 4+) per-row [alpha, deg, mu*conf, 2*lam]; X: (B, m, p),
    y/mask: (B, m) padded data rows; noise: (B, p); theta: (nt, p).
    Returns the (nt, p) f32 updated slab — same contract as
    ``fused_row_update`` (quadratic loss, optional per-point L1 clip).
    """
    t32 = theta.astype(jnp.float32)
    nt = t32.shape[0]
    safe = jnp.minimum(rows, nt - 1)
    tr = t32[safe]  # (B, p)
    neigh = jnp.einsum("bk,bkp->bp", w.astype(jnp.float32), t32[idx])
    X32 = X.astype(jnp.float32)
    resid = 2.0 * (jnp.einsum("bmp,bp->bm", X32, tr) - y.astype(jnp.float32))
    if clip is not None:
        norms = jnp.abs(resid) * jnp.sum(jnp.abs(X32), axis=-1)
        resid = resid * jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    m32 = mask.astype(jnp.float32)
    m_hat = jnp.maximum(jnp.sum(m32, axis=-1), 1.0)
    g_sum = jnp.einsum("bm,bmp->bp", resid * m32, X32)
    c32 = coef.astype(jnp.float32)
    alpha, deg, cmu, lam2 = c32[:, 0:1], c32[:, 1:2], c32[:, 2:3], c32[:, 3:4]
    grads = g_sum / m_hat[:, None] + lam2 * tr + noise.astype(jnp.float32)
    new = (1.0 - alpha) * tr + alpha * (neigh / deg - cmu * grads)
    keep = rows < limit
    tgt = jnp.where(keep, rows, nt)
    return t32.at[tgt].set(jnp.where(keep[:, None], new, 0.0), mode="drop")
