"""Pallas TPU kernel: the fused woken-row super-tick update.

One launch covers the whole hot path a woken agent runs per super-tick —
the four separate XLA ops of the unfused engines collapsed into one
VMEM-resident program:

1. **gather**    — read the woken row ``theta[rows[b]]`` out of the slab;
2. **mix**       — its padded neighbour sum ``sum_k w[b,k] theta[idx[b,k]]``
   (the ``sparse_mix`` machinery, row batch B independent of the slab
   height);
3. **row update** — the Eq. 4 / Eq. 6 quadratic-loss step
   ``(1-a) th + a (neigh/d - mu c (grad L + noise))`` with the gradient
   computed in-kernel from the agent's padded data rows
   (``grad L = sum_m mask 2(x.th - y) x / m_hat + 2 lam th``, optional
   per-point L1 clip);
4. **scatter**   — write the replacement row back into the slab; rows
   carrying the sentinel (``rows[b] >= limit``: slot-capacity padding or
   a budget-exhausted DP agent) are skipped, leaving the stale value —
   the engines' ``.at[tgt].set(mode="drop")`` semantics.

Scope mirrors ``sparse_mix``: the on-chip regime where the (nt, pp) slab
fits VMEM (single-device: nt = n; sharded: the (R + Hmax, p) extended
block *after* the halo exchange, which stays a separate collective — the
kernel fuses everything on-chip). The quadratic loss only: the logistic
path keeps the unfused vmap (its exp/log1p inner loop gains nothing from
fusion and the engines gate on ``loss.name``).

Layout: grid over row tiles (bb rows per step). The wake-index and
neighbour-index tables ride in SMEM via scalar prefetch so the kernel
can issue data-dependent row gathers; the slab streams in once and stays
VMEM-resident; the output slab is initialized from it at step 0 and
updated in place across grid steps (constant out-block index =>
revisited VMEM buffer, one writeback at the end). Feature dim is a
single lane-aligned tile (pp multiple of 128) because the in-kernel
gradient needs whole rows — p past ~512 should stay on the unfused path.
``interpret=True`` runs the same program on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEF_BB = 8  # woken rows per grid step (sublane multiple)


def _fused_row_update_kernel(
    B,
    K,
    limit,
    clip,
    rows_ref,
    idx_ref,
    w_ref,
    coef_ref,
    X_ref,
    y_ref,
    mask_ref,
    noise_ref,
    theta_ref,
    out_ref,
):
    step = pl.program_id(0)
    bb = w_ref.shape[0]
    b0 = step * bb
    nt, pp = out_ref.shape

    @pl.when(step == 0)
    def _init_slab():
        # Constant out-block index: this VMEM buffer persists across grid
        # steps, so rows never scattered keep their slab value (drop-mode
        # scatter semantics) and the final writeback emits the full slab.
        out_ref[:, :] = theta_ref[:, :].astype(out_ref.dtype)

    def one_row(r, _):
        b = b0 + r  # caller pads B to a tile multiple with sentinel rows
        row = rows_ref[b]
        grow = jnp.minimum(row, nt - 1)  # sentinel clamps for the gather
        tr = theta_ref[pl.ds(grow, 1), :].astype(jnp.float32)  # (1, pp)

        def neighbor(k, acc):
            j = idx_ref[b, k]
            contrib = theta_ref[pl.ds(j, 1), :].astype(jnp.float32)
            return acc + w_ref[pl.ds(r, 1), pl.ds(k, 1)].astype(jnp.float32) * contrib

        neigh = jax.lax.fori_loop(0, K, neighbor, jnp.zeros((1, pp), jnp.float32))

        Xr = X_ref[r].astype(jnp.float32)  # (m, pp)
        yr = y_ref[pl.ds(r, 1), :].astype(jnp.float32)  # (1, m)
        mr = mask_ref[pl.ds(r, 1), :].astype(jnp.float32)  # (1, m)
        # Per-point residuals 2 (x.th - y) — the quadratic point grad is
        # resid * x, so the clip/mask/mean pipeline stays rank-2 (1, m).
        dots = jax.lax.dot_general(
            tr, Xr, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (1, m)
        resid = 2.0 * (dots - yr)
        if clip is not None:
            # L1 clip per point: |g|_1 = |resid| * sum_p |x_p|.
            abs_x = jax.lax.dot_general(
                jnp.ones((1, pp), jnp.float32),
                jnp.abs(Xr),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (1, m)
            norms = jnp.abs(resid) * abs_x
            resid = resid * jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
        m_hat = jnp.maximum(jnp.sum(mr), 1.0)
        g_sum = jax.lax.dot_general(
            resid * mr, Xr, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (1, pp)

        alpha = coef_ref[pl.ds(r, 1), pl.ds(0, 1)]  # (1, 1) broadcasts below
        deg = coef_ref[pl.ds(r, 1), pl.ds(1, 1)]
        cmu = coef_ref[pl.ds(r, 1), pl.ds(2, 1)]
        lam2 = coef_ref[pl.ds(r, 1), pl.ds(3, 1)]
        grads = g_sum / m_hat + lam2 * tr + noise_ref[pl.ds(r, 1), :].astype(jnp.float32)
        new = (1.0 - alpha) * tr + alpha * (neigh / deg - cmu * grads)

        @pl.when(row < limit)
        def _scatter():
            out_ref[pl.ds(grow, 1), :] = new.astype(out_ref.dtype)

        return 0

    jax.lax.fori_loop(0, bb, one_row, 0)


def fused_row_update(
    rows,
    idx,
    w,
    coef,
    X,
    y,
    mask,
    noise,
    theta,
    limit,
    clip=None,
    block_b=DEF_BB,
    interpret=False,
):
    """Fused gather + mix + Eq. 4 row update + scatter over a theta slab.

    ``rows``: (B,) int32 slab rows to update; entries ``>= limit`` are
    sentinels (computed but never scattered). ``idx``/``w``: (B, K)
    padded neighbour tables *already row-gathered* to the woken batch
    (indices address the slab, which may be halo-extended). ``coef``:
    (B, 4+) f32 per-row ``[alpha, deg, mu*conf, 2*lam]`` (extra columns
    ignored). ``X``: (B, m, p), ``y``/``mask``: (B, m) padded data rows;
    ``noise``: (B, p) gradient perturbation (zeros = non-private).
    ``theta``: (nt, p) slab. Returns the (nt, p) f32 updated slab.

    Caller contract (``repro.kernels.ops`` handles both): p is one
    lane-aligned feature tile, and B is a multiple of ``block_b`` with
    sentinel padding rows.
    """
    nt, p = theta.shape
    B, K = idx.shape
    bb = min(block_b, B)
    nb = pl.cdiv(B, bb)
    m = X.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # rows + neighbour indices ride in SMEM
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, K), lambda b, *_: (b, 0)),
            pl.BlockSpec((bb, coef.shape[1]), lambda b, *_: (b, 0)),
            pl.BlockSpec((bb, m, p), lambda b, *_: (b, 0, 0)),
            pl.BlockSpec((bb, m), lambda b, *_: (b, 0)),
            pl.BlockSpec((bb, m), lambda b, *_: (b, 0)),
            pl.BlockSpec((bb, p), lambda b, *_: (b, 0)),
            pl.BlockSpec((nt, p), lambda b, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nt, p), lambda b, *_: (0, 0)),
    )
    kernel = functools.partial(
        _fused_row_update_kernel, B, K, limit, None if clip is None else float(clip)
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt, p), jnp.float32),
        interpret=interpret,
    )(rows.astype(jnp.int32), idx.astype(jnp.int32), w, coef, X, y, mask, noise, theta)
