"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the TPU
lowering is the target); ``INTERPRET`` flips automatically based on the
backend so the same call sites run compiled on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip_noise as _dpk
from repro.kernels import fused_row_update as _frk
from repro.kernels import graph_mix as _gmk
from repro.kernels import sparse_mix as _smk
from repro.kernels import ssm_scan as _ssk


def _default_interpret():
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("clip", "noise_scale", "block_n", "block_d", "interpret")
)
def dp_clip_noise(grads, noise, clip, noise_scale, block_n=128, block_d=512, interpret=None):
    """Fused per-example clip -> mean -> noise. grads (N,D), noise (D,) -> (D,)."""
    interpret = _default_interpret() if interpret is None else interpret
    N, D = grads.shape
    bn = min(block_n, max(8, N))
    bd = min(block_d, max(128, D))
    g = _pad_to(_pad_to(grads, bn, 0), bd, 1)
    nz = _pad_to(noise, bd, 0)
    # zero-padded rows have zero norm/zero grad: they do not affect the mean
    # because the kernel divides by the true N.
    out = _dpk.dp_clip_noise(
        g, nz, clip, noise_scale, block_n=bn, block_d=bd, interpret=interpret, n_true=N
    )
    return out[:D]


@functools.partial(jax.jit, static_argnames=("block_p", "block_k", "interpret"))
def graph_mix(mix, theta, block_p=256, block_k=128, interpret=None):
    """Y = mix @ theta. mix (n,n), theta (n,p) -> (n,p) float32."""
    interpret = _default_interpret() if interpret is None else interpret
    n, p = theta.shape
    bp = min(block_p, max(128, p))
    t = _pad_to(theta, bp, 1)
    out = _gmk.graph_mix(mix, t, block_p=bp, block_k=block_k, interpret=interpret)
    return out[:, :p]


@functools.partial(jax.jit, static_argnames=("block_a", "block_p", "interpret"))
def sparse_mix(idx, w, theta, block_a=8, block_p=256, interpret=None):
    """Y[i] = sum_k w[i,k] theta[idx[i,k]]. idx/w (n,K), theta (n,p) -> (n,p) f32."""
    interpret = _default_interpret() if interpret is None else interpret
    n, p = theta.shape
    bp = min(block_p, max(128, p))
    with jax.named_scope("obs.sparse_mix"):
        t = _pad_to(theta, bp, 1)
        out = _smk.sparse_mix(idx, w, t, block_a=block_a, block_p=bp, interpret=interpret)
        return out[:, :p]


@functools.partial(jax.jit, static_argnames=("limit", "clip", "block_b", "interpret"))
def fused_row_update(
    rows, idx, w, coef, X, y, mask, noise, theta, limit, clip=None, block_b=8, interpret=None
):
    """Fused woken-row super-tick: gather + mix + Eq. 4 + scatter in one launch.

    rows (B,) slab rows (sentinel >= limit skipped); idx/w (B, K)
    row-gathered neighbour tables over the slab; coef (B, 4) per-row
    [alpha, deg, mu*conf, 2*lam]; X (B, m, p), y/mask (B, m); noise
    (B, p); theta (nt, p). Returns the (nt, p) f32 updated slab.
    Quadratic loss only — see ``repro.kernels.fused_row_update``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    nt, p = theta.shape
    B = rows.shape[0]
    bb = min(8 if block_b is None else block_b, max(8, B))
    f32 = jnp.float32
    with jax.named_scope("obs.fused_row_update"):
        # Pad the feature dim to one lane-aligned tile (the in-kernel gradient
        # needs whole rows, so p is never split) and the row batch to a tile
        # multiple with sentinel rows (computed, never scattered).
        theta_p = _pad_to(theta.astype(f32), 128, 1)
        Xp = _pad_to(_pad_to(X.astype(f32), 128, 2), 8, 1)
        yp = _pad_to(y.astype(f32), 8, 1)
        mp = _pad_to(mask.astype(f32), 8, 1)
        rows_p = _pad_to(rows.astype(jnp.int32), bb, 0)
        pad_b = rows_p.shape[0] - B
        if pad_b:
            rows_p = rows_p.at[B:].set(jnp.int32(limit))
        idx_p = _pad_to(idx.astype(jnp.int32), bb, 0)
        w_p = _pad_to(w.astype(f32), bb, 0)
        coef_p = _pad_to(_pad_to(coef.astype(f32), 128, 1), bb, 0)
        # Padded coef rows carry deg=0; set deg=1 so the sentinel rows' dead
        # arithmetic stays finite (0/0 NaNs would trip debug-nan runs).
        if pad_b:
            coef_p = coef_p.at[B:, 1].set(1.0)
        Xp = _pad_to(Xp, bb, 0)
        yp = _pad_to(yp, bb, 0)
        mp = _pad_to(mp, bb, 0)
        noise_p = _pad_to(_pad_to(noise.astype(f32), 128, 1), bb, 0)
        out = _frk.fused_row_update(
            rows_p, idx_p, w_p, coef_p, Xp, yp, mp, noise_p, theta_p,
            limit=limit, clip=clip, block_b=bb, interpret=interpret,
        )
        return out[:, :p]


# Woken-rows neighbour mix: Y[b] = sum_k w[b,k] theta[idx[b,k]] for (B, K)
# tiles already gathered down to the rows that woke this super-tick. The
# generalized kernel makes the row batch independent of n, so this IS
# sparse_mix; the alias marks the repro.sim call sites and keeps the two
# paths from ever diverging.
sparse_rows_mix = sparse_mix


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_chunk(C, B, cum, dt, x, interpret=None):
    """Mamba2 intra-chunk SSD. See repro.kernels.ssm_scan."""
    interpret = _default_interpret() if interpret is None else interpret
    return _ssk.ssm_chunk(C, B, cum, dt, x, interpret=interpret)


# Differentiable variant: Pallas kernel on the forward pass, oracle VJP on
# the backward pass (standard practice until a hand-written bwd kernel
# lands; the bwd is the same einsum family and XLA fuses it well).
@jax.custom_vjp
def ssm_chunk_ad(C, B, cum, dt, x):
    return ssm_chunk(C, B, cum, dt, x)


def _ssm_chunk_fwd(C, B, cum, dt, x):
    from repro.kernels import ref as _ref

    out = ssm_chunk(C, B, cum, dt, x)
    return out, (C, B, cum, dt, x)


def _ssm_chunk_bwd(res, g):
    from repro.kernels import ref as _ref

    _, vjp = jax.vjp(_ref.ssm_chunk_ref, *res)
    return vjp(g)


ssm_chunk_ad.defvjp(_ssm_chunk_fwd, _ssm_chunk_bwd)
