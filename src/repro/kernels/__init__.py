# Pallas TPU kernels for the compute hot-spots of the P2P-DP update:
#   dp_clip_noise — fused per-example clip -> mean -> noise add (Eq. 6 inner loop)
#   graph_mix     — on-chip dense neighbour mixing  A @ Theta
#   sparse_mix    — CSR neighbour mixing over padded (n, K) neighbour tiles
#   ssm_scan      — Mamba2 intra-chunk SSD block (zamba2 backbone hot-spot)
# Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
# tests sweep shapes/dtypes in interpret mode against the oracle.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
