"""Pallas TPU kernel: sparse neighbour mixing over padded neighbour tiles.

Computes ``Y[r] = sum_k w[r, k] * Theta[idx[r, k]]`` — the CSR neighbour
sum in padded (R, K) form (K = max degree; pad entries point at the row
itself with weight 0). The row batch R is independent of the agent count
n = Theta.shape[0]: with R == n this is the full neighbour sum (O(n * K * p)
vs the dense ``graph_mix`` kernel's O(n^2 * p) matmul); with R == B << n it
is the woken-rows path of the ``repro.sim`` super-tick, where only the
agents that woke this slot need their neighbourhoods mixed.

Scope: like ``graph_mix``, this kernel serves the *on-chip* regime — the
n agents co-resident on one chip, whose (n, bp) Theta slab fits VMEM
(float32: n <= ~8k at bp=256 against a ~16 MB budget). Past that,
mixing runs through the unbounded-n ``segment_sum``/gather paths in
``repro.core.mixing`` (see ``kernels/ref.py`` for the oracles); an
HBM-resident Theta variant with DMA'd row gathers is the follow-up.

Layout: grid (agent_tiles, feature_tiles). The neighbour index table rides
in SMEM via scalar prefetch so the kernel can issue data-dependent row
gathers from the Theta slab; Theta streams through the feature dimension in
(n, bp) slabs that stay VMEM-resident across one agent tile, with bp a
multiple of 128 (lane-aligned) and the agent tile a multiple of 8
(sublane-aligned). Weights sit in VMEM as an (ba, K) tile. The ``interpret``
path runs the same program on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEF_BA = 8  # agents per tile (sublane multiple)
DEF_BP = 256  # feature-tile width (lane multiple)


def _sparse_mix_kernel(R, K, idx_ref, w_ref, theta_ref, out_ref):
    a0 = pl.program_id(0) * out_ref.shape[0]
    bp = out_ref.shape[1]

    def agent_row(r, _):
        row = jnp.minimum(a0 + r, R - 1)  # clamp grid padding rows

        def neighbor(k, acc):
            j = idx_ref[row, k]
            contrib = theta_ref[pl.ds(j, 1), :].astype(jnp.float32)
            return acc + w_ref[pl.ds(r, 1), pl.ds(k, 1)].astype(jnp.float32) * contrib

        acc = jax.lax.fori_loop(0, K, neighbor, jnp.zeros((1, bp), jnp.float32))
        out_ref[pl.ds(r, 1), :] = acc
        return 0

    jax.lax.fori_loop(0, out_ref.shape[0], agent_row, 0)


def sparse_mix(idx, w, theta, block_a=DEF_BA, block_p=DEF_BP, interpret=False):
    """idx: (R, K) int32 into theta's rows; w: (R, K) float; theta: (n, p).

    Returns (R, p) float32. R == n gives the full neighbour sum; R < n is
    the gathered woken-rows batch.
    """
    n, p = theta.shape
    R, K = idx.shape
    ba = min(block_a, R)
    bp = min(block_p, p)
    nb_a = pl.cdiv(R, ba)
    nb_p = pl.cdiv(p, bp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb_a, nb_p),
        in_specs=[
            pl.BlockSpec((ba, K), lambda a, j, idx_ref: (a, 0)),
            pl.BlockSpec((n, bp), lambda a, j, idx_ref: (0, j)),
        ],
        out_specs=pl.BlockSpec((ba, bp), lambda a, j, idx_ref: (a, j)),
    )
    kernel = functools.partial(_sparse_mix_kernel, R, K)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, p), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), w, theta)
