"""Pallas TPU kernel: dense neighbour mixing  Y = A @ Theta.

The simulator fast-path and the dense-W SPMD fallback both need the mixing
matrix A = f(W) applied to the stacked agent models Theta (n, p) every
round. n (agents co-resident on a chip) is small — A fits VMEM whole — but
p is the full (sharded) parameter dimension, so Theta streams through in
feature tiles. Grid: (feature_tiles, contraction_tiles) with the (n, bp)
output tile resident in VMEM across the contraction; MXU-aligned 128x128
tiles by default.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_BP = 256  # feature-tile width
DEF_BK = 128  # contraction tile


def _mix_kernel(a_ref, t_ref, out_ref):
    k = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    partial = jax.lax.dot(a, t, precision=jax.lax.Precision.HIGHEST)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(k != 0)
    def _acc():
        out_ref[...] += partial


def graph_mix(mix, theta, block_p=DEF_BP, block_k=DEF_BK, interpret=False):
    """mix: (n, n) float; theta: (n, p). Returns (n, p) float32."""
    n, p = theta.shape
    bk = min(block_k, n)
    bp = min(block_p, p)
    nb_k = pl.cdiv(n, bk)
    nb_p = pl.cdiv(p, bp)
    return pl.pallas_call(
        _mix_kernel,
        grid=(nb_p, nb_k),
        in_specs=[
            pl.BlockSpec((n, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk, bp), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((n, bp), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(mix, theta)
