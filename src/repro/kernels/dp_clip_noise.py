"""Pallas TPU kernel: fused per-example clip -> mean -> Laplace-noise add.

This is the DP hot-spot of the private update (Eq. 6): every agent must,
per round, clip N per-example gradients (Supp. D.2), average them, and
perturb the average. Done naively this is three HBM round-trips over an
(N, D) tensor; fused it is one.

TPU adaptation: two-pass structure over a (N_blk, D_blk) grid.
Pass 1 (``_norms_kernel``): accumulate per-example squared norms across
feature blocks — D is the innermost grid axis so the (N_blk,) accumulator
block stays resident in VMEM while feature tiles stream through.
Pass 2 (``_clip_mean_kernel``): re-stream the tiles, scale each example row
by min(1, C/norm), accumulate the mean over example blocks (N innermost),
and on the last example block add ``noise_scale * noise``.

Block shapes are VPU-lane aligned: examples in multiples of 8 (sublane),
features in multiples of 128 (lane).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_BN = 128  # examples per tile
DEF_BD = 512  # features per tile


def _norms_kernel(g_ref, out_ref):
    j = pl.program_id(1)
    g = g_ref[...].astype(jnp.float32)
    partial = jnp.sum(g * g, axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += partial


def _clip_mean_kernel(g_ref, norms_ref, noise_ref, out_ref, *, clip, noise_scale, n_total, nb):
    i = pl.program_id(1)  # example-block index (innermost)
    g = g_ref[...].astype(jnp.float32)
    nrm = jnp.sqrt(jnp.maximum(norms_ref[...], 1e-24))
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    partial = jnp.sum(g * scale[:, None], axis=0) / n_total

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _acc():
        out_ref[...] += partial

    @pl.when(i == nb - 1)
    def _noise():
        out_ref[...] += noise_scale * noise_ref[...].astype(jnp.float32)


def dp_clip_noise(grads, noise, clip, noise_scale, block_n=DEF_BN, block_d=DEF_BD,
                  interpret=False, n_true=None):
    """grads: (N, D); noise: (D,) standard Laplace. Returns (D,) float32.

    ``n_true``: denominator for the mean (true example count when rows are
    zero-padded to a block multiple; padded rows contribute 0 to the sum).
    """
    N, D = grads.shape
    n_true = N if n_true is None else n_true
    bn = min(block_n, N)
    bd = min(block_d, D)
    nb_n = pl.cdiv(N, bn)
    nb_d = pl.cdiv(D, bd)

    norms = pl.pallas_call(
        _norms_kernel,
        grid=(nb_n, nb_d),
        in_specs=[pl.BlockSpec((bn, bd), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(grads)

    out = pl.pallas_call(
        functools.partial(
            _clip_mean_kernel,
            clip=float(clip),
            noise_scale=float(noise_scale),
            n_total=float(n_true),
            nb=nb_n,
        ),
        grid=(nb_d, nb_n),  # features outer, examples inner (accumulate over N)
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, i: (i, j)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
            pl.BlockSpec((bd,), lambda j, i: (j,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(grads, norms, noise)
    return out
