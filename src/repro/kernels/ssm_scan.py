"""Pallas TPU kernel: Mamba2 intra-chunk SSD block (zamba2 hot-spot).

One grid step processes one (batch, chunk, head) group entirely in VMEM:
  scores[q,t] = (C_q . B_t) * exp(cum_q - cum_t) * dt_t   (t <= q)
  y           = scores @ x                                 (Q x P)
  s_loc[p,n]  = sum_t exp(cum_Q - cum_t) dt_t x_t[p] B_t[n]
Chunk tiles (Q<=128, N=64, P=64) are MXU-friendly; the inter-chunk
recurrence stays a lax.scan in repro.models.ssm (it is O(chunks) and
bandwidth-trivial).

Inputs are pre-flattened to G = batch*chunks*heads groups. cum/dt arrive as
(G, Q, 1) so every VMEM tile is >=2D (TPU vector layout requirement).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_chunk_kernel(c_ref, b_ref, cum_ref, dt_ref, x_ref, y_ref, s_ref):
    C = c_ref[0].astype(jnp.float32)  # (Q, N)
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    cum = cum_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    Q = C.shape[0]

    cb = jax.lax.dot(C, B.T, precision=jax.lax.Precision.HIGHEST)  # (Q, Q)
    delta = cum[:, None] - cum[None, :]
    decay = jnp.exp(jnp.clip(delta, -60.0, 0.0))
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    scores = jnp.where(ti <= qi, cb * decay * dt[None, :], 0.0)
    y_ref[0] = jax.lax.dot(scores, x, precision=jax.lax.Precision.HIGHEST)

    w_end = jnp.exp(jnp.clip(cum[-1] - cum, -60.0, 0.0)) * dt  # (Q,)
    xw = x * w_end[:, None]  # (Q, P)
    s_ref[0] = jax.lax.dot(xw.T, B, precision=jax.lax.Precision.HIGHEST)  # (P, N)


def ssm_chunk(C, B, cum, dt, x, interpret=False):
    """C,B: (G,Q,N); cum,dt: (G,Q); x: (G,Q,P) -> y (G,Q,P), s_loc (G,P,N)."""
    G, Q, N = C.shape
    P = x.shape[-1]
    cum3 = cum[..., None]
    dt3 = dt[..., None]
    return pl.pallas_call(
        _ssm_chunk_kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, P, N), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((G, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(C, B, cum3, dt3, x)
