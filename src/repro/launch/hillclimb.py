import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: runs named variants of the three selected
(arch x shape) pairs and appends hypothesis/before/after rows to
results/perf_iterations.jsonl.

    PYTHONPATH=src python -m repro.launch.hillclimb --pair llama
"""

import argparse
import json

from repro.launch.dryrun import run_one

PAIRS = {
    # Most representative of the paper's technique: full P2P-DP round,
    # 16 personal models, ppermute gossip.
    "llama": ("llama3.2-1b", "train_4k"),
    # Most collective-bound baseline.
    "qwen": ("qwen2.5-14b", "train_4k"),
    # Worst roofline fraction (compute term tiny vs the rest): MoE with
    # 512-wide experts — dispatch machinery dwarfs the expert GEMMs.
    "moe": ("granite-moe-3b-a800m", "train_4k"),
    # Bonus iteration: decode is all-gather-bound (the seq-sharded GQA cache
    # gets gathered for attention).
    "decode": ("llama3.2-1b", "decode_32k"),
}

VARIANTS = {
    "llama": [
        ("baseline", {}),
        # H1: dense gossip all-gathers full agent-stacked params; circulant
        # ppermute should move ~A/k x fewer bytes. (validates the paper-side
        # design choice by measuring its inverse)
        ("gossip_dense", dict(gossip="dense")),
        # H2: Megatron sequence-parallel residual: per-layer activation
        # all-reduce (2x operand) becomes reduce-scatter + all-gather
        # (1x operand each, but operands are 1/16 the size per device).
        ("seq_parallel", dict(seq_parallel=True)),
        # H3: DP off isolates the cost of the privacy machinery (noise
        # sampling + clipping) — expected ~0 collective delta.
        ("no_dp", dict(dp_on=False)),
        # Iter 2 (dominant term now memory): drop remat — trades HBM
        # *capacity* (stored activations) for ~fwd-pass fewer HBM reads.
        ("seqpar_noremat", dict(seq_parallel=True, remat=False)),
    ],
    "qwen": [
        ("baseline", {}),
        ("seq_parallel", dict(seq_parallel=True)),
        # H: disabling gossip isolates the P2P exchange's share of the
        # collective term (expected small vs TP all-reduces: ppermute moves
        # params once/round, TP moves activations ~3x per layer).
        ("no_p2p", dict(p2p_on=False)),
        ("seqpar_noremat", dict(seq_parallel=True, remat=False)),
    ],
    "decode": [
        ("baseline", {}),
        # H: pre-repeat KV in the cache so the head dim (32) divides the
        # model axis -> per-shard attention, no cache all-gather. Cost: 4x
        # cache bytes (kv 8 -> 32 heads).
        ("repeat_kv_cache", dict(repeat_kv=True)),
    ],
    "moe": [
        ("baseline", {}),
        # H1: bigger dispatch groups + cf 1.0 cut one-hot dispatch tensors
        # (G x gs x E x C scales with C ~ gs k cf / E) and router padding.
        ("gs512_cf1", dict(moe_overrides=dict(group_size=512, capacity_factor=1.0))),
        # H2: seq-parallel on top.
        ("gs512_cf1_seqpar", dict(moe_overrides=dict(group_size=512, capacity_factor=1.0),
                                  seq_parallel=True)),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="results/perf_iterations.jsonl")
    args = ap.parse_args()
    arch, shape = PAIRS[args.pair]
    for name, kw in VARIANTS[args.pair]:
        if args.variant and name != args.variant:
            continue
        kw = dict(kw)
        repeat_kv = kw.pop("repeat_kv", False)
        from repro.models.attention import set_repeat_kv_cache

        set_repeat_kv_cache(repeat_kv)
        try:
            row = run_one(arch, shape, multi_pod=False,
                          variant=f"{args.pair}:{name}", **kw)
        finally:
            set_repeat_kv_cache(False)
        with open(args.out, "a") as f:
            f.write(json.dumps(row) + "\n")
        print(f"[{args.pair}:{name}] compute={row['compute_s']:.3f} "
              f"memory={row['memory_s']:.3f} collective={row['collective_s']:.3f} "
              f"dominant={row['dominant']}")


if __name__ == "__main__":
    main()
