"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --preset tiny --batch 4 --prompt-len 64 --decode-tokens 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import build_model
from repro.models.encdec import enc_len


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.preset == "full":
        cfg = get_config(args.arch)
    elif args.preset == "small":
        cfg = get_reduced(args.arch, num_layers=2, d_model=256, d_ff=512,
                          vocab_size=2048, dtype="float32")
    else:
        cfg = get_reduced(args.arch, dtype="float32")
    bundle = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init(key)
    max_len = args.max_len or (args.prompt_len + args.decode_tokens)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    t0 = time.time()
    if bundle.prefill is not None and not cfg.is_encdec and cfg.family not in ("hybrid", "ssm"):
        from repro.models import transformer

        prefill = jax.jit(lambda p, t: transformer.prefill(p, t, cfg, max_len=max_len))
        logits, caches = prefill(params, prompts)
        pos0 = args.prompt_len
    elif cfg.is_encdec:
        from repro.models import encdec

        embeds = jax.random.normal(
            key, (args.batch, enc_len(args.prompt_len), cfg.d_model), jnp.float32
        )
        prefill = jax.jit(lambda p, e, t: encdec.prefill(p, e, t, cfg, max_len=max_len))
        logits, caches = prefill(params, embeds, prompts)
        pos0 = args.prompt_len
    else:
        # recurrent families: run the prompt token-by-token through decode
        caches = bundle.init_cache(params, args.batch, max_len)
        decode = jax.jit(bundle.decode)
        logits = None
        for i in range(args.prompt_len):
            logits, caches = decode(params, prompts[:, i : i + 1], caches, jnp.int32(i))
        pos0 = args.prompt_len
    t_prefill = time.time() - t0

    decode = jax.jit(bundle.decode)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    tok = jnp.clip(tok, 0, cfg.vocab_size - 1)
    out_tokens = [np.asarray(tok)]
    t1 = time.time()
    for i in range(args.decode_tokens - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(pos0 + i))
        tok = jnp.clip(jnp.argmax(logits, axis=-1).astype(jnp.int32), 0, cfg.vocab_size - 1)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    toks_per_s = args.batch * (args.decode_tokens - 1) / max(t_decode, 1e-9)
    print(json.dumps({
        "arch": args.arch, "preset": args.preset, "batch": args.batch,
        "prompt_len": args.prompt_len, "decode_tokens": args.decode_tokens,
        "prefill_s": round(t_prefill, 3), "decode_s": round(t_decode, 3),
        "decode_tokens_per_s": round(toks_per_s, 1),
    }))
    gen = np.concatenate(out_tokens, axis=1)
    print("sample generated ids:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()
