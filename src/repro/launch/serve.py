"""Deprecated location — the serving CLI lives at ``repro.serve``.

The LLM prefill/decode driver that used to live here was unrelated to
this paper and is gone; the serving tier is now the personalized
peer-to-peer inference path:

    PYTHONPATH=src python -m repro.serve --checkpoint-dir ckpts
    PYTHONPATH=src python -m repro.serve --live --n 20000 --shards 8

This stub forwards ``main`` to :mod:`repro.serve.__main__` with a
DeprecationWarning so old entry points keep resolving.
"""

from __future__ import annotations

import warnings


def main(argv=None) -> int:
    """Forward to ``python -m repro.serve`` (deprecated path)."""
    warnings.warn(
        "repro.launch.serve is deprecated; use `python -m repro.serve` "
        "(repro.serve.__main__.main) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.serve.__main__ import main as serve_main

    return serve_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
