"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; ordinary processes (tests, benches) see 1 device and only ever call
this with meshes that fit.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.6
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:  # jax 0.4.x: meshes are Auto-typed implicitly
    _AXIS_KW = lambda n: {}  # noqa: E731


def use_mesh(mesh):
    """Ambient-mesh context across jax versions.

    jax >= 0.6 spells it ``jax.set_mesh``; on 0.4.x the Mesh object itself
    is the (legacy global-mesh) context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes):
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


# TPU v5e hardware constants used by the roofline analysis (launch target).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
