"""End-to-end P2P-DP training driver.

Runs the paper's technique on a real model end-to-end on whatever devices
exist (CPU here, TPU mesh in production): personal models per agent,
per-round DP perturbation, ppermute/dense gossip, periodic checkpointing
and eval. This is the driver behind examples/decentralized_lm.py.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --preset tiny --steps 50 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import P2PConfig
from repro.core import spmd
from repro.data.synthetic import token_stream
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import build_model
from repro.models.encdec import enc_len


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"],
                    help="tiny/small = reduced configs for CPU; full = assigned config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4, help="per-agent batch")
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--agents", type=int, default=None, help="default: data-axis size")
    ap.add_argument("--mesh", default="1x1", help="e.g. 4x2 (data x model)")
    ap.add_argument("--mu", type=float, default=0.5)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--eps", type=float, default=0.0, help="DP budget; 0 = off")
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--gossip", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--no-p2p", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoint rotation depth (newest K entries kept)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def build(args):
    if args.preset == "full":
        cfg = get_config(args.arch)
    elif args.preset == "small":
        cfg = get_reduced(args.arch, num_layers=2, d_model=256, d_ff=512,
                          vocab_size=2048, dtype="float32")
    else:
        cfg = get_reduced(args.arch, dtype="float32")
    return cfg


def main(argv=None):
    args = parse_args(argv)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    n_dev = len(jax.devices())
    assert np.prod(dshape) <= n_dev, f"mesh {dshape} needs more than {n_dev} devices"
    mesh = make_mesh(dshape, ("data", "model"))
    cfg = build(args)
    bundle = build_model(cfg, remat=False)
    A = args.agents or mesh.shape["data"]

    p2p = P2PConfig(
        agent_mode="full",
        enabled=not args.no_p2p,
        dp_enabled=args.eps > 0,
        eps_bar=args.eps if args.eps > 0 else 1.0,
        planned_rounds=args.steps,
        clip=args.clip,
        mu=args.mu,
        neighbor_offsets=(1,) if A <= 4 else (1, 2),
        gossip_dtype=None,
    )

    key = jax.random.PRNGKey(args.seed)
    params = jax.vmap(bundle.init)(jax.random.split(key, A))
    start_step = 0
    if args.resume and args.checkpoint_dir:
        try:
            params, start_step, _ = load_checkpoint(args.checkpoint_dir, params)
            print(f"resumed from step {start_step}")
        except FileNotFoundError:
            pass

    with use_mesh(mesh):
        step_fn, eps_step, noise_scale = spmd.make_train_step(
            bundle, p2p, mesh, args.batch, alpha=args.alpha, gossip=args.gossip
        )
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

        # Heterogeneous per-agent token streams (personalization signal).
        stream = token_stream(cfg.vocab_size, A * args.batch, args.seq, args.seed, A)
        t0 = time.time()
        history = []
        for step in range(start_step, args.steps):
            toks = next(stream).reshape(A, args.batch, args.seq)
            batch = {"tokens": jnp.asarray(toks)}
            if cfg.is_encdec:
                batch["embeds"] = jax.random.normal(
                    jax.random.fold_in(key, step),
                    (A, args.batch, enc_len(args.seq), cfg.d_model),
                    jnp.float32,
                )
            params, metrics = step_fn(params, batch, jax.random.fold_in(key, 10_000 + step))
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                row = {"step": step, "loss": round(loss, 4),
                       "grad_norm": round(float(metrics["grad_norm"]), 3),
                       "elapsed_s": round(time.time() - t0, 1)}
                history.append(row)
                print(json.dumps(row), flush=True)
            if args.checkpoint_dir and (step + 1) % args.checkpoint_every == 0:
                save_checkpoint(args.checkpoint_dir, params, step=step + 1,
                                extra={"eps_step": eps_step},
                                keep_last=args.keep_last)
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, params, step=args.steps,
                        extra={"eps_step": eps_step, "noise_scale": noise_scale},
                        keep_last=args.keep_last)
    if args.eps > 0:
        from repro.core.privacy import compose_kairouz

        spent = compose_kairouz(np.full(args.steps - start_step, eps_step), p2p.delta_bar)
        print(f"DP: per-step eps={eps_step:.4f}, composed eps over run={spent:.3f} "
              f"(budget {p2p.eps_bar})")
    return history


if __name__ == "__main__":
    main()
