import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e).

Lowers + compiles the real train/prefill/serve step for every
(architecture x input shape) on the production mesh — 16x16 single-pod and
2x16x16 multi-pod — using ShapeDtypeStruct inputs (no allocation), then
prints memory_analysis / cost_analysis and derives the roofline terms
(deliverable g).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import AGENT_MODES, ARCH_IDS, SHAPES, get_config
from repro.configs.base import P2PConfig
from repro.core import spmd
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, use_mesh
from repro.models import build_model
from repro.models.encdec import enc_len
from repro.models.sharding import batch_specs, cache_specs, param_specs
from repro.roofline.analysis import analyze_compiled

SLIDING_WINDOW_500K = 8192


def arch_config_for_shape(arch: str, shape_name: str):
    """Resolve the model config, applying the long-context attention policy."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family != "ssm" and cfg.sliding_window is None:
        # sub-quadratic requirement: windowed attention for attention archs
        # (SSM/hybrid state paths are already O(1); zamba2's shared attention
        # block gets the same ring-buffer window).
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_500K)
    return cfg


def input_specs(arch: str, shape_name: str, mesh, gossip="ppermute",
                p2p_on=True, dp_on=True, cfg_overrides=None, moe_overrides=None,
                remat=True):
    """ShapeDtypeStruct stand-ins + shardings for one (arch, shape) combo.

    Returns (step_fn, example_args (SDS), in_shardings, out_shardings, meta).
    """
    cfg = arch_config_for_shape(arch, shape_name)
    if moe_overrides and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    bundle = build_model(cfg, remat=remat)
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    if shape.kind == "train":
        agent_mode = AGENT_MODES[arch]
        A = spmd.num_agents(mesh, agent_mode)
        assert shape.global_batch % A == 0, (arch, shape_name, A)
        per_agent = shape.global_batch // A
        p2p = P2PConfig(
            agent_mode=agent_mode, enabled=p2p_on, dp_enabled=dp_on,
            planned_rounds=100,
        )
        step, eps_step, noise_scale = spmd.make_train_step(
            bundle, p2p, mesh, per_agent, gossip=gossip
        )
        params = jax.eval_shape(
            jax.vmap(bundle.init), jax.eval_shape(lambda: jax.random.split(jax.random.PRNGKey(0), A))
        )
        pspecs = param_specs(params, mesh, agent_mode, A)
        batch = {"tokens": jax.ShapeDtypeStruct((A, per_agent, shape.seq_len + 1), jnp.int32)}
        if cfg.is_encdec:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (A, per_agent, enc_len(shape.seq_len), cfg.d_model), jnp.float32
            )
        bspecs = batch_specs(batch, mesh, agent_mode)
        shardify = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
        )
        in_sh = (shardify(pspecs), shardify(bspecs), NamedSharding(mesh, P()))
        out_sh = (shardify(pspecs), None)
        args = (params, batch, key_sds)
        # tokens per round across all agents:
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens
        meta = dict(agent_mode=agent_mode, n_agents=A, eps_step=eps_step,
                    noise_scale=noise_scale, model_flops=model_flops,
                    donate=(0,))
        return step, args, in_sh, out_sh, meta

    # ---- inference shapes (serve): single shared model, FSDP+TP ----------
    params = jax.eval_shape(bundle.init, key_sds)
    pspecs = param_specs(params, mesh, "serve", 1)
    shardify = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            return bundle.prefill(params, batch)

        batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)}
        if cfg.is_encdec:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, enc_len(shape.seq_len), cfg.d_model), jnp.float32
            )
        bspecs = batch_specs(batch, mesh, "serve")
        in_sh = (shardify(pspecs), shardify(bspecs))
        # constrain outputs: logits batch-sharded (+vocab over model), caches
        # via cache_specs — leaving them open lets GSPMD replicate the whole
        # prefill loop carry.
        out_shapes = jax.eval_shape(prefill_step, params, batch)
        lead = ("pod", "data") if "pod" in mesh.shape else "data"

        def out_spec(leaf):
            spec = [None] * len(leaf.shape)
            if len(leaf.shape) == 3 and leaf.shape[-1] == cfg.padded_vocab:
                spec[0] = lead
                if cfg.padded_vocab % mesh.shape["model"] == 0:
                    spec[-1] = "model"
                return P(*spec)
            return None  # resolved below for caches

        logits_spec = out_spec(out_shapes[0])
        cache_sp = cache_specs(out_shapes[1], mesh, batch_sharded=True) if (
            isinstance(out_shapes, tuple) and len(out_shapes) > 1 and out_shapes[1] is not None
        ) else None
        out_sh = (
            NamedSharding(mesh, logits_spec) if logits_spec else None,
            shardify(cache_sp) if cache_sp is not None else None,
        )
        args = (params, batch)
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
        meta = dict(agent_mode="serve", model_flops=model_flops, donate=())
        return prefill_step, args, in_sh, out_sh, meta

    # decode
    def serve_step(params, token, caches, pos):
        return bundle.decode(params, token, caches, pos)

    caches = jax.eval_shape(lambda: bundle.init_cache(None, shape.global_batch, shape.seq_len))
    cspecs = cache_specs(caches, mesh, batch_sharded=True)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = batch_specs({"t": token}, mesh, "serve")["t"]
    in_sh = (
        shardify(pspecs),
        NamedSharding(mesh, tok_spec),
        shardify(cspecs),
        NamedSharding(mesh, P()),
    )
    out_sh = (None, shardify(cspecs))
    args = (params, token, caches, pos)
    model_flops = 2.0 * cfg.active_param_count() * shape.global_batch
    meta = dict(agent_mode="serve", model_flops=model_flops, donate=(2,))
    return serve_step, args, in_sh, out_sh, meta


def run_one(arch: str, shape_name: str, multi_pod: bool, gossip="ppermute",
            p2p_on=True, dp_on=True, verbose=True, seq_parallel=False,
            cfg_overrides=None, moe_overrides=None, variant="", remat=True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    # Inference paths anchor activation shardings at the batch dim (GSPMD
    # otherwise replicates unconstrained loop carries). Train steps get their
    # sharding from the agent-stacked params/batch, so axes stay unset there.
    from repro.models.sharding import set_activation_axes, set_seq_axis

    if SHAPES[shape_name].kind != "train":
        set_activation_axes(("pod", "data") if multi_pod else "data")
    else:
        set_activation_axes(None)
    set_seq_axis("model" if seq_parallel else None)
    try:
        step, args, in_sh, out_sh, meta = input_specs(
            arch, shape_name, mesh, gossip=gossip, p2p_on=p2p_on, dp_on=dp_on,
            cfg_overrides=cfg_overrides, moe_overrides=moe_overrides, remat=remat,
        )
        with use_mesh(mesh):
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=meta.get("donate", ()),
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        set_activation_axes(None)
        set_seq_axis(None)
    mem = compiled.memory_analysis()
    roof = analyze_compiled(
        compiled, chips, meta["model_flops"],
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, link_bw=ICI_BW,
    )
    mem_row = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            mem_row[k] = int(getattr(mem, k))
        except Exception:
            pass
    row = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "gossip": gossip,
        "p2p": p2p_on,
        "dp": dp_on,
        "agent_mode": meta["agent_mode"],
        "n_agents": meta.get("n_agents"),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_row,
        **roof.row(),
        "collective_ops": roof.collectives.get("_counts"),
        "collective_breakdown": {k: v for k, v in roof.collectives.items() if not k.startswith("_")},
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {row['mesh']} ({meta['agent_mode']}) ==")
        print("memory_analysis:", mem)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))))
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs dominant=%s useful=%.2f" % (
            roof.compute_s, roof.memory_s, roof.collective_s, roof.dominant, roof.useful_ratio))
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all arch x shape combos")
    ap.add_argument("--gossip", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--no-p2p", action="store_true")
    ap.add_argument("--no-dp", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    if args.out and args.skip_existing:
        try:
            with open(args.out) as f:
                for line in f:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r["gossip"]))
        except FileNotFoundError:
            pass

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name, args.gossip) in done:
                    print(f"skip {arch} x {shape_name} on {mesh_name} (done)")
                    continue
                try:
                    row = run_one(
                        arch, shape_name, mp, gossip=args.gossip,
                        p2p_on=not args.no_p2p, dp_on=not args.no_dp,
                    )
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(row) + "\n")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("dry-run: all combinations lowered and compiled OK")


if __name__ == "__main__":
    main()
