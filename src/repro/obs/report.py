"""Run reports: periodic metric drains, JSONL export, and the report CLI.

The drain side of the telemetry layer. The engines accumulate counters
on-device (:mod:`repro.obs.metrics`); ``run(..., metrics_every=N)``
drains them to the host every N slots and appends each drain to a
:class:`RunReport` — the one artifact that holds a run's metadata, its
counter trajectory, and (when a phase profile ran) the per-phase timing
rows. Reports round-trip through JSONL (one ``kind``-tagged object per
line, so files stream and append) and merge their rows into the
``BENCH_summary.json`` perf trajectory under ``obs_*`` names.

CLI::

    python -m repro.obs.report results/obs_runreport.jsonl
    python -m repro.obs.report report.jsonl --merge-bench BENCH_summary.json
    python -m repro.obs.report --validate-trace results/obs_trace.json

The first form renders the run summary table (metadata, final counter
totals, per-phase attribution); ``--merge-bench`` folds the report's
``obs_*`` rows into a bench summary file (same merge semantics as
``benchmarks/run.py``, which imports :func:`merge_bench_summary` from
here so the two writers cannot drift); ``--validate-trace`` asserts a
Chrome ``trace.json`` loads and carries spans (the CI obs lane check).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro.obs.metrics import summarize_counters


def merge_bench_summary(path: str, rows) -> None:
    """Merge ``(name, us_per_call, derived)`` rows into a bench summary.

    The shared writer for the ``name -> {us_per_call, derived}`` map:
    merging (not clobbering) lets partial runs — ``--only`` debug
    passes, subprocess benches, obs reports — update their own entries
    without erasing the accumulated trajectory of everything else.
    """
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    if not isinstance(data, dict):
        data = {}
    data.update({n: {"us_per_call": float(u), "derived": str(d)} for n, u, d in rows})
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)


@dataclasses.dataclass
class RunReport:
    """One run's telemetry: metadata, drained snapshots, phase rows."""

    meta: dict = dataclasses.field(default_factory=dict)
    snapshots: list = dataclasses.field(default_factory=list)
    phase_rows: list = dataclasses.field(default_factory=list)

    def add_snapshot(self, slot: int, counters: dict, derived: dict | None = None):
        """Append one drained metrics snapshot (host-side dict of arrays)."""
        self.snapshots.append(
            {
                "slot": int(slot),
                "counters": summarize_counters(counters),
                "derived": {k: _jsonable(v) for k, v in (derived or {}).items()},
            }
        )

    def add_phase_rows(self, rows) -> None:
        """Attach per-phase bench rows (``(name, us, note)`` triples)."""
        self.phase_rows.extend((str(n), float(v), str(note)) for n, v, note in rows)

    # -- serialization -----------------------------------------------------
    def to_jsonl(self, path: str) -> None:
        """Write the report as kind-tagged JSONL (meta, snapshots, rows)."""
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "meta", **self.meta}) + "\n")
            for snap in self.snapshots:
                f.write(json.dumps({"kind": "snapshot", **snap}) + "\n")
            for name, value, note in self.phase_rows:
                f.write(
                    json.dumps(
                        {"kind": "phase_row", "name": name, "value": value, "note": note}
                    )
                    + "\n"
                )

    @classmethod
    def from_jsonl(cls, path: str) -> "RunReport":
        """Load a report written by :meth:`to_jsonl`."""
        report = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                kind = obj.pop("kind", None)
                if kind == "meta":
                    report.meta = obj
                elif kind == "snapshot":
                    report.snapshots.append(obj)
                elif kind == "phase_row":
                    report.phase_rows.append((obj["name"], obj["value"], obj["note"]))
                else:
                    raise ValueError(f"{path}: unknown report line kind {kind!r}")
        return report

    # -- rendering ---------------------------------------------------------
    def bench_rows(self) -> list:
        """The report's contribution to ``BENCH_summary.json``.

        Phase rows pass through as-is (they are already bench-shaped);
        the final snapshot's scalar counters become ``obs_<counter>``
        rows with the slot count in the note.
        """
        rows = list(self.phase_rows)
        if self.snapshots:
            last = self.snapshots[-1]
            for name, value in last["counters"].items():
                if isinstance(value, (int, float)):
                    rows.append(
                        (f"obs_{name}", float(value), f"through slot {last['slot']}")
                    )
        return rows

    def summary_table(self) -> str:
        """Human-readable run summary (the report CLI's default output)."""
        lines = ["== run =="]
        for k, v in sorted(self.meta.items()):
            lines.append(f"  {k:<24} {v}")
        if self.snapshots:
            last = self.snapshots[-1]
            lines.append(f"== counters (slot {last['slot']}, {len(self.snapshots)} drains) ==")
            for k, v in sorted(last["counters"].items()):
                lines.append(f"  {k:<24} {v}")
            for k, v in sorted(last.get("derived", {}).items()):
                lines.append(f"  {k:<24} {v}")
        if self.phase_rows:
            lines.append("== phases ==")
            for name, value, note in self.phase_rows:
                lines.append(f"  {name:<32} {value:>12.1f}us  {note}")
        return "\n".join(lines)


def _jsonable(v):
    import numpy as np

    if isinstance(v, (np.generic, np.ndarray)):
        return np.asarray(v).tolist()
    return v


def main(argv=None) -> int:
    """Entry point for ``python -m repro.obs.report``."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a RunReport JSONL, merge its rows into a bench "
        "summary, or validate an exported trace.json.",
    )
    ap.add_argument("report", nargs="?", default=None, help="RunReport JSONL path")
    ap.add_argument(
        "--merge-bench",
        action="append",
        default=[],
        metavar="PATH",
        help="merge the report's obs_* rows into this BENCH_summary.json "
        "(repeatable; keeps the dual-written copies in sync)",
    )
    ap.add_argument(
        "--validate-trace",
        default=None,
        metavar="TRACE",
        help="assert a Chrome trace.json loads and carries spans",
    )
    args = ap.parse_args(argv)
    if args.report is None and args.validate_trace is None:
        ap.error("nothing to do: pass a report JSONL and/or --validate-trace")
    if args.validate_trace is not None:
        from repro.obs.trace import validate_trace

        n = validate_trace(args.validate_trace)
        print(f"{args.validate_trace}: valid Chrome trace, {n} spans")
    if args.report is not None:
        report = RunReport.from_jsonl(args.report)
        print(report.summary_table())
        rows = report.bench_rows()
        for path in args.merge_bench:
            merge_bench_summary(path, rows)
            print(f"merged {len(rows)} obs rows into {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
