"""Super-tick phase tracing: host span recorder + prefix-timing profiler.

The ROADMAP's open ``sharded_roofline_supertick_gap`` row says how far
the measured super-tick sits from its bandwidth bound, but not *which
phase* — halo publish, collective, dequant/scatter, the fused row
kernel, or the final scatter — eats the difference. Two mechanisms
close that:

* every engine phase body is wrapped in ``jax.named_scope`` (HLO-level
  names, visible in XLA profiles) and the host-side driver sections in
  ``jax.profiler.TraceAnnotation`` (visible in a live ``jax.profiler``
  trace);
* :func:`profile_supertick` measures per-phase wall-clock **by prefix
  differencing**: the engines expose ``phase_program(upto)`` — the
  jitted slot cut after a named phase, returning that phase's live
  intermediates — so timing each prefix and differencing consecutive
  prefixes attributes the pipeline time phase by phase. The phase times
  sum to the full-slot time by construction (up to clamping of timing
  noise), which is what lets them decompose the roofline gap row.

:class:`SpanRecorder` collects named spans (both the real host timing
sections and the synthetic per-phase attribution) and exports a
Chrome/Perfetto-loadable ``trace.json``; :func:`validate_trace` is the
loader the CI obs lane asserts with.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time

import jax
import numpy as np


class SpanRecorder:
    """Lightweight host-side span recorder with Chrome-trace export.

    Spans land in the ``traceEvents`` "X" (complete-event) form; wall
    times are ``time.perf_counter`` microseconds relative to the
    recorder's creation. ``tid`` separates tracks (0 = live host spans,
    1 = synthetic per-phase attribution).
    """

    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: list[dict] = []

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, tid: int = 0, **args):
        """Record a live span around a host-side section (also annotated
        for ``jax.profiler`` so device traces line up with ours)."""
        start = self._now_us()
        with jax.profiler.TraceAnnotation(name):
            yield
        self.add(name, start, self._now_us() - start, tid=tid, **args)

    def add(self, name: str, start_us: float, dur_us: float, tid: int = 0, **args):
        """Append one complete event (used for synthetic attribution spans)."""
        self.events.append(
            {
                "name": name,
                "ph": "X",
                "ts": float(start_us),
                "dur": float(max(dur_us, 0.0)),
                "pid": 0,
                "tid": int(tid),
                "args": {k: _jsonable(v) for k, v in args.items()},
            }
        )

    def export_chrome_trace(self, path: str) -> None:
        """Write the collected spans as a Chrome/Perfetto ``trace.json``."""
        with open(path, "w") as f:
            json.dump(
                {"traceEvents": self.events, "displayTimeUnit": "ms"}, f, indent=1
            )


def _jsonable(v):
    if isinstance(v, (np.generic, np.ndarray)):
        return np.asarray(v).tolist()
    return v


def validate_trace(path: str) -> int:
    """Load a ``trace.json`` and return its span count.

    Raises ``ValueError`` when the file is not a Chrome-trace object or
    carries no spans — the assertion the CI obs lane runs on the
    exported artifact.
    """
    with open(path) as f:
        data = json.load(f)
    events = data.get("traceEvents") if isinstance(data, dict) else None
    if not isinstance(events, list) or not events:
        raise ValueError(f"{path} is not a Chrome trace with events")
    for e in events:
        if not isinstance(e, dict) or "name" not in e or "ph" not in e:
            raise ValueError(f"{path} carries a malformed trace event: {e!r}")
    return len(events)


@dataclasses.dataclass
class PhaseProfile:
    """Per-phase wall-clock attribution of one engine super-tick."""

    phases: dict  # name -> seconds per slot (prefix-differenced)
    total_s: float  # sum of the phase times (last prefix time + noise clamps)
    measured_s: float  # independently timed full-slot wall-clock
    prefix_s: dict  # name -> seconds of the cumulative prefix program

    def rows(self, prefix: str = "obs_phase") -> list:
        """CSV-style ``(name, us, note)`` rows for the bench summary."""
        out = [
            (f"{prefix}_{name}", s * 1e6, f"{100.0 * s / max(self.total_s, 1e-12):.1f}% of slot")
            for name, s in self.phases.items()
        ]
        cov = self.total_s / max(self.measured_s, 1e-12)
        out.append(
            (
                f"{prefix}_total",
                self.total_s * 1e6,
                f"sum of phases; measured full slot {self.measured_s * 1e6:.4g}us "
                f"(coverage {cov:.2f})",
            )
        )
        return out


def profile_supertick(
    engine,
    state=None,
    inner: int = 4,
    repeats: int = 3,
    recorder: SpanRecorder | None = None,
) -> PhaseProfile:
    """Attribute one sampled super-tick's wall-clock to its phases.

    Times the engine's jitted phase-prefix programs (compile excluded:
    each program is warmed before timing; best-of-``repeats`` over
    ``inner``-call loops) and differences consecutive prefixes. A
    ``recorder`` collects both the live timing spans and a synthetic
    per-phase track laid out as one reconstructed super-tick; pass the
    same recorder across calls to accumulate one trace file.
    """
    if state is None:
        state = engine.init_state(np.zeros((engine.n, engine.p)))
    recorder = SpanRecorder() if recorder is None else recorder
    names = list(engine.phase_names)

    def timed(fn, label):
        out = fn(state)  # compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(repeats):
            with recorder.span(f"obs.time.{label}"):
                t0 = time.perf_counter()
                for _ in range(inner):
                    out = fn(state)
                jax.block_until_ready(out)
                best = min(best, (time.perf_counter() - t0) / inner)
        return best

    prefix_s = {}
    for name in names:
        prefix_s[name] = timed(engine.phase_program(name), f"prefix.{name}")
    measured = timed(engine.phase_program(None), "full_slot")

    phases, prev, cursor = {}, 0.0, 0.0
    for name in names:
        dt = max(prefix_s[name] - prev, 0.0)
        phases[name] = dt
        prev = prefix_s[name]
        recorder.add(f"obs.phase.{name}", cursor * 1e6, dt * 1e6, tid=1)
        cursor += dt
    return PhaseProfile(
        phases=phases,
        total_s=sum(phases.values()),
        measured_s=measured,
        prefix_s=prefix_s,
    )
