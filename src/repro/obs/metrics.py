"""Device-side engine telemetry: the metrics pytree and its accumulator.

The async engines run their whole super-tick inside a jit-compiled
``lax.scan``; anything worth observing (realized wake rates vs the
Poisson clocks, halo traffic, quantization error, DP budget burn-down,
churn) therefore has to be accumulated *inside* the compiled program —
a host read per slot would serialize the scan. This module provides:

* :class:`MetricsSpec` — a small frozen selector of counter groups,
  carried on :class:`repro.sim.EngineConfig` (``metrics=``; ``True``
  coerces to the default spec, ``None``/``False`` disables collection
  entirely — the default, so runs pay nothing unless asked);
* :class:`MetricsAccumulator` — built once per engine with the static
  context (row count, churn/straggler presence, DP budget limit,
  exchange-plan shape), it owns the metrics pytree: :meth:`init`
  produces the zeroed leaves that ride in ``SimState.metrics`` /
  ``ShardedSimState.metrics`` (the sharded engine stacks S copies along
  a leading shard axis), and :meth:`tick` advances them inside the
  traced slot.

Every counter is computed from values the super-tick already produces —
no extra PRNG draws, no host round-trips — so a metrics-on run is
bit-exact in Theta vs a metrics-off run (pinned by
``tests/test_obs.py``; the only cost is the counter arithmetic itself,
measured as the ``obs_overhead`` bench row).

Counter groups (leaves present only when the spec selects them and the
engine context supports them):

* ``wakes``: ``wakes_realized`` (wake mask sum before straggler/capacity
  losses), ``wakes_thinned`` (straggler drops), ``wakes_capacity_dropped``
  (static-batch overflow), ``wakes_applied`` (rows actually scattered);
* ``churn``: cumulative ``churn_departures`` / ``churn_rejoins``
  (active-flag transitions of the churn Markov chain);
* ``privacy``: ``dp_updates_applied`` (cumulative private updates) and
  ``dp_budget_stopped`` (gauge: agents at their planned budget now);
* ``exchange`` (sharded engine only): ``border_rows_published`` plus
  ``exchange_rows`` / ``exchange_bytes`` shipped on the interconnect
  (padded rows — static shapes ship them), and per-ring-offset
  ``p2p_rows_by_offset`` / ``p2p_bytes_by_offset`` under the
  point-to-point plan. The per-slot volumes are static properties of
  the exchange plan, but they differ per shard, so they arrive as
  shard-sliced inputs (``ExchangeVolume.tiles``) rather than Python
  constants;
* ``quantization``: cumulative squared quantization error of the
  compressed halo wire (``quant_err_sq``) and the current
  error-feedback residual energy (``ef_residual_sq``, a gauge);
* ``staleness``: a log2-bucketed histogram of slots-since-last-update
  per applied wake (bucketing is approximate by construction — recorded
  in ``docs/DEVIATIONS.md``) plus the ``last_wake`` slot marker it
  needs (dropped from drains: it is state, not a counter).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """Selects which counter groups the engines accumulate in-jit.

    Fields toggle groups (see the module docstring for the leaves each
    one contributes); ``staleness_buckets`` sizes the staleness
    histogram (bucket b collects staleness in slots ``[2^b, 2^(b+1))``,
    the last bucket open-ended).
    """

    wakes: bool = True
    exchange: bool = True
    quantization: bool = True
    privacy: bool = True
    churn: bool = True
    staleness: bool = True
    staleness_buckets: int = 8

    def __post_init__(self):
        if self.staleness_buckets < 1:
            raise ValueError("staleness_buckets must be >= 1")

    @classmethod
    def coerce(cls, value) -> "MetricsSpec | None":
        """Accept a spec, ``True`` (defaults), or ``None``/``False`` (off)."""
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(
            f"metrics must be a MetricsSpec, True, False, or None, got {type(value)!r}"
        )


@dataclasses.dataclass(frozen=True)
class ExchangeVolume:
    """Per-shard static interconnect volume of the halo exchange.

    Built once at engine build from the partition's plan; every array
    carries a leading shard axis so the stacked tiles ride through
    ``shard_map`` next to the graph tiles (per-shard border sizes
    differ, so these cannot be baked into the shared SPMD program as
    constants). Rows are padded rows, because static shapes ship them.
    """

    border_rows: np.ndarray  # (S,) real border rows published per slot
    rows_shipped: np.ndarray  # (S,) padded rows sent on the wire per slot
    bytes_shipped: np.ndarray  # (S,) rows_shipped * payload bytes per row
    p2p_rows: np.ndarray | None = None  # (S, O) padded P_d per ring offset
    p2p_bytes: np.ndarray | None = None  # (S, O)

    @property
    def num_offsets(self) -> int:
        """O: ring offsets in the point-to-point plan (0 for all_gather)."""
        return 0 if self.p2p_rows is None else int(self.p2p_rows.shape[1])

    def tiles(self) -> dict:
        """The stacked (S, ...) arrays to pass through ``shard_map``."""
        t = {
            "border_rows": jnp.asarray(self.border_rows, jnp.int32),
            "rows_shipped": jnp.asarray(self.rows_shipped, jnp.int32),
            "bytes_shipped": jnp.asarray(self.bytes_shipped, jnp.float32),
        }
        if self.p2p_rows is not None:
            t["p2p_rows"] = jnp.asarray(self.p2p_rows, jnp.int32)
            t["p2p_bytes"] = jnp.asarray(self.p2p_bytes, jnp.float32)
        return t


class MetricsAccumulator:
    """Owns the metrics pytree for one engine instance.

    ``rows`` is the scatter domain (n for the single-device engine, R
    per shard for the sharded one). Optional context enables groups:
    ``churn``/``straggler`` flags, ``dp_limit`` (the planned per-agent
    update budget ``planned_Ti``), ``exchange_offsets`` (None = no
    halo exchange; an int = the point-to-point plan's offset count,
    0 for the all_gather wire), ``quantized`` (the halo wire is lossy
    and reports error stats). Groups whose context is absent contribute
    no leaves, whatever the spec says — the pytree structure is fixed
    at engine build and stable across the scan.
    """

    def __init__(
        self,
        spec: MetricsSpec,
        rows: int,
        *,
        churn: bool = False,
        straggler: bool = False,
        dp_limit: int | None = None,
        exchange_offsets: int | None = None,
        quantized: bool = False,
    ):
        self.spec = spec
        self.rows = int(rows)
        self.churn = bool(churn) and spec.churn
        self.straggler = bool(straggler) and spec.wakes
        self.dp_limit = dp_limit if spec.privacy else None
        self.exchange_offsets = exchange_offsets if spec.exchange else None
        self.quantized = bool(quantized) and spec.quantization

    # -- pytree ------------------------------------------------------------
    def init(self) -> dict:
        """The zeroed metrics pytree (no leading shard axis; the sharded
        engine stacks S copies along axis 0)."""
        i32 = jnp.int32
        m: dict = {}
        if self.spec.wakes:
            m["wakes_realized"] = jnp.zeros((), i32)
            m["wakes_capacity_dropped"] = jnp.zeros((), i32)
            m["wakes_applied"] = jnp.zeros((), i32)
            if self.straggler:
                m["wakes_thinned"] = jnp.zeros((), i32)
        if self.churn:
            m["churn_departures"] = jnp.zeros((), i32)
            m["churn_rejoins"] = jnp.zeros((), i32)
        if self.dp_limit is not None:
            m["dp_updates_applied"] = jnp.zeros((), i32)
            m["dp_budget_stopped"] = jnp.zeros((), i32)
        if self.exchange_offsets is not None:
            m["border_rows_published"] = jnp.zeros((), i32)
            m["exchange_rows"] = jnp.zeros((), i32)
            m["exchange_bytes"] = jnp.zeros((), jnp.float32)
            if self.exchange_offsets > 0:
                m["p2p_rows_by_offset"] = jnp.zeros((self.exchange_offsets,), i32)
                m["p2p_bytes_by_offset"] = jnp.zeros(
                    (self.exchange_offsets,), jnp.float32
                )
        if self.quantized:
            m["quant_err_sq"] = jnp.zeros((), jnp.float32)
            m["ef_residual_sq"] = jnp.zeros((), jnp.float32)
        if self.spec.staleness:
            m["staleness_hist"] = jnp.zeros((self.spec.staleness_buckets,), i32)
            m["last_wake"] = jnp.zeros((self.rows,), i32)
        return m

    def leaf_kinds(self) -> dict:
        """Classify each metrics leaf for the checkpoint layer.

        ``"per_agent"`` leaves are keyed by agent row (``last_wake``) and
        must be re-tiled through the partition on an elastic restore;
        ``"counter"`` leaves are shard-additive accumulators that can be
        summed across shards without changing any drained snapshot.
        """
        return {
            k: "per_agent" if k == "last_wake" else "counter"
            for k in self.init()
        }

    # -- in-jit update -----------------------------------------------------
    def tick(
        self,
        m: dict,
        *,
        ptr,
        wake_pre,
        wake,
        applied,
        woken,
        capacity_dropped,
        active_prev=None,
        active_new=None,
        dp_counts=None,
        exchange=None,
        quant_stats=None,
    ) -> dict:
        """Advance the metrics pytree by one slot (runs inside the trace).

        ``wake_pre`` is the wake mask before straggler thinning,
        ``wake`` the realized mask, ``woken`` the (B,) scatter rows with
        sentinel ``rows``, ``applied`` their applied mask,
        ``capacity_dropped`` the static-batch overflow count,
        ``exchange`` this shard's slice of :meth:`ExchangeVolume.tiles`,
        ``quant_stats`` the halo wire's error stats dict. All inputs are
        values the slot already computed — the accumulator draws no
        randomness and never touches Theta.
        """
        m = dict(m)
        applied_count = applied.sum().astype(jnp.int32)
        if self.spec.wakes:
            m["wakes_realized"] = m["wakes_realized"] + wake_pre.sum().astype(jnp.int32)
            m["wakes_capacity_dropped"] = (
                m["wakes_capacity_dropped"] + capacity_dropped.astype(jnp.int32)
            )
            m["wakes_applied"] = m["wakes_applied"] + applied_count
            if self.straggler:
                thinned = (wake_pre & ~wake).sum().astype(jnp.int32)
                m["wakes_thinned"] = m["wakes_thinned"] + thinned
        if self.churn and active_prev is not None:
            departed = (active_prev & ~active_new).sum().astype(jnp.int32)
            rejoined = ((~active_prev) & active_new).sum().astype(jnp.int32)
            m["churn_departures"] = m["churn_departures"] + departed
            m["churn_rejoins"] = m["churn_rejoins"] + rejoined
        if self.dp_limit is not None and dp_counts is not None:
            m["dp_updates_applied"] = m["dp_updates_applied"] + applied_count
            stopped = (dp_counts >= jnp.int32(self.dp_limit)).sum().astype(jnp.int32)
            m["dp_budget_stopped"] = stopped  # gauge, not cumulative
        if self.exchange_offsets is not None and exchange is not None:
            m["border_rows_published"] = (
                m["border_rows_published"] + exchange["border_rows"]
            )
            m["exchange_rows"] = m["exchange_rows"] + exchange["rows_shipped"]
            m["exchange_bytes"] = m["exchange_bytes"] + exchange["bytes_shipped"]
            if self.exchange_offsets > 0:
                m["p2p_rows_by_offset"] = m["p2p_rows_by_offset"] + exchange["p2p_rows"]
                m["p2p_bytes_by_offset"] = (
                    m["p2p_bytes_by_offset"] + exchange["p2p_bytes"]
                )
        if self.quantized and quant_stats is not None:
            m["quant_err_sq"] = m["quant_err_sq"] + quant_stats["quant_err_sq"]
            m["ef_residual_sq"] = quant_stats["ef_residual_sq"]  # gauge
        if self.spec.staleness:
            nb = self.spec.staleness_buckets
            safe = jnp.minimum(woken, self.rows - 1)
            stale = (ptr - m["last_wake"][safe]).astype(jnp.float32)
            bucket = jnp.clip(
                jnp.floor(jnp.log2(jnp.maximum(stale, 1.0))), 0, nb - 1
            ).astype(jnp.int32)
            m["staleness_hist"] = (
                m["staleness_hist"].at[jnp.where(applied, bucket, nb)].add(1, mode="drop")
            )
            m["last_wake"] = (
                m["last_wake"].at[jnp.where(applied, woken, self.rows)]
                .set(ptr + 1, mode="drop")
            )
        return m

    # -- host drain --------------------------------------------------------
    def snapshot(self, m: dict) -> dict:
        """Device metrics -> host dict of numpy arrays (drain helper).

        Sharded callers pass the stacked (S, ...) pytree; per-shard
        leaves keep their leading shard axis so the report layer can
        show per-shard burn-down as well as totals. The internal
        ``last_wake`` marker is dropped — it is state, not a counter.
        """
        return {k: np.asarray(v) for k, v in m.items() if k != "last_wake"}


# Host-side dynamic-topology counters. Unlike the in-jit groups above,
# topology changes happen between chunks on the host (edge refreshes,
# arrivals, partition patches), so the engines keep a plain dict and
# merge it into the ``derived`` side of ``metrics_snapshot`` with a
# ``topology_`` prefix.
TOPOLOGY_COUNTERS = (
    "edge_refreshes",  # GraphUpdate.refresh rounds fired
    "edges_added",  # undirected edges created across all topology swaps
    "edges_removed",  # undirected edges dropped across all topology swaps
    "weight_patches",  # same-structure partition rebinds (weights only)
    "structural_patches",  # GraphPartition.patch() calls (ownership frozen)
    "repartitions",  # full partition_graph rebuilds (drift over threshold)
    "arrivals",  # agents admitted mid-run
    "last_drift",  # gauge: cut-fraction drift measured at the last swap
)


def topology_log_init() -> dict:
    """A fresh host-side dynamic-topology counter dict (all zeros)."""
    return {k: (0.0 if k == "last_drift" else 0) for k in TOPOLOGY_COUNTERS}


# Host-side serving counters. Like the topology log, serving activity
# happens on the host (snapshot publication from run() events, batched
# predict() calls against the latest published version), so the
# ``repro.serve.ServeHandle`` keeps a plain dict in this layout.
SERVE_COUNTERS = (
    "serve_requests",  # predict()/rows() calls answered
    "serve_predictions",  # total rows scored across all batches
    "serve_batch_rows_max",  # gauge: largest request batch seen
    "serve_cold_starts",  # rows synthesized via the Eq. 16 neighbour average
    "serve_snapshots_published",  # publish() calls (one per snapshot_every slots)
    "serve_version_lag",  # gauge: newest published slot minus the slot just served
    "serve_version_lag_max",  # worst version lag any request observed
    "serve_publish_s_total",  # wall seconds spent publishing snapshots (float)
)


def serve_counters_init() -> dict:
    """A fresh host-side serving counter dict (all zeros)."""
    return {k: (0.0 if k == "serve_publish_s_total" else 0) for k in SERVE_COUNTERS}


def summarize_counters(snapshot: dict) -> dict:
    """Collapse a (possibly shard-stacked) snapshot into JSON-ready totals.

    Scalar counters sum over the shard axis; per-offset / histogram
    vectors sum over shards but keep their own axis (returned as
    lists). Gauges sum too — a per-shard gauge's total is the
    fleet-wide gauge.
    """
    vector = ("staleness_hist", "p2p_rows_by_offset", "p2p_bytes_by_offset")
    out: dict = {}
    for k, v in snapshot.items():
        a = np.asarray(v)
        if k in vector:
            collapsed = a.sum(axis=0) if a.ndim > 1 else a
            cast = float if collapsed.dtype.kind == "f" else int
            out[k] = [cast(x) for x in collapsed]
        else:
            out[k] = float(a.sum()) if a.dtype.kind == "f" else int(a.sum())
    return out
