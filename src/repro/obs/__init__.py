"""``repro.obs`` — in-jit engine telemetry, phase tracing, run reports.

Three layers, importable in any combination:

* :mod:`repro.obs.metrics` — :class:`MetricsSpec` selects counter
  groups; the engines carry the resulting metrics pytree through their
  jit-scanned super-ticks (``EngineConfig(metrics=...)``), so
  collection adds no host round-trips and leaves Theta bit-exact;
* :mod:`repro.obs.trace` — :class:`SpanRecorder` + Chrome-trace export
  and :func:`profile_supertick`, which attributes a super-tick's
  wall-clock to its named phases by prefix differencing;
* :mod:`repro.obs.report` — :class:`RunReport` (periodic metric drains
  + phase rows, JSONL round-trip) and the ``python -m repro.obs.report``
  CLI that renders summaries and merges ``obs_*`` rows into
  ``BENCH_summary.json``.
"""

from repro.obs.metrics import (
    SERVE_COUNTERS,
    TOPOLOGY_COUNTERS,
    ExchangeVolume,
    MetricsAccumulator,
    MetricsSpec,
    serve_counters_init,
    summarize_counters,
    topology_log_init,
)
from repro.obs.report import RunReport, merge_bench_summary
from repro.obs.trace import (
    PhaseProfile,
    SpanRecorder,
    profile_supertick,
    validate_trace,
)

__all__ = [
    "SERVE_COUNTERS",
    "TOPOLOGY_COUNTERS",
    "ExchangeVolume",
    "MetricsAccumulator",
    "MetricsSpec",
    "PhaseProfile",
    "RunReport",
    "SpanRecorder",
    "merge_bench_summary",
    "profile_supertick",
    "serve_counters_init",
    "summarize_counters",
    "topology_log_init",
    "validate_trace",
]
