"""Versioned Theta snapshots + batched personalized inference.

The paper trains one personalized linear model per agent (row ``i`` of
Theta); this module is the read path that actually answers agent ``i``'s
prediction requests while the swarm keeps training. The trainer
publishes double-buffered, version-tagged snapshots from inside
``run(..., snapshot_every=, serve=)`` — zero-copy references to the
engine's own immutable per-shard tiles, never an ``(n, p)`` gather —
and a :class:`ServeHandle` answers batched ``predict(agent_ids, X)``
against the latest published version via one jitted per-shard
row-gather + dot, routing original agent ids through the
``GraphPartition`` ownership maps (``shard_of``/``local_of``).

Ids not yet in the swarm (scheduled-but-pending arrivals, or ids beyond
``n``) are served by a cold-start tier that synthesizes their row as the
Eq. 16 confidence-zero neighbour average — exactly the warm start
``ArrivalConfig`` applies at admission, folded into the same gather as a
K-neighbour weighted row instead of a K=1 self row.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import serve_counters_init


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Frozen serving configuration (a real spec, never bare strings).

    ``buffers`` sets the snapshot ring depth: publication writes the
    next slot and atomically swaps the reader reference, so at least
    the last ``buffers`` published versions stay alive for readers that
    pinned one mid-request. ``neighbors`` maps a cold agent id to the
    warm ids whose Eq. 16 average synthesizes its row; per-call
    ``predict(..., neighbors=)`` entries override it.
    """

    buffers: int = 2
    neighbors: dict | None = None

    def __post_init__(self):
        """Validate at construction — a bad spec never reaches serving."""
        if int(self.buffers) < 2:
            raise ValueError(
                f"ServeSpec.buffers={self.buffers}: double-buffered publication "
                "needs at least 2 snapshot slots"
            )
        if self.neighbors is not None:
            for cold, nbrs in self.neighbors.items():
                if len(tuple(nbrs)) == 0:
                    raise ValueError(
                        f"ServeSpec.neighbors[{cold}] is empty; the Eq. 16 "
                        "cold-start average needs at least one neighbour"
                    )

    @classmethod
    def coerce(cls, value) -> "ServeSpec":
        """``None`` -> defaults, a spec passes through; anything else
        (bare strings included) is a TypeError. Mirrors the
        ``ExchangeSpec.coerce`` / ``MetricsSpec.coerce`` contract."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(
            f"serve spec must be a ServeSpec or None for defaults, "
            f"got {type(value).__name__}: {value!r}"
        )


class ThetaSnapshot(NamedTuple):
    """One published, immutable serving view of the swarm model.

    ``tiles`` is the engine's own ``(S, R, p)`` shard stack (a
    single-device engine publishes ``Theta[None]``, i.e. S=1, R=n) —
    jax arrays are immutable, so holding the reference *is* the
    consistent snapshot; the trainer's next super-tick allocates fresh
    buffers and never mutates a published version.
    """

    version: int  # trainer slot counter at publication
    tiles: jnp.ndarray  # (S, R, p) shard blocks; padding rows never routed to
    shard_of: np.ndarray | None  # (n,) owning shard per original id (None: S=1 identity)
    local_of: np.ndarray | None  # (n,) local row within the owning shard
    pending: frozenset  # ids scheduled but not yet admitted — served cold


class SnapshotStore:
    """Double-buffered, version-tagged snapshot ring.

    ``publish`` fills the oldest ring slot and swaps the single reader
    reference under a lock; ``latest`` is one attribute read with no
    lock, so a reader mid-``predict`` keeps its pinned snapshot while
    the trainer publishes behind it. The ring's only job is keeping the
    newest ``buffers`` versions' device buffers alive for such readers.
    """

    def __init__(self, buffers: int = 2):
        """Create an empty ring of ``buffers`` snapshot slots."""
        self._ring: list = [None] * int(buffers)
        self._idx = 0
        self._lock = threading.Lock()
        self._latest: ThetaSnapshot | None = None

    def publish(self, snap: ThetaSnapshot) -> None:
        """Install ``snap`` as the served version (atomic ref swap)."""
        with self._lock:
            self._ring[self._idx] = snap
            self._idx = (self._idx + 1) % len(self._ring)
            self._latest = snap

    @property
    def latest(self) -> ThetaSnapshot:
        """The newest published snapshot (raises before first publish)."""
        snap = self._latest
        if snap is None:
            raise RuntimeError(
                "no snapshot published yet; run the engine with "
                "run(..., snapshot_every=, serve=handle) or serve from a "
                "checkpoint via repro.serve.serve_from_checkpoint"
            )
        return snap

    @property
    def latest_version(self) -> int:
        """Version tag of the newest published snapshot."""
        return self.latest.version


class ServeResult(NamedTuple):
    """One answered batch: scores/rows plus the version that served it."""

    values: np.ndarray  # (B,) scores from predict(), (B, p) rows from rows()
    version: int  # snapshot version (trainer slot) the batch was served from
    cold: np.ndarray  # (B,) bool — True where the row was Eq. 16 synthesized


@partial(jax.jit, static_argnames=())
def _gather_rows(tiles, sids, lids, w):
    """Gather + Eq. 16 combine: ``(B, K)`` routed rows -> ``(B, p)`` f32.

    Touches exactly B*K rows of the shard tiles — the gather is the
    whole read path, so no ``(n, p)`` intermediate can exist here.
    """
    rows = tiles[sids, lids].astype(w.dtype)  # (B, K, p)
    return jnp.einsum("bk,bkp->bp", w, rows)


@partial(jax.jit, static_argnames=())
def _score_rows(tiles, sids, lids, w, X):
    """Fused gather + combine + per-row dot: ``(B,)`` scores."""
    theta = _gather_rows(tiles, sids, lids, w)
    return jnp.sum(theta * X.astype(theta.dtype), axis=-1)


class ServeHandle:
    """Batched personalized inference over published Theta snapshots.

    Front a *live* engine with :meth:`for_engine` +
    ``run(..., snapshot_every=, serve=handle)``, or a finished /
    crash-recovered run with :func:`repro.serve.serve_from_checkpoint`;
    the read API is identical either way. Thread-safe: ``predict`` may
    run from request threads while the training thread publishes.
    """

    def __init__(self, store: SnapshotStore, spec: ServeSpec, *, n: int, p: int):
        """Wrap ``store``; prefer :meth:`for_engine` / checkpoint serving."""
        self.spec = spec
        self.n = int(n)
        self.p = int(p)
        self._store = store
        self._engine = None
        self._lock = threading.Lock()
        self._counters = serve_counters_init()

    # -- publication -------------------------------------------------------
    @classmethod
    def for_engine(cls, engine, spec: ServeSpec | None = None) -> "ServeHandle":
        """A handle bound to a live engine, ready for ``run(serve=...)``.

        When the engine carries an arrival scenario with an explicit
        attachment map and the spec names no neighbours, the arrival
        map becomes the cold-start neighbour default — pending arrivals
        are then served with exactly the neighbours they will warm-start
        from at admission.
        """
        spec = ServeSpec.coerce(spec)
        arrival = getattr(getattr(engine, "scenario", None), "arrival", None)
        if spec.neighbors is None and arrival is not None and arrival.attach:
            spec = dataclasses.replace(
                spec,
                neighbors={int(k): tuple(v) for k, v in arrival.attach.items()},
            )
        handle = cls(SnapshotStore(spec.buffers), spec, n=engine.n, p=engine.p)
        handle._engine = engine
        return handle

    def publish(self, state) -> None:
        """Publish the engine state's Theta as the next served version.

        Zero-copy by construction: the sharded engine's ``(S, R, p)``
        tile stack (or ``Theta[None]`` single-device) is referenced as
        published, alongside the partition's ownership maps so routing
        survives dynamic-topology repartitions; only the slot counter is
        pulled to the host.
        """
        eng = self._engine
        if eng is None:
            raise RuntimeError(
                "this ServeHandle is not bound to a live engine; build it "
                "with ServeHandle.for_engine(engine) (checkpoint-served "
                "handles are read-only)"
            )
        t0 = time.perf_counter()
        part = getattr(eng, "part", None)
        if part is not None:
            snap = ThetaSnapshot(
                version=eng._ptr_of(state),
                tiles=state.Theta,
                shard_of=part.shard_of,
                local_of=part.local_of,
                pending=frozenset(eng._pending),
            )
        else:
            snap = ThetaSnapshot(
                version=eng._ptr_of(state),
                tiles=state.Theta[None],
                shard_of=None,
                local_of=None,
                pending=frozenset(eng._pending),
            )
        self._store.publish(snap)
        dt = time.perf_counter() - t0
        with self._lock:
            self._counters["serve_snapshots_published"] += 1
            self._counters["serve_publish_s_total"] += dt

    # -- the read path -----------------------------------------------------
    def snapshot(self) -> ThetaSnapshot:
        """Pin the latest published version for a multi-call consistent
        read (pass it back via ``predict(..., at=snap)``)."""
        return self._store.latest

    @property
    def version(self) -> int:
        """Version tag (trainer slot) of the latest published snapshot."""
        return self._store.latest_version

    def counters(self) -> dict:
        """A copy of the host-side ``serve_*`` counters
        (:data:`repro.obs.SERVE_COUNTERS` layout)."""
        with self._lock:
            return dict(self._counters)

    def rows(self, agent_ids, neighbors=None, at=None) -> ServeResult:
        """The served ``(B, p)`` model rows (f32) for ``agent_ids``.

        Warm ids return their snapshot row bit-exactly (bf16 tiles
        upcast exactly); cold ids return the Eq. 16 neighbour average.
        """
        ids = self._check_ids(agent_ids)
        snap = self._store.latest if at is None else at
        sids, lids, w, cold = self._route(ids, snap, neighbors)
        out = np.asarray(_gather_rows(snap.tiles, sids, lids, w))
        self._account(ids.size, int(cold.sum()), snap.version)
        return ServeResult(values=out, version=snap.version, cold=cold)

    def predict(self, agent_ids, X, neighbors=None, at=None) -> ServeResult:
        """Batched personalized predictions ``<theta_i, x_b>`` -> (B,).

        ``agent_ids`` is (B,) original ids; ``X`` is (B, p) features.
        Served from the latest published snapshot (or a pinned ``at=``
        one): a single jitted per-shard row-gather + dot over exactly
        the requested rows. Cold ids (pending arrivals, or ids >= n)
        need neighbours — from ``neighbors={id: (warm ids...)}``, the
        spec, or the engine's arrival attachment map — and are scored
        on their Eq. 16 confidence-zero average row.
        """
        ids = self._check_ids(agent_ids)
        X = np.asarray(X)
        if X.shape != (ids.size, self.p):
            raise ValueError(
                f"X must be (B, p) = ({ids.size}, {self.p}) to match "
                f"agent_ids; got {X.shape}"
            )
        snap = self._store.latest if at is None else at
        sids, lids, w, cold = self._route(ids, snap, neighbors)
        y = np.asarray(_score_rows(snap.tiles, sids, lids, w, jnp.asarray(X)))
        self._account(ids.size, int(cold.sum()), snap.version)
        return ServeResult(values=y, version=snap.version, cold=cold)

    # -- internals ---------------------------------------------------------
    def _check_ids(self, agent_ids) -> np.ndarray:
        ids = np.asarray(agent_ids, dtype=np.int64).ravel()
        if ids.size == 0:
            raise ValueError("empty agent_ids batch")
        if (ids < 0).any():
            raise ValueError(f"negative agent ids: {ids[ids < 0][:5].tolist()}")
        return ids

    def _neighbors_for(self, i: int, neighbors) -> tuple:
        if neighbors is not None and i in neighbors:
            return tuple(int(j) for j in neighbors[i])
        if self.spec.neighbors is not None and i in self.spec.neighbors:
            return tuple(int(j) for j in self.spec.neighbors[i])
        raise ValueError(
            f"agent id {i} is not in the swarm yet and has no attachment "
            f"neighbours; pass neighbors={{{i}: (warm ids...)}} (or set "
            f"ServeSpec.neighbors) so Eq. 16 can synthesize its row"
        )

    def _route(self, ids, snap, neighbors):
        """Original ids -> ``(B, K)`` (shard, local, weight) gather plan.

        Warm ids are a K=1 self-gather with weight 1 (padded slots route
        to row 0 with weight 0); cold ids spread uniform weight over
        their neighbours — the Eq. 16 average with zero confidence and
        the uniform attachment weights ``ArrivalConfig`` uses.
        """
        cold = np.fromiter(
            ((i >= self.n or i in snap.pending) for i in ids.tolist()),
            dtype=bool,
            count=ids.size,
        )
        plans = []
        for i, is_cold in zip(ids.tolist(), cold.tolist()):
            if not is_cold:
                plans.append(((i,), (1.0,)))
                continue
            nbrs = self._neighbors_for(i, neighbors)
            bad = [j for j in nbrs if j >= self.n or j < 0 or j in snap.pending]
            if bad:
                raise ValueError(
                    f"cold agent id {i}: attachment neighbours {bad} are not "
                    f"established in the swarm (pending or out of range)"
                )
            plans.append((nbrs, (1.0 / len(nbrs),) * len(nbrs)))
        K = max(len(p[0]) for p in plans)
        gids = np.zeros((ids.size, K), dtype=np.int64)
        w = np.zeros((ids.size, K), dtype=np.float32)
        for b, (g, ws) in enumerate(plans):
            gids[b, : len(g)] = g
            w[b, : len(ws)] = ws
        if snap.shard_of is None:
            sids = np.zeros_like(gids)
            lids = gids
        else:
            sids = snap.shard_of[gids]
            lids = snap.local_of[gids]
        return jnp.asarray(sids), jnp.asarray(lids), jnp.asarray(w), cold

    def _account(self, batch: int, cold: int, served_version: int) -> None:
        lag = self._store.latest_version - served_version
        with self._lock:
            c = self._counters
            c["serve_requests"] += 1
            c["serve_predictions"] += batch
            c["serve_batch_rows_max"] = max(c["serve_batch_rows_max"], batch)
            c["serve_cold_starts"] += cold
            c["serve_version_lag"] = lag
            c["serve_version_lag_max"] = max(c["serve_version_lag_max"], lag)
