"""``python -m repro.serve`` — batched personalized inference CLI.

Two modes, one JSON summary line on stdout (bench-style):

    # read-only serving from a checkpoint rotation written by
    # run(..., checkpoint_every=, checkpoint_dir=) or the examples
    python -m repro.serve --checkpoint-dir ckpts --batch 256 --requests 32

    # live: train a synthetic swarm and serve it concurrently
    python -m repro.serve --live --n 20000 --shards 8 --slots 6 \
        --snapshot-every 2 --batch 256

The live mode runs the engine in a background thread and keeps issuing
batched ``predict`` calls against whatever version is newest — the
summary reports predictions/s, p50/p99 batch latency, the distinct
versions served, and the full ``serve_*`` counter dict.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time


def _parse(argv):
    ap = argparse.ArgumentParser(prog="python -m repro.serve", description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--checkpoint-dir", default=None, metavar="PATH",
                      help="serve read-only from a repro.checkpoint engine entry")
    mode.add_argument("--live", action="store_true",
                      help="train a synthetic swarm and serve it concurrently")
    ap.add_argument("--batch", type=int, default=256, help="rows per predict()")
    ap.add_argument("--requests", type=int, default=32,
                    help="predict() calls to issue (live mode: minimum)")
    ap.add_argument("--n", type=int, default=20_000, help="live: swarm size")
    ap.add_argument("--p", type=int, default=8, help="live: model dimension")
    ap.add_argument("--shards", type=int, default=1, help="live: shard count")
    ap.add_argument("--slots", type=int, default=6, help="live: training slots")
    ap.add_argument("--slot-wakes", type=float, default=0.0,
                    help="live: mean wakes per slot (0 = n/20)")
    ap.add_argument("--snapshot-every", type=int, default=2,
                    help="live: publication period in slots")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def _measure(handle, rng, batch, requests, stop=None):
    """Issue batched predicts until ``requests`` (and ``stop``, if given)."""
    import numpy as np

    ids = rng.integers(0, handle.n, size=batch)
    X = rng.normal(size=(batch, handle.p))
    handle.predict(ids, X)  # compile outside the timed window
    lat, versions = [], set()
    while len(lat) < requests or (stop is not None and not stop.is_set()):
        t0 = time.perf_counter()
        r = handle.predict(ids, X)
        lat.append(time.perf_counter() - t0)
        versions.add(int(r.version))
    return np.asarray(lat), versions


def _summary(mode, handle, batch, lat, versions, extra=None):
    import numpy as np

    out = {
        "mode": mode,
        "n": handle.n,
        "p": handle.p,
        "version": handle.version,
        "requests": int(lat.size),
        "predictions_per_s": float(batch * lat.size / lat.sum()),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "versions_served": sorted(versions),
        **(extra or {}),
        **handle.counters(),
    }
    print(json.dumps(out))


def _serve_checkpoint(args) -> int:
    import numpy as np

    from repro.serve import serve_from_checkpoint

    handle = serve_from_checkpoint(args.checkpoint_dir)
    rng = np.random.default_rng(args.seed)
    lat, versions = _measure(handle, rng, args.batch, args.requests)
    _summary("checkpoint", handle, args.batch, lat, versions)
    return 0


def _serve_live(args) -> int:
    import numpy as np

    from repro.core import AgentData, make_objective, random_geometric_graph
    from repro.sim import CDUpdate, EngineConfig, make_engine
    from repro.serve import ServeHandle

    rng = np.random.default_rng(args.seed)
    n, p, m = args.n, args.p, 4
    graph = random_geometric_graph(n, rng, avg_degree=12.0)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    data = AgentData(X=X, y=np.einsum("nmp,np->nm", X, targets),
                     mask=np.ones((n, m)))
    update = CDUpdate(make_objective(graph, data, "quadratic", mu=0.5,
                                     mix_mode="sparse"))
    cfg = EngineConfig(
        slot_wakes=args.slot_wakes or n / 20.0,
        seed=args.seed,
        relabel="rcm" if args.shards > 1 else None,
    )
    engine = make_engine(update, cfg,
                         shards=args.shards if args.shards > 1 else None)
    handle = ServeHandle.for_engine(engine)

    done = threading.Event()
    box = {}

    def _train():
        try:
            box["result"] = engine.run(
                np.zeros((n, p)), args.slots,
                snapshot_every=args.snapshot_every, serve=handle,
            )
        finally:
            done.set()

    trainer = threading.Thread(target=_train, name="trainer")
    trainer.start()
    while not done.is_set():  # the run publishes version 0 as it starts
        try:
            handle.version
            break
        except RuntimeError:
            time.sleep(0.005)
    lat, versions = _measure(handle, rng, args.batch, args.requests, stop=done)
    trainer.join()
    if "result" not in box:
        raise SystemExit("training thread died before finishing")
    final = handle.predict(
        rng.integers(0, n, size=args.batch), rng.normal(size=(args.batch, p))
    )
    versions.add(int(final.version))
    if int(final.version) != int(box["result"].slots):
        raise SystemExit(
            f"latest served version {final.version} != final trainer slot "
            f"{box['result'].slots}"
        )
    _summary("live", handle, args.batch, lat, versions,
             extra={"shards": args.shards, "slots": args.slots})
    return 0


def main(argv=None) -> int:
    """CLI entry point."""
    args = _parse(argv)
    if args.live and args.shards > 1:
        # Must land before jax initializes its backends; respects an
        # externally-pinned XLA_FLAGS (the CI lanes set their own).
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.shards}",
        )
    if args.live:
        return _serve_live(args)
    return _serve_checkpoint(args)


if __name__ == "__main__":
    raise SystemExit(main())
