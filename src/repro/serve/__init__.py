"""``repro.serve`` — the online personalized serving tier.

Versioned snapshot publication plus batched personalized inference over
the training swarm: a :class:`ServeHandle` answers
``predict(agent_ids, X)`` against the latest published Theta version —
live (``engine.run(..., snapshot_every=, serve=handle)``) or offline
from a ``repro.checkpoint`` entry (:func:`serve_from_checkpoint`) —
with an Eq. 16 neighbour-average cold-start tier for ids not yet in the
swarm. ``python -m repro.serve`` fronts both modes from the command
line.

Exports resolve lazily (PEP 562) so the CLI can pin XLA device flags
before anything imports jax.
"""

__all__ = [
    "ServeHandle",
    "ServeResult",
    "ServeSpec",
    "SnapshotStore",
    "ThetaSnapshot",
    "serve_from_checkpoint",
]

_HANDLE = ("ServeHandle", "ServeResult", "ServeSpec", "SnapshotStore", "ThetaSnapshot")


def __getattr__(name: str):
    """Lazy re-export from the implementation modules."""
    if name in _HANDLE:
        from repro.serve import handle

        return getattr(handle, name)
    if name == "serve_from_checkpoint":
        from repro.serve.checkpoint_io import serve_from_checkpoint

        return serve_from_checkpoint
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
