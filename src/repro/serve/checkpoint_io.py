"""Read-only serving from a ``repro.checkpoint`` engine entry.

The same :class:`~repro.serve.handle.ServeHandle` API that fronts a
live engine can front a finished or crash-recovered run: resolve the
newest verified entry in a rotation directory (per-file sha256 gates,
torn-entry fallback — the crash-safety recipe from ``repro.checkpoint``
unchanged), gate on the saved engine fingerprint, and stream the
per-shard theta blocks one file at a time into a fresh ``(S, R, p)``
tile stack. The ownership routing is rebuilt from each shard file's own
original-id list, so no graph, partition object, or ``(n, p)`` gather
is ever needed — serving a checkpoint costs exactly one pass over the
shard files it contains.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (
    CheckpointError,
    _from_numpy,
    _load_arrays,
    _resolve_entry,
)
from repro.checkpoint.engine_io import _load_file
from repro.serve.handle import ServeHandle, ServeSpec, SnapshotStore, ThetaSnapshot


def _check_expected(entry: str, saved: dict, expect: dict | None) -> None:
    """Reject a serve request whose caller expects a different swarm.

    ``expect`` is any subset of the saved ``engine_fingerprint`` keys
    (``n``, ``p``, ``dtype``, ``engine``, ``graph``, ...); every named
    key must match exactly — same error shape as the restore-side
    ``_check_fingerprint``.
    """
    if not expect:
        return
    for key in sorted(expect):
        if saved.get(key) != expect[key]:
            raise CheckpointError(
                f"{entry}: fingerprint mismatch on {key!r}: checkpoint has "
                f"{saved.get(key)!r}, caller expects {expect[key]!r}"
            )


def _pending_of(entry: str, fp: dict) -> frozenset:
    if not fp.get("dynamic"):
        return frozenset()
    topo = _load_file(entry, "topology.npz")
    return frozenset(int(i) for i in topo["pending"])


def _async_snapshot(entry: str, manifest: dict) -> ThetaSnapshot:
    """Theta of an ``AsyncEngine`` entry as a single (1, n, p) tile."""
    by_path = {r["path"]: r for r in manifest["leaves"]}
    rec = by_path[".Theta"]
    data = _load_arrays(entry, manifest)
    theta = _from_numpy(data[rec["key"]], rec["dtype"])
    return ThetaSnapshot(
        version=int(manifest["step"]),
        tiles=jnp.asarray(theta)[None],
        shard_of=None,
        local_of=None,
        pending=_pending_of(entry, manifest["fingerprint"]),
    )


def _sharded_snapshot(entry: str, manifest: dict) -> ThetaSnapshot:
    """Stream shard files into an (S, R, p) tile stack + ownership maps.

    One shard file is resident at a time; each block lands at the local
    rows its saved original-id list dictates, and those same ids define
    ``shard_of``/``local_of`` — the serving layout is self-describing,
    independent of the partition mode that produced the checkpoint.
    """
    fp = manifest["fingerprint"]
    S, n, p = int(fp["num_shards"]), int(fp["n"]), int(fp["p"])
    sizes = _load_file(entry, "partition.npz")["sizes"]
    R = int(np.max(sizes))
    bf16 = set(manifest.get("bf16", []))
    shard_of = np.full(n, -1, dtype=np.int32)
    local_of = np.zeros(n, dtype=np.int32)
    tiles = None
    for s in range(S):
        fname = f"shard_{s}.npz"
        arrs = _load_file(entry, fname)
        ids = np.asarray(arrs["ids"], dtype=np.int64)
        theta = _from_numpy(
            arrs["theta"],
            "bfloat16" if f"{fname}/theta" in bf16 else str(arrs["theta"].dtype),
        )
        if tiles is None:
            tiles = np.zeros((S, R, p), dtype=theta.dtype)
        tiles[s, : ids.size] = theta
        shard_of[ids] = s
        local_of[ids] = np.arange(ids.size, dtype=np.int32)
    if tiles is None or (shard_of < 0).any():
        raise CheckpointError(f"{entry}: shard files do not cover all {n} agents")
    return ThetaSnapshot(
        version=int(manifest["step"]),
        tiles=jnp.asarray(tiles),
        shard_of=shard_of,
        local_of=local_of,
        pending=_pending_of(entry, fp),
    )


def serve_from_checkpoint(
    path: str, spec: ServeSpec | None = None, expect_fingerprint: dict | None = None
) -> ServeHandle:
    """A read-only :class:`ServeHandle` over a checkpointed swarm.

    ``path`` is a rotation directory or a single entry (same resolution
    as ``repro.checkpoint.restore``: newest sha256-verified entry wins,
    torn entries fall back). Non-engine checkpoints are rejected, and
    ``expect_fingerprint`` lets the caller pin any subset of the saved
    engine fingerprint (``{"n": ..., "dtype": ...}``) before serving a
    single prediction. The handle's snapshot version is the saved step;
    ``publish`` raises — train-side publication needs a live engine.
    """
    entry, manifest = _resolve_entry(path)
    if manifest.get("kind") != "engine":
        raise CheckpointError(
            f"{entry}: not an engine checkpoint (kind={manifest.get('kind')!r}); "
            "serve_from_checkpoint needs a save_engine_checkpoint entry"
        )
    fp = manifest["fingerprint"]
    _check_expected(entry, fp, expect_fingerprint)
    spec = ServeSpec.coerce(spec)
    if fp["engine"] == "sharded":
        snap = _sharded_snapshot(entry, manifest)
    else:
        snap = _async_snapshot(entry, manifest)
    store = SnapshotStore(spec.buffers)
    store.publish(snap)
    handle = ServeHandle(store, spec, n=int(fp["n"]), p=int(fp["p"]))
    with handle._lock:
        handle._counters["serve_snapshots_published"] += 1
    return handle
