"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` of an SPMD-partitioned executable reports per-device
FLOPs/bytes, so the formulas above are the per-chip version of the spec's
(global / (chips * bw)) — identical numbers.

collective_bytes is not in cost_analysis: we parse the partitioned HLO text
and sum the traffic of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, using per-op formulas on the (per-shard)
printed shapes:

    all-gather         ~ result_bytes           (ring, (K-1)/K ~ 1)
    reduce-scatter     ~ operand_bytes
    all-reduce         ~ 2 * operand_bytes      (RS + AG)
    all-to-all         ~ operand_bytes
    collective-permute ~ operand_bytes
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(?:%?[\w.\-]+)\s*=\s*(?:\(?)([a-z0-9\[\],{}() ]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device collective traffic by op kind from partitioned HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # avoid double counting async pairs
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        result_shapes, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(result_shapes)
        # operand shapes: inside the parens after the op name
        paren = line[m.end():]
        operand_bytes = _shape_bytes(paren.split("),")[0] if ")," in paren else paren)
        if operand_bytes == 0:
            # operands printed as bare names (common): fall back to result
            operand_bytes = result_bytes
        if kind == "all-gather":
            out[kind] += result_bytes
        elif kind == "all-reduce":
            out[kind] += 2 * operand_bytes
        else:
            out[kind] += operand_bytes
        counts[kind] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_ratio: float
    collectives: dict

    def row(self):
        out = {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "useful_ratio": self.useful_ratio,
        }
        if hasattr(self, "xla_raw"):
            out["xla_raw"] = self.xla_raw
        return out


def roofline_terms(cost, hlo_text, chips, model_flops_global,
                   peak_flops=197e12, hbm_bw=819e9, link_bw=50e9) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    # cost_analysis reports "bytes accessed" (HBM traffic proxy).
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    cbytes = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    compute_s = flops / peak_flops
    memory_s = bytes_acc / hbm_bw
    collective_s = cbytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = flops * chips
    useful = model_flops_global / hlo_flops_global if hlo_flops_global > 0 else 0.0
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=cbytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        collectives=coll,
    )


def analyze_compiled(compiled, chips, model_flops_global, **kw) -> Roofline:
    """Primary path: loop-aware HLO parse (see hlo_parse.py) — XLA's
    cost_analysis() counts while bodies once, which under-reports any
    scan-over-layers program by ~num_layers x. The raw cost_analysis values
    are attached for reference as ``xla_raw``."""
    from repro.roofline.hlo_parse import analyze_hlo

    text = compiled.as_text()
    totals = analyze_hlo(text)
    peak_flops = kw.get("peak_flops", 197e12)
    hbm_bw = kw.get("hbm_bw", 819e9)
    link_bw = kw.get("link_bw", 50e9)
    cbytes = float(sum(totals.coll.values()))
    compute_s = totals.flops / peak_flops
    memory_s = totals.bytes / hbm_bw
    collective_s = cbytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = totals.flops * chips
    useful = model_flops_global / hlo_flops_global if hlo_flops_global > 0 else 0.0
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    roof = Roofline(
        flops_per_device=totals.flops,
        bytes_per_device=totals.bytes,
        collective_bytes_per_device=cbytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        collectives={**{k: float(v) for k, v in totals.coll.items()},
                     "_counts": {k: int(v) for k, v in totals.coll_counts.items()}},
    )
    roof.xla_raw = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes accessed": float(cost.get("bytes accessed", 0.0)),
    }
    return roof
