"""Loop-aware cost extraction from optimized (SPMD-partitioned) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` visits a ``while`` body
ONCE, so any scan-over-layers program under-reports FLOPs/bytes/collectives
by ~num_layers x. The HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on every while op, so we
can recover exact loop-weighted totals:

* FLOPs: every ``dot`` contributes 2 * prod(result_dims) * prod(contracting
  dims of the lhs) — the MAC-x2 convention, matching the roofline peak.
* bytes: every top-level op (fusions count their operands + results, their
  internals stay on-chip) contributes operand+result bytes — an HBM-traffic
  model equivalent to HloCostAnalysis's "bytes accessed".
* collectives: by kind, using per-op formulas (all-gather: result bytes;
  all-reduce: 2x operand; reduce-scatter / all-to-all / collective-permute:
  operand bytes). ``-start``/``-done`` async pairs are counted once.

Weighting: while bodies x trip_count; fusion/call bodies x1; conditionals
take the max over branches (an approximation for interleaved-block archs,
noted in EXPERIMENTS.md); reduce/sort ``to_apply`` scalar lambdas are
ignored.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+|[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*?)\)(.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP = re.compile(r"known_trip_count\D+(\d+)")

COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"}
NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                "while", "conditional", "call", "after-all", "add-dependency",
                "partition-id", "replica-id", "iota", "reshape", "fusion"}
# fusion bytes are counted from its own operands/result below (special case).


def _shape_list(type_str):
    """[(dtype, [dims...]), ...] for a (possibly tuple) HLO type string."""
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dt, dl))
    return out


def _bytes_of(type_str):
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other, mult=1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] += mult * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += mult * v


def parse_computations(text):
    comps = {}
    cur_name, cur_ops = None, []
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m:
            cur_name = m.group(1).lstrip("%")
            cur_ops = []
            comps[cur_name] = cur_ops
            continue
        if cur_name is None:
            continue
        if line.strip() == "}":
            cur_name = None
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, type_str, opcode, operand_str, attrs = om.groups()
        operands = re.findall(r"%[\w.\-]+", operand_str)
        cur_ops.append(Op(name.lstrip("%"), type_str, opcode, [o.lstrip("%") for o in operands], attrs))
    return comps


def _called(attrs, key):
    m = re.search(key + r"=(%[\w.\-]+)", attrs)
    return m.group(1).lstrip("%") if m else None


def _branches(attrs):
    m = re.search(r"branch_computations=\{([^}]*)\}", attrs)
    if m:
        return [b.strip().lstrip("%") for b in m.group(1).split(",")]
    out = []
    for key in ("true_computation", "false_computation"):
        c = _called(attrs, key)
        if c:
            out.append(c)
    return out


def _dot_flops(op, symtab):
    result = _shape_list(op.type_str)
    if not result:
        return 0.0
    rnum = 1
    for d in result[0][1]:
        rnum *= d
    lhs_t = symtab.get(op.operands[0]) if op.operands else None
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.attrs)
    contract = 1
    if lhs_t and m and m.group(1).strip():
        lhs_shapes = _shape_list(lhs_t)
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * rnum * contract


def analyze_hlo(text, entry=None):
    comps = parse_computations(text)
    memo = {}

    def comp_totals(name):
        if name in memo:
            return memo[name]
        t = Totals()
        memo[name] = t  # guard cycles (none expected)
        ops = comps.get(name, [])
        symtab = {op.name: op.type_str for op in ops}
        for op in ops:
            oc = op.opcode
            if oc == "dot":
                t.flops += _dot_flops(op, symtab)
                t.bytes += _bytes_of(op.type_str)
                t.bytes += sum(_bytes_of(symtab.get(o, "")) for o in op.operands)
            elif oc == "fusion":
                sub = _called(op.attrs, "calls")
                if sub:
                    st = comp_totals(sub)
                    t.flops += st.flops  # dots inside the fusion
                # HBM traffic: fusion boundary only
                t.bytes += _bytes_of(op.type_str)
                t.bytes += sum(_bytes_of(symtab.get(o, "")) for o in op.operands)
            elif oc == "while":
                body = _called(op.attrs, "body")
                cond = _called(op.attrs, "condition")
                trip = 1
                tm = _TRIP.search(op.attrs)
                if tm:
                    trip = int(tm.group(1))
                if body:
                    t.add(comp_totals(body), trip)
                if cond:
                    t.add(comp_totals(cond), trip)
            elif oc == "conditional":
                brs = _branches(op.attrs)
                if brs:
                    sub_totals = [comp_totals(b) for b in brs]
                    best = max(sub_totals, key=lambda s: (s.flops, s.bytes))
                    t.add(best, 1.0)
            elif oc == "call":
                sub = _called(op.attrs, "to_apply")
                if sub:
                    t.add(comp_totals(sub), 1.0)
            else:
                base = oc.replace("-start", "")
                if base in COLLECTIVES:
                    if oc.endswith("-done"):
                        continue
                    opnd = sum(_bytes_of(symtab.get(o, "")) for o in op.operands)
                    res = _bytes_of(op.type_str)
                    if base == "all-gather":
                        val = res
                    elif base == "all-reduce":
                        val = 2 * (opnd or res)
                    else:
                        val = opnd or res
                    t.coll[base] += val
                    t.coll_counts[base] += 1
                    t.bytes += res + opnd
                elif oc not in NO_BYTES_OPS:
                    t.bytes += _bytes_of(op.type_str)
                    t.bytes += sum(_bytes_of(symtab.get(o, "")) for o in op.operands)
        return t

    if entry is None:
        # the ENTRY computation is the one a) named like main or b) not
        # referenced by any other computation; find via header text.
        m = re.search(r"^ENTRY\s+(%?[\w.\-]+)", text, re.M)
        entry = m.group(1).lstrip("%") if m else next(iter(comps))
    return comp_totals(entry)
