"""Roofline placement of one engine super-tick (PR-6 deliverable).

Lowers an already-built :class:`repro.sim.AsyncEngine` /
:class:`repro.sim.ShardedAsyncEngine` chunk — the exact jitted program
``advance`` runs, fused kernel and compressed halo exchange included —
and pushes the compiled HLO through :func:`repro.roofline.analyze_compiled`
to place the super-tick against the three-term bandwidth roofline:

    bound_s = max(compute_s, memory_s, collective_s) / steps

The ``gap`` row is measured wall-clock per super-tick divided by that
bound: gap ~ 1 means the super-tick runs at the roofline; the remainder
is launch overhead, pipeline bubbles, and unmodelled scalar work. The
MODEL_FLOPs numerator is the *useful* Eq. 4 arithmetic for the expected
wakes per slot (residual + gradient + neighbour mix + axpy), so
``useful_ratio`` exposes padding waste from the static woken-row batch.

Peak numbers default to one TPU v5e-class chip (197 TF/s, 819 GB/s HBM,
50 GB/s link); pass ``peak_flops``/``hbm_bw``/``link_bw`` to re-place the
same program on other hardware. On a CPU host the placement is still the
TPU roofline — the HLO is the same program, only the peaks are nominal.
"""

from __future__ import annotations

import numpy as np

from repro.roofline.analysis import Roofline, analyze_compiled


def model_flops_per_supertick(engine) -> float:
    """Useful Eq. 4 FLOPs for the expected wakes of one super-tick.

    Per woken agent: ``2*m*p`` residual matvec + ``2*m*p`` gradient
    reduction + ``2*deg*p`` neighbour mix + ``~8*p`` axpy/regulariser.
    Rate-weighted over agents (an agent's wake probability scales its
    own degree/data contribution), so heterogeneous-rate configs are
    counted correctly.
    """
    probs = np.asarray(engine.wake_probs, dtype=np.float64)
    p = float(engine.p)
    deg = np.zeros_like(probs)
    graph = getattr(engine.update, "graph", None)
    if graph is not None:
        from repro.core.graph import neighbor_counts

        deg = np.asarray(neighbor_counts(graph), dtype=np.float64)
    m = 0.0
    obj = getattr(engine.update, "obj", None)
    data = getattr(obj, "data", None)
    if data is not None and getattr(data, "X", None) is not None:
        m = float(np.asarray(data.X).shape[1])
    per_wake = 4.0 * m * p + 2.0 * deg * p + 8.0 * p
    return float(np.sum(probs * per_wake))


def supertick_roofline(engine, state=None, steps: int = 8, **roofline_kw) -> Roofline:
    """Compile ``steps`` super-ticks of ``engine`` and analyse the HLO.

    ``state`` defaults to a fresh zero-model ``init_state``; pass a real
    one to analyse mid-run (the program is shape-identical either way).
    Works for both engines: the sharded chunk is lowered with its static
    shard tiles, so the halo collective-permutes / all-gathers land in
    the collective term at their wire dtype (f32/bf16/int8 payloads).
    """
    if state is None:
        state = engine.init_state(np.zeros((engine.n, engine.p)))
    steps = int(steps)
    if hasattr(engine, "_static"):  # ShardedAsyncEngine
        compiled = engine._chunk.lower(state, engine._static, steps).compile()
        chips = int(engine.num_shards)
    else:
        compiled = engine._chunk.lower(state, steps).compile()
        chips = 1
    model_flops = model_flops_per_supertick(engine) * steps
    roof = analyze_compiled(compiled, chips, model_flops, **roofline_kw)
    roof.steps = steps
    return roof


def supertick_report(
    engine,
    state=None,
    steps: int = 8,
    measured_s_per_tick: float | None = None,
    prefix: str = "roofline_supertick",
    **roofline_kw,
) -> list:
    """CSV-style ``(name, value, note)`` rows for the bench summary.

    Always emits the per-super-tick roofline bound (us) with the
    dominant term; with a measured wall-clock time per super-tick it
    also emits the ``gap`` row (measured / bound — the "remaining gap"
    between the simulator and the bandwidth roofline).
    """
    roof = supertick_roofline(engine, state=state, steps=steps, **roofline_kw)
    bound_s = max(roof.compute_s, roof.memory_s, roof.collective_s) / max(steps, 1)
    note = (
        f"dominant={roof.dominant} compute={roof.compute_s / steps * 1e6:.3g}us "
        f"memory={roof.memory_s / steps * 1e6:.3g}us "
        f"collective={roof.collective_s / steps * 1e6:.3g}us "
        f"useful_ratio={roof.useful_ratio:.3g} us/slot"
    )
    rows = [(f"{prefix}_bound", bound_s * 1e6, note)]
    if measured_s_per_tick is not None and bound_s > 0:
        gap = measured_s_per_tick / bound_s
        rows.append(
            (
                f"{prefix}_gap",
                gap,
                f"measured {measured_s_per_tick * 1e6:.4g}us / bound "
                f"{bound_s * 1e6:.4g}us ({roof.dominant}-bound); gap = launch "
                "overhead + bubbles + unmodelled scalar work",
            )
        )
    return rows
