from repro.roofline.analysis import analyze_compiled, collective_bytes, roofline_terms

__all__ = ["analyze_compiled", "collective_bytes", "roofline_terms"]
