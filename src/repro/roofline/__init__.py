from repro.roofline.analysis import analyze_compiled, collective_bytes, roofline_terms
from repro.roofline.supertick import (
    model_flops_per_supertick,
    supertick_report,
    supertick_roofline,
)

__all__ = [
    "analyze_compiled",
    "collective_bytes",
    "model_flops_per_supertick",
    "roofline_terms",
    "supertick_report",
    "supertick_roofline",
]
