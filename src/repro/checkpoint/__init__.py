"""Crash-safe checkpointing: bare pytrees and full engine resume closures."""

from repro.checkpoint.checkpoint import (
    CheckpointError,
    load_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.engine_io import (
    engine_fingerprint,
    restore,
    save_engine_checkpoint,
)

__all__ = [
    "CheckpointError",
    "save_checkpoint",
    "load_checkpoint",
    "save_engine_checkpoint",
    "restore",
    "engine_fingerprint",
]
