"""Dependency-free sharded checkpointing: npz shards + JSON manifest.

Layout:
    <dir>/manifest.json   — pytree structure, leaf dtypes/shapes, step, extra
    <dir>/shard_<k>.npz   — flat leaves, chunked so no single file exceeds
                            ``max_shard_bytes``

Works for any pytree of arrays (params, P2P agent-stacked params, optimizer
state). Loading restores exact dtypes (bf16 round-trips via uint16 views).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _to_numpy(x):
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def save_checkpoint(path, tree, step=0, extra=None, max_shard_bytes=1 << 30):
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {
        "step": int(step),
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if False else None,  # structure stored via flatten paths below
        "paths": [],
        "extra": extra or {},
        "shards": [],
    }
    # store key paths for structure-checked reload
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    manifest["paths"] = paths

    shard, shard_bytes, shard_idx = {}, 0, 0
    for i, leaf in enumerate(leaves):
        arr, dt = _to_numpy(leaf)
        shard[f"leaf_{i}"] = arr
        manifest.setdefault("dtypes", {})[f"leaf_{i}"] = dt
        shard_bytes += arr.nbytes
        if shard_bytes >= max_shard_bytes:
            np.savez(os.path.join(path, f"shard_{shard_idx}.npz"), **shard)
            manifest["shards"].append({"file": f"shard_{shard_idx}.npz", "keys": list(shard)})
            shard, shard_bytes, shard_idx = {}, 0, shard_idx + 1
    if shard:
        np.savez(os.path.join(path, f"shard_{shard_idx}.npz"), **shard)
        manifest["shards"].append({"file": f"shard_{shard_idx}.npz", "keys": list(shard)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path, like):
    """Restore into the structure of ``like`` (a pytree of arrays/structs)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = {}
    for sh in manifest["shards"]:
        with np.load(os.path.join(path, sh["file"])) as z:
            for k in sh["keys"]:
                data[k] = z[k]
    leaves_like, treedef = jax.tree.flatten(like)
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        if manifest.get("dtypes", {}).get(f"leaf_{i}") == _BF16:
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), manifest["step"], manifest.get("extra", {})
