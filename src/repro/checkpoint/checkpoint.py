"""Crash-safe, shard-friendly pytree checkpointing.

A checkpoint is a directory: numbered ``shard_*.npz`` array files plus a
``manifest.json`` carrying the step, user extras, a structure digest
(leaf paths + dtypes + shapes) verified against the ``like`` tree on
load, and a sha256 per file. Writes stage into ``<dir>.tmp`` (every file
fsynced, the manifest written last) and atomically rename into place — a
writer killed mid-save can never leave a directory that loads. With
``keep_last=K`` the target path is a *rotation root* holding
``ckpt-<step>`` entries; loading a root falls back to the newest entry
that verifies, so a torn newest write recovers the previous one.

bf16 arrays round-trip through a uint16 view (npz has no bfloat16).
:mod:`repro.checkpoint.engine_io` builds the engine-aware layer (full
``AsyncEngine``/``ShardedAsyncEngine`` resume closures, per-shard files,
shard-count-elastic restore) on the same entry primitives.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"
_MANIFEST = "manifest.json"
_FORMAT = 2


class CheckpointError(ValueError):
    """A checkpoint directory is torn, corrupted, or structurally wrong."""


# ---------------------------------------------------------------------------
# Leaf <-> numpy codecs
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    """Flatten a jax key-path into a stable ``a/b/0`` string."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _flatten_with_paths(tree):
    """``(path_str, leaf)`` pairs plus the treedef, in canonical order."""
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), leaf) for p, leaf in leaves_p], treedef


def _to_numpy(x):
    """Host array + recorded dtype name (bf16 ships as a uint16 view)."""
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def _from_numpy(arr, dtype: str):
    """Invert :func:`_to_numpy` (restores the bf16 view)."""
    if dtype == _BF16:
        return arr.view(jnp.bfloat16)
    return arr


def _leaf_dtype_name(leaf) -> str:
    """Recorded dtype name of a template leaf (``'bfloat16'`` for bf16)."""
    dt = getattr(leaf, "dtype", None)
    if dt is None:
        dt = np.asarray(leaf).dtype
    return str(dt)


def structure_digest(records) -> str:
    """sha256 over ``(path, dtype, shape)`` triples — the tree's identity."""
    h = hashlib.sha256()
    for path, dtype, shape in records:
        h.update(f"{path}|{dtype}|{tuple(shape)}\n".encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Crash-safe entry I/O (shared with engine_io)
# ---------------------------------------------------------------------------


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_entry(entry: str, files: dict, manifest: dict) -> str:
    """Crash-safely materialize ``entry/`` from ``{filename: {key: array}}``.

    Everything stages under ``<entry>.tmp`` — each npz fsynced, its
    sha256 recorded, the manifest written (and fsynced) last — then one
    atomic rename publishes the directory. A crash at any earlier point
    leaves only a ``.tmp`` directory, which no loader ever counts.
    """
    tmp = entry + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    hashes = {}
    for name, arrays in files.items():
        fp = os.path.join(tmp, name)
        np.savez(fp, **arrays)
        with open(fp, "rb+") as f:
            os.fsync(f.fileno())
        hashes[name] = _sha256_file(fp)
    manifest = dict(manifest, format=_FORMAT, file_sha256=hashes)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(entry):
        old = entry + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(entry, old)
        os.rename(tmp, entry)
        shutil.rmtree(old)
    else:
        os.rename(tmp, entry)
    _fsync_dir(os.path.dirname(os.path.abspath(entry)))
    return entry


def _save_entry(path: str, files: dict, manifest: dict, step: int, keep_last):
    """Write one entry at ``path`` (or into its ``keep_last`` rotation)."""
    if keep_last is not None:
        keep = int(keep_last)
        if keep < 1:
            raise ValueError("keep_last must be >= 1")
        os.makedirs(path, exist_ok=True)
        entry = _write_entry(
            os.path.join(path, f"ckpt-{int(step):012d}"), files, manifest
        )
        _prune_rotation(path, keep)
        return entry
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    return _write_entry(path, files, manifest)


def _read_manifest(entry: str) -> dict:
    mp = os.path.join(entry, _MANIFEST)
    if not os.path.isfile(mp):
        raise CheckpointError(
            f"{entry}: no {_MANIFEST} (torn write or foreign directory)"
        )
    try:
        with open(mp) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"{entry}: unreadable manifest: {e}") from e


def _verify_entry(entry: str) -> dict:
    """Manifest + per-file sha256 check; CheckpointError on a torn entry."""
    manifest = _read_manifest(entry)
    for name, want in manifest.get("file_sha256", {}).items():
        fp = os.path.join(entry, name)
        if not os.path.isfile(fp):
            raise CheckpointError(f"{entry}: missing file {name} (torn write)")
        got = _sha256_file(fp)
        if got != want:
            raise CheckpointError(
                f"{entry}: {name} sha256 mismatch (torn or corrupted write): "
                f"{got[:12]} != {want[:12]}"
            )
    return manifest


def _rotation_entries(root: str) -> list[str]:
    """``ckpt-*`` entries under ``root``, newest step first.

    ``*.tmp`` / ``*.old`` staging leftovers are never candidates.
    """
    names = [
        name
        for name in os.listdir(root)
        if name.startswith("ckpt-")
        and not name.endswith((".tmp", ".old"))
        and os.path.isdir(os.path.join(root, name))
    ]

    def step_of(name: str) -> int:
        try:
            return int(name.split("-", 1)[1])
        except ValueError:
            return -1

    return [os.path.join(root, n) for n in sorted(names, key=step_of, reverse=True)]


def _prune_rotation(root: str, keep_last: int) -> None:
    for entry in _rotation_entries(root)[keep_last:]:
        shutil.rmtree(entry)


def _resolve_entry(path: str):
    """Map ``path`` (one entry, or a rotation root) to a verified entry.

    Returns ``(entry, manifest)``. A rotation root falls back across its
    entries newest-first; FileNotFoundError when nothing was ever
    written, CheckpointError when entries exist but none verifies.
    """
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint at {path}")
    if os.path.isfile(os.path.join(path, _MANIFEST)):
        return path, _verify_entry(path)
    entries = _rotation_entries(path)
    if not entries:
        raise FileNotFoundError(f"no checkpoint entries under {path}")
    errors = []
    for entry in entries:
        try:
            return entry, _verify_entry(entry)
        except CheckpointError as e:
            errors.append(str(e))
    raise CheckpointError(
        f"{path}: no valid checkpoint among {len(entries)} entries:\n"
        + "\n".join(errors)
    )


def _load_arrays(entry: str, manifest: dict) -> dict:
    """All arrays of a verified entry, keyed as saved."""
    data: dict = {}
    for name in manifest.get("file_sha256", {}):
        if not name.endswith(".npz"):
            continue
        with np.load(os.path.join(entry, name)) as z:
            for k in z.files:
                data[k] = z[k]
    return data


# ---------------------------------------------------------------------------
# Pytree checkpoint API
# ---------------------------------------------------------------------------


def save_checkpoint(path, tree, step=0, extra=None, max_shard_bytes=1 << 30,
                    keep_last=None):
    """Write ``tree`` (any pytree of arrays) as one crash-safe checkpoint.

    Leaves are grouped into ``shard_*.npz`` files of at most
    ``max_shard_bytes`` each (a single larger leaf gets its own file);
    the manifest records ``step``, the JSON-serializable ``extra``, every
    leaf's path/dtype/shape plus a structure digest, and per-file sha256.
    With ``keep_last=K``, ``path`` is a rotation root and the entry lands
    at ``path/ckpt-<step>`` with only the newest K entries retained.
    Returns the entry directory actually written.
    """
    flat, _ = _flatten_with_paths(tree)
    leaves = []
    files: dict[str, dict[str, np.ndarray]] = {}
    shard: dict[str, np.ndarray] = {}
    shard_bytes = 0

    def flush():
        nonlocal shard, shard_bytes
        if shard:
            files[f"shard_{len(files)}.npz"] = shard
            shard, shard_bytes = {}, 0

    for i, (pth, leaf) in enumerate(flat):
        arr, dt = _to_numpy(leaf)
        key = f"leaf_{i}"
        shard[key] = arr
        shard_bytes += arr.nbytes
        leaves.append(
            {"key": key, "path": pth, "dtype": dt, "shape": list(arr.shape)}
        )
        if shard_bytes >= max_shard_bytes:
            flush()
    flush()
    manifest = {
        "kind": "pytree",
        "step": int(step),
        "extra": extra or {},
        "leaves": leaves,
        "structure": structure_digest(
            (r["path"], r["dtype"], r["shape"]) for r in leaves
        ),
    }
    return _save_entry(path, files, manifest, step, keep_last)


def _check_structure(entry: str, records, like_flat) -> None:
    """Compare the manifest leaf records against the ``like`` tree.

    Raises a CheckpointError naming the first mismatch (leaf set, dtype,
    or shape) — the readable form of the structure-digest check.
    """
    saved_paths = [r["path"] for r in records]
    like_paths = [p for p, _ in like_flat]
    if saved_paths != like_paths:
        missing = [p for p in saved_paths if p not in like_paths]
        added = [p for p in like_paths if p not in saved_paths]
        raise CheckpointError(
            f"{entry}: tree structure mismatch — checkpoint has "
            f"{len(saved_paths)} leaves, `like` has {len(like_paths)}"
            + (f"; only in checkpoint: {missing[:4]}" if missing else "")
            + (f"; only in `like`: {added[:4]}" if added else "")
        )
    for rec, (pth, ref) in zip(records, like_flat):
        want_dtype = _leaf_dtype_name(ref)
        if rec["dtype"] != want_dtype:
            raise CheckpointError(
                f"{entry}: leaf {pth!r}: checkpoint dtype {rec['dtype']} != "
                f"{want_dtype}"
            )
        want_shape = tuple(np.shape(ref))
        if tuple(rec["shape"]) != want_shape:
            raise CheckpointError(
                f"{entry}: leaf {pth!r}: checkpoint shape "
                f"{tuple(rec['shape'])} != {want_shape}"
            )


def load_checkpoint(path, like):
    """Load a checkpoint written by :func:`save_checkpoint`.

    ``path`` may be one entry or a ``keep_last`` rotation root (newest
    valid entry wins; torn entries are skipped). ``like`` is a pytree
    with the expected structure/dtypes/shapes — any mismatch raises
    :class:`CheckpointError` (a ``ValueError``) naming the offending
    leaf. Returns ``(tree, step, extra)``.
    """
    entry, manifest = _resolve_entry(path)
    if manifest.get("kind") != "pytree":
        raise CheckpointError(
            f"{entry}: not a pytree checkpoint (kind={manifest.get('kind')!r}); "
            "engine checkpoints load via repro.checkpoint.restore(engine, path)"
        )
    like_flat, treedef = _flatten_with_paths(like)
    records = manifest["leaves"]
    like_digest = structure_digest(
        (p, _leaf_dtype_name(ref), list(np.shape(ref))) for p, ref in like_flat
    )
    if manifest.get("structure") != like_digest:
        _check_structure(entry, records, like_flat)
        raise CheckpointError(f"{entry}: structure digest mismatch")
    data = _load_arrays(entry, manifest)
    out = [jnp.asarray(_from_numpy(data[r["key"]], r["dtype"])) for r in records]
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        manifest["step"],
        manifest.get("extra", {}),
    )
