"""Engine-aware checkpointing: full resume closures for both engines.

Built on the crash-safe entry primitives of
:mod:`repro.checkpoint.checkpoint` (staged ``<entry>.tmp`` writes,
per-file sha256, atomic rename, ``keep_last`` rotation), this module
captures everything a killed run needs to resume exactly:

* **AsyncEngine** — one ``state.npz`` holding every :class:`SimState`
  leaf (Theta, delay-ring ``hist``, slot counter, churn mask, PRNG key,
  the update state — including the DP accountant's spend counts — and
  the in-jit metrics counters), plus ``topology.npz`` for dynamic runs
  (the live CSR graph, slot capacity, topology version, pending-arrival
  ids) and the host topology log.
* **ShardedAsyncEngine** — a **per-shard layout with no gather**: one
  ``shard_<s>.npz`` per shard carrying that shard's owned rows (Theta
  block, churn mask, per-agent update-state leaves, ``last_wake``)
  keyed by relabel-stable *original agent ids*, plus ``partition.npz``
  (the frozen ownership: order permutation, block bounds, tile width)
  and ``scalars.npz`` (per-shard PRNG keys, counters, the CHOCO ``ef``
  accumulator, counter-type metrics leaves). Theta never materializes
  as one (n, p) host array at save *or* load.

Restore validates a **manifest fingerprint** — graph sha256, n, p,
dtype, an :class:`repro.sim.EngineConfig` digest, topology version —
before touching engine state, and supports **shard-count-elastic**
resume: a checkpoint written at S shards restores into an engine at S'
shards by re-cutting via ``partition_graph`` and re-tiling the saved
per-shard rows through :meth:`GraphPartition.place_rows`. Same-S resume
is bit-exact; the elastic policies (per-shard keys re-derived, shard
counters collapsed into shard 0, ``ef`` re-initialized) are recorded in
``docs/DEVIATIONS.md``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (
    CheckpointError,
    _flatten_with_paths,
    _from_numpy,
    _leaf_dtype_name,
    _load_arrays,
    _resolve_entry,
    _save_entry,
    _to_numpy,
    structure_digest,
)
from repro.core.graph import CSRGraph, TopologyState, as_csr
from repro.sim.partition import partition_from_ownership, partition_graph

_EXCLUDED_CONFIG_FIELDS = ("partition", "devices")


# ---------------------------------------------------------------------------
# Fingerprint
# ---------------------------------------------------------------------------


def _token(v) -> str:
    """Deterministic string form of a config field value (digest input)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return repr(v)
    if isinstance(v, (np.ndarray, jnp.ndarray)):
        a = np.ascontiguousarray(np.asarray(v))
        return f"array:{a.dtype}:{a.shape}:{hashlib.sha256(a.tobytes()).hexdigest()[:16]}"
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        inner = ",".join(
            f"{f.name}={_token(getattr(v, f.name))}" for f in dataclasses.fields(v)
        )
        return f"{type(v).__name__}({inner})"
    if isinstance(v, dict):
        return "{" + ",".join(f"{k!r}:{_token(v[k])}" for k in sorted(v)) + "}"
    if isinstance(v, (list, tuple, set, frozenset)):
        items = sorted(v, key=repr) if isinstance(v, (set, frozenset)) else v
        return "[" + ",".join(_token(x) for x in items) + "]"
    try:
        return f"dtype:{jnp.dtype(v).name}"
    except TypeError:
        pass
    r = repr(v)
    # Default object reprs embed a memory address — useless as identity.
    return type(v).__name__ if " at 0x" in r else r


def config_digest(cfg) -> str:
    """sha256 identity of an :class:`EngineConfig`, placement fields
    (``partition``/``devices``) excluded — those pick *where* the run
    executes, not *what* it computes, and must not block a resume on a
    different device set."""
    parts = [
        f"{f.name}={_token(getattr(cfg, f.name))}"
        for f in dataclasses.fields(cfg)
        if f.name not in _EXCLUDED_CONFIG_FIELDS
    ]
    return hashlib.sha256("|".join(parts).encode()).hexdigest()


def _live_csr(engine) -> CSRGraph:
    """The engine's current collaboration graph (live CSR when dynamic)."""
    if getattr(engine, "_csr", None) is not None:
        return engine._csr
    return as_csr(engine.update.graph)


def engine_fingerprint(engine) -> dict:
    """The identity a checkpoint must match to restore into ``engine``."""
    is_sharded = hasattr(engine, "part")
    fp = {
        "engine": "sharded" if is_sharded else "async",
        "n": int(engine.n),
        "p": int(engine.p),
        "dtype": str(jnp.dtype(engine.dtype).name),
        "config": config_digest(engine.config),
        "metrics": engine._macc is not None,
        "dynamic": bool(engine.dynamic),
        "graph": _live_csr(engine).digest(),
        "topology_version": (
            int(np.asarray(engine.topo.version))
            if getattr(engine, "topo", None) is not None
            else 0
        ),
    }
    if is_sharded:
        fp["num_shards"] = int(engine.num_shards)
    return fp


def _check_fingerprint(entry: str, saved: dict, now: dict) -> None:
    """Reject a checkpoint/engine identity mismatch with a clear error.

    ``num_shards`` may differ (elastic restore) and ``graph`` /
    ``topology_version`` are authoritative *from the checkpoint* on
    dynamic runs (restore adopts the saved topology), so only static
    engines compare graphs.
    """
    strict = ["engine", "n", "p", "dtype", "config", "metrics", "dynamic"]
    if not saved.get("dynamic"):
        strict.append("graph")
    for key in strict:
        if saved.get(key) != now.get(key):
            raise CheckpointError(
                f"{entry}: fingerprint mismatch on {key!r}: checkpoint has "
                f"{saved.get(key)!r}, engine has {now.get(key)!r}"
            )


# ---------------------------------------------------------------------------
# Topology capture (shared)
# ---------------------------------------------------------------------------


def _topology_arrays(engine) -> dict:
    csr = engine._csr
    arrs = {
        "indptr": np.asarray(csr.indptr, np.int64),
        "indices": np.asarray(csr.indices, np.int32),
        "data": np.asarray(csr.data, np.float64),
        "pending": np.asarray(sorted(engine._pending), np.int64),
    }
    if getattr(engine, "topo", None) is not None:
        arrs["capacity"] = np.int64(engine.topo.capacity)
        arrs["version"] = np.int64(np.asarray(engine.topo.version))
    return arrs


def _topology_from_arrays(arrs) -> tuple[CSRGraph, set[int]]:
    csr = CSRGraph(
        indptr=np.asarray(arrs["indptr"], np.int64),
        indices=np.asarray(arrs["indices"], np.int32),
        data=np.asarray(arrs["data"], np.float64),
    )
    return csr, {int(i) for i in arrs["pending"]}


def _restore_topology_log(engine, manifest: dict) -> None:
    for k, v in manifest.get("topology_log", {}).items():
        engine.topology_log[k] = float(v) if k == "last_drift" else int(v)


# ---------------------------------------------------------------------------
# AsyncEngine closure
# ---------------------------------------------------------------------------


def _async_state_dict(engine, state, step: int):
    flat, _ = _flatten_with_paths(state)
    arrays = {}
    records = []
    for i, (pth, leaf) in enumerate(flat):
        arr, dt = _to_numpy(leaf)
        key = f"leaf_{i}"
        arrays[key] = arr
        records.append(
            {"key": key, "path": pth, "dtype": dt, "shape": list(arr.shape)}
        )
    files = {"state.npz": arrays}
    manifest = {
        "kind": "engine",
        "engine": "async",
        "step": int(step),
        "fingerprint": engine_fingerprint(engine),
        "leaves": records,
        "structure": structure_digest(
            (r["path"], r["dtype"], r["shape"]) for r in records
        ),
    }
    if engine.dynamic:
        files["topology.npz"] = _topology_arrays(engine)
        manifest["topology_log"] = dict(engine.topology_log)
    return files, manifest


def _restore_async(engine, entry: str, manifest: dict):
    fp = manifest["fingerprint"]
    _check_fingerprint(entry, fp, engine_fingerprint(engine))
    data = _load_arrays(entry, manifest)
    if fp.get("dynamic"):
        csr, pending = _topology_from_arrays(data)
        engine._pending = pending
        engine.topo = TopologyState.from_csr(
            csr,
            capacity=int(data["capacity"]),
            version=int(data["version"]),
        )
        engine._csr = csr
        engine._dyn = engine._dyn_tiles()
        _restore_topology_log(engine, manifest)
    like = engine.init_state(np.zeros((engine.n, engine.p)))
    like_flat, treedef = _flatten_with_paths(like)
    records = manifest["leaves"]
    saved_digest = manifest.get("structure")
    like_digest = structure_digest(
        (p, _leaf_dtype_name(ref), list(np.shape(ref))) for p, ref in like_flat
    )
    if saved_digest != like_digest:
        from repro.checkpoint.checkpoint import _check_structure

        _check_structure(entry, records, like_flat)
        raise CheckpointError(f"{entry}: engine state structure digest mismatch")
    leaves = [
        jnp.asarray(_from_numpy(data[r["key"]], r["dtype"])) for r in records
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves), int(manifest["step"])


# ---------------------------------------------------------------------------
# ShardedAsyncEngine closure (per-shard layout, no gather)
# ---------------------------------------------------------------------------


def _sharded_state_dict(engine, state, step: int):
    part, S = engine.part, engine.num_shards
    files: dict = {}
    bf16: list[str] = []

    def put(fname, arrs, key, value):
        arr, dt = _to_numpy(value)
        arrs[key] = arr
        if dt == "bfloat16":
            bf16.append(f"{fname}/{key}")

    files["partition.npz"] = {
        "order": np.asarray(part.order, np.int64),
        "bounds": np.asarray(part.bounds, np.int64),
        "sizes": np.asarray(part.sizes, np.int64),
        "tile_width": np.int64(part.tile_width),
        "batch_size": np.int64(engine.batch_size),
    }

    ustate_flat, _ = _flatten_with_paths(state.ustate)
    ustate_records = [
        {
            "path": pth,
            "dtype": _leaf_dtype_name(leaf),
            "shape_tail": list(np.shape(leaf)[2:]),
        }
        for pth, leaf in ustate_flat
    ]
    metrics = state.metrics if engine._macc is not None else None
    counter_keys = (
        []
        if metrics is None
        else [
            k for k, kind in engine._macc.leaf_kinds().items() if kind == "counter"
        ]
    )
    has_last_wake = metrics is not None and "last_wake" in metrics

    # One file per shard, owned rows only, keyed by original agent ids —
    # each block is pulled as its own (R, ...) tile; the (n, p) model
    # matrix is never assembled on the host.
    for s in range(S):
        size = int(part.sizes[s])
        fname = f"shard_{s}.npz"
        arrs: dict = {"ids": np.asarray(part.owned[s, :size], np.int64)}
        put(fname, arrs, "theta", state.Theta[s][:size])
        arrs["active"] = np.asarray(state.active[s][:size])
        for j, (_pth, leaf) in enumerate(ustate_flat):
            put(fname, arrs, f"ustate_{j}", leaf[s][:size])
        if has_last_wake:
            arrs["last_wake"] = np.asarray(metrics["last_wake"][s][:size])
        files[fname] = arrs

    sc: dict = {
        "keys": np.asarray(state.keys),
        "applied": np.asarray(state.applied),
        "dropped": np.asarray(state.dropped),
        "messages": np.asarray(state.messages),
        "ptr": np.asarray(state.ptr),
    }
    if state.ef is not None:
        put("scalars.npz", sc, "ef", state.ef)
    for k in counter_keys:
        put("scalars.npz", sc, f"metric_{k}", metrics[k])
    files["scalars.npz"] = sc

    manifest = {
        "kind": "engine",
        "engine": "sharded",
        "step": int(step),
        "fingerprint": engine_fingerprint(engine),
        "bf16": bf16,
        "theta_dtype": _leaf_dtype_name(state.Theta),
        "ustate": ustate_records,
        "metrics_keys": counter_keys,
        "has_last_wake": has_last_wake,
        "partition": {"mode": part.mode, "relabel": part.relabel},
    }
    if engine.dynamic:
        files["topology.npz"] = _topology_arrays(engine)
        manifest["topology_log"] = dict(engine.topology_log)
    return files, manifest


def _adopt_partition(engine, manifest: dict, data: dict):
    """Point the engine at the checkpoint's graph + partition.

    Same-S: the saved ownership (order/bounds/tile width) is rebuilt
    verbatim via :func:`partition_from_ownership` — the only way to
    reproduce a patch-chain partition bit-exactly. Elastic (S differs):
    static engines keep their own fresh cut of the (identical) graph;
    dynamic engines re-cut the *saved* live graph at the engine's S.
    Never routes through ``set_topology`` — its relayout path assembles
    (n, p) host arrays, which the per-shard restore contract forbids.
    """
    fp = manifest["fingerprint"]
    saved_S = int(fp["num_shards"])
    dynamic = bool(fp.get("dynamic"))
    pending_changed = False
    if dynamic:
        csr, pending = _topology_from_arrays(data)
        pending_changed = pending != engine._pending
        engine._pending = pending
        _restore_topology_log(engine, manifest)
    else:
        csr = engine._csr
    meta = manifest.get("partition", {})
    if saved_S == engine.num_shards:
        part = engine.part
        same_cut = (
            np.array_equal(np.asarray(data["order"]), np.asarray(part.order))
            and np.array_equal(np.asarray(data["bounds"]), np.asarray(part.bounds))
            and int(data["tile_width"]) == part.tile_width
        )
        same_graph = csr is engine._csr or csr.digest() == engine._csr.digest()
        engine.batch_size = int(data["batch_size"])
        if same_cut and same_graph and not pending_changed:
            return saved_S  # the engine already sits on the saved cut
        new_part = partition_from_ownership(
            csr,
            data["order"],
            data["bounds"],
            mode=meta.get("mode", engine.config.partition_mode),
            relabel=meta.get("relabel"),
            tile_width=int(data["tile_width"]),
        )
    elif dynamic or pending_changed:
        new_part = partition_graph(
            csr,
            engine.num_shards,
            mode=engine.config.partition_mode,
            relabel=engine.config.relabel,
            coords=engine.config.coords,
        )
    else:
        return saved_S  # elastic static: the engine's own fresh cut serves
    engine._csr = csr
    engine.part = new_part
    engine.smix = engine.smix.rebound(new_part)
    engine.exchange_method = engine.smix.method
    engine.batch_size = int(min(engine.batch_size, new_part.rows_per_shard))
    engine._rebuild_static()
    return saved_S


def _host_zeros(leaf) -> np.ndarray:
    return np.zeros(np.shape(leaf), np.asarray(jnp.zeros((), leaf.dtype)).dtype)


def _load_file(entry: str, name: str) -> dict:
    """One verified npz file of an entry as ``{key: array}``."""
    with np.load(os.path.join(entry, name)) as z:
        return {k: z[k] for k in z.files}


def _restore_sharded(engine, entry: str, manifest: dict):
    fp = manifest["fingerprint"]
    _check_fingerprint(entry, fp, engine_fingerprint(engine))
    pmeta = _load_file(entry, "partition.npz")
    topo = _load_file(entry, "topology.npz") if fp.get("dynamic") else {}
    saved_S = _adopt_partition(engine, manifest, {**pmeta, **topo})
    elastic = saved_S != engine.num_shards
    part, S = engine.part, engine.num_shards
    bf16 = set(manifest.get("bf16", []))

    def from_file(fname, arrs, key):
        return _from_numpy(
            arrs[key], "bfloat16" if f"{fname}/{key}" in bf16 else str(arrs[key].dtype)
        )

    blank = engine._blank_state()
    ustate_flat, ustate_def = _flatten_with_paths(blank.ustate)
    records = manifest.get("ustate", [])
    if len(records) != len(ustate_flat):
        raise CheckpointError(
            f"{entry}: update-state mismatch — checkpoint has {len(records)} "
            f"leaves, engine expects {len(ustate_flat)}"
        )
    for rec, (pth, leaf) in zip(records, ustate_flat):
        if (
            rec["path"] != pth
            or rec["dtype"] != _leaf_dtype_name(leaf)
            or tuple(rec["shape_tail"]) != tuple(np.shape(leaf)[2:])
        ):
            raise CheckpointError(
                f"{entry}: update-state leaf {pth!r} mismatch: checkpoint "
                f"({rec['path']!r}, {rec['dtype']}, {tuple(rec['shape_tail'])}) "
                f"!= engine ({pth!r}, {_leaf_dtype_name(leaf)}, "
                f"{tuple(np.shape(leaf)[2:])})"
            )
    if bool(manifest.get("has_last_wake")) and engine._macc is None:
        raise CheckpointError(f"{entry}: checkpoint carries metrics, engine has none")

    theta_t = _host_zeros(blank.Theta)
    active_t = np.zeros((S, part.rows_per_shard), bool)
    ustate_t = [_host_zeros(leaf) for _pth, leaf in ustate_flat]
    lw_t = (
        _host_zeros(blank.metrics["last_wake"])
        if manifest.get("has_last_wake")
        else None
    )
    # Re-tile each saved shard's owned rows through the live partition's
    # id maps — works unchanged whether the cut moved or S changed, and
    # only one shard file is resident on the host at a time.
    for s in range(saved_S):
        fname = f"shard_{s}.npz"
        z = _load_file(entry, fname)
        ids = z["ids"]
        part.place_rows(theta_t, ids, from_file(fname, z, "theta"))
        part.place_rows(active_t, ids, z["active"])
        for j, t in enumerate(ustate_t):
            part.place_rows(t, ids, from_file(fname, z, f"ustate_{j}"))
        if lw_t is not None:
            part.place_rows(lw_t, ids, z["last_wake"])

    sc = _load_file(entry, "scalars.npz")
    if not elastic:
        keys = jnp.asarray(sc["keys"])
        applied = jnp.asarray(sc["applied"])
        dropped = jnp.asarray(sc["dropped"])
        messages = jnp.asarray(sc["messages"])
        ptr = jnp.asarray(sc["ptr"])
        ef = blank.ef
        if (
            engine._use_ef
            and "ef" in sc
            and np.shape(sc["ef"]) == np.shape(blank.ef)
        ):
            ef = jnp.asarray(from_file("scalars.npz", sc, "ef"))
    else:
        # Elastic policies (recorded in docs/DEVIATIONS.md): per-shard
        # PRNG keys re-derive from the seed for the new S, additive
        # counters collapse into shard 0 (run totals preserved), and the
        # error-feedback accumulator restarts (its rows describe the old
        # cut's border).
        keys = blank.keys
        ptr0 = int(np.asarray(sc["ptr"])[0])
        ptr = jnp.full((S,), ptr0, jnp.int32)
        applied = jnp.zeros(S, jnp.int32).at[0].set(int(sc["applied"].sum()))
        dropped = jnp.zeros(S, jnp.int32).at[0].set(int(sc["dropped"].sum()))
        messages = (
            jnp.zeros(S, jnp.float32).at[0].set(float(sc["messages"].sum()))
        )
        ef = blank.ef

    metrics = blank.metrics
    if engine._macc is not None:
        metrics = dict(metrics)
        if lw_t is not None:
            metrics["last_wake"] = jnp.asarray(lw_t)
        for k in manifest.get("metrics_keys", []):
            if k not in metrics or f"metric_{k}" not in sc:
                continue
            saved = np.asarray(from_file("scalars.npz", sc, f"metric_{k}"))
            tmpl = metrics[k]
            if not elastic:
                if saved.shape == tuple(np.shape(tmpl)):
                    metrics[k] = jnp.asarray(saved)
            elif saved.shape[1:] == tuple(np.shape(tmpl))[1:]:
                total = saved.sum(axis=0)
                metrics[k] = (
                    jnp.zeros_like(tmpl).at[0].set(jnp.asarray(total, tmpl.dtype))
                )

    state = blank._replace(
        Theta=jnp.asarray(theta_t),
        active=jnp.asarray(active_t),
        keys=keys,
        ustate=jax.tree_util.tree_unflatten(
            ustate_def, [jnp.asarray(t) for t in ustate_t]
        ),
        applied=applied,
        dropped=dropped,
        messages=messages,
        ptr=ptr,
        ef=ef,
        metrics=metrics,
    )
    return state, int(manifest["step"])


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def engine_state_dict(engine, state, step: int | None = None):
    """The engine's complete resume closure as ``(files, manifest)``.

    ``files`` maps checkpoint file names to ``{key: numpy array}``;
    ``manifest`` is the JSON-serializable header (fingerprint included).
    This is exactly what :func:`save_engine_checkpoint` writes.
    """
    step = engine._ptr_of(state) if step is None else int(step)
    if hasattr(engine, "part"):
        return _sharded_state_dict(engine, state, step)
    return _async_state_dict(engine, state, step)


def save_engine_checkpoint(engine, state, path, *, step=None, keep_last=None):
    """Write a crash-safe engine checkpoint (see module docstring).

    ``step`` defaults to the state's slot counter. With ``keep_last=K``,
    ``path`` is a rotation root (entries ``ckpt-<step>``, newest K
    kept); otherwise it is the entry directory itself. Returns the entry
    directory written.
    """
    files, manifest = engine_state_dict(engine, state, step=step)
    return _save_entry(path, files, manifest, manifest["step"], keep_last)


def restore(engine, path):
    """Load an engine checkpoint into ``engine``; returns ``(state, step)``.

    ``path`` may be one entry or a ``keep_last`` rotation root (newest
    valid entry wins, torn entries skipped). The manifest fingerprint
    (graph hash, n, p, dtype, config digest) is validated first — any
    mismatch raises :class:`CheckpointError` naming the field. Dynamic
    runs re-adopt the saved live topology (graph, capacity, version,
    pending arrivals, host log); sharded restores re-tile per-shard
    files through the live partition, elastically when S changed.
    """
    entry, manifest = _resolve_entry(path)
    if manifest.get("kind") != "engine":
        raise CheckpointError(
            f"{entry}: not an engine checkpoint (kind={manifest.get('kind')!r}); "
            "pytree checkpoints load via repro.checkpoint.load_checkpoint"
        )
    is_sharded = hasattr(engine, "part")
    saved_engine = manifest.get("engine")
    want = "sharded" if is_sharded else "async"
    if saved_engine != want:
        raise CheckpointError(
            f"{entry}: {saved_engine} checkpoint cannot restore into a "
            f"{type(engine).__name__}"
        )
    if not is_sharded:
        return _restore_async(engine, entry, manifest)
    return _restore_sharded(engine, entry, manifest)
