"""Zamba2-style hybrid: Mamba2 backbone + a single *shared* attention block
applied every ``shared_attn_every`` layers (arXiv:2411.15242).

The shared block's weights are one copy (not scanned); inside the layer scan
a lax.cond applies it on the designated layers. This is the faithful Zamba
structure (shared transformer block re-used across depth) and keeps the HLO
small: one mamba body + one attention body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ssm
from repro.models.layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    init_embedding,
    init_rmsnorm,
    init_swiglu,
    rms_norm,
    swiglu,
    unembed,
)


def init_params(key, cfg):
    dtype = dtype_of(cfg)
    ke, kl, ka, kf, kh = jax.random.split(key, 5)
    layer_keys = jax.random.split(kl, cfg.num_layers)

    def init_layer(k):
        return {
            "ln": init_rmsnorm(cfg.d_model, dtype),
            "mamba": ssm.init_mamba2(k, cfg, dtype),
        }

    params = {
        "embed": init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": jax.vmap(init_layer)(layer_keys),
        "shared_attn": {
            "ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn_mod.init_attention(ka, cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
        },
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(kh, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _attn_maybe(cfg, shared, x, positions, use_attn, window):
    def yes(x):
        h, _ = attn_mod.attention(
            shared["attn"], rms_norm(shared["ln1"], x, cfg.norm_eps), cfg,
            positions=positions, window=window,
        )
        x = x + h
        return x + swiglu(shared["ffn"], rms_norm(shared["ln2"], x, cfg.norm_eps))

    return jax.lax.cond(use_attn, yes, lambda x: x, x)


def forward(params, tokens, cfg, remat=True, window=None, last_only=False):
    from repro.models.sharding import constrain_batch

    x = constrain_batch(embed(params["embed"], tokens))
    S = tokens.shape[1]
    positions = jnp.arange(S)
    window = window if window is not None else cfg.sliding_window
    every = cfg.shared_attn_every or (cfg.num_layers + 1)
    use_attn = jnp.arange(cfg.num_layers) % every == every - 1
    shared = params["shared_attn"]

    def body(layer_params, use_a, x):
        x = x + ssm.mamba2_forward(
            layer_params["mamba"], rms_norm(layer_params["ln"], x, cfg.norm_eps), cfg
        )
        return _attn_maybe(cfg, shared, x, positions, use_a, window)

    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, inp):
        layer_params, use_a = inp
        return constrain_batch(body(layer_params, use_a, x)), None

    x, _ = jax.lax.scan(scan_fn, x, (params["layers"], use_attn))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, remat=True):
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, remat=remat)
    return cross_entropy_loss(logits, tokens[:, 1:]) + aux


def init_cache(params, cfg, batch, max_len):
    dtype = dtype_of(cfg)
    m = ssm.init_mamba2_cache(None, cfg, batch, dtype)
    caches = {
        "mamba": jax.tree.map(lambda c: jnp.broadcast_to(c, (cfg.num_layers, *c.shape)), m),
        "attn": attn_mod.init_cache(cfg, batch, max_len, dtype),
        # one attention cache per attention application site
    }
    every = cfg.shared_attn_every or (cfg.num_layers + 1)
    n_sites = sum(1 for i in range(cfg.num_layers) if i % every == every - 1)
    caches["attn"] = jax.tree.map(
        lambda c: jnp.broadcast_to(c, (max(n_sites, 1), *c.shape)), caches["attn"]
    )
    return caches


def decode_step(params, token, cfg, caches, pos):
    x = embed(params["embed"], token)
    every = cfg.shared_attn_every or (cfg.num_layers + 1)
    use_attn = jnp.arange(cfg.num_layers) % every == every - 1
    site_idx = jnp.cumsum(use_attn.astype(jnp.int32)) - 1  # attn cache slot per layer
    shared = params["shared_attn"]

    def scan_fn(carry, inp):
        x, attn_caches = carry
        layer_params, mcache, use_a, site = inp
        h, new_m = ssm.mamba2_decode(
            layer_params["mamba"], rms_norm(layer_params["ln"], x, cfg.norm_eps), cfg, mcache
        )
        x = x + h

        def yes(operand):
            x, attn_caches = operand
            cache = jax.tree.map(lambda c: c[site], attn_caches)
            h_in = rms_norm(shared["ln1"], x, cfg.norm_eps)
            h, new_cache = attn_mod.decode_attention(shared["attn"], h_in, cfg, cache, pos)
            x = x + h
            x = x + swiglu(shared["ffn"], rms_norm(shared["ln2"], x, cfg.norm_eps))
            attn_caches = jax.tree.map(
                lambda all_c, c: jax.lax.dynamic_update_index_in_dim(all_c, c, site, 0),
                attn_caches,
                new_cache,
            )
            return x, attn_caches

        x, attn_caches = jax.lax.cond(use_a, yes, lambda op: op, (x, attn_caches))
        return (x, attn_caches), new_m

    (x, new_attn), new_mamba = jax.lax.scan(
        scan_fn,
        (x, caches["attn"]),
        (params["layers"], caches["mamba"], use_attn, site_idx),
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, {"mamba": new_mamba, "attn": new_attn}
