"""Full xLSTM language model: mLSTM backbone with periodic sLSTM blocks
(xLSTM[a:b] pattern). Per-type stacked params with index-mapped gathers
inside the layer scan (HLO: one mLSTM body + one sLSTM body).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import xlstm
from repro.models.layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    init_embedding,
    init_rmsnorm,
    rms_norm,
    unembed,
)


def _layer_types(cfg):
    every = cfg.xlstm.slstm_every
    is_s = [(i % every == every - 1) for i in range(cfg.num_layers)]
    return is_s


def init_params(key, cfg):
    dtype = dtype_of(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)
    is_s = _layer_types(cfg)
    n_s = sum(is_s)
    n_m = cfg.num_layers - n_s
    mkeys = jax.random.split(km, max(n_m, 1))
    skeys = jax.random.split(ks, max(n_s, 1))
    params = {
        "embed": init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "mlstm": jax.vmap(lambda k: xlstm.init_mlstm(k, cfg, dtype))(mkeys),
        "slstm": jax.vmap(lambda k: xlstm.init_slstm(k, cfg, dtype))(skeys),
        "ln_m": jax.vmap(lambda k: init_rmsnorm(cfg.d_model, dtype))(mkeys),
        "ln_s": jax.vmap(lambda k: init_rmsnorm(cfg.d_model, dtype))(skeys),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(kh, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _indices(cfg):
    is_s = _layer_types(cfg)
    m_idx, s_idx = [], []
    mi = si = 0
    for flag in is_s:
        if flag:
            s_idx.append(si)
            m_idx.append(0)
            si += 1
        else:
            m_idx.append(mi)
            s_idx.append(0)
            mi += 1
    return (
        jnp.asarray(is_s, dtype=bool),
        jnp.asarray(m_idx, dtype=jnp.int32),
        jnp.asarray(s_idx, dtype=jnp.int32),
    )


def forward(params, tokens, cfg, remat=True, last_only=False):
    from repro.models.sharding import constrain_batch

    x = constrain_batch(embed(params["embed"], tokens))
    is_s, m_idx, s_idx = _indices(cfg)

    def body(x, flag, mi, si):
        def s_branch(x):
            p = jax.tree.map(lambda a: a[si], params["slstm"])
            ln = jax.tree.map(lambda a: a[si], params["ln_s"])
            return x + xlstm.slstm_forward(p, rms_norm(ln, x, cfg.norm_eps), cfg)

        def m_branch(x):
            p = jax.tree.map(lambda a: a[mi], params["mlstm"])
            ln = jax.tree.map(lambda a: a[mi], params["ln_m"])
            return x + xlstm.mlstm_forward(p, rms_norm(ln, x, cfg.norm_eps), cfg)

        return jax.lax.cond(flag, s_branch, m_branch, x)

    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, inp):
        flag, mi, si = inp
        return constrain_batch(body(x, flag, mi, si)), None

    x, _ = jax.lax.scan(scan_fn, x, (is_s, m_idx, s_idx))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, remat=True):
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, remat=remat)
    return cross_entropy_loss(logits, tokens[:, 1:]) + aux


def init_cache(params, cfg, batch, max_len):
    is_s = _layer_types(cfg)
    n_s = max(sum(is_s), 1)
    n_m = max(len(is_s) - sum(is_s), 1)
    mc = xlstm.init_mlstm_cache(cfg, batch)
    sc = xlstm.init_slstm_cache(cfg, batch)
    return {
        "mlstm": jax.tree.map(lambda c: jnp.broadcast_to(c, (n_m, *c.shape)), mc),
        "slstm": jax.tree.map(lambda c: jnp.broadcast_to(c, (n_s, *c.shape)), sc),
    }


def decode_step(params, token, cfg, caches, pos):
    x = embed(params["embed"], token)
    is_s, m_idx, s_idx = _indices(cfg)

    def scan_fn(carry, inp):
        x, mcaches, scaches = carry
        flag, mi, si = inp

        def s_branch(op):
            x, mcaches, scaches = op
            p = jax.tree.map(lambda a: a[si], params["slstm"])
            ln = jax.tree.map(lambda a: a[si], params["ln_s"])
            cache = jax.tree.map(lambda c: c[si], scaches)
            h, new = xlstm.slstm_decode(p, rms_norm(ln, x, cfg.norm_eps), cfg, cache)
            scaches = jax.tree.map(
                lambda allc, c: jax.lax.dynamic_update_index_in_dim(allc, c, si, 0),
                scaches,
                new,
            )
            return x + h, mcaches, scaches

        def m_branch(op):
            x, mcaches, scaches = op
            p = jax.tree.map(lambda a: a[mi], params["mlstm"])
            ln = jax.tree.map(lambda a: a[mi], params["ln_m"])
            cache = jax.tree.map(lambda c: c[mi], mcaches)
            h, new = xlstm.mlstm_decode(p, rms_norm(ln, x, cfg.norm_eps), cfg, cache)
            mcaches = jax.tree.map(
                lambda allc, c: jax.lax.dynamic_update_index_in_dim(allc, c, mi, 0),
                mcaches,
                new,
            )
            return x + h, mcaches, scaches

        carry = jax.lax.cond(flag, s_branch, m_branch, (x, mcaches, scaches))
        return carry, None

    (x, new_m, new_s), _ = jax.lax.scan(
        scan_fn, (x, caches["mlstm"], caches["slstm"]), (is_s, m_idx, s_idx)
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, {"mlstm": new_m, "slstm": new_s}
