"""PartitionSpec rules for every model family on the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

Two P2P agent modes (DESIGN.md §5):
* ``full`` — params carry a leading agent axis of size n_agents
  (= data-axis size x pods) sharded over ("pod","data"); within an agent the
  model is tensor-parallel over "model".
* ``silo`` — no agent axis (or pod-sized); params are FSDP-sharded over
  "data" and tensor-parallel over "model" (giant archs).

Rules are divisibility-aware: a dim is only sharded if the axis size divides
it; otherwise the rule falls through to the next candidate dim (pjit is
layout-only here — any valid spec is semantically correct, the choice just
moves collective traffic, which is what §Perf iterates on).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


# Optional activation-batch constraint used by inference paths: GSPMD's
# propagation can replicate loop carries whose init is an unconstrained
# constant (e.g. a zeros KV cache), which silently un-shards the whole
# prefill. The launcher sets the batch axes here; library code calls
# constrain_batch at anchor points.
_ACT_AXES = None

# §Perf lever: Megatron-style sequence parallelism. When set (to the model
# axis name), the residual stream between TP regions is sharded on the SEQ
# dim, turning per-layer activation all-reduces into reduce-scatter +
# all-gather pairs (half the ICI bytes). Applied at the layer boundary by
# constrain_seq.
_SEQ_AXIS = None


def set_seq_axis(axis):
    global _SEQ_AXIS
    _SEQ_AXIS = axis


def constrain_seq(x, dim=1):
    """Shard the sequence dim of an activation (B, S, d) over the TP axis."""
    if _SEQ_AXIS is None:
        return x
    try:
        from jax.sharding import get_abstract_mesh

        size = get_abstract_mesh().shape[_SEQ_AXIS]
    except Exception:
        return x
    if size <= 1 or x.ndim <= dim or x.shape[dim] % size != 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = _SEQ_AXIS
    return jax.lax.with_sharding_constraint(x, P(*spec))


def set_activation_axes(axes):
    global _ACT_AXES
    _ACT_AXES = axes


def constrain_batch(x, dim=0):
    if _ACT_AXES is None:
        return x
    try:
        size = _act_axes_size()
    except Exception:
        return x  # no mesh context (e.g. eval_shape) — constraint is a no-op
    if size <= 1 or x.shape[dim] % size != 0 or x.shape[dim] < size:
        return x
    spec = [None] * x.ndim
    spec[dim] = _ACT_AXES if isinstance(_ACT_AXES, str) else tuple(_ACT_AXES)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _act_axes_size():
    import numpy as _np

    from jax.sharding import get_abstract_mesh

    mesh = get_abstract_mesh()
    axes = (_ACT_AXES,) if isinstance(_ACT_AXES, str) else _ACT_AXES
    return int(_np.prod([mesh.shape[a] for a in axes]))


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _pick_dims(shape, skip, mesh, model_axis, fsdp_axis=None):
    """Choose (model_dim, fsdp_dim) to shard for a leaf of `shape`.

    Preference: shard the *last* divisible dim over model (column-parallel
    default), and the largest remaining divisible dim over data (FSDP).
    Dims in `skip` (leading layer-stack / agent dims) are never sharded.
    """
    msz = _axis_size(mesh, model_axis)
    cands = [i for i in range(len(shape)) if i not in skip]
    model_dim = None
    for i in reversed(cands):
        if shape[i] % msz == 0 and shape[i] >= msz:
            model_dim = i
            break
    fsdp_dim = None
    if fsdp_axis is not None:
        fsz = _axis_size(mesh, fsdp_axis)
        rest = [i for i in cands if i != model_dim]
        rest.sort(key=lambda i: -shape[i])
        for i in rest:
            if shape[i] % fsz == 0 and shape[i] >= fsz:
                fsdp_dim = i
                break
    return model_dim, fsdp_dim


def param_specs(params, mesh, agent_mode: str, n_agents: int, scan_dims=("layers",)):
    """Build a PartitionSpec pytree matching ``params``.

    ``params`` may be a pytree of arrays or of ShapeDtypeStructs.
    In ``full`` mode the leaves are expected to carry the leading agent dim.
    """
    has_pod = "pod" in mesh.shape
    agent_axes = ("pod", "data") if has_pod else ("data",)
    fsdp_axis = "data" if agent_mode in ("silo", "serve") else None

    def one(path, leaf):
        shape = leaf.shape
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        skip = set()
        spec = [None] * len(shape)
        dim0 = 0
        if agent_mode == "full":
            # leading agent dim
            spec[0] = agent_axes if len(agent_axes) > 1 else agent_axes[0]
            skip.add(0)
            dim0 = 1
        elif agent_mode == "silo":
            # leading pod-agent dim (size 1 on a single pod -> replicated)
            spec[0] = "pod" if (has_pod and n_agents > 1) else None
            skip.add(0)
            dim0 = 1
        # layer-stack dim (from scanned init) right after the agent dim.
        if ("layers" in path_str or "encoder" in path_str or "decoder" in path_str
                or "mlstm" in path_str or "slstm" in path_str or "ln_m" in path_str
                or "ln_s" in path_str) and len(shape) > dim0:
            skip.add(dim0)
        if len(shape) - len(skip) == 0:
            return P(*spec)
        if len(shape) - len(skip) == 1 and shape[-1] < 1024:
            return P(*spec)  # small vectors (norm scales, biases): replicate
        if path_str.endswith("table") and len(shape) - dim0 == 2:
            # embedding / lm-head: shard the (padded) vocab dim over "model"
            # so logits stay vocab-sharded instead of replicated at full V.
            msz = _axis_size(mesh, "model")
            vdim, ddim = dim0, dim0 + 1
            if shape[vdim] % msz == 0:
                spec[vdim] = "model"
                if fsdp_axis is not None and shape[ddim] % _axis_size(mesh, fsdp_axis) == 0:
                    spec[ddim] = fsdp_axis
                return P(*spec)
        model_dim, fsdp_dim = _pick_dims(shape, skip, mesh, "model", fsdp_axis)
        if model_dim is not None:
            spec[model_dim] = "model"
        if fsdp_dim is not None:
            spec[fsdp_dim] = "data"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_specs(batch, mesh, agent_mode: str):
    """Batch specs, divisibility-safe.

    full  — dim0 is the agent dim, sharded over ("pod","data");
    silo  — dim0 is the pod-agent dim ("pod" when pods>1, else replicated),
            dim1 (per-agent batch) sharded over "data";
    serve — dim0 is the request batch, sharded over ("pod","data") when the
            size divides (long_500k's batch of 1 stays replicated).
    """
    has_pod = "pod" in mesh.shape
    data_axes = ("pod", "data") if has_pod else ("data",)
    lead = data_axes if len(data_axes) > 1 else data_axes[0]
    lead_sz = _axis_size(mesh, data_axes)
    dsz = _axis_size(mesh, "data")

    def one(leaf):
        spec = [None] * leaf.ndim
        if agent_mode == "full":
            if leaf.ndim >= 1 and leaf.shape[0] % lead_sz == 0:
                spec[0] = lead
        elif agent_mode == "silo":
            if has_pod and leaf.ndim >= 1 and leaf.shape[0] % mesh.shape["pod"] == 0:
                spec[0] = "pod"
            if leaf.ndim >= 2 and leaf.shape[1] % dsz == 0 and leaf.shape[1] >= dsz:
                spec[1] = "data"
        else:  # serve
            if leaf.ndim >= 1 and leaf.shape[0] % lead_sz == 0 and leaf.shape[0] >= lead_sz:
                spec[0] = lead
            elif leaf.ndim >= 1 and not has_pod and leaf.shape[0] % dsz == 0 and leaf.shape[0] >= dsz:
                spec[0] = "data"
        return P(*spec)

    return jax.tree_util.tree_map(one, batch)


def cache_specs(cache, mesh, batch_sharded: bool):
    """Decode-cache specs.

    Layout is (layer, batch, seq, kv_heads, head_dim) for KV buffers. Batch
    shards over ("pod","data"); the model axis takes the KV-head dim when it
    divides, otherwise the SEQ dim (flash-decoding style: per-shard partial
    attention + softmax combine, which GSPMD lowers to partial reductions).
    Without either, a 32k x 128 MHA cache exceeds per-chip HBM.
    """
    has_pod = "pod" in mesh.shape
    data_axes = ("pod", "data") if has_pod else ("data",)
    lead = data_axes if len(data_axes) > 1 else data_axes[0]
    dsz = _axis_size(mesh, data_axes)
    msz = _axis_size(mesh, "model")

    def one(leaf):
        spec = [None] * leaf.ndim
        # caches are stacked per layer: dim0 = layer, dim1 = batch
        if batch_sharded and leaf.ndim >= 2 and leaf.shape[1] % dsz == 0 and leaf.shape[1] >= dsz:
            spec[1] = lead
        if leaf.ndim == 5:  # (L, B, S, KV, hd)
            if leaf.shape[3] % msz == 0 and leaf.shape[3] >= msz:
                spec[3] = "model"
            elif leaf.shape[2] % msz == 0 and leaf.shape[2] >= msz:
                spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map(one, cache)
