"""Uniform model API over all families.

``build_model(cfg)`` returns a :class:`ModelBundle` with a normalized
surface: init / loss (train) / prefill / decode / cache-init. The launch
layer (train.py, serve.py, dryrun.py) and the SPMD P2P layer only talk to
this interface, so the paper's technique composes with every architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, transformer, xlstm_stack


@dataclasses.dataclass(frozen=True, eq=False)
class ModelBundle:
    cfg: ModelConfig
    init: Callable  # (key) -> params
    loss: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch) -> (logits, caches)
    decode: Callable  # (params, token, caches, pos) -> (logits, caches)
    init_cache: Callable  # (params, batch_size, max_len) -> caches

    def train_inputs(self, batch, seq):
        """Concrete-shape template for the training batch (used by tests)."""
        out = {"tokens": jnp.zeros((batch, seq), jnp.int32)}
        if self.cfg.is_encdec:
            out["embeds"] = jnp.zeros((batch, encdec.enc_len(seq), self.cfg.d_model), jnp.float32)
        return out


def build_model(cfg: ModelConfig, remat: bool = True) -> ModelBundle:
    if cfg.is_encdec:
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            loss=lambda p, b: encdec.loss_fn(p, b, cfg, remat=remat),
            prefill=lambda p, b: encdec.prefill(p, b["embeds"], b["tokens"], cfg),
            decode=lambda p, t, c, pos: encdec.decode_step(p, t, cfg, c, pos),
            init_cache=lambda p, bsz, mx: encdec.init_cache(p, cfg, bsz, mx),
        )
    if cfg.family == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: hybrid.init_params(key, cfg),
            loss=lambda p, b: hybrid.loss_fn(p, b, cfg, remat=remat),
            # recurrent-family prefill cost = the forward pass (state capture
            # for serving continuity goes through the decode loop; see
            # launch/serve.py). last_only avoids the full-seq lm_head.
            prefill=lambda p, b: hybrid.forward(p, b["tokens"], cfg, remat=False,
                                                last_only=True),
            decode=lambda p, t, c, pos: hybrid.decode_step(p, t, cfg, c, pos),
            init_cache=lambda p, bsz, mx: hybrid.init_cache(p, cfg, bsz, mx),
        )
    if cfg.family == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: xlstm_stack.init_params(key, cfg),
            loss=lambda p, b: xlstm_stack.loss_fn(p, b, cfg, remat=remat),
            prefill=lambda p, b: xlstm_stack.forward(p, b["tokens"], cfg, remat=False,
                                                     last_only=True),
            decode=lambda p, t, c, pos: xlstm_stack.decode_step(p, t, cfg, c, pos),
            init_cache=lambda p, bsz, mx: xlstm_stack.init_cache(p, cfg, bsz, mx),
        )
    # dense / moe / vlm are all decoder-only transformers.
    return ModelBundle(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        loss=lambda p, b: transformer.loss_fn(p, b, cfg, remat=remat),
        prefill=lambda p, b: transformer.prefill(p, b["tokens"], cfg),
        decode=lambda p, t, c, pos: transformer.decode_step(p, t, cfg, c, pos),
        init_cache=lambda p, bsz, mx: transformer.init_cache(p, cfg, bsz, mx),
    )
