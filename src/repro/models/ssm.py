"""Mamba2-style selective SSM block (SSD), chunked for TPU.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute within chunks of size Q plus a linear inter-chunk state recurrence
(lax.scan over chunks). Decode is the O(1) recurrent state update.

Layout: d_inner = expand * d_model, nheads = d_inner / head_dim, single
B/C group (ngroups=1), state_dim = N.

The intra-chunk einsums are the compute hot-spot; ``repro.kernels.ssm_scan``
provides the Pallas TPU kernel for them, validated against this reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, dense


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    nheads = di // s.head_dim
    N = s.state_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj packs [z, x, B, C, dt].
    d_in_proj = 2 * di + 2 * N + nheads
    return {
        "in_proj": init_dense(k1, d, d_in_proj, dtype=dtype),
        "conv": (jax.random.normal(k2, (s.conv_kernel, di + 2 * N)) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nheads,), dtype=jnp.float32),
        "out_proj": init_dense(k3, di, d, dtype=dtype),
        "norm_z": jnp.ones((di,), dtype=dtype),
    }


def _split_proj(proj, di, N, nheads):
    z = proj[..., :di]
    x = proj[..., di : 2 * di]
    B = proj[..., 2 * di : 2 * di + N]
    C = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N :]
    return z, x, B, C, dt


def _causal_conv(x, w):
    """Depthwise causal conv along seq. x: (B,S,D), w: (K,D)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    return out


def mamba2_forward(params, xin, cfg, use_kernel=False):
    """xin: (B, S, d_model) -> (B, S, d_model). Chunked SSD.

    ``use_kernel=True`` routes the intra-chunk compute through the Pallas
    kernel (repro.kernels.ops.ssm_chunk_ad; oracle VJP on backward).
    """
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    N = s.state_dim
    nheads = di // s.head_dim
    hd = s.head_dim
    Bsz, S, _ = xin.shape
    Q = min(s.chunk, S)
    assert S % Q == 0, f"seq {S} must be divisible by chunk {Q}"
    nc = S // Q

    proj = dense(params["in_proj"], xin)
    z, x, Bssm, Cssm, dt = _split_proj(proj, di, N, nheads)
    conv_in = jnp.concatenate([x, Bssm, Cssm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv"]))
    x = conv_out[..., :di]
    Bssm = conv_out[..., di : di + N]
    Cssm = conv_out[..., di + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative
    # per-step log decay: log a_t = A * dt_t  (<= 0)
    loga = dt * A  # (B,S,H)

    xh = x.reshape(Bsz, nc, Q, nheads, hd).astype(jnp.float32)
    Bc = Bssm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cssm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, nheads)
    lac = loga.reshape(Bsz, nc, Q, nheads)
    cum = jnp.cumsum(lac, axis=2)  # (B,nc,Q,H) inclusive
    total = cum[:, :, -1]  # (B,nc,H)

    if use_kernel:
        from repro.kernels import ops as kops

        # Flatten (B, nc, H) groups; C/B are shared across heads.
        G = Bsz * nc * nheads
        rep = lambda t: jnp.broadcast_to(
            t[:, :, None], (Bsz, nc, nheads, Q, N)
        ).reshape(G, Q, N)
        Ck = rep(Cc)
        Bk = rep(Bc)
        cumk = cum.transpose(0, 1, 3, 2).reshape(G, Q)
        dtk = dtc.transpose(0, 1, 3, 2).reshape(G, Q)
        xk = xh.transpose(0, 1, 3, 2, 4).reshape(G, Q, hd)
        yk, sk = kops.ssm_chunk_ad(Ck, Bk, cumk, dtk, xk)
        y_intra = yk.reshape(Bsz, nc, nheads, Q, hd).transpose(0, 1, 3, 2, 4)
        s_loc = sk.reshape(Bsz, nc, nheads, hd, N)
    else:
        # Intra-chunk (attention-like, causal):
        # scores[b,c,h,q,t] = exp(cum_q - cum_t) * (C_q . B_t) * dt_t  for t <= q
        cb = jnp.einsum("bcqn,bctn->bcqt", Cc, Bc)  # (B,nc,Q,Q)
        decay = jnp.exp(
            jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
        )  # (B,nc,Q,T,H)
        causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
        scores = cb[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,Q,T,H)
        scores = jnp.where(causal[None, None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bcqth,bcthp->bcqhp", scores, xh)

        # Chunk-local end state: S_loc[b,c,h,p,n] = sum_t exp(total-cum_t) dt_t x_t B_t
        w_end = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60.0, 0.0)) * dtc
        s_loc = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_end, xh, Bc)

    # Inter-chunk recurrence: S_c = exp(total_c) S_{c-1} + s_loc_c
    def scan_fn(S_prev, inp):
        s_l, tot = inp  # (B,H,hd,N), (B,H)
        S_new = jnp.exp(tot)[:, :, None, None] * S_prev + s_l
        return S_new, S_prev  # emit state *entering* the chunk

    S0 = jnp.zeros((Bsz, nheads, hd, N), jnp.float32)
    _, S_in = jax.lax.scan(
        scan_fn,
        S0,
        (s_loc.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,hd,N)

    # Inter-chunk output: y_inter[q] = exp(cum_q) * C_q . S_in
    w_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, S_in, w_in)

    y = (y_intra + y_inter).reshape(Bsz, S, di)
    y = y + params["D"].repeat(hd) * x.astype(jnp.float32)
    # Gated RMS-style norm with z (Mamba2's norm-before-out_proj).
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_z"].astype(jnp.float32)
    return dense(params["out_proj"], y.astype(xin.dtype))


def init_mamba2_cache(params, cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nheads = di // s.head_dim
    return {
        "state": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        "conv_buf": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * s.state_dim), dtype),
    }


def mamba2_decode(params, xin, cfg, cache):
    """One-token decode. xin: (B, 1, d_model)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    N = s.state_dim
    nheads = di // s.head_dim
    hd = s.head_dim
    Bsz = xin.shape[0]

    proj = dense(params["in_proj"], xin[:, 0])
    z, x, Bssm, Cssm, dt = _split_proj(proj, di, N, nheads)
    conv_in = jnp.concatenate([x, Bssm, Cssm], axis=-1)  # (B, di+2N)
    buf = jnp.concatenate([cache["conv_buf"], conv_in[:, None]], axis=1)  # (B,K,·)
    w = params["conv"]
    conv_out = jax.nn.silu(jnp.einsum("bkd,kd->bd", buf, w))
    new_conv_buf = buf[:, 1:]
    x = conv_out[:, :di]
    Bssm = conv_out[:, di : di + N].astype(jnp.float32)
    Cssm = conv_out[:, di + N :].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)  # (B,H)
    xh = x.reshape(Bsz, nheads, hd).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bssm)
    state = a[:, :, None, None] * cache["state"] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cssm, state)  # (B,H,hd)
    y = y.reshape(Bsz, di) + params["D"].repeat(hd) * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_z"].astype(jnp.float32)
    out = dense(params["out_proj"], y.astype(xin.dtype))
    return out[:, None], {"state": state, "conv_buf": new_conv_buf}
