from repro.models.registry import build_model, ModelBundle

__all__ = ["build_model", "ModelBundle"]
