"""Mixture-of-Experts FFN: top-k router, grouped one-hot dispatch with
capacity (GShard-style), SwiGLU experts, load-balance auxiliary loss.

Memory note (DESIGN.md §7): dispatch tensors scale as T * E * C_g where the
per-group capacity C_g = ceil(gs * top_k * cf / E) is bounded by the group
size ``gs`` (config; tokens are grouped in chunks of gs). Small groups keep
the dispatch footprint linear in T.

Experts are tensor-parallel (d_ff sharded over the "model" axis) rather than
expert-parallel: the assigned expert counts (40, 8) do not divide the 16-wide
model axis, and TP-experts keeps the sharding uniform across all MoE archs.
FLOPs remain honest: expert GEMMs run on top_k * cf * T tokens.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    fscale = 1.0 / math.sqrt(f)
    return {
        "router": init_dense(kr, d, E, dtype=jnp.float32),  # router kept in f32
        "gate": (jax.random.normal(kg, (E, d, f)) * scale).astype(dtype),
        "up": (jax.random.normal(ku, (E, d, f)) * scale).astype(dtype),
        "down": (jax.random.normal(kd, (E, f, d)) * fscale).astype(dtype),
    }


def capacity(group_size: int, top_k: int, num_experts: int, cf: float) -> int:
    return max(int(math.ceil(group_size * top_k * cf / num_experts)), 1)


def moe_ffn(params, x, cfg):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar)."""
    mcfg = cfg.moe
    B, S, d = x.shape
    E, k = mcfg.num_experts, mcfg.top_k
    T = B * S
    gs = min(mcfg.group_size, T)
    # Pad T to a multiple of gs.
    G = -(-T // gs)
    pad = G * gs - T
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(G, gs, d)

    logits = (xg.astype(jnp.float32) @ params["router"]["w"]).astype(jnp.float32)  # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)  # (G,gs,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    C = capacity(gs, k, E, mcfg.capacity_factor)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (G,gs,k,E)
    # Queue position of each (token, choice) in its expert (priority: rank
    # order then token order).
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * gs, E)  # rank-major
    qpos = jnp.cumsum(flat, axis=1) - flat  # (G, k*gs, E)
    qpos = qpos.reshape(G, k, gs, E).transpose(0, 2, 1, 3)  # (G,gs,k,E)
    keep = (qpos < C) & (onehot > 0)
    slot = jax.nn.one_hot(qpos.astype(jnp.int32), C, dtype=jnp.float32) * keep[..., None]
    dispatch = slot.sum(axis=2)  # (G, gs, E, C)
    combine = jnp.einsum("gsec,gske,gsk->gsec", dispatch, onehot, top_w)

    xd = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)  # (G,E,C,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xd, params["gate"])) * jnp.einsum(
        "gecd,edf->gecf", xd, params["up"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, params["down"])  # (G,E,C,d)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)  # (G,gs,d)

    y = y.reshape(G * gs, d)
    if pad:
        y = y[:T]
    y = y.reshape(B, S, d)

    # Load-balance loss (Switch/GShard): E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    fe = onehot.sum(axis=2).mean(axis=(0, 1))  # fraction routed per expert
    aux = E * jnp.sum(me * fe) * mcfg.router_aux_weight
    return y, aux
