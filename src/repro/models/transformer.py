"""Decoder-only transformer (dense GQA / MoE / early-fusion VLM).

Layers are scanned (stacked params, lax.scan) so the HLO contains a single
layer body — essential to keep 512-device dry-run compiles tractable and to
make remat policies uniform.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    init_embedding,
    init_rmsnorm,
    init_swiglu,
    rms_norm,
    swiglu,
    unembed,
)
from repro.models.moe import init_moe, moe_ffn


def _init_block(key, cfg, dtype):
    ka, kf = jax.random.split(key)
    block = {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ka, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        block["ffn"] = init_moe(kf, cfg, dtype)
    else:
        block["ffn"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype)
    return block


def init_params(key, cfg):
    dtype = dtype_of(cfg)
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)
    params = {
        "embed": init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(kh, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _block_apply(cfg, layer_params, x, positions, window):
    from repro.models.sharding import constrain_seq

    x = constrain_seq(x)  # seq-parallel residual (no-op unless enabled)
    h, _ = attn_mod.attention(
        layer_params["attn"],
        rms_norm(layer_params["ln1"], x, cfg.norm_eps),
        cfg,
        positions=positions,
        window=window,
    )
    x = constrain_seq(x + h)
    h2 = rms_norm(layer_params["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_ffn(layer_params["ffn"], h2, cfg)
    else:
        f, aux = swiglu(layer_params["ffn"], h2), jnp.zeros((), jnp.float32)
    return x + f, aux


def forward(params, tokens, cfg, remat=True, window=None):
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    x = embed(params["embed"], tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S)
    window = window if window is not None else cfg.sliding_window

    body = functools.partial(_block_apply, cfg)
    if remat:
        body = jax.checkpoint(body)

    def scan_fn(carry, layer_params):
        x, aux = carry
        x, a = body(layer_params, x, positions, window)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, aux


def loss_fn(params, batch, cfg, remat=True):
    """Next-token LM loss. batch: {"tokens": (B,S)} (labels = shifted)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, remat=remat)
    return cross_entropy_loss(logits, tokens[:, 1:]) + aux


def init_cache(params, cfg, batch, max_len):
    dtype = dtype_of(cfg)
    one = attn_mod.init_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda c: jnp.broadcast_to(c, (cfg.num_layers, *c.shape)), one
    )


def prefill(params, tokens, cfg, max_len=None, remat=False):
    """Run the full prompt, build per-layer KV caches, return last logits."""
    from repro.models.sharding import constrain_batch

    B, S = tokens.shape
    max_len = max_len if max_len is not None else S
    x = constrain_batch(embed(params["embed"], tokens))
    positions = jnp.arange(S)
    window = cfg.sliding_window
    dtype = dtype_of(cfg)
    cache0 = attn_mod.init_cache(cfg, B, max_len, dtype)
    cache0 = {k: (constrain_batch(v) if v.ndim == 4 else v) for k, v in cache0.items()}

    def scan_fn(x, layer_params):
        x = constrain_batch(x)
        h_in = rms_norm(layer_params["ln1"], x, cfg.norm_eps)
        h, (k, v) = attn_mod.attention(
            layer_params["attn"], h_in, cfg, positions=positions, window=window
        )
        cache = attn_mod.prefill_into_cache(cfg, cache0, k, v, S)
        x = x + h
        h2 = rms_norm(layer_params["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_ffn(layer_params["ffn"], h2, cfg)
        else:
            f = swiglu(layer_params["ffn"], h2)
        return x + f, cache

    x, caches = jax.lax.scan(scan_fn, x, params["layers"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x[:, -1:])
    return logits, caches


def decode_step(params, token, cfg, caches, pos):
    """token: (B, 1) int32; caches: stacked per-layer; pos: scalar."""
    x = embed(params["embed"], token)

    def scan_fn(x, inp):
        layer_params, cache = inp
        h_in = rms_norm(layer_params["ln1"], x, cfg.norm_eps)
        h, new_cache = attn_mod.decode_attention(layer_params["attn"], h_in, cfg, cache, pos)
        x = x + h
        h2 = rms_norm(layer_params["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_ffn(layer_params["ffn"], h2, cfg)
        else:
            f = swiglu(layer_params["ffn"], h2)
        return x + f, new_cache

    x, new_caches = jax.lax.scan(scan_fn, x, (params["layers"], caches))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, new_caches
