"""GQA attention with RoPE, optional QKV bias, sliding window, logit
soft-capping, and a KV-cache decode path (ring buffer for windowed attention).

Decode assumption (documented in DESIGN.md): batched aligned decode — all
sequences in the batch are at the same absolute position ``pos`` (scalar).
This matches the dry-run shapes (decode_32k / long_500k) and keeps cache
indexing a single dynamic_update_slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, init_dense

NEG_INF = -1e30

# §Perf lever (decode): store the KV cache pre-repeated to the full q-head
# count. 2x (GQA 4x) cache memory, but the kv-head dim then divides the
# model axis, so per-shard attention needs NO cache all-gather.
REPEAT_KV_IN_CACHE = False


def set_repeat_kv_cache(flag: bool):
    global REPEAT_KV_IN_CACHE
    REPEAT_KV_IN_CACHE = flag


def init_attention(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_dense(kq, d, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": init_dense(kk, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": init_dense(kv, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": init_dense(ko, cfg.num_heads * hd, d, dtype=dtype),
    }


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


CHUNKED_ATTN_THRESHOLD = 16384  # above this S, q-block chunking (flash-style)


def _chunked_causal_attention(q, kr, vr, positions, cfg, window, q_chunk=1024):
    """Flash-style q-block attention: never materializes the (S, S) score
    matrix — per block it is (q_chunk, S). Sequential lax.map keeps one
    block's transients live at a time (the TPU kernel analogue tiles the
    same way in VMEM)."""
    B, S, H, hd = q.shape
    nq = S // q_chunk
    qi = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pi = positions.reshape(nq, q_chunk)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def one(args):
        qc, pc = args  # (B, qc, H, hd), (qc,)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, kr).astype(jnp.float32) * scale
        scores = _softcap(scores, cfg.logit_softcap)
        mask = positions[None, :] <= pc[:, None]
        if window is not None:
            mask = mask & (pc[:, None] - positions[None, :] < window)
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
        scores = scores + bias[None, None]
        w = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, vr)

    out = jax.lax.map(one, (qi, pi))  # (nq, B, qc, H, hd)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def attention(params, x, cfg, positions=None, causal=True, window=None, kv_memory=None):
    """Full-sequence attention (train / prefill / encoder).

    x: (B, S, d). kv_memory: optional (B, S_kv, d) for cross-attention (then
    causal/window are ignored and no RoPE is applied to memory keys).
    Returns (y, (k, v)) — cached K/V in (B, S_kv, KV, hd) layout.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    src = kv_memory if kv_memory is not None else x
    q = _split_heads(dense(params["q"], x), H, hd)
    k = _split_heads(dense(params["k"], src), KV, hd)
    v = _split_heads(dense(params["v"], src), KV, hd)
    if kv_memory is None:
        if positions is None:
            positions = jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kr = _repeat_kv(k, H // KV)
    vr = _repeat_kv(v, H // KV)
    if kv_memory is None and causal and S >= CHUNKED_ATTN_THRESHOLD and S % 1024 == 0:
        y = _chunked_causal_attention(q, kr, vr, positions, cfg, window)
        y = y.reshape(B, S, H * hd)
        return dense(params["o"], y), (k, v)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) / jnp.sqrt(hd).astype(
        jnp.float32
    )
    scores = _softcap(scores, cfg.logit_softcap)
    if kv_memory is None and causal:
        # additive bias (not where/select): keeps the bool mask out of the
        # saved-residual set and off the per-layer remat stacks.
        qi = positions[:, None]
        ki = positions[None, :]
        mask = ki <= qi
        if window is not None:
            mask = mask & (qi - ki < window)
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
        scores = scores + bias[None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = jnp.einsum("bhqk,bkhd->bqhd", w, vr)
    y = y.reshape(B, S, H * hd)
    return dense(params["o"], y), (k, v)


def init_cache(cfg, batch, max_len, dtype):
    """KV cache. For windowed attention the buffer is the window (ring)."""
    hd = cfg.resolved_head_dim
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    heads = cfg.num_heads if REPEAT_KV_IN_CACHE else cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, size, heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, size, heads, hd), dtype=dtype),
        "slot_pos": jnp.full((size,), -1, dtype=jnp.int32),  # absolute pos per slot
    }


def prefill_into_cache(cfg, cache, k, v, seq_len):
    """Write prefill K/V (already RoPE'd) into the cache buffer."""
    size = cache["k"].shape[1]
    if seq_len <= size:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        cache["slot_pos"] = cache["slot_pos"].at[:seq_len].set(jnp.arange(seq_len))
        return cache
    # Windowed: keep the last `size` positions, ring-aligned.
    start = seq_len - size
    tail_k = k[:, start:]
    tail_v = v[:, start:]
    pos = jnp.arange(start, seq_len)
    slots = pos % size
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, slots].set(tail_k)
    cache["v"] = cache["v"].at[:, slots].set(tail_v)
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(pos)
    return cache


def decode_attention(params, x, cfg, cache, pos):
    """One-token decode. x: (B, 1, d); pos: scalar int32 absolute position.

    Returns (y, new_cache). RoPE is applied at write time for K.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = _split_heads(dense(params["q"], x), H, hd)
    k = _split_heads(dense(params["k"], x), KV, hd)
    v = _split_heads(dense(params["v"], x), KV, hd)
    posv = jnp.full((1,), pos, dtype=jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    if REPEAT_KV_IN_CACHE:
        k = _repeat_kv(k, H // KV)
        v = _repeat_kv(v, H // KV)

    size = cache["k"].shape[1]
    slot = jnp.asarray(pos % size if cfg.sliding_window else pos, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (zero, slot, zero, zero))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (zero, slot, zero, zero))
    slot_pos = jax.lax.dynamic_update_slice(cache["slot_pos"], posv, (slot,))
    new_cache = {"k": new_k, "v": new_v, "slot_pos": slot_pos}

    rep = 1 if REPEAT_KV_IN_CACHE else H // KV
    kr = _repeat_kv(new_k, rep)
    vr = _repeat_kv(new_v, rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) / jnp.sqrt(hd).astype(
        jnp.float32
    )
    scores = _softcap(scores, cfg.logit_softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, None, None, :]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = jnp.einsum("bhqk,bkhd->bqhd", w, vr).reshape(B, 1, H * hd)
    return dense(params["o"], y), new_cache


def decode_cross_attention(params, x, cfg, mem_k, mem_v):
    """Cross-attention during decode against a fixed encoder memory."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = _split_heads(dense(params["q"], x), H, hd)
    kr = _repeat_kv(mem_k, H // KV)
    vr = _repeat_kv(mem_v, H // KV)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) / jnp.sqrt(hd).astype(
        jnp.float32
    )
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = jnp.einsum("bhqk,bkhd->bqhd", w, vr).reshape(B, 1, H * hd)
    return dense(params["o"], y)
