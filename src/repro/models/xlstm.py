"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential) with exponential gating + stabilizers.

The 1.3B config uses the paper's xLSTM[7:1] pattern: one sLSTM block every
``slstm_every`` blocks, the rest mLSTM. mLSTM training uses a chunkwise
form (quadratic within chunks, recurrent across chunks) like Mamba2's SSD;
sLSTM is inherently sequential (lax.scan over time).

Both have O(1)-state decode, which is what qualifies xlstm-1.3b for the
long_500k shape without any attention window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    x = cfg.xlstm
    di = int(x.proj_factor * d)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "up": init_dense(ks[0], d, 2 * di, dtype=dtype),  # [x_in, z gate]
        "q": init_dense(ks[1], di, di, dtype=dtype),
        "k": init_dense(ks[2], di, di, dtype=dtype),
        "v": init_dense(ks[3], di, di, dtype=dtype),
        "igate": init_dense(ks[4], di, H, dtype=jnp.float32),
        "fgate": init_dense(ks[5], di, H, dtype=jnp.float32),
        "down": init_dense(ks[6], di, d, dtype=dtype),
        "norm": init_rmsnorm(di, dtype=dtype),
    }


def mlstm_forward(params, xin, cfg):
    """Chunkwise-parallel mLSTM. xin: (B,S,d) -> (B,S,d)."""
    x = cfg.xlstm
    d = cfg.d_model
    di = int(x.proj_factor * d)
    H = cfg.num_heads
    hd = di // H
    B, S, _ = xin.shape
    Q = min(x.chunk, S)
    assert S % Q == 0
    nc = S // Q

    up = dense(params["up"], xin)
    xi, z = up[..., :di], up[..., di:]
    q = dense(params["q"], xi).reshape(B, S, H, hd).astype(jnp.float32)
    k = dense(params["k"], xi).reshape(B, S, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    v = dense(params["v"], xi).reshape(B, S, H, hd).astype(jnp.float32)
    ig = dense(params["igate"], xi).astype(jnp.float32)  # (B,S,H) log-space input gate
    fg = jax.nn.log_sigmoid(dense(params["fgate"], xi).astype(jnp.float32))  # (B,S,H) <= 0

    qc = q.reshape(B, nc, Q, H, hd)
    kc = k.reshape(B, nc, Q, H, hd)
    vc = v.reshape(B, nc, Q, H, hd)
    igc = ig.reshape(B, nc, Q, H)
    fgc = fg.reshape(B, nc, Q, H)
    cum = jnp.cumsum(fgc, axis=2)  # inclusive cumulative log forget
    total = cum[:, :, -1]  # (B,nc,H)

    # Intra-chunk: D[q,t] = exp(cum_q - cum_t + ig_t) for t <= q (log-space,
    # stabilized by the per-row max m).
    logD = cum[:, :, :, None, :] - cum[:, :, None, :, :] + igc[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    logD = jnp.where(causal[None, None, :, :, None], logD, -jnp.inf)

    # Inter-chunk: contribution weight for q against the entering state:
    # exp(cum_q) (state already carries its own stabilizer m_prev).
    def scan_fn(carry, inp):
        Cst, nst, mst = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        kcc, vcc, igcc, cumc, totc = inp
        # local chunk state in log-space with stabilizer m_loc
        w = cumc[:, -1][:, None] - cumc + igcc  # (B,Q,H): exp weight for k_t v_t
        m_loc = jnp.max(w, axis=1)  # (B,H)
        m_new = jnp.maximum(mst + totc, m_loc)
        scale_prev = jnp.exp(mst + totc - m_new)  # (B,H)
        wexp = jnp.exp(w - m_new[:, None, :])  # (B,Q,H)
        C_loc = jnp.einsum("bqh,bqhk,bqhv->bhkv", wexp, kcc, vcc)
        n_loc = jnp.einsum("bqh,bqhk->bhk", wexp, kcc)
        C_new = scale_prev[:, :, None, None] * Cst + C_loc
        n_new = scale_prev[:, :, None] * nst + n_loc
        return (C_new, n_new, m_new), (Cst, nst, mst)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    axes = lambda t: jnp.moveaxis(t, 1, 0)
    (_, _, _), (C_in, n_in, m_in) = jax.lax.scan(
        scan_fn, (C0, n0, m0), (axes(kc), axes(vc), axes(igc), axes(cum), axes(total))
    )
    C_in = jnp.moveaxis(C_in, 0, 1)  # (B,nc,H,hd,hd) state entering each chunk
    n_in = jnp.moveaxis(n_in, 0, 1)
    m_in = jnp.moveaxis(m_in, 0, 1)  # (B,nc,H)

    # Stabilized combination of intra and inter parts.
    m_intra = jnp.max(jnp.where(jnp.isfinite(logD), logD, -1e30), axis=3)  # (B,nc,Q,H)
    m_inter = cum + m_in[:, :, None, :]  # log weight scale of inter contribution
    m_row = jnp.maximum(m_intra, m_inter)  # (B,nc,Q,H)
    Dexp = jnp.exp(jnp.where(jnp.isfinite(logD), logD - m_row[:, :, :, None, :], -jnp.inf))
    Dexp = jnp.where(causal[None, None, :, :, None], Dexp, 0.0)

    qk = jnp.einsum("bcqhd,bcthd->bcqth", qc, kc)
    y_intra = jnp.einsum("bcqth,bcthv->bcqhv", qk * Dexp, vc)
    # mLSTM normalizer: n = sum_t D_t k_t (+ inter part), denom = max(|q.n|, exp(-m)).
    n_intra = jnp.einsum("bcqth,bcthd->bcqhd", Dexp, kc)

    w_inter = jnp.exp(m_inter - m_row)  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhd,bchdv,bcqh->bcqhv", qc, C_in, w_inter)
    n_inter = n_in[:, :, None, :, :] * w_inter[..., None]  # (B,nc,Q,H,hd)

    num = y_intra + y_inter  # (B,nc,Q,H,hd)
    nvec = n_intra + n_inter  # (B,nc,Q,H,hd)
    denom = jnp.abs(jnp.einsum("bcqhd,bcqhd->bcqh", qc, nvec))
    denom = jnp.maximum(denom, jnp.exp(-m_row))  # xLSTM: max(|q.n|, exp(-m))
    y = num / denom[..., None]

    y = y.reshape(B, S, di).astype(xin.dtype)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return dense(params["down"], y)


def init_mlstm_cache(cfg, batch):
    d = cfg.d_model
    x = cfg.xlstm
    di = int(x.proj_factor * d)
    H = cfg.num_heads
    hd = di // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(params, xin, cfg, cache):
    """One-token recurrent mLSTM step. xin: (B,1,d)."""
    x = cfg.xlstm
    d = cfg.d_model
    di = int(x.proj_factor * d)
    H = cfg.num_heads
    hd = di // H
    B = xin.shape[0]
    up = dense(params["up"], xin[:, 0])
    xi, z = up[..., :di], up[..., di:]
    q = dense(params["q"], xi).reshape(B, H, hd).astype(jnp.float32)
    k = dense(params["k"], xi).reshape(B, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    v = dense(params["v"], xi).reshape(B, H, hd).astype(jnp.float32)
    ig = dense(params["igate"], xi).astype(jnp.float32)  # (B,H)
    fg = jax.nn.log_sigmoid(dense(params["fgate"], xi).astype(jnp.float32))

    m_new = jnp.maximum(fg + cache["m"], ig)
    scale_prev = jnp.exp(fg + cache["m"] - m_new)
    scale_in = jnp.exp(ig - m_new)
    C = scale_prev[:, :, None, None] * cache["C"] + scale_in[:, :, None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k, v
    )
    n = scale_prev[:, :, None] * cache["n"] + scale_in[:, :, None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    y = (num / denom[..., None]).reshape(B, di).astype(xin.dtype)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = dense(params["down"], y)
    return out[:, None], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ks = jax.random.split(key, 4)
    # 4 gates (i, f, z, o), each with input weights and per-head recurrent
    # block-diagonal weights.
    return {
        "w_in": init_dense(ks[0], d, 4 * d, dtype=dtype),
        "r": (jax.random.normal(ks[1], (4, H, hd, hd)) * (1.0 / jnp.sqrt(hd))).astype(dtype),
        "bias": jnp.zeros((4 * d,), dtype=jnp.float32),
        "down": init_dense(ks[2], d, d, dtype=dtype),
        "norm": init_rmsnorm(d, dtype=dtype),
    }


def init_slstm_cache(cfg, batch):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.zeros((batch, H), jnp.float32)}


def _slstm_cell(params, cfg, xt, state):
    """One sLSTM time step. xt: (B, 4*d) pre-computed input projection."""
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("ghkv,bhk->bghv", params["r"].astype(jnp.float32), h)  # (B,4,H,hd)
    pre = xt.reshape(-1, 4, H, hd).astype(jnp.float32) + rec + params["bias"].reshape(
        4, H, hd
    )
    it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    # Exponential gating with stabilizer state m (per head: use max over dims).
    it_s = jnp.max(it, axis=-1)  # (B,H) head-level log input gate scale
    ft_s = jax.nn.log_sigmoid(jnp.mean(ft, axis=-1))  # (B,H)
    m_new = jnp.maximum(ft_s + m, it_s)
    i_gate = jnp.exp(it - m_new[..., None])
    f_gate = jnp.exp(ft_s + m - m_new)[..., None]
    c_new = f_gate * c + i_gate * jnp.tanh(zt)
    n_new = f_gate * n + i_gate
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(params, xin, cfg):
    """Sequential sLSTM over the sequence. xin: (B,S,d)."""
    B, S, d = xin.shape
    xproj = dense(params["w_in"], xin)  # (B,S,4d)

    state0 = init_slstm_cache(cfg, B)

    def step(state, xt):
        new = _slstm_cell(params, cfg, xt, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(xproj, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(xin.dtype)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    return dense(params["down"], y)


def slstm_decode(params, xin, cfg, cache):
    xt = dense(params["w_in"], xin[:, 0])
    new = _slstm_cell(params, cfg, xt, cache)
    y = new["h"].reshape(xin.shape[0], cfg.d_model).astype(xin.dtype)
    y = rms_norm(params["norm"], y, cfg.norm_eps)
    return dense(params["down"], y)[:, None], new
