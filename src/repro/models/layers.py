"""Shared building blocks: norms, projections, RoPE, SwiGLU, embeddings.

Parameters are plain nested dicts of jnp arrays; every init function has a
matching ``*_specs`` partner in ``repro.models.sharding`` that emits the
PartitionSpec tree of identical structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def init_dense(key, d_in, d_out, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(p, x, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_swiglu(key, d, f, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, f, dtype=dtype),
        "up": init_dense(k2, d, f, dtype=dtype),
        "down": init_dense(k3, f, d, dtype=dtype),
    }


def swiglu(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, head_dim); positions: (S,) or (..., S) absolute ids."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    return x @ p["table"].T


def cross_entropy_loss(logits, labels, mask=None):
    """Mean next-token NLL; logits (..., V), labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    nll = -ll
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
