"""Encoder-decoder transformer (seamless-m4t style speech-to-text backbone).

The modality frontend is the documented stub: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model) supplied by
``input_specs`` — we implement the transformer that processes them, a
bidirectional encoder + causal decoder with cross-attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    init_embedding,
    init_rmsnorm,
    init_swiglu,
    rms_norm,
    swiglu,
    unembed,
)

ENC_DOWNSAMPLE = 4  # stubbed conv frontend downsampling factor (frames -> d)


def enc_len(seq_len: int) -> int:
    return max(seq_len // ENC_DOWNSAMPLE, 1)


def _init_enc_block(key, cfg, dtype):
    ka, kf = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ka, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": attn_mod.init_attention(ka, cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": attn_mod.init_attention(kx, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg):
    dtype = dtype_of(cfg)
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    params = {
        "embed": init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(kh, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def encode(params, embeds, cfg, remat=True):
    """embeds: (B, S_enc, d) from the stubbed frontend."""
    from repro.models.sharding import constrain_batch

    x = constrain_batch(embeds.astype(dtype_of(cfg)))
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(layer_params, x):
        h, _ = attn_mod.attention(
            layer_params["attn"],
            rms_norm(layer_params["ln1"], x, cfg.norm_eps),
            cfg,
            positions=positions,
            causal=False,
        )
        x = x + h
        return x + swiglu(layer_params["ffn"], rms_norm(layer_params["ln2"], x, cfg.norm_eps))

    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, layer_params):
        return body(layer_params, x), None

    x, _ = jax.lax.scan(scan_fn, x, params["encoder"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(cfg, layer_params, x, memory, positions, window):
    h, _ = attn_mod.attention(
        layer_params["self_attn"],
        rms_norm(layer_params["ln1"], x, cfg.norm_eps),
        cfg,
        positions=positions,
        window=window,
    )
    x = x + h
    h, kv = attn_mod.attention(
        layer_params["cross_attn"],
        rms_norm(layer_params["ln_x"], x, cfg.norm_eps),
        cfg,
        kv_memory=memory,
    )
    x = x + h
    return x + swiglu(layer_params["ffn"], rms_norm(layer_params["ln2"], x, cfg.norm_eps)), kv


def forward(params, embeds, tokens, cfg, remat=True):
    memory = encode(params, embeds, cfg, remat=remat)
    x = embed(params["embed"], tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S)

    body = functools.partial(_dec_block, cfg)
    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, layer_params):
        x, _ = body(layer_params, x, memory, positions, cfg.sliding_window)
        return x, None

    x, _ = jax.lax.scan(scan_fn, x, params["decoder"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, remat=True):
    logits, aux = forward(params, batch["embeds"], batch["tokens"][:, :-1], cfg, remat=remat)
    return cross_entropy_loss(logits, batch["tokens"][:, 1:]) + aux


def prefill(params, embeds, tokens, cfg, max_len=None):
    """Encode + run decoder prompt; build self-attn caches and cross K/V."""
    from repro.models.sharding import constrain_batch

    memory = encode(params, embeds, cfg, remat=False)
    B, S = tokens.shape
    max_len = max_len if max_len is not None else S
    x = constrain_batch(embed(params["embed"], tokens))
    positions = jnp.arange(S)
    dtype = dtype_of(cfg)
    cache0 = attn_mod.init_cache(cfg, B, max_len, dtype)

    def scan_fn(x, layer_params):
        x = constrain_batch(x)
        h_in = rms_norm(layer_params["ln1"], x, cfg.norm_eps)
        h, (k, v) = attn_mod.attention(
            layer_params["self_attn"], h_in, cfg, positions=positions, window=cfg.sliding_window
        )
        self_cache = attn_mod.prefill_into_cache(cfg, cache0, k, v, S)
        x = x + h
        h, (mk, mv) = attn_mod.attention(
            layer_params["cross_attn"], rms_norm(layer_params["ln_x"], x, cfg.norm_eps), cfg,
            kv_memory=memory,
        )
        x = x + h
        x = x + swiglu(layer_params["ffn"], rms_norm(layer_params["ln2"], x, cfg.norm_eps))
        return x, {"self": self_cache, "mem_k": mk, "mem_v": mv}

    x, caches = jax.lax.scan(scan_fn, x, params["decoder"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x[:, -1:])
    return logits, caches


def init_cache(params, cfg, batch, max_len, enc_seq=None):
    """Decode-cache template (self-attn ring/full + cross-attn memory K/V)."""
    dtype = dtype_of(cfg)
    enc_seq = enc_seq if enc_seq is not None else enc_len(max_len)
    hd = cfg.resolved_head_dim
    one = {
        "self": attn_mod.init_cache(cfg, batch, max_len, dtype),
        "mem_k": jnp.zeros((batch, enc_seq, cfg.num_kv_heads, hd), dtype),
        "mem_v": jnp.zeros((batch, enc_seq, cfg.num_kv_heads, hd), dtype),
    }
    return jax.tree.map(lambda c: jnp.broadcast_to(c, (cfg.num_layers, *c.shape)), one)


def decode_step(params, token, cfg, caches, pos):
    x = embed(params["embed"], token)

    def scan_fn(x, inp):
        layer_params, cache = inp
        h_in = rms_norm(layer_params["ln1"], x, cfg.norm_eps)
        h, new_self = attn_mod.decode_attention(
            layer_params["self_attn"], h_in, cfg, cache["self"], pos
        )
        x = x + h
        h = attn_mod.decode_cross_attention(
            layer_params["cross_attn"],
            rms_norm(layer_params["ln_x"], x, cfg.norm_eps),
            cfg,
            cache["mem_k"],
            cache["mem_v"],
        )
        x = x + h
        x = x + swiglu(layer_params["ffn"], rms_norm(layer_params["ln2"], x, cfg.norm_eps))
        return x, {"self": new_self, "mem_k": cache["mem_k"], "mem_v": cache["mem_v"]}

    x, new_caches = jax.lax.scan(scan_fn, x, (params["decoder"], caches))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("lm_head", params["embed"]), x)
    return logits, new_caches
