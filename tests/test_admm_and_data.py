"""Tests for the ADMM baseline (Fig. 1 comparison), local-DP baseline and the
data generators."""

import numpy as np
import pytest

from repro.core import AgentData, make_objective, run_admm, run_scan, perturb_dataset
from repro.data.movielens import movielens_twin, rmse
from repro.data.synthetic import linear_classification_problem, eval_accuracy


@pytest.fixture(scope="module")
def quad_problem():
    prob = linear_classification_problem(n=8, p=5, m_low=5, m_high=15, seed=13)
    X = prob.train.X
    y = np.einsum("nmp,np->nm", X, prob.targets) * prob.train.mask
    data = AgentData(X=X, y=y, mask=prob.train.mask)
    return make_objective(prob.graph, data, "quadratic", mu=0.5)


def test_admm_decreases_objective_toward_optimum(quad_problem):
    obj = quad_problem
    q_star = float(obj.value(obj.solve_exact()))
    rng = np.random.default_rng(0)
    Theta0 = np.zeros((obj.n, obj.p))
    res = run_admm(obj, Theta0, T=400, rng=rng, rho=1.0, local_grad_steps=10)
    gap0 = res.objective[0] - q_star
    gapT = res.objective[-1] - q_star
    assert gapT < 0.3 * gap0  # clear progress toward the same optimum


def test_cd_beats_admm_per_message(quad_problem):
    """The paper's Fig.-1 claim: CD reaches a lower objective than ADMM for
    the same number of p-dimensional vectors transmitted."""
    obj = quad_problem
    rng = np.random.default_rng(1)
    Theta0 = np.zeros((obj.n, obj.p))
    admm = run_admm(obj, Theta0, T=150, rng=rng, local_grad_steps=10)
    budget = admm.messages[-1]
    # Run CD until it has used the same message budget.
    cd = run_scan(obj, Theta0, T=2000, rng=np.random.default_rng(2))
    k = int(np.searchsorted(cd.messages, budget))
    k = min(k, len(cd.objective) - 1)
    assert cd.objective[k] < admm.objective[-1]


def test_local_dp_perturbation_destroys_little_at_huge_eps():
    prob = linear_classification_problem(n=6, p=4, m_low=10, m_high=20, seed=17)
    pert = perturb_dataset(prob.train, eps=1e7, rng=np.random.default_rng(0))
    assert np.abs(pert.X - prob.train.X).max() < 1e-2
    # tiny eps -> heavy damage
    pert2 = perturb_dataset(prob.train, eps=0.1, rng=np.random.default_rng(0))
    assert np.abs(pert2.X - prob.train.X).max() > 1.0


def test_synthetic_problem_statistics():
    prob = linear_classification_problem(n=20, p=10, seed=19)
    m = prob.train.num_examples
    assert m.min() >= 10 and m.max() <= 100
    assert prob.graph.is_connected()
    # features unit-normalized -> logistic loss 1-Lipschitz wrt L2
    norms = np.linalg.norm(prob.train.X, axis=-1)
    assert norms.max() <= 1.0 + 1e-9
    # targets produce balanced-ish labels
    frac_pos = (prob.train.y * prob.train.mask > 0).sum() / prob.train.mask.sum()
    assert 0.2 < frac_pos < 0.8


def test_movielens_twin_statistics():
    tw = movielens_twin(n_users=120, n_items=300, p=8, rank=8, als_iters=5, seed=23)
    m = tw.train.num_examples
    assert m.min() >= 15  # 80% of >= 20
    assert tw.graph.is_connected() or tw.graph.num_edges() > 0
    # ALS features allow a linear fit much better than predicting 0 (= user mean).
    base = rmse(np.zeros((120, 8)), tw.test)
    # Ridge per user on train:
    theta = np.zeros((120, 8))
    for u in range(120):
        Xu = tw.train.X[u][tw.train.mask[u] > 0]
        yu = tw.train.y[u][tw.train.mask[u] > 0]
        theta[u] = np.linalg.solve(Xu.T @ Xu + 0.1 * np.eye(8), Xu.T @ yu)
    fit = rmse(theta, tw.test)
    assert fit < base
