"""Dynamic topology: versioned TopologyState, Dada-style edge refresh,
agent arrivals, and the engines' patch/repartition policy.

In-process tests run on the 1 visible CPU device (dynamic mode on a
1-shard mesh must already agree with the single-device engine). The
multi-shard semantics — pre/post-refresh parity across 4 shards, a full
churn + arrival run, and a forced ``patch()``/repartition — run in a
subprocess with 8 XLA host devices, in the ``test_sharded_engine.py``
style, so this process keeps seeing 1 device."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgentData, knn_graph, make_objective
from repro.core.graph import TopologyState, as_csr, knn_cosine_graph
from repro.sim import (
    ArrivalConfig,
    AsyncEngine,
    CDUpdate,
    DelayConfig,
    EngineConfig,
    GraphUpdate,
    Scenario,
    ShardedAsyncEngine,
)


def _quad_problem(n, p=3, m=3, seed=0, mu=0.5, k=6, targets=None):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 6)), k=k)
    if targets is None:
        targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode="sparse")


# ---------------------------------------------------------------- graph layer


def test_topology_state_roundtrip_and_capacity():
    obj = _quad_problem(24, seed=0)
    csr = as_csr(obj.graph)
    topo = TopologyState.from_csr(csr)
    back = topo.to_csr()
    np.testing.assert_array_equal(back.indptr, csr.indptr)
    np.testing.assert_array_equal(back.indices, csr.indices)
    np.testing.assert_allclose(back.data, csr.data)
    assert topo.capacity >= csr.max_degree()
    assert int(np.asarray(topo.version)) == 0
    # Weighted degrees / live-slot counts agree with the CSR view.
    np.testing.assert_allclose(np.asarray(topo.degrees()), csr.degrees)
    np.testing.assert_array_equal(
        np.asarray(topo.neighbor_counts()), np.diff(csr.indptr)
    )
    with pytest.raises(ValueError, match="capacity"):
        TopologyState.from_csr(csr, capacity=csr.max_degree() - 1)


def test_topology_state_in_jit_edge_ops():
    """The three slot mutators are pure scatters — usable under jit, with
    symmetric effect and a version bump per call."""
    obj = _quad_problem(16, seed=1)
    csr = as_csr(obj.graph)
    topo = TopologyState.from_csr(csr, slack=4)
    i, j = 0, int(csr.neighbors(0)[0])
    rows = jnp.asarray([i])
    cols = jnp.asarray([j])

    @jax.jit
    def mutate(t):
        t = t.with_edge_weights(rows, cols, jnp.asarray([2.5]))
        t = t.deactivate_edges(rows, cols)
        t = t.activate_edges(rows, cols, jnp.asarray([0.75]))
        return t

    out = mutate(topo)
    assert int(np.asarray(out.version)) == 3
    new_csr = out.to_csr()
    nb, w = new_csr.row(i)
    assert w[list(nb).index(j)] == 0.75
    nb_j, w_j = new_csr.row(j)
    assert w_j[list(nb_j).index(i)] == 0.75  # symmetric by construction
    # Everything else untouched.
    dense_before = np.zeros((csr.n, csr.n))
    r = np.repeat(np.arange(csr.n), np.diff(csr.indptr))
    dense_before[r, csr.indices] = csr.data
    dense_after = np.zeros_like(dense_before)
    r2 = np.repeat(np.arange(new_csr.n), np.diff(new_csr.indptr))
    dense_after[r2, new_csr.indices] = new_csr.data
    dense_before[i, j] = dense_before[j, i] = 0.75
    np.testing.assert_allclose(dense_after, dense_before)


def test_apply_edge_updates_grows_capacity_in_multiples_of_8():
    obj = _quad_problem(20, seed=2, k=4)
    topo = TopologyState.from_csr(as_csr(obj.graph))
    cap = topo.capacity
    # Attach row 0 to every other agent: max degree jumps past capacity.
    others = np.arange(1, 20)
    grown = topo.apply_edge_updates(
        add_rows=np.zeros_like(others), add_cols=others, add_vals=np.ones(19)
    )
    assert grown.capacity >= 19 and grown.capacity % 8 == 0
    assert grown.capacity >= cap  # never shrinks
    assert int(np.asarray(grown.version)) == 1
    nb, _ = grown.to_csr().row(0)
    assert set(nb) == set(range(1, 20))


def test_knn_cosine_chunked_matches_unchunked_and_sparse():
    """The streamed (block_rows) top-k must select the same graph as a
    single-slab pass, and sparse=True the same graph again in CSR form."""
    rng = np.random.default_rng(3)
    feats = rng.normal(size=(57, 9))
    dense = knn_cosine_graph(feats, k=7, block_rows=57)
    for block in (1, 8, 13):
        chunked = knn_cosine_graph(feats, k=7, block_rows=block)
        np.testing.assert_allclose(chunked.weights, dense.weights)
    sp = knn_cosine_graph(feats, k=7, block_rows=8, sparse=True)
    np.testing.assert_allclose(sp.to_dense().weights, dense.weights)


# --------------------------------------------------------------- update layer


def test_graph_update_refresh_deterministic_symmetric_connected():
    obj = _quad_problem(40, seed=4)
    csr = as_csr(obj.graph)
    rng = np.random.default_rng(0)
    Theta = rng.normal(size=(40, 3))
    gu = GraphUpdate(every=5, k=5, candidates=6, gamma=2.0, seed=9)
    a = gu.refresh(csr, Theta, round_index=3)
    b = gu.refresh(csr, Theta, round_index=3)
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_allclose(a.data, b.data)
    c = gu.refresh(csr, Theta, round_index=4)
    assert not (
        np.array_equal(a.indices, c.indices) and np.allclose(a.data, c.data)
    ), "distinct rounds should draw distinct candidates"
    # Structural invariants: symmetric, no self loops, no orphans.
    dense = np.zeros((40, 40))
    r = np.repeat(np.arange(40), np.diff(a.indptr))
    dense[r, a.indices] = a.data
    np.testing.assert_allclose(dense, dense.T)
    assert np.all(np.diag(dense) == 0)
    assert (np.diff(a.indptr) >= 1).all()


def test_graph_update_allowed_mask_freezes_outside_edges():
    """Edges touching a non-allowed agent pass through frozen (same
    weight), and no new edge may attach to a non-allowed agent."""
    obj = _quad_problem(30, seed=5)
    csr = as_csr(obj.graph)
    Theta = np.random.default_rng(1).normal(size=(30, 3))
    allowed = np.ones(30, bool)
    blocked = [4, 11, 27]
    allowed[blocked] = False
    gu = GraphUpdate(every=1, k=4, candidates=6, gamma=2.0, seed=2)
    out = gu.refresh(csr, Theta, round_index=1, allowed=allowed)

    def edge_set(g, pred):
        rows = np.repeat(np.arange(g.n), np.diff(g.indptr))
        keep = pred(rows, g.indices)
        return {
            (int(i), int(j)): float(v)
            for i, j, v in zip(rows[keep], g.indices[keep], g.data[keep])
        }

    touch = lambda r, c: ~allowed[r] | ~allowed[c]
    assert edge_set(out, touch) == edge_set(csr, touch)
    # Fully-allowed refresh with the same seed/round matches the masked
    # refresh on the allowed<->allowed subgraph rng-stream-stably? Not
    # required — but the masked result must differ somewhere, proving the
    # mask didn't simply freeze the whole graph.
    both = lambda r, c: allowed[r] & allowed[c]
    assert edge_set(out, both) != edge_set(csr, both)


# --------------------------------------------------------- single-device engine


def test_dynamic_engine_no_refresh_matches_static_bitwise():
    """Static anchor: with a GraphUpdate that never fires, the dynamic
    slot path (capacity-padded tiles + consts gather) must reproduce the
    static engine bit-for-bit under forced wakes in f64."""
    obj = _quad_problem(20, seed=0, p=3)
    n, p = obj.n, obj.p
    stat = AsyncEngine(CDUpdate(obj), slot_wakes=6.0, seed=7, dtype=jnp.float64)
    dyn = AsyncEngine(
        CDUpdate(obj),
        config=EngineConfig(
            graph_update=GraphUpdate(every=10**9),
            slot_wakes=6.0,
            seed=7,
            dtype=jnp.float64,
        ),
    )
    assert dyn.dynamic and not stat.dynamic
    ss, sd = stat.init_state(np.zeros((n, p))), dyn.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(0)
    for _ in range(6):
        mask = rng.random(n) < 0.4
        ss = stat.step(ss, mask)
        sd = dyn.step(sd, mask)
    np.testing.assert_array_equal(np.asarray(ss.Theta), np.asarray(sd.Theta))
    assert float(ss.messages) == float(sd.messages)
    assert int(ss.applied) == int(sd.applied)


def test_dynamic_run_fires_refreshes_and_objective_decreases():
    obj = _quad_problem(20, seed=0, p=3)
    gu = GraphUpdate(every=5, k=5, candidates=4, gamma=2.0)
    eng = AsyncEngine(
        CDUpdate(obj),
        config=EngineConfig(slot_wakes=6.0, seed=3, graph_update=gu,
                            dtype=jnp.float64, metrics=True),
    )
    res = eng.run(np.zeros((obj.n, obj.p)), 20, record_every=10)
    counters = eng.topology_counters()
    assert counters["edge_refreshes"] == 3  # slots 5, 10, 15 (not 20)
    assert counters["edges_added"] > 0 or counters["edges_removed"] > 0
    assert res.objective[-1] <= res.objective[0]
    # Dynamic runs surface topology counters through metrics_snapshot.
    _, derived = eng.metrics_snapshot(eng.init_state(np.zeros((obj.n, obj.p))))
    assert "topology_edge_refreshes" in derived


def test_arrivals_detach_then_admit_with_warm_start():
    obj = _quad_problem(20, seed=0, p=3)
    arr = ArrivalConfig(schedule=((5, (18, 19)),), attach_k=3, seed=1)
    eng = AsyncEngine(
        CDUpdate(obj),
        config=EngineConfig(
            slot_wakes=6.0, seed=3, dtype=jnp.float64,
            scenario=Scenario(arrival=arr),
            graph_update=GraphUpdate(every=5, k=5, candidates=4, gamma=2.0),
        ),
    )
    st = eng.init_state(np.zeros((obj.n, obj.p)))
    assert list(np.flatnonzero(~np.asarray(st.active))) == [18, 19]
    # Pending agents are edge-detached: their rows have no live edges.
    assert (np.diff(eng._csr.indptr)[[18, 19]] == 0).all()
    res = eng.run(np.zeros((obj.n, obj.p)), 12)
    counters = eng.topology_counters()
    assert counters["arrivals"] == 2
    assert bool(np.asarray(res.active).all())
    # Eq. 16 warm start: arrived rows are live (nonzero) immediately.
    assert (np.abs(res.Theta[[18, 19]]).sum(axis=1) > 0).all()


def test_warm_arrivals_start_closer_than_cold():
    """The Eq. 16 warm start must land the arriving agents nearer their
    converged parameters than a cold (zero) start, at admission time.

    Targets share a cluster center: the propagation warm start is a
    neighbour average, which only beats zero when the graph-regularized
    solution is smooth across the attachment neighbourhood (iid random
    targets would make the neighbour average uninformative)."""
    rng = np.random.default_rng(6)
    n, p = 24, 3
    targets = rng.normal(size=(1, p)) + 0.15 * rng.normal(size=(n, p))
    obj = _quad_problem(n, seed=6, p=p, targets=targets)
    star = obj.solve_exact()
    ids = (22, 23)

    def admitted_rows(warm):
        arr = ArrivalConfig(schedule=((7, ids),), attach_k=4, seed=1,
                            warm_start=warm)
        eng = AsyncEngine(
            CDUpdate(obj),
            config=EngineConfig(slot_wakes=8.0, seed=3, dtype=jnp.float64,
                                scenario=Scenario(arrival=arr)),
        )
        st = eng.init_state(np.zeros((obj.n, obj.p)))
        st = eng.advance(st, 6)  # slots 1..6: arrivals still pending
        st = eng.admit(st, list(ids))
        return np.asarray(st.Theta)[list(ids)]

    warm, cold = admitted_rows(True), admitted_rows(False)
    assert np.allclose(cold, 0.0)
    d_warm = np.linalg.norm(warm - star[list(ids)])
    d_cold = np.linalg.norm(cold - star[list(ids)])
    assert d_warm < d_cold


def test_dada_refresh_beats_fixed_graph_on_clustered_targets():
    """Dada-style joint optimization (arXiv 1901.08460): on clustered
    targets with an uninformative initial graph, refreshing edges by
    model similarity must end nearer the true targets than the fixed
    graph, which keeps averaging across clusters."""
    rng = np.random.default_rng(8)
    n, p, m = 32, 3, 2
    centers = np.stack([np.ones(p), -np.ones(p)])
    labels = np.arange(n) % 2
    targets = centers[labels] + 0.1 * rng.normal(size=(n, p))
    obj = _quad_problem(n, p=p, m=m, seed=8, mu=0.4, targets=targets)

    def final_error(gu):
        eng = AsyncEngine(
            CDUpdate(obj),
            config=EngineConfig(slot_wakes=float(n), seed=5,
                                dtype=jnp.float64, graph_update=gu),
        )
        res = eng.run(np.zeros((n, p)), 60)
        return float(np.linalg.norm(res.Theta - targets, axis=1).mean())

    fixed = final_error(GraphUpdate(every=10**9))
    dada = final_error(GraphUpdate(every=5, k=6, candidates=8, gamma=8.0))
    assert dada < fixed, (dada, fixed)


# ------------------------------------------------------------- sharded engine


def test_sharded_dynamic_single_shard_matches_single_device():
    """S=1 dynamic mesh: forced wakes reproduce the single-device dynamic
    engine exactly before any refresh, and the refreshed graphs and
    counters agree through a refresh + further steps."""
    obj = _quad_problem(24, seed=1, p=3)
    n, p = obj.n, obj.p
    cfg = EngineConfig(slot_wakes=6.0, seed=5, dtype=jnp.float64,
                       graph_update=GraphUpdate(every=4, k=5, candidates=4,
                                                gamma=2.0))
    single = AsyncEngine(CDUpdate(obj), config=cfg)
    shard = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, config=cfg)
    ss, sh = single.init_state(np.zeros((n, p))), shard.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(0)
    for _ in range(3):
        mask = rng.random(n) < 0.4
        ss = single.step(ss, mask)
        sh = shard.step(sh, mask)
    np.testing.assert_array_equal(np.asarray(ss.Theta), shard.global_theta(sh))
    # Same refresh on both: identical graphs, then near-identical steps
    # (the capacity-padded gather and the halo gather may sum the same
    # neighbourhood in different orders after a rewire).
    ss = single._refresh_topology(ss, 1)
    sh = shard._refresh_topology(sh, 1)
    np.testing.assert_array_equal(single._csr.indptr, shard._csr.indptr)
    np.testing.assert_array_equal(single._csr.indices, shard._csr.indices)
    np.testing.assert_allclose(single._csr.data, shard._csr.data)
    for _ in range(3):
        mask = rng.random(n) < 0.4
        ss = single.step(ss, mask)
        sh = shard.step(sh, mask)
    np.testing.assert_allclose(
        np.asarray(ss.Theta), shard.global_theta(sh), atol=1e-12, rtol=0.0
    )
    assert shard.topology_counters()["edge_refreshes"] == 1


def test_sharded_set_topology_policy_counters():
    """Weight-only retile, structural patch, and the drift-forced full
    repartition each land in their own counter."""
    obj = _quad_problem(24, seed=2, p=3)
    n, p = obj.n, obj.p
    base = EngineConfig(slot_wakes=6.0, seed=5, dtype=jnp.float64,
                        graph_update=GraphUpdate(every=4))
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, config=base)
    st = eng.init_state(np.zeros((n, p)))
    # 1) same structure, new weights -> weight patch.
    csr = eng._csr
    reweighted = type(csr)(
        indptr=csr.indptr, indices=csr.indices, data=csr.data * 2.0
    )
    st = eng.set_topology(st, reweighted)
    assert eng.topology_counters()["weight_patches"] == 1
    # 2) structural change under the drift threshold -> structural patch.
    gu = GraphUpdate(every=1, k=5, candidates=2, gamma=1.0)
    st = eng.set_topology(st, gu.refresh(eng._csr, np.zeros((n, p))))
    assert eng.topology_counters()["structural_patches"] == 1
    # 3) negative threshold forces the full rebuild path.
    forced = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, config=base.replace(drift_threshold=-10.0)
    )
    st2 = forced.init_state(np.zeros((n, p)))
    st2 = forced._refresh_topology(st2, 1)
    assert forced.topology_counters()["repartitions"] == 1
    st2 = forced.step(st2, np.ones(n, bool))
    assert np.isfinite(forced.global_theta(st2)).all()


def test_dynamic_mode_rejects_unsupported_configs():
    obj = _quad_problem(16, seed=3)
    gu = GraphUpdate(every=4)
    with pytest.raises(ValueError, match="fused"):
        AsyncEngine(CDUpdate(obj),
                    config=EngineConfig(graph_update=gu, fused=True))
    with pytest.raises(NotImplementedError, match="delay"):
        AsyncEngine(
            CDUpdate(obj),
            config=EngineConfig(
                graph_update=gu,
                scenario=Scenario(delay=DelayConfig(max_delay=1)),
            ),
        )
    # A prebuilt partition cannot be reused once arrivals detach edges.
    from repro.sim import partition_graph

    part = partition_graph(as_csr(obj.graph), 1)
    arr = ArrivalConfig(schedule=((2, (15,)),))
    with pytest.raises(ValueError, match="partition"):
        ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1,
            config=EngineConfig(partition=part,
                                scenario=Scenario(arrival=arr)),
        )
    # Topology swaps validate shape and connectivity of non-pending rows.
    eng = AsyncEngine(CDUpdate(obj), config=EngineConfig(graph_update=gu))
    st = eng.init_state(np.zeros((obj.n, obj.p)))
    with pytest.raises(ValueError):
        eng.set_topology(as_csr(_quad_problem(8, seed=0).graph))
    # ... and reject any swap that orphans an established agent (Eq. 4 /
    # Eq. 16 divide by the degree the moment the agent wakes).
    csr = as_csr(obj.graph)
    rows, cols, vals = csr.row_ids(), csr.indices, csr.data
    keep = (rows != 0) & (cols != 0)
    from repro.core.graph import csr_from_coo

    orphaned = csr_from_coo(obj.n, rows[keep], cols[keep], vals[keep])
    with pytest.raises(ValueError, match="no neighbours"):
        eng.set_topology(orphaned)


# ------------------------------------------------------- 8-device subprocess

MULTIDEV_DYNAMIC_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, knn_graph, make_objective
    from repro.sim import (ArrivalConfig, AsyncEngine, CDUpdate, ChurnConfig,
                           EngineConfig, GraphUpdate, Scenario,
                           ShardedAsyncEngine)

    assert len(jax.devices()) == 8

    def prob(n=48, p=3, m=3, seed=0):
        rng = np.random.default_rng(seed)
        graph = knn_graph(rng.normal(size=(n, 6)), k=6)
        targets = rng.normal(size=(n, p)) / np.sqrt(p)
        X = rng.normal(size=(n, m, p)) / np.sqrt(p)
        y = np.einsum("nmp,np->nm", X, targets)
        data = AgentData(X=X, y=y, mask=np.ones((n, m)))
        return make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")

    obj = prob()
    n, p = obj.n, obj.p
    T0 = np.zeros((n, p))
    gu = GraphUpdate(every=4, k=6, candidates=4, gamma=2.0)
    arr = ArrivalConfig(schedule=((6, (46, 47)),), attach_k=3, seed=1)
    cfg = EngineConfig(slot_wakes=8.0, seed=5, dtype=jnp.float64,
                       graph_update=gu, scenario=Scenario(arrival=arr),
                       drift_threshold=0.25)

    # 1) Forced-wake parity, single vs 4 shards, dynamic mode: exact
    #    before any refresh; identical refreshed graphs; tiny-atol equal
    #    after (gather order inside a rewired row may differ).
    single = AsyncEngine(CDUpdate(obj), config=cfg)
    shard = ShardedAsyncEngine(CDUpdate(obj), num_shards=4, config=cfg)
    ss, sh = single.init_state(T0), shard.init_state(T0)
    rng = np.random.default_rng(0)
    for _ in range(3):
        mask = rng.random(n) < 0.4
        ss = single.step(ss, mask); sh = shard.step(sh, mask)
    pre = np.abs(np.asarray(ss.Theta) - shard.global_theta(sh)).max()
    assert pre == 0.0, pre
    ss = single._refresh_topology(ss, 1)
    sh = shard._refresh_topology(sh, 1)
    assert np.array_equal(single._csr.indptr, shard._csr.indptr)
    assert np.array_equal(single._csr.indices, shard._csr.indices)
    assert np.allclose(single._csr.data, shard._csr.data)
    for _ in range(3):
        mask = rng.random(n) < 0.4
        ss = single.step(ss, mask); sh = shard.step(sh, mask)
    post = np.abs(np.asarray(ss.Theta) - shard.global_theta(sh)).max()
    assert post < 1e-12, post
    print("DYNAMIC_PARITY_OK")

    # 2) Full sampled run: churn + refreshes + arrivals on 4 shards.
    run_cfg = EngineConfig(slot_wakes=8.0, seed=5, dtype=jnp.float64,
                           graph_update=gu,
                           scenario=Scenario(arrival=arr,
                                             churn=ChurnConfig(leave_prob=0.05)),
                           drift_threshold=0.25)
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=4, config=run_cfg)
    res = eng.run(T0, 16, record_every=8)
    c = eng.topology_counters()
    assert c["edge_refreshes"] == 3, c
    assert c["arrivals"] == 2, c
    assert c["weight_patches"] + c["structural_patches"] + c["repartitions"] > 0, c
    assert np.isfinite(res.Theta).all()
    assert res.objective[-1] <= res.objective[0]
    print("DYNAMIC_RUN_OK")

    # 3) Forced repartition: drift threshold below any drift makes every
    #    structural swap a full rebuild + state re-layout.
    eng2 = ShardedAsyncEngine(CDUpdate(obj), num_shards=4,
                              config=cfg.replace(drift_threshold=-10.0,
                                                 scenario=None))
    st = eng2.init_state(T0)
    st = eng2._refresh_topology(st, 1)
    assert eng2.topology_counters()["repartitions"] == 1
    st = eng2.step(st, np.random.default_rng(1).random(n) < 0.4)
    assert np.isfinite(eng2.global_theta(st)).all()
    print("REPARTITION_OK")
    """
)


def _run_multidev(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )


def test_sharded_dynamic_topology_multidevice():
    res = _run_multidev(MULTIDEV_DYNAMIC_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("DYNAMIC_PARITY_OK", "DYNAMIC_RUN_OK", "REPARTITION_OK"):
        assert marker in res.stdout, res.stdout
