"""Fused super-tick kernel + compressed halo exchange + config API tests.

In-process: single-device fused-vs-unfused forced-wake parity (dense and
sparse mix backends, CD and DP updates), the ExchangeSpec deprecation
shim, and the EngineConfig/make_engine factory. Subprocess (8 forced
host devices): the fused parity matrix across S=4 x {all_gather, p2p} x
{f32, bf16} wires, and the compressed fixed-point acceptance — bf16
halos with error feedback land within 1e-4 of the exact optimum while
plain bf16 halos do not.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgentData, DPConfig, knn_graph, make_objective
from repro.core.mixing import ExchangeSpec
from repro.sim import (
    AsyncEngine,
    CDUpdate,
    DPCDUpdate,
    EngineConfig,
    ShardedAsyncEngine,
    make_engine,
)

FUSED_TOL = 1e-6  # recorded deviation: f32 reduction-order, see DEVIATIONS.md


def _quad_problem(n, p=4, m=3, seed=0, mix_mode="sparse", clip=None):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=0.5, mix_mode=mix_mode, clip=clip)


def _forced_run(engine, n, masks):
    state = engine.init_state(np.zeros((n, engine.p)))
    for mask in masks:
        state = engine.step(state, mask)
    return state


# ---------------------------------------------------------------------------
# single-device fused parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mix_mode", ["sparse", "dense"])
def test_fused_forced_wakes_match_unfused_single_device(mix_mode):
    obj = _quad_problem(48, mix_mode=mix_mode, seed=1)
    n = obj.n
    rng = np.random.default_rng(5)
    masks = [rng.random(n) < 0.25 for _ in range(8)]
    s0 = _forced_run(AsyncEngine(CDUpdate(obj), slot_wakes=8.0, fused=False), n, masks)
    s1 = _forced_run(AsyncEngine(CDUpdate(obj), slot_wakes=8.0, fused=True), n, masks)
    np.testing.assert_allclose(
        np.asarray(s1.Theta), np.asarray(s0.Theta), rtol=0, atol=FUSED_TOL
    )
    assert int(s1.applied) == int(s0.applied)


def test_fused_dp_parity_including_budget_stop():
    """DP-CD fused path: same noise draws, same budget accounting — agents
    freeze after planned_Ti wakes on both paths."""
    obj = _quad_problem(24, seed=2, clip=1.0)
    n = obj.n
    upd = lambda: DPCDUpdate.plan(obj, DPConfig(eps_bar=0.8), planned_Ti=3)
    masks = [np.ones(n, bool)] * 5  # 5 all-wake slots > planned_Ti=3
    s0 = _forced_run(AsyncEngine(upd(), slot_wakes=float(n), fused=False), n, masks)
    s1 = _forced_run(AsyncEngine(upd(), slot_wakes=float(n), fused=True), n, masks)
    np.testing.assert_allclose(
        np.asarray(s1.Theta), np.asarray(s0.Theta), rtol=0, atol=FUSED_TOL
    )
    assert np.array_equal(np.asarray(s1.ustate), np.asarray(s0.ustate))
    assert np.array_equal(np.asarray(s1.ustate), np.full(n, 3))


def test_fused_sharded_single_shard_matches_single_device():
    obj = _quad_problem(32, seed=3)
    n = obj.n
    rng = np.random.default_rng(9)
    masks = [rng.random(n) < 0.3 for _ in range(6)]
    s0 = _forced_run(AsyncEngine(CDUpdate(obj), slot_wakes=8.0, fused=False), n, masks)
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, slot_wakes=8.0, fused=True)
    sS = _forced_run(eng, n, masks)
    np.testing.assert_allclose(
        eng.global_theta(sS), np.asarray(s0.Theta), rtol=0, atol=FUSED_TOL
    )


def test_fused_true_raises_for_unsupported_update():
    """fused=True is a hard request: non-quadratic losses have no fused
    kernel and must fail loudly, not silently fall back."""
    obj = _quad_problem(16, seed=0)
    rng = np.random.default_rng(0)
    y = np.sign(rng.normal(size=(16, 3)))
    logistic = make_objective(
        obj.graph, AgentData(X=np.asarray(obj.data.X), y=y, mask=np.ones((16, 3))),
        "logistic", mu=0.5,
    )
    with pytest.raises(ValueError, match="fused"):
        AsyncEngine(CDUpdate(logistic), fused=True)
    # "auto" silently resolves off instead.
    eng = AsyncEngine(CDUpdate(logistic), fused="auto")
    assert eng.fused is False


# ---------------------------------------------------------------------------
# ExchangeSpec + deprecation shim
# ---------------------------------------------------------------------------


def test_exchange_spec_validation_and_strings():
    spec = ExchangeSpec.from_string("p2p:bf16:ef")
    assert (spec.method, spec.dtype, spec.error_feedback) == ("p2p", "bf16", True)
    assert ExchangeSpec.from_string("auto") == ExchangeSpec()
    with pytest.raises(ValueError):
        ExchangeSpec(method="ring")
    with pytest.raises(ValueError):
        ExchangeSpec(dtype="f16")
    with pytest.raises(ValueError):  # EF over a lossless wire is meaningless
        ExchangeSpec(dtype="f32", error_feedback=True)
    with pytest.raises(TypeError):
        ExchangeSpec.coerce(123)
    assert ExchangeSpec(dtype="bf16").payload_bytes_per_row(8) == 16
    assert ExchangeSpec(dtype="int8").payload_bytes_per_row(8) == 12  # q + scale
    assert ExchangeSpec().payload_bytes_per_row(8) == 32


def test_deprecated_exchange_string_warns_and_matches_spec():
    obj = _quad_problem(32, seed=4)
    n = obj.n
    rng = np.random.default_rng(2)
    masks = [rng.random(n) < 0.3 for _ in range(4)]
    with pytest.warns(DeprecationWarning, match="ExchangeSpec"):
        old = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, slot_wakes=8.0,
                                 exchange="p2p")
    new = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, slot_wakes=8.0,
                             exchange=ExchangeSpec(method="p2p"))
    s_old = _forced_run(old, n, masks)
    s_new = _forced_run(new, n, masks)
    assert np.array_equal(old.global_theta(s_old), new.global_theta(s_new))
    assert old.exchange_method == new.exchange_method == "p2p"


def test_exchange_spec_passes_without_warning():
    obj = _quad_problem(24, seed=6)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1,
            exchange=ExchangeSpec(method="all_gather", dtype="bf16"),
        )


# ---------------------------------------------------------------------------
# EngineConfig / make_engine
# ---------------------------------------------------------------------------


def test_engine_config_and_kwargs_build_identical_engines():
    obj = _quad_problem(32, seed=7)
    n = obj.n
    rng = np.random.default_rng(3)
    masks = [rng.random(n) < 0.3 for _ in range(4)]
    cfg = EngineConfig(slot_wakes=8.0, seed=1, fused=False)
    a = _forced_run(AsyncEngine(CDUpdate(obj), config=cfg), n, masks)
    b = _forced_run(AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=1, fused=False),
                    n, masks)
    assert np.array_equal(np.asarray(a.Theta), np.asarray(b.Theta))
    # kwargs override config fields
    eng = AsyncEngine(CDUpdate(obj), config=cfg, slot_wakes=4.0)
    assert eng.config.slot_wakes == 4.0 and eng.config.seed == 1


def test_make_engine_dispatches_on_shards():
    obj = _quad_problem(24, seed=8)
    upd = CDUpdate(obj)
    assert isinstance(make_engine(upd, slot_wakes=8.0), AsyncEngine)
    assert isinstance(make_engine(upd, shards=0, slot_wakes=8.0), AsyncEngine)
    eng = make_engine(upd, shards=1, slot_wakes=8.0, relabel="rcm")
    assert isinstance(eng, ShardedAsyncEngine)
    assert eng.num_shards == 1


def test_engine_config_rejects_unknown_options():
    obj = _quad_problem(16, seed=9)
    with pytest.raises(TypeError, match="slot_wake"):
        AsyncEngine(CDUpdate(obj), slot_wake=8.0)  # typo'd kwarg
    with pytest.raises(ValueError, match="fused"):
        EngineConfig(fused="yes")


# ---------------------------------------------------------------------------
# multi-device subprocess matrices
# ---------------------------------------------------------------------------

FUSED_MATRIX_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, knn_graph, make_objective
    from repro.sim import AsyncEngine, CDUpdate, ExchangeSpec, ShardedAsyncEngine

    assert len(jax.devices()) == 8

    rng = np.random.default_rng(0)
    n, p, m = 96, 4, 3
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    obj = make_objective(graph, AgentData(X=X, y=y, mask=np.ones((n, m))),
                         "quadratic", mu=0.5, mix_mode="sparse")
    upd = CDUpdate(obj)
    wrng = np.random.default_rng(7)
    masks = [wrng.random(n) < 0.15 for _ in range(4)]

    ref_eng = AsyncEngine(upd, slot_wakes=8.0, fused=False)
    rs = ref_eng.init_state(np.zeros((n, p)))
    for msk in masks:
        rs = ref_eng.step(rs, msk)
    R = np.asarray(rs.Theta)

    # Parity matrix: fused x {all_gather, p2p} x {f32, bf16 (+EF)} at S=4.
    # f32 wires must match the single-device engine to fused-kernel
    # tolerance; compressed wires must match the *unfused* engine with the
    # identical wire bit-for-bit (the quantizer runs outside the kernel).
    for spec in (ExchangeSpec(method="all_gather"),
                 ExchangeSpec(method="p2p"),
                 ExchangeSpec(method="all_gather", dtype="bf16"),
                 ExchangeSpec(method="p2p", dtype="bf16"),
                 ExchangeSpec(method="p2p", dtype="bf16", error_feedback=True)):
        outs = {}
        for fused in (False, True):
            eng = ShardedAsyncEngine(upd, num_shards=4, relabel="rcm",
                                     exchange=spec, slot_wakes=8.0, fused=fused)
            st = eng.init_state(np.zeros((n, p)))
            for msk in masks:
                st = eng.step(st, msk)
            outs[fused] = eng.global_theta(st)
        fu_err = np.abs(outs[True] - outs[False]).max()
        assert fu_err < 1e-6, (spec, fu_err)
        if spec.dtype == "f32":
            ref_err = np.abs(outs[True] - R).max()
            assert ref_err < 1e-6, (spec, ref_err)
        else:
            wire_err = np.abs(outs[False] - R).max()
            assert 0 < wire_err < 5e-2, (spec, wire_err)
        print(f"{spec.method}:{spec.dtype}:ef={int(spec.error_feedback)} "
              f"fused_vs_unfused={fu_err:.2e}")
    print("FUSED_MATRIX_OK")
    """
)


COMPRESSED_FIXED_POINT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, knn_graph, make_objective
    from repro.sim import CDUpdate, ExchangeSpec, ShardedAsyncEngine

    rng = np.random.default_rng(0)
    n, p, m = 256, 4, 3
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    obj = make_objective(graph, AgentData(X=X, y=y, mask=np.ones((n, m))),
                         "quadratic", mu=0.5, mix_mode="sparse")
    star = obj.solve_exact()
    upd = CDUpdate(obj)

    def fixed_point_err(spec):
        eng = ShardedAsyncEngine(upd, num_shards=4, relabel="rcm", exchange=spec,
                                 slot_wakes=64.0, seed=7)
        res = eng.run(np.zeros((n, p)), slots=1000)
        return float(np.abs(res.Theta - star).max())

    err_f32 = fixed_point_err(ExchangeSpec(method="p2p"))
    err_bf16 = fixed_point_err(ExchangeSpec(method="p2p", dtype="bf16"))
    err_ef = fixed_point_err(ExchangeSpec(method="p2p", dtype="bf16",
                                          error_feedback=True))
    print(f"f32={err_f32:.3e} bf16={err_bf16:.3e} bf16+ef={err_ef:.3e}")
    # Acceptance: error feedback recovers the f32 fixed point through a
    # lossy wire; the plain quantized wire demonstrably does not.
    assert err_f32 < 2e-5, err_f32
    assert err_ef <= 1e-4, err_ef
    assert err_bf16 > 1e-4, err_bf16
    assert err_ef < err_bf16 / 1.5, (err_ef, err_bf16)
    print("COMPRESSED_FIXED_POINT_OK")
    """
)


def _run_multidev(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )


def test_fused_parity_matrix_multidevice():
    res = _run_multidev(FUSED_MATRIX_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "FUSED_MATRIX_OK" in res.stdout


@pytest.mark.slow
def test_compressed_halo_fixed_point_multidevice():
    """Acceptance: bf16+EF halos reach <=1e-4 of the exact optimum at S=4
    while plain bf16 halos stall above it (quantization bias)."""
    res = _run_multidev(COMPRESSED_FIXED_POINT_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "COMPRESSED_FIXED_POINT_OK" in res.stdout
