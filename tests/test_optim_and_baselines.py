"""Tests: optimizers, FedAvg baseline, Gaussian mechanism, Prop2 schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgentData, DPConfig, make_objective, run_private, run_scan
from repro.core.spmd import make_fedavg_step
from repro.launch.mesh import make_mesh, use_mesh
from repro.configs import get_reduced
from repro.data.synthetic import linear_classification_problem
from repro.models import build_model
from repro.optim import adamw, apply_updates, sgd


def _quad_loss(params, batch):
    return jnp.sum((params["w"] - batch) ** 2)


def test_sgd_descends():
    params = {"w": jnp.ones((4,), jnp.float32) * 3}
    target = jnp.zeros((4,))
    init, update = sgd(0.1)
    state = init(params)
    for _ in range(50):
        g = jax.grad(_quad_loss)(params, target)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_descends_and_tracks_moments():
    params = {"w": jnp.ones((8,), jnp.float32) * 2}
    target = jnp.zeros((8,))
    init, update = adamw(0.05, weight_decay=0.0)
    state = init(params)
    losses = []
    for _ in range(100):
        g = jax.grad(_quad_loss)(params, target)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
        losses.append(float(_quad_loss(params, target)))
    assert losses[-1] < 0.05 * losses[0]
    assert int(state["t"]) == 100


def test_fedavg_step_keeps_agents_identical():
    """The global-model baseline must keep all agent replicas in lockstep."""
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("llama3.2-1b", dtype="float32")
    m = build_model(cfg, remat=False)
    A = 2
    one = m.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: jnp.broadcast_to(p, (A, *p.shape)), one)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (A, 2, 17)), jnp.int32)}
    with use_mesh(mesh):
        step = jax.jit(make_fedavg_step(m, mesh, lr=0.1))
        new_params, metrics = step(params, batch, jax.random.PRNGKey(1))
    for leaf in jax.tree.leaves(new_params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]), rtol=1e-6)
    assert np.isfinite(float(metrics["loss"]))


@pytest.fixture(scope="module")
def problem():
    return linear_classification_problem(n=10, p=6, m_low=50, m_high=100, seed=7)


def test_gaussian_mechanism_runs_and_respects_budget(problem):
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3, clip=1.0)
    cfg = DPConfig(eps_bar=1.0, mechanism="gaussian", delta_step=1e-6)
    res = run_private(obj, np.zeros((obj.n, obj.p)), T=80, cfg=cfg,
                      rng=np.random.default_rng(0))
    assert np.all(res.eps_spent <= 1.0 + 1e-9)
    assert np.isfinite(res.objective[-1])


def test_prop2_schedule_decreasing_noise_allocation(problem):
    """Prop. 2: later wake-ups get smaller eps (larger noise) — the
    allocation must be decreasing over global time."""
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3, clip=1.0)
    cfg = DPConfig(eps_bar=1.0, schedule="prop2")
    rng = np.random.default_rng(1)
    res = run_private(obj, np.zeros((obj.n, obj.p)), T=100, cfg=cfg, rng=rng)
    # For one agent with multiple wake-ups, noise scales must increase
    # (eps decreasing) over time.
    wake = res.wake_sequence
    for agent in range(obj.n):
        ticks = np.nonzero((wake == agent) & (res.noise_scales[: len(wake)] > 0))[0]
        if len(ticks) >= 2:
            scales = res.noise_scales[ticks]
            assert np.all(np.diff(scales) >= -1e-12)
            break


def test_prop2_vs_uniform_budget_equivalence(problem):
    """Both schedules must spend within the same overall budget."""
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3, clip=1.0)
    for schedule in ["uniform", "prop2"]:
        res = run_private(
            obj, np.zeros((obj.n, obj.p)), T=60,
            cfg=DPConfig(eps_bar=0.8, schedule=schedule),
            rng=np.random.default_rng(2),
        )
        assert np.all(res.eps_spent <= 0.8 + 1e-6), schedule
