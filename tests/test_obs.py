"""Observability layer: in-jit metrics, phase tracing, run reports.

The load-bearing invariant is that telemetry is *free of side effects*:
metrics-on must leave Theta bit-exact versus metrics-off under forced
wakes, on both engines and both wire formats (the counters only
re-reduce values the slot already computed — no extra PRNG draws, no
Theta writes). On top of that: counter semantics against host-side
ground truth (churn schedule, DP accountant), the phase profiler +
Chrome-trace export, the report JSONL round-trip and CLI, the
once-per-process ExchangeSpec string deprecation, and the
BENCH_summary sync guard. Multi-shard (S=4) parity and counters run in
an 8-host-device subprocess, ``test_spmd.py`` style."""

import importlib.util
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgentData, DPConfig, knn_graph, make_objective
from repro.obs import (
    MetricsSpec,
    RunReport,
    SpanRecorder,
    merge_bench_summary,
    profile_supertick,
    summarize_counters,
    validate_trace,
)
from repro.sim import (
    AsyncEngine,
    CDUpdate,
    ChurnConfig,
    DPCDUpdate,
    EngineConfig,
    ExchangeSpec,
    Scenario,
    ShardedAsyncEngine,
)

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _quad_problem(n, p=4, m=3, seed=0, mu=0.5, clip=None):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode="sparse", clip=clip)


# -- spec / config plumbing --------------------------------------------------


def test_metrics_spec_coerce():
    assert MetricsSpec.coerce(None) is None
    assert MetricsSpec.coerce(False) is None
    assert MetricsSpec.coerce(True) == MetricsSpec()
    spec = MetricsSpec(staleness=False)
    assert MetricsSpec.coerce(spec) is spec
    with pytest.raises(TypeError):
        MetricsSpec.coerce("yes")
    assert EngineConfig(metrics=True).metrics_spec() == MetricsSpec()
    assert EngineConfig().metrics_spec() is None


def test_metrics_off_engine_refuses_snapshot_and_drain():
    obj = _quad_problem(n=24)
    eng = AsyncEngine(CDUpdate(obj), seed=0)
    state = eng.init_state(np.zeros((obj.n, obj.p)))
    with pytest.raises(ValueError, match="metrics"):
        eng.metrics_snapshot(state)
    with pytest.raises(ValueError, match="metrics"):
        eng.run(np.zeros((obj.n, obj.p)), slots=2, metrics_every=1)


# -- bit-exactness: metrics must not perturb the dynamics --------------------


def test_async_forced_wakes_bit_exact_metrics_on_vs_off():
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p
    eng_off = AsyncEngine(CDUpdate(obj), slot_wakes=40.0, seed=0, dtype=jnp.float64)
    eng_on = AsyncEngine(
        CDUpdate(obj), slot_wakes=40.0, seed=0, dtype=jnp.float64, metrics=True
    )
    s_off = eng_off.init_state(np.zeros((n, p)))
    s_on = eng_on.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(7)
    total = 0
    for _ in range(8):
        mask = rng.random(n) < 0.3
        total += int(mask.sum())
        s_off = eng_off.step(s_off, mask)
        s_on = eng_on.step(s_on, mask)
    np.testing.assert_array_equal(np.asarray(s_off.Theta), np.asarray(s_on.Theta))
    counters, _ = eng_on.metrics_snapshot(s_on)
    # slot_wakes=n makes the batch cover every forced wake: nothing dropped,
    # every realized wake applied, and each application binned by staleness.
    assert int(counters["wakes_capacity_dropped"]) == 0
    assert int(counters["wakes_realized"]) == total == int(s_on.applied)
    assert int(counters["wakes_applied"]) == total
    assert int(counters["staleness_hist"].sum()) == total


@pytest.mark.parametrize(
    "spec",
    [ExchangeSpec(), ExchangeSpec(method="all_gather", dtype="bf16", error_feedback=True)],
    ids=["f32", "bf16_ef"],
)
def test_sharded_forced_wakes_bit_exact_metrics_on_vs_off(spec):
    obj = _quad_problem(n=40, seed=2)
    n, p = obj.n, obj.p
    kw = dict(num_shards=1, slot_wakes=40.0, seed=0, exchange=spec)
    eng_off = ShardedAsyncEngine(CDUpdate(obj), **kw)
    eng_on = ShardedAsyncEngine(CDUpdate(obj), metrics=True, **kw)
    s_off = eng_off.init_state(np.zeros((n, p)))
    s_on = eng_on.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(3)
    for _ in range(6):
        mask = rng.random(n) < 0.3
        s_off = eng_off.step(s_off, mask)
        s_on = eng_on.step(s_on, mask)
    np.testing.assert_array_equal(eng_off.global_theta(s_off), eng_on.global_theta(s_on))
    counters, _ = eng_on.metrics_snapshot(s_on)
    assert int(counters["wakes_applied"].sum()) == int(np.asarray(s_on.applied).sum())
    if spec.dtype != "f32":
        # The quantized wire reports its per-slot error energy (a gauge of
        # the published-border quantization; exact value is wire-dependent,
        # presence and finiteness are the contract).
        assert np.isfinite(counters["quant_err_sq"]).all()


def test_sampled_advance_bit_exact_metrics_on_vs_off():
    obj = _quad_problem(n=48, seed=3)
    n, p = obj.n, obj.p
    scenario = Scenario(churn=ChurnConfig(leave_prob=0.05, rejoin_prob=0.3))
    eng_off = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=5, scenario=scenario)
    eng_on = AsyncEngine(
        CDUpdate(obj), slot_wakes=8.0, seed=5, scenario=scenario, metrics=True
    )
    s_off = eng_off.advance(eng_off.init_state(np.zeros((n, p))), 9)
    s_on = eng_on.advance(eng_on.init_state(np.zeros((n, p))), 9)
    np.testing.assert_array_equal(np.asarray(s_off.Theta), np.asarray(s_on.Theta))
    np.testing.assert_array_equal(np.asarray(s_off.active), np.asarray(s_on.active))


# -- counter semantics vs host-side ground truth -----------------------------


def test_churn_departures_match_schedule():
    """A deterministic departure schedule (leave_prob=1 on a chosen subset,
    no rejoins): the telemetry must count exactly those agents, once."""
    obj = _quad_problem(n=40, seed=4)
    n, p = obj.n, obj.p
    leavers = np.zeros(n)
    leavers[:17] = 1.0  # the schedule: agents 0..16 depart on slot 1
    scenario = Scenario(churn=ChurnConfig(leave_prob=leavers, rejoin_prob=0.0))
    eng = AsyncEngine(
        CDUpdate(obj), slot_wakes=8.0, seed=0, scenario=scenario, metrics=True
    )
    state = eng.advance(eng.init_state(np.zeros((n, p))), 5)
    counters, _ = eng.metrics_snapshot(state)
    assert int(counters["churn_departures"]) == 17
    assert int(counters["churn_rejoins"]) == 0
    # Cross-check against the engine's own churn state.
    assert int(np.asarray(state.active).sum()) == n - 17

    engS = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0,
        scenario=scenario, metrics=True,
    )
    stS = engS.advance(engS.init_state(np.zeros((n, p))), 5)
    countersS, _ = engS.metrics_snapshot(stS)
    assert int(countersS["churn_departures"].sum()) == 17
    assert int(countersS["churn_rejoins"].sum()) == 0


def test_dp_budget_stopped_matches_accountant():
    """The dp_budget_stopped gauge equals the host accountant's count, and
    the derived eps-spent matches DPCDUpdate.eps_spent, on both engines."""
    obj = _quad_problem(n=48, seed=3, clip=1.0)
    n, p = obj.n, obj.p
    planned_Ti = 3
    dp = DPCDUpdate.plan(obj, DPConfig(eps_bar=1.0), planned_Ti=planned_Ti)
    for eng in (
        AsyncEngine(dp, slot_wakes=48.0, seed=0, metrics=True),
        ShardedAsyncEngine(dp, num_shards=1, slot_wakes=48.0, seed=0, metrics=True),
    ):
        state = eng.init_state(np.zeros((n, p)))
        for k in range(planned_Ti + 2):
            state = eng.step(state, np.ones(n, bool))
            counters, derived = eng.metrics_snapshot(state)
            gauge = int(np.asarray(counters["dp_budget_stopped"]).sum())
            ustate = state.ustate
            if isinstance(eng, ShardedAsyncEngine):
                ustate = eng.part.unpad_rows(np.asarray(ustate))
            assert gauge == dp.budget_stopped(ustate), (type(eng).__name__, k)
        # slot_wakes=n gives every forced wake batch room: after
        # planned_Ti + 2 all-wake slots every agent has spent its budget.
        assert gauge == n
        np.testing.assert_allclose(
            derived["dp_eps_spent_max"], dp.eps_spent(np.asarray(ustate)).max()
        )


def test_exchange_counters_accumulate_per_slot_volume():
    """Sharded exchange counters advance by the partition's static
    per-slot volume each super-tick (padded rows included: static shapes
    ship them)."""
    obj = _quad_problem(n=40, seed=6)
    n, p = obj.n, obj.p
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0, metrics=True
    )
    steps = 4
    state = eng.init_state(np.zeros((n, p)))
    for _ in range(steps):
        state = eng.step(state, np.ones(n, bool))
    counters, _ = eng.metrics_snapshot(state)
    xrows = eng.part.exchange_rows(eng.exchange_method)
    xbytes = xrows * eng.exchange_spec.payload_bytes_per_row(p)
    assert int(counters["exchange_rows"].sum()) == steps * xrows
    assert float(counters["exchange_bytes"].sum()) == float(steps * xbytes)


# -- phase tracing -----------------------------------------------------------


def test_profile_supertick_and_trace_export(tmp_path):
    obj = _quad_problem(n=32, seed=7)
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, metrics=True)
    recorder = SpanRecorder()
    prof = profile_supertick(eng, inner=1, repeats=1, recorder=recorder)
    assert tuple(prof.phases) == eng.phase_names
    assert all(dt >= 0.0 for dt in prof.phases.values())
    np.testing.assert_allclose(sum(prof.phases.values()), prof.total_s)
    rows = prof.rows(prefix="obs_phase")
    assert rows[-1][0] == "obs_phase_total"
    trace = tmp_path / "trace.json"
    recorder.export_chrome_trace(str(trace))
    # live timing spans + one synthetic attribution span per phase
    assert validate_trace(str(trace)) >= len(prof.phases)
    events = json.loads(trace.read_text())["traceEvents"]
    names = {e["name"] for e in events if e["tid"] == 1}
    assert names == {f"obs.phase.{name}" for name in eng.phase_names}


def test_validate_trace_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"no_events": 1}))
    with pytest.raises(ValueError, match="Chrome trace"):
        validate_trace(str(bad))
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    with pytest.raises(ValueError, match="malformed"):
        validate_trace(str(bad))


def test_phase_program_rejects_unknown_phase():
    obj = _quad_problem(n=24, seed=8)
    eng = AsyncEngine(CDUpdate(obj), seed=0)
    with pytest.raises(ValueError, match="phase"):
        eng.phase_program("not_a_phase")


# -- run reports -------------------------------------------------------------


def test_run_metrics_every_drains_and_reports():
    obj = _quad_problem(n=40, seed=9)
    n, p = obj.n, obj.p
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, metrics=True)
    res = eng.run(np.zeros((n, p)), slots=12, metrics_every=4, record_every=6)
    assert len(res.report.snapshots) == 3
    assert res.report.meta["engine"] == "AsyncEngine"
    assert len(res.objective) == 3  # initial + record_every at slots 6, 12
    # Drains are cumulative reads of the same accumulator: monotone.
    applied = [s["counters"]["wakes_applied"] for s in res.report.snapshots]
    assert applied == sorted(applied)
    assert applied[-1] == int(np.asarray(res.state.applied).sum())
    # And the drain must not perturb the dynamics.
    plain = eng.run(np.zeros((n, p)), slots=12)
    np.testing.assert_array_equal(plain.Theta, res.Theta)


def test_report_jsonl_roundtrip_and_bench_rows(tmp_path):
    report = RunReport(meta={"engine": "AsyncEngine", "n": 8})
    report.add_snapshot(
        2,
        {"wakes_applied": np.int64(5), "staleness_hist": np.array([3, 2])},
        derived={"dp_eps_spent_max": np.float64(0.5)},
    )
    report.add_phase_rows([("obs_phase_total", 12.5, "sum of phases")])
    path = tmp_path / "report.jsonl"
    report.to_jsonl(str(path))
    back = RunReport.from_jsonl(str(path))
    assert back.meta == {"engine": "AsyncEngine", "n": 8}
    assert back.snapshots == report.snapshots
    assert back.phase_rows == [("obs_phase_total", 12.5, "sum of phases")]
    rows = dict((name, v) for name, v, _ in back.bench_rows())
    assert rows["obs_wakes_applied"] == 5.0
    assert rows["obs_phase_total"] == 12.5
    assert "staleness_hist" not in rows  # vectors render in the table, not rows


def test_report_cli_renders_merges_and_validates(tmp_path, capsys):
    from repro.obs import report as report_cli

    report = RunReport(meta={"engine": "AsyncEngine"})
    report.add_snapshot(1, {"wakes_applied": np.int64(3)})
    rpath = tmp_path / "r.jsonl"
    report.to_jsonl(str(rpath))
    recorder = SpanRecorder()
    recorder.add("span", 0.0, 1.0)
    tpath = tmp_path / "t.json"
    recorder.export_chrome_trace(str(tpath))
    bench = tmp_path / "BENCH_summary.json"
    merge_bench_summary(str(bench), [("existing_row", 1.0, "kept")])

    rc = report_cli.main(
        [str(rpath), "--merge-bench", str(bench), "--validate-trace", str(tpath)]
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "wakes_applied" in out and "valid Chrome trace" in out
    merged = json.loads(bench.read_text())
    assert merged["obs_wakes_applied"]["us_per_call"] == 3.0
    assert merged["existing_row"]["us_per_call"] == 1.0  # merge, not clobber

    with pytest.raises(SystemExit):
        report_cli.main([])  # nothing to do


# -- satellites: warning dedup, bench sync, run.py CLI -----------------------


def test_exchange_string_deprecation_warns_once_per_process():
    import repro.core.mixing as mixing

    obj = _quad_problem(n=24, seed=10)
    mixing._warned_bare_exchange_string = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(3):
            ShardedAsyncEngine(CDUpdate(obj), num_shards=1, seed=0, exchange="p2p")
    dep = [
        w for w in caught
        if issubclass(w.category, DeprecationWarning) and "bare string" in str(w.message)
    ]
    assert len(dep) == 1, [str(w.message) for w in caught]


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO_ROOT, "tools", f"{name}.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_sync(tmp_path):
    sync = _load_tool("check_bench_sync")
    root = tmp_path / "BENCH_summary.json"
    results = tmp_path / "results" / "BENCH_summary.json"
    results.parent.mkdir()
    assert sync.check(root, results) == []  # neither exists: nothing to flag
    root.write_text(json.dumps({"a": {"us_per_call": 1.0, "derived": ""}}))
    errors = sync.check(root, results)
    assert len(errors) == 1 and "counterpart" in errors[0]
    results.write_text(root.read_text())
    assert sync.check(root, results) == []
    results.write_text(json.dumps({"a": {"us_per_call": 2.0, "derived": ""}}))
    assert any("differs" in e for e in sync.check(root, results))
    results.write_text(json.dumps({"b": {"us_per_call": 1.0, "derived": ""}}))
    assert len(sync.check(root, results)) == 2  # 'a' and 'b' each one-sided


def _run_benchrun(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [os.path.join(REPO_ROOT, "src"), env.get("PYTHONPATH", "")])
    )
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=120,
    )


def test_benchmarks_run_list_and_unknown_only():
    listed = _run_benchrun(["--list"])
    assert listed.returncode == 0
    names = listed.stdout.split()
    assert "obs" in names and "sharded_engine" in names
    bogus = _run_benchrun(["--only", "definitely_not_a_bench"])
    assert bogus.returncode != 0
    assert "definitely_not_a_bench" in bogus.stderr
    for name in names:
        assert name in bogus.stderr  # the error lists every valid name


# -- multi-shard metrics: 8-host-device subprocess ---------------------------

OBS_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, DPConfig, knn_graph, make_objective
    from repro.sim import (AsyncEngine, CDUpdate, DPCDUpdate, ExchangeSpec,
                           ShardedAsyncEngine)

    assert len(jax.devices()) == 8

    def quad(n, p=4, m=3, seed=0, clip=None):
        rng = np.random.default_rng(seed)
        graph = knn_graph(rng.normal(size=(n, 8)), k=8)
        targets = rng.normal(size=(n, p)) / np.sqrt(p)
        X = rng.normal(size=(n, m, p)) / np.sqrt(p)
        y = np.einsum("nmp,np->nm", X, targets)
        data = AgentData(X=X, y=y, mask=np.ones((n, m)))
        return make_objective(graph, data, "quadratic", mu=0.5,
                              mix_mode="sparse", clip=clip)

    # 1) S=4 forced-wake parity metrics-on vs metrics-off, f32 p2p and
    #    the compressed bf16+EF wire; counters match host ground truth.
    obj = quad(64, seed=1)
    n, p = obj.n, obj.p
    masks = [np.random.default_rng(5).random(n) < 0.3 for _ in range(6)]
    for spec in (ExchangeSpec(method="p2p"),
                 ExchangeSpec(method="p2p", dtype="bf16", error_feedback=True)):
        kw = dict(num_shards=4, relabel="rcm", slot_wakes=64.0, seed=0,
                  exchange=spec)
        eng_off = ShardedAsyncEngine(CDUpdate(obj), **kw)
        eng_on = ShardedAsyncEngine(CDUpdate(obj), metrics=True, **kw)
        s_off = eng_off.init_state(np.zeros((n, p)))
        s_on = eng_on.init_state(np.zeros((n, p)))
        for mask in masks:
            s_off = eng_off.step(s_off, mask)
            s_on = eng_on.step(s_on, mask)
        assert np.array_equal(eng_off.global_theta(s_off),
                              eng_on.global_theta(s_on)), spec
        counters, _ = eng_on.metrics_snapshot(s_on)
        assert int(counters["wakes_applied"].sum()) == int(
            np.asarray(s_on.applied).sum())
        xrows = eng_on.part.exchange_rows(eng_on.exchange_method)
        assert int(counters["exchange_rows"].sum()) == len(masks) * xrows
        assert counters["p2p_rows_by_offset"].shape[-1] > 0
        if spec.dtype != "f32":
            assert np.isfinite(counters["quant_err_sq"]).all()
    print("S4_PARITY_OK")

    # 2) S=4 DP budget-stop gauge == host accountant.
    objc = quad(48, seed=3, clip=1.0)
    dp = DPCDUpdate.plan(objc, DPConfig(eps_bar=1.0), planned_Ti=3)
    eng = ShardedAsyncEngine(dp, num_shards=4, relabel="rcm", slot_wakes=48.0,
                             seed=0, metrics=True)
    st = eng.init_state(np.zeros((objc.n, objc.p)))
    for _ in range(5):
        st = eng.step(st, np.ones(objc.n, bool))
    counters, derived = eng.metrics_snapshot(st)
    counts = eng.part.unpad_rows(np.asarray(st.ustate))
    gauge = int(np.asarray(counters["dp_budget_stopped"]).sum())
    assert gauge == dp.budget_stopped(counts) == objc.n, gauge
    np.testing.assert_allclose(derived["dp_eps_spent_max"],
                               dp.eps_spent(counts).max())
    print("S4_DP_OK")

    # 3) Drained run + phase profile + trace on the 8-shard engine: the
    #    CI obs lane's in-test twin.
    eng8 = ShardedAsyncEngine(CDUpdate(obj), num_shards=8, relabel="rcm",
                              slot_wakes=16.0, seed=0, metrics=True)
    res = eng8.run(np.zeros((n, p)), slots=6, metrics_every=3)
    assert len(res.report.snapshots) == 2
    from repro.obs import SpanRecorder, profile_supertick, validate_trace
    rec = SpanRecorder()
    prof = profile_supertick(eng8, state=res.state, inner=1, repeats=1,
                             recorder=rec)
    assert tuple(prof.phases) == eng8.phase_names
    res.report.add_phase_rows(prof.rows())
    rec.export_chrome_trace("obs_trace_test.json")
    assert validate_trace("obs_trace_test.json") >= len(prof.phases)
    res.report.to_jsonl("obs_report_test.jsonl")
    from repro.obs import RunReport
    back = RunReport.from_jsonl("obs_report_test.jsonl")
    assert back.meta["num_shards"] == 8
    print("S8_REPORT_OK")
    """
)


def test_obs_multidevice_parity_counters_and_report(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env.pop("JAX_ENABLE_X64", None)
    res = subprocess.run(
        [sys.executable, "-c", OBS_MULTIDEV_SCRIPT],
        env=env, capture_output=True, text=True, timeout=900, cwd=str(tmp_path),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("S4_PARITY_OK", "S4_DP_OK", "S8_REPORT_OK"):
        assert marker in res.stdout
