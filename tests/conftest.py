"""Test configuration.

x64 is enabled because the paper-faithful core (objectives, DP accounting,
convergence-rate checks) needs float64 for finite-difference and theory
assertions. Model/smoke/kernel tests pass explicit dtypes (f32/bf16) and are
unaffected. The dry-run runs in its own process (launch/dryrun.py) and does
NOT inherit this — nor the 512-device XLA flag, which is deliberately not set
here (smoke tests must see 1 device).
"""

import jax

jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running simulation/e2e tests; CI's fast lane runs -m 'not slow'",
    )
