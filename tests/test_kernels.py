"""Per-kernel allclose sweeps: Pallas (interpret mode on CPU) vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# dp_clip_noise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D", [(8, 128), (33, 200), (128, 512), (200, 1000), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dp_clip_noise_matches_ref(N, D, dtype):
    rng = np.random.default_rng(N * 1000 + D)
    grads = jnp.asarray(rng.normal(size=(N, D)) * 3.0, dtype)
    noise = jnp.asarray(rng.laplace(size=(D,)), jnp.float32)
    clip, s = 1.5, 0.37
    got = ops.dp_clip_noise(grads, noise, clip, s, interpret=True)
    want = ref.dp_clip_noise_ref(grads, noise, clip, s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_dp_clip_noise_clips_every_row():
    """Property: with zero noise the output norm is bounded by the clip."""
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(16, 256)) * 100.0, jnp.float32)
    out = ops.dp_clip_noise(grads, jnp.zeros((256,)), 1.0, 0.0, interpret=True)
    assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-5


@pytest.mark.parametrize("block_n,block_d", [(8, 128), (64, 256), (128, 1024)])
def test_dp_clip_noise_block_shape_invariance(block_n, block_d):
    rng = np.random.default_rng(7)
    grads = jnp.asarray(rng.normal(size=(77, 300)), jnp.float32)
    noise = jnp.asarray(rng.laplace(size=(300,)), jnp.float32)
    got = ops.dp_clip_noise(grads, noise, 2.0, 0.1, block_n=block_n, block_d=block_d,
                            interpret=True)
    want = ref.dp_clip_noise_ref(grads, noise, 2.0, 0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# graph_mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p", [(4, 128), (16, 100), (100, 300), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_graph_mix_matches_ref(n, p, dtype):
    rng = np.random.default_rng(n + p)
    mix = jnp.asarray(rng.random((n, n)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(n, p)), dtype)
    got = ops.graph_mix(mix, theta, interpret=True)
    want = ref.graph_mix_ref(mix, theta).astype(jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_graph_mix_identity():
    theta = jnp.asarray(np.random.default_rng(1).normal(size=(32, 257)), jnp.float32)
    got = ops.graph_mix(jnp.eye(32), theta, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(theta), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sparse_mix
# ---------------------------------------------------------------------------


def _random_padded_graph(n, k, rng):
    """Padded (idx, w) neighbour tiles of a random symmetric graph."""
    from repro.core import knn_cosine_graph

    csr = knn_cosine_graph(rng.normal(size=(n, 8)), k=k).to_csr()
    idx, w = csr.padded_neighbors()
    return jnp.asarray(idx), jnp.asarray(w, jnp.float32), csr


@pytest.mark.parametrize("n,k,p", [(8, 3, 128), (33, 5, 200), (100, 10, 300), (128, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_mix_matches_refs(n, k, p, dtype):
    rng = np.random.default_rng(n * 100 + p)
    idx, w, csr = _random_padded_graph(n, k, rng)
    theta = jnp.asarray(rng.normal(size=(n, p)), dtype)
    got = ops.sparse_mix(idx, w, theta, interpret=True)
    want_gather = ref.sparse_mix_ref(idx, w, theta)
    want_segsum = ref.csr_mix_ref(
        jnp.asarray(csr.row_ids()), jnp.asarray(csr.indices),
        jnp.asarray(csr.data, jnp.float32), theta, n,
    )
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_gather), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_segsum), rtol=tol, atol=tol)


def test_sparse_mix_agrees_with_dense_graph_mix():
    """The sparse kernel on CSR tiles == the dense kernel on the full matrix."""
    from repro.core.graph import dense_weights

    rng = np.random.default_rng(0)
    idx, w, csr = _random_padded_graph(64, 6, rng)
    theta = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    got = ops.sparse_mix(idx, w, theta, interpret=True)
    want = ops.graph_mix(jnp.asarray(dense_weights(csr), jnp.float32), theta, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_a,block_p", [(8, 128), (16, 256), (64, 512)])
def test_sparse_mix_block_shape_invariance(block_a, block_p):
    rng = np.random.default_rng(7)
    idx, w, _ = _random_padded_graph(50, 4, rng)
    theta = jnp.asarray(rng.normal(size=(50, 300)), jnp.float32)
    got = ops.sparse_mix(idx, w, theta, block_a=block_a, block_p=block_p, interpret=True)
    want = ref.sparse_mix_ref(idx, w, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_sparse_mix_pad_entries_contribute_nothing():
    """Rows padded past their true degree (weight 0) must not alter the sum."""
    rng = np.random.default_rng(3)
    _, _, csr = _random_padded_graph(32, 4, rng)
    theta = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    idx_a, w_a = csr.padded_neighbors()
    idx_b, w_b = csr.padded_neighbors(pad_to=idx_a.shape[1] + 5)
    out_a = ops.sparse_mix(jnp.asarray(idx_a), jnp.asarray(w_a, jnp.float32), theta, interpret=True)
    out_b = ops.sparse_mix(jnp.asarray(idx_b), jnp.asarray(w_b, jnp.float32), theta, interpret=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sparse_rows_mix (woken-rows batch; the repro.sim super-tick path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", [1, 7, 24, 64])
def test_sparse_rows_mix_is_row_slice_of_sparse_mix(B):
    rng = np.random.default_rng(B)
    idx, w, _ = _random_padded_graph(64, 6, rng)
    theta = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    rows = jnp.asarray(rng.choice(64, size=B, replace=False))
    got = ops.sparse_rows_mix(idx[rows], w[rows], theta, interpret=True)
    full = ops.sparse_mix(idx, w, theta, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full)[np.asarray(rows)],
                               rtol=1e-6, atol=1e-6)
    want = ref.sparse_rows_mix_ref(idx[rows], w[rows], theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssm_chunk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("G,Q,N,P", [(2, 16, 8, 16), (4, 64, 64, 64), (1, 128, 64, 64), (3, 32, 16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_chunk_matches_ref(G, Q, N, P, dtype):
    rng = np.random.default_rng(G * Q + N + P)
    C = jnp.asarray(rng.normal(size=(G, Q, N)), dtype)
    B = jnp.asarray(rng.normal(size=(G, Q, N)), dtype)
    loga = -np.abs(rng.normal(size=(G, Q)) * 0.1)
    cum = jnp.asarray(np.cumsum(loga, axis=1), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(G, Q))) * 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(G, Q, P)), dtype)
    y, s = ops.ssm_chunk(C, B, cum, dt, x, interpret=True)
    yr, sr = ref.ssm_chunk_ref(C, B, cum, dt, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=tol, atol=tol)


def test_ssm_chunk_causality():
    """Property: output at position q must not depend on inputs at t > q."""
    rng = np.random.default_rng(3)
    G, Q, N, P = 1, 32, 16, 16
    C = jnp.asarray(rng.normal(size=(G, Q, N)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(G, Q, N)), jnp.float32)
    cum = jnp.asarray(np.cumsum(-np.abs(rng.normal(size=(G, Q)) * 0.1), axis=1), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(G, Q))), jnp.float32)
    x = jnp.asarray(rng.normal(size=(G, Q, P)), jnp.float32)
    y1, _ = ops.ssm_chunk(C, B, cum, dt, x, interpret=True)
    x2 = x.at[:, Q // 2 :].set(999.0)
    y2, _ = ops.ssm_chunk(C, B, cum, dt, x2, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y1[:, : Q // 2]), np.asarray(y2[:, : Q // 2]), rtol=1e-6
    )


@pytest.mark.slow
def test_mamba2_kernel_path_matches_einsum_path():
    """use_kernel=True must be numerically identical (fwd) and allclose (bwd)."""
    from repro.configs.base import ModelConfig, SSMConfig
    from repro.models import ssm as ssm_mod

    cfg = ModelConfig(
        name="t", family="hybrid", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        ssm=SSMConfig(state_dim=8, head_dim=8, conv_kernel=4, chunk=16, expand=2),
    )
    params = ssm_mod.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32), jnp.float32)
    y0 = ssm_mod.mamba2_forward(params, x, cfg, use_kernel=False)
    y1 = ssm_mod.mamba2_forward(params, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5, atol=1e-5)
    g0 = jax.grad(lambda p: jnp.sum(ssm_mod.mamba2_forward(p, x, cfg) ** 2))(params)
    g1 = jax.grad(
        lambda p: jnp.sum(ssm_mod.mamba2_forward(p, x, cfg, use_kernel=True) ** 2)
    )(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_ssm_chunk_consistency_with_model_reference():
    """The kernel must agree with the full mamba2_forward intra-chunk math on
    a single-chunk sequence (inter-chunk contribution is zero there)."""
    import dataclasses

    from repro.configs.base import ModelConfig, SSMConfig
    from repro.models import ssm as ssm_mod

    cfg = ModelConfig(
        name="t", family="hybrid", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        ssm=SSMConfig(state_dim=8, head_dim=8, conv_kernel=4, chunk=16, expand=2),
    )
    key = jax.random.PRNGKey(0)
    params = ssm_mod.init_mamba2(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    out = ssm_mod.mamba2_forward(params, x, cfg)
    assert out.shape == (2, 16, 32)
    assert not bool(jnp.any(jnp.isnan(out)))


# ---------------------------------------------------------------------------
# fused_row_update
# ---------------------------------------------------------------------------


def _fused_instance(B, K, m, p, nt, rng, sentinels=0):
    """Random fused-update operands; the last `sentinels` rows are >= limit."""
    rows = rng.choice(nt, size=B, replace=False).astype(np.int32)
    limit = nt
    if sentinels:
        limit = nt - 1
        rows[-sentinels:] = nt - 1  # == limit after the cap below
        rows = np.minimum(rows, nt - 1)
    idx = rng.integers(0, nt, size=(B, K)).astype(np.int32)
    w = rng.random((B, K)).astype(np.float32)
    coef = np.stack(
        [
            rng.uniform(0.2, 0.9, B),       # alpha
            rng.uniform(1.0, K, B),         # degree
            rng.uniform(0.05, 0.5, B),      # mu * confidence
            rng.uniform(0.0, 0.3, B),       # 2 * lambda
        ],
        axis=1,
    ).astype(np.float32)
    X = rng.normal(size=(B, m, p)).astype(np.float32)
    y = rng.normal(size=(B, m)).astype(np.float32)
    mask = (rng.random((B, m)) < 0.8).astype(np.float32)
    noise = rng.normal(size=(B, p)).astype(np.float32) * 0.01
    theta = rng.normal(size=(nt, p)).astype(np.float32)
    args = tuple(jnp.asarray(a) for a in (rows, idx, w, coef, X, y, mask, noise, theta))
    return args, limit


@pytest.mark.parametrize("B,K,m,p,nt", [(8, 4, 3, 8, 64), (17, 7, 5, 100, 128),
                                        (1, 3, 2, 128, 32), (40, 10, 4, 200, 256)])
@pytest.mark.parametrize("clip", [None, 0.7])
def test_fused_row_update_matches_ref(B, K, m, p, nt, clip):
    rng = np.random.default_rng(B + p)
    args, limit = _fused_instance(B, K, m, p, nt, rng)
    got = ops.fused_row_update(*args, limit=limit, clip=clip, interpret=True)
    want = ref.fused_row_update_ref(*args, limit=limit, clip=clip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=4e-6, atol=4e-6)
    # Untouched slab rows pass through bit-identically (drop-mode scatter).
    rows = np.asarray(args[0])
    untouched = np.setdiff1d(np.arange(nt), rows[rows < limit])
    theta = np.asarray(args[8])
    assert np.array_equal(np.asarray(got)[untouched], theta[untouched])


def test_fused_row_update_sentinel_rows_never_write():
    """Rows >= limit (padding / budget-stopped agents) leave the slab alone."""
    rng = np.random.default_rng(0)
    args, limit = _fused_instance(12, 5, 3, 16, 64, rng, sentinels=4)
    got = ops.fused_row_update(*args, limit=limit, interpret=True)
    want = ref.fused_row_update_ref(*args, limit=limit)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=4e-6, atol=4e-6)
    theta = np.asarray(args[8])
    assert np.array_equal(np.asarray(got)[limit:], theta[limit:])


@pytest.mark.parametrize("block_b", [1, 4, 16])
def test_fused_row_update_block_shape_invariance(block_b):
    rng = np.random.default_rng(7)
    args, limit = _fused_instance(24, 6, 4, 32, 128, rng)
    got = ops.fused_row_update(*args, limit=limit, block_b=block_b, interpret=True)
    want = ops.fused_row_update(*args, limit=limit, block_b=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=4e-6, atol=4e-6)


def test_fused_row_update_pads_ragged_shapes():
    """p not a multiple of 128, m not a multiple of 8, B not a multiple of
    block_b: the wrapper pads, the valid region still matches the oracle."""
    rng = np.random.default_rng(3)
    args, limit = _fused_instance(11, 4, 3, 37, 50, rng)
    got = ops.fused_row_update(*args, limit=limit, interpret=True)
    want = ref.fused_row_update_ref(*args, limit=limit)
    assert got.shape == (50, 37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=4e-6, atol=4e-6)
