"""Checkpointing round-trips and data-pipeline invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import token_stream


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"w": jnp.ones((5,), jnp.bfloat16) * 1.5, "s": jnp.int32(7).reshape(())},
    }
    save_checkpoint(str(tmp_path / "ck"), tree, step=42, extra={"note": "x"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step, extra = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 42 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_sharding_into_multiple_files(tmp_path):
    tree = {"big": jnp.zeros((1024, 1024), jnp.float32)}  # 4 MB
    save_checkpoint(str(tmp_path / "ck"), tree, max_shard_bytes=1 << 20)
    import os

    shards = [f for f in os.listdir(tmp_path / "ck") if f.startswith("shard_")]
    assert len(shards) >= 1
    restored, _, _ = load_checkpoint(str(tmp_path / "ck"), tree)
    np.testing.assert_array_equal(np.asarray(restored["big"]), 0.0)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((4, 4))}
    save_checkpoint(str(tmp_path / "ck"), tree)
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path / "ck"), {"w": jnp.zeros((5, 4))})


@given(
    st.integers(min_value=2, max_value=64),
    st.integers(min_value=1, max_value=4),
)
@settings(max_examples=10, deadline=None)
def test_token_stream_bounds_and_shape(vocab, n_agents):
    batch = n_agents * 2
    gen = token_stream(vocab, batch, seq_len=8, seed=0, n_agents=n_agents)
    toks = next(gen)
    assert toks.shape == (batch, 8)
    assert toks.min() >= 0 and toks.max() < vocab


def test_token_stream_agent_heterogeneity():
    """Different agents must have measurably different unigram distributions."""
    gen = token_stream(64, 4, seq_len=4096, seed=1, n_agents=2)
    toks = next(gen)
    h0 = np.bincount(toks[:2].ravel(), minlength=64) / (2 * 4096)
    h1 = np.bincount(toks[2:].ravel(), minlength=64) / (2 * 4096)
    assert np.abs(h0 - h1).sum() > 0.3  # clearly distinct distributions
