"""Unit tests for the roofline HLO parser (loop-trip weighting, dot FLOPs,
collective byte formulas)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_parse import analyze_hlo, parse_computations
from repro.roofline.analysis import collective_bytes


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    txt = _hlo_of(lambda a, b: a @ b, a, b)
    t = analyze_hlo(txt)
    assert t.flops == 2 * 64 * 128 * 32


def test_scan_trip_weighting():
    """FLOPs of a scanned matmul must scale with the trip count."""
    w = jnp.eye(64, dtype=jnp.float32)

    def body_n(n):
        def f(x):
            def step(c, _):
                return c @ w, None

            out, _ = jax.lax.scan(step, x, None, length=n)
            return out

        return f

    x = jnp.ones((64, 64), jnp.float32)
    t1 = analyze_hlo(_hlo_of(body_n(3), x))
    t2 = analyze_hlo(_hlo_of(body_n(12), x))
    assert t1.flops > 0
    ratio = t2.flops / t1.flops
    assert 3.5 <= ratio <= 4.5  # 12/3 = 4


def test_bytes_positive_and_loop_scaled():
    w = jnp.eye(32, dtype=jnp.float32)

    def f(x):
        def step(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(step, x, None, length=10)
        return out

    t = analyze_hlo(_hlo_of(f, jnp.ones((32, 32), jnp.float32)))
    assert t.bytes > 10 * 32 * 32 * 4  # at least one rw per iteration


def test_collective_regex_on_synthetic_hlo():
    txt = """
ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%ag), to_apply=%add
  ROOT %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    out = collective_bytes(txt)
    sz = 128 * 256 * 4
    assert out["all-gather"] == sz
    assert out["all-reduce"] == 2 * sz
    assert out["collective-permute"] == sz


def test_parser_handles_tuple_headers():
    def f(x):
        def step(c, _):
            return (c[0] + 1, c[1] * 2.0), None

        out, _ = jax.lax.scan(step, (x, x), None, length=4)
        return out

    txt = _hlo_of(f, jnp.ones((8, 128), jnp.float32))
    comps = parse_computations(txt)
    assert len(comps) >= 2  # entry + loop body at least
