"""Tests for the DP machinery (Thm. 1, Prop. 2, Remark 4) and the private
algorithm (Eq. 6, Thm. 2)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AgentData,
    DPConfig,
    compose_kairouz,
    invert_uniform_budget,
    laplace_scale,
    gaussian_scale,
    make_objective,
    proposition2_allocation,
    run_private,
    run_scan,
    theorem2_bound,
)
from repro.core.privacy import PrivacyAccountant, compose_uniform, schedule_renormalization
from repro.data.synthetic import linear_classification_problem


# ---------------------------------------------------------------------------
# Composition / accounting
# ---------------------------------------------------------------------------


@given(
    st.floats(min_value=1e-3, max_value=1.0),
    st.integers(min_value=0, max_value=50),
    st.sampled_from([0.0, 1e-6, np.exp(-5.0)]),
)
@settings(max_examples=40, deadline=None)
def test_compose_uniform_matches_kairouz(eps_step, k, delta):
    """The vectorized equal-steps composition == the general formula."""
    want = compose_kairouz(np.full(k, eps_step), delta)
    got = compose_uniform(eps_step, np.array([k]), delta)
    np.testing.assert_allclose(got, [want], rtol=1e-12, atol=1e-15)


def test_compose_single_step_is_identity():
    assert compose_kairouz(np.array([0.5]), 0.0) == pytest.approx(0.5)
    # With delta slack, a single step can never report more than eps.
    assert compose_kairouz(np.array([0.5]), 1e-3) <= 0.5 + 1e-12


def test_compose_beats_basic_for_many_steps():
    eps = np.full(200, 0.05)
    adv = compose_kairouz(eps, 1e-5)
    assert adv < eps.sum()  # advanced composition strictly better here


@given(
    st.lists(st.floats(min_value=1e-4, max_value=0.5), min_size=1, max_size=50),
    st.floats(min_value=1e-8, max_value=0.1),
)
@settings(max_examples=50, deadline=None)
def test_compose_monotone_and_bounded(steps, delta):
    """Property: composed eps is positive, at most the basic sum, and
    monotone in adding steps."""
    e = np.asarray(steps)
    total = compose_kairouz(e, delta)
    assert 0 < total <= e.sum() + 1e-12
    more = compose_kairouz(np.append(e, 0.1), delta)
    assert more >= total - 1e-12


@given(
    st.floats(min_value=0.05, max_value=5.0),
    st.integers(min_value=1, max_value=300),
)
@settings(max_examples=30, deadline=None)
def test_invert_uniform_budget_respects_budget(eps_bar, T_i):
    """Property: the inverted per-step eps composes to <= eps_bar and is not
    wastefully small (>= the naive eps_bar / T_i)."""
    delta = np.exp(-5.0)
    eps_step = invert_uniform_budget(eps_bar, T_i, delta)
    assert compose_kairouz(np.full(T_i, eps_step), delta) <= eps_bar + 1e-9
    assert eps_step >= eps_bar / T_i - 1e-12


def test_laplace_scale_formula():
    # s = 2 L0 / (eps m)
    assert laplace_scale(1.0, 0.5, 10) == pytest.approx(0.4)
    assert gaussian_scale(1.0, 0.5, 1e-5, 10) > 0


def test_prop2_allocation_sums_to_budget():
    sched = proposition2_allocation(2.0, T=500, C=0.99)
    assert sched.sum() == pytest.approx(2.0, rel=1e-9)
    # Lemma 3: decreasing epsilon over time => increasing noise.
    assert np.all(np.diff(sched) < 0)


def test_schedule_renormalization_bounded():
    lam = schedule_renormalization(np.arange(0, 500, 5), 500, 0.99)
    assert 0 < lam <= 1.0 + 1e-12


def test_accountant_tracks_and_blocks():
    acc = PrivacyAccountant(delta_bar=1e-3)
    for _ in range(5):
        acc.spend(0.1)
    assert acc.eps_bar <= 0.5 + 1e-12
    assert acc.can_spend(0.1, budget=1.0)
    assert not acc.can_spend(10.0, budget=1.0)


# ---------------------------------------------------------------------------
# Private algorithm end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def problem():
    return linear_classification_problem(n=10, p=6, m_low=50, m_high=100, seed=7)


def test_private_cd_respects_budget(problem):
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3)
    rng = np.random.default_rng(0)
    cfg = DPConfig(eps_bar=1.0, delta_bar=np.exp(-5.0))
    res = run_private(obj, np.zeros((obj.n, obj.p)), T=200, cfg=cfg, rng=rng)
    assert np.all(res.eps_spent <= 1.0 + 1e-9)
    assert np.all(res.eps_spent > 0)  # everyone participated


def test_private_cd_noise_scales_inverse_in_m(problem):
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3)
    rng = np.random.default_rng(1)
    cfg = DPConfig(eps_bar=1.0)
    res = run_private(obj, np.zeros((obj.n, obj.p)), T=100, cfg=cfg, rng=rng)
    m = obj.data.num_examples
    # For ticks of two agents with equal planned T_i, scale ratio ~ m ratio.
    wake = res.wake_sequence
    scales = res.noise_scales
    agents = np.unique(wake[:50])
    i, j = agents[0], agents[1]
    si = scales[np.nonzero(wake == i)[0][0]]
    sj = scales[np.nonzero(wake == j)[0][0]]
    assert si > 0 and sj > 0
    # larger dataset -> smaller noise (inverse proportionality up to eps split)
    if m[i] > 2 * m[j]:
        assert si < sj


def test_private_improves_then_pays_noise_cost(problem):
    """The Fig. 2(a) behaviour: the private trajectory descends early (useful
    signal) and always sits above the non-private one (noise cost)."""
    # Paper operational choice: treat the logistic loss as 1-Lipschitz (L0=1)
    # — enforced here via L1 gradient clipping at 1 (Supp. D.2 style).
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3, clip=1.0)
    T = 100
    rng = np.random.default_rng(2)
    wake = rng.integers(0, obj.n, size=T)
    # Constant init, as in Fig. 2(a) (zero init is already near-stationary).
    Theta0 = 2.0 * np.ones((obj.n, obj.p))
    nonpriv = run_scan(obj, Theta0, T=T, rng=rng, wake_sequence=wake)
    priv = run_private(
        obj,
        Theta0,
        T=T,
        cfg=DPConfig(eps_bar=1.0),
        rng=np.random.default_rng(3),
        wake_sequence=wake,
    )
    q0 = priv.objective[0]
    nonpriv_descent = q0 - nonpriv.objective.min()
    assert nonpriv_descent > 0
    # Collaboration signal survives the noise: the private run recovers at
    # least 25% of the non-private descent ...
    assert q0 - priv.objective.min() > 0.25 * nonpriv_descent
    # ... and the private curve never beats the non-private one (utility loss).
    assert priv.objective.min() >= nonpriv.objective.min() - 1e-9


def test_theorem2_bound_holds(problem):
    """Empirical mean gap of the private algorithm must lie below Thm. 2's
    bound (with the exact constants from the objective)."""
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3)
    from repro.core.objective import AgentData as AD

    # Quadratic version for exact Q*.
    X = problem.train.X
    y = np.einsum("nmp,np->nm", X, problem.targets) * problem.train.mask
    data = AD(X=X, y=y, mask=problem.train.mask)
    obj = make_objective(problem.graph, data, "quadratic", mu=0.3, clip=1.0)
    q_star = float(obj.value(obj.solve_exact()))
    T = 150
    n = obj.n
    sigma = obj.strong_convexity()
    L = obj.block_lipschitz()
    d, c = obj.degrees, obj.confidences
    l0 = obj.lipschitz_l1()
    m = obj.data.num_examples
    eps_step = 0.5
    scales = 2.0 * l0 / (eps_step * np.maximum(m, 1.0))

    gaps = []
    for s in range(6):
        rng = np.random.default_rng(50 + s)
        wake = rng.integers(0, n, size=T)
        noise_sched = scales[wake]
        res = run_scan(
            obj,
            np.zeros((obj.n, obj.p)),
            T=T,
            rng=rng,
            wake_sequence=wake,
            noise_scales=noise_sched,
        )
        gaps.append(res.objective - q_star)
    mean_gap = np.mean(gaps, axis=0)

    # Thm. 2 noise term: E||eta~(t)||^2 / 2 = p * sum_i (mu D_ii c_i s_i)^2
    # (Laplace per-coordinate variance 2 s^2 over p coordinates; the paper's
    # statement drops the dimension factor — we keep it to get a true bound).
    p = obj.p
    noise_sq = np.full(T, p * np.sum((obj.mu * d * c * scales) ** 2))
    bound = theorem2_bound(mean_gap[0], T, n, float(L.max()), float(L.min()), sigma, noise_sq)
    assert np.all(mean_gap <= bound * 1.5 + 1e-6)
