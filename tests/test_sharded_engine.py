"""Multi-device sharded async engine: cross-validation against the
single-device engine.

In-process tests run on the 1 visible CPU device (a 1-shard mesh is a
legal degenerate case and must already match the single-device engine
bit-for-bit under forced wakes). Multi-device semantics — forced-wake
exact parity, 512-agent fixed-point agreement across 2/4/8 shards, and
DP budget-stop parity — run in a subprocess with 8 XLA host devices, in
the ``test_spmd.py`` style, so this process keeps seeing 1 device."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgentData, knn_graph, make_objective
from repro.sim import (
    AsyncEngine,
    CDUpdate,
    DelayConfig,
    Scenario,
    ShardedAsyncEngine,
)


def _quad_problem(n, p=4, m=3, seed=0, mu=0.5):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode="sparse")


def test_single_shard_forced_wakes_match_single_device_bitwise():
    """S=1 is the degenerate mesh: same tiles, empty halo — the sharded
    super-tick must reproduce AsyncEngine exactly under forced wakes."""
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p
    eng1 = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    engS = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0, dtype=jnp.float64
    )
    s1 = eng1.init_state(np.zeros((n, p)))
    sS = engS.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(7)
    for _ in range(10):
        mask = rng.random(n) < 0.25
        s1 = eng1.step(s1, mask)
        sS = engS.step(sS, mask)
    np.testing.assert_array_equal(np.asarray(s1.Theta), engS.global_theta(sS))
    assert float(s1.messages) == float(np.asarray(sS.messages).sum())
    assert int(s1.applied) == int(np.asarray(sS.applied).sum())


def test_sharded_sampled_run_reaches_fixed_point_single_shard():
    obj = _quad_problem(n=96, seed=2)
    star = obj.solve_exact()
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=24.0, seed=3, dtype=jnp.float64
    )
    res = eng.run(np.zeros((obj.n, obj.p)), slots=500, record_every=250)
    assert np.abs(res.Theta - star).max() < 1e-5
    assert res.objective[-1] <= res.objective[0]
    assert res.slots == 500


def test_relabeled_forced_wakes_match_single_device_bitwise():
    """The permutation round-trip at the engine level: relabel -> run ->
    results come back in original ids and equal the unrelabeled run (which
    itself equals AsyncEngine bit-for-bit). S=1 exercises the full relabel
    machinery in-process; multi-shard relabeling runs in the 8-device
    subprocess scripts below."""
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p
    eng1 = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    s1 = eng1.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(7)
    masks = [rng.random(n) < 0.25 for _ in range(6)]
    for mask in masks:
        s1 = eng1.step(s1, mask)
    ref = np.asarray(s1.Theta)
    shuffle = np.random.default_rng(8).permutation(n)
    for relabel in ("rcm", shuffle):
        engS = ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, relabel=relabel,
            slot_wakes=8.0, seed=0, dtype=jnp.float64,
        )
        assert not np.array_equal(engS.part.order, np.arange(n)) or relabel == "rcm"
        sS = engS.init_state(np.zeros((n, p)))
        for mask in masks:
            sS = engS.step(sS, mask)
        np.testing.assert_array_equal(engS.global_theta(sS), ref)


def test_sharded_super_tick_closes_over_no_per_agent_array():
    """Acceptance: obj.data (and every per-agent constant) is
    shard-resident — the jitted sharded super-tick must not close over
    any array with n or more elements; everything that scales with n
    arrives as a shard_map input sliced along the shards axis."""
    obj = _quad_problem(n=48, seed=5)
    n = obj.n
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, seed=0)
    state = eng.init_state(np.zeros((n, obj.p)))
    mask = jnp.asarray(eng.part.pad_rows(np.ones(n, bool), fill=False))
    jaxpr = jax.make_jaxpr(eng._forced_impl)(state, eng._static, mask)
    leaked = [
        np.shape(c) for c in jaxpr.consts if hasattr(c, "shape") and np.size(c) >= n
    ]
    assert not leaked, f"replicated per-agent constants leaked into the super-tick: {leaked}"
    # Sanity-check the check: the single-device engine's slot *does* close
    # over the replicated data, so the probe can tell the difference.
    eng1 = AsyncEngine(CDUpdate(obj), seed=0)
    s1 = eng1.init_state(np.zeros((n, obj.p)))
    jaxpr1 = jax.make_jaxpr(eng1._slot_forced)(s1, jnp.ones(n, bool))
    assert any(hasattr(c, "shape") and np.size(c) >= n for c in jaxpr1.consts)


def test_default_batch_size_follows_owned_agents_under_relabel():
    """Regression: B_s must be sized from each shard's *owned agents'*
    rates (bounds index positions, not ids, under a relabel), so every
    shard's expected wake mass stays covered to mean + 6 sigma."""
    from repro.sim import clocks

    obj = _quad_problem(n=60, seed=6)
    rates = np.where(np.arange(obj.n) % 3 == 0, 25.0, 0.04)  # skewed classes
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, relabel="rcm", rates=rates, slot_wakes=16.0
    )
    part = eng.part
    for s in range(part.num_shards):
        owned = part.owned[s, : int(part.sizes[s])]
        need = clocks.default_batch_size(rates[owned], eng.tau)
        assert eng.batch_size >= min(need, part.rows_per_shard), (s, need)


def test_sharded_engine_rejects_delay_and_bad_shard_counts():
    obj = _quad_problem(n=24, seed=3)
    with pytest.raises(NotImplementedError, match="delay"):
        ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1,
            scenario=Scenario(delay=DelayConfig(max_delay=1)),
        )
    with pytest.raises(ValueError, match="devices"):
        ShardedAsyncEngine(CDUpdate(obj), num_shards=9999)


class _NoObjectiveUpdate:
    def __init__(self, inner):
        self._inner = inner
        self.n, self.p, self.graph, self.mix = inner.n, inner.p, inner.graph, inner.mix

    def init_state(self):
        return self._inner.init_state()

    def apply(self, *args, **kw):
        return self._inner.apply(*args, **kw)

    def apply_rows(self, *args, **kw):
        return self._inner.apply_rows(*args, **kw)


def test_sharded_record_every_without_objective_raises():
    obj = _quad_problem(n=24, seed=4)
    eng = ShardedAsyncEngine(_NoObjectiveUpdate(CDUpdate(obj)), num_shards=1, seed=0)
    with pytest.raises(ValueError, match="record_every"):
        eng.run(np.zeros((obj.n, obj.p)), slots=2, record_every=1)


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import (AgentData, DPConfig, erdos_renyi_graph, knn_graph,
                            make_objective, run_private)
    from repro.sim import (AsyncEngine, CDUpdate, DPCDUpdate, ExchangeSpec,
                       ShardedAsyncEngine)

    assert len(jax.devices()) == 8

    def quad(n, p=4, m=3, seed=0):
        rng = np.random.default_rng(seed)
        graph = knn_graph(rng.normal(size=(n, 8)), k=8)
        targets = rng.normal(size=(n, p)) / np.sqrt(p)
        X = rng.normal(size=(n, m, p)) / np.sqrt(p)
        y = np.einsum("nmp,np->nm", X, targets)
        data = AgentData(X=X, y=y, mask=np.ones((n, m)))
        return make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")

    # 1) Forced wake sets: bit-exact parity with the single-device engine
    #    across partition modes, relabel passes, and both halo-exchange
    #    wire formats, including counters.
    obj = quad(64, seed=1)
    n, p = obj.n, obj.p
    eng1 = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    s1 = eng1.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(5)
    masks = [rng.random(n) < 0.3 for _ in range(12)]
    for mask in masks:
        s1 = eng1.step(s1, mask)
    configs = [
        dict(partition_mode="contiguous"),
        dict(partition_mode="degree"),
        dict(partition_mode="degree", exchange=ExchangeSpec(method="p2p")),
        dict(partition_mode="degree", relabel="rcm",
             exchange=ExchangeSpec(method="all_gather")),
        dict(partition_mode="degree", relabel="rcm", exchange=ExchangeSpec(method="p2p")),
        dict(partition_mode="contiguous", relabel="rcm", exchange=ExchangeSpec()),
    ]
    for kw in configs:
        engS = ShardedAsyncEngine(CDUpdate(obj), num_shards=4, slot_wakes=8.0,
                                  seed=0, dtype=jnp.float64, **kw)
        sS = engS.init_state(np.zeros((n, p)))
        for mask in masks:
            sS = engS.step(sS, mask)
        assert np.array_equal(np.asarray(s1.Theta), engS.global_theta(sS)), kw
        assert float(s1.messages) == float(np.asarray(sS.messages).sum())
        assert int(s1.applied) == int(np.asarray(sS.applied).sum())
    print("FORCED_PARITY_OK")

    # 2) DP budget-stop parity under sharding (with the locality relabel
    #    and point-to-point exchange engaged): forced all-wake slots spend
    #    exactly the planned budget, matching run_private and the
    #    single-device engine's accounting.
    rngd = np.random.default_rng(0)
    gd = erdos_renyi_graph(12, 0.5, rngd)
    td = rngd.normal(size=(12, 3))
    Xd = rngd.normal(size=(12, 4, 3))
    yd = np.sign(np.einsum("nmp,np->nm", Xd, td))
    objd = make_objective(gd, AgentData(X=Xd, y=yd, mask=np.ones((12, 4))), "logistic", mu=0.3)
    planned_Ti = 3
    cfg = DPConfig(eps_bar=0.8)
    wake = np.concatenate([np.tile(np.arange(12), planned_Ti), np.arange(11)])
    seq = run_private(objd, np.zeros((12, 3)), T=len(wake), cfg=cfg,
                      rng=np.random.default_rng(0), wake_sequence=wake,
                      record_objective=False)
    upd = DPCDUpdate.plan(objd, cfg, planned_Ti=planned_Ti)
    engd = ShardedAsyncEngine(upd, num_shards=4, slot_wakes=12.0, seed=0,
                              relabel="rcm", exchange=ExchangeSpec(method="p2p"))
    st = engd.init_state(np.zeros((12, 3)))
    for _ in range(5):
        st = engd.step(st, np.ones(12, bool))
    counts = engd.part.unpad_rows(np.asarray(st.ustate))
    assert np.array_equal(counts, np.full(12, planned_Ti)), counts
    np.testing.assert_allclose(upd.eps_spent(counts), seq.eps_spent, rtol=1e-10)
    # Spent agents freeze: params and messages stop moving.
    frozen = engd.global_theta(st)
    msgs = float(np.asarray(st.messages).sum())
    st = engd.step(st, np.ones(12, bool))
    assert np.array_equal(engd.global_theta(st), frozen)
    assert float(np.asarray(st.messages).sum()) == msgs
    print("DP_PARITY_OK")
    """
)


FIXED_POINT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, knn_graph, make_objective
    from repro.sim import CDUpdate, ExchangeSpec, ShardedAsyncEngine

    rng = np.random.default_rng(0)
    n, p, m = 512, 4, 3
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    obj = make_objective(graph, data, "quadratic", mu=0.5, mix_mode="sparse")
    star = obj.solve_exact()
    upd = CDUpdate(obj)
    # Cover the exchange/relabel matrix across the shard counts without
    # blowing up runtime: each S exercises a different configuration.
    for S, kw in ((2, {}), (4, dict(relabel="rcm", exchange=ExchangeSpec(method="p2p"))),
                  (8, dict(relabel="rcm", exchange=ExchangeSpec()))):
        eng = ShardedAsyncEngine(upd, num_shards=S, slot_wakes=128.0, seed=3,
                                 dtype=jnp.float64, **kw)
        res = eng.run(np.zeros((n, p)), slots=700)
        err = np.abs(res.Theta - star).max()
        assert err < 1e-5, (S, err)
        # The exact optimum is a fixed point of the sharded super-tick too.
        st = eng.init_state(star)
        st = eng.advance(st, 5)
        drift = np.abs(eng.global_theta(st) - star).max()
        assert drift < 1e-9, (S, drift)
        print(f"S={S} {kw} err={err:.2e} drift={drift:.2e} method={eng.exchange_method}")
    print("FIXED_POINT_OK")
    """
)


def _run_multidev(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )


def test_sharded_forced_parity_and_dp_multidevice():
    res = _run_multidev(MULTIDEV_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "FORCED_PARITY_OK" in res.stdout and "DP_PARITY_OK" in res.stdout


@pytest.mark.slow
def test_sharded_fixed_point_512_agents_2_4_8_devices():
    """Acceptance: 512-agent fixed-point agreement <= 1e-5 across 2/4/8
    host devices (and the optimum stays a fixed point of the super-tick)."""
    res = _run_multidev(FIXED_POINT_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "FIXED_POINT_OK" in res.stdout
