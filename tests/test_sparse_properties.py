"""Property tests (hypothesis): dense/sparse mixing parity on random graphs.

The sparse CSR backend must be bit-for-bit interchangeable (to float
tolerance) with the dense (n, n) path on ANY valid graph — not just the
topologies the deterministic tests pick. Strategies generate random
symmetric weighted graphs; properties assert parity of the mix operator
and of full coordinate-descent trajectories.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import AgentData, AgentGraph, make_objective, mix_op, run_scan


def random_graph(n: int, density: float, seed: int) -> AgentGraph:
    """Random symmetric weighted graph with every degree >= 1."""
    rng = np.random.default_rng(seed)
    upper = np.triu((rng.random((n, n)) < density) * rng.random((n, n)), 1)
    w = upper + upper.T
    for i in range(n):  # guarantee D_ii > 0
        if w[i].sum() == 0.0:
            j = (i + 1) % n
            w[i, j] = w[j, i] = 1.0
    return AgentGraph(w)


graph_params = st.tuples(
    st.integers(min_value=2, max_value=24),  # n
    st.floats(min_value=0.05, max_value=0.9),  # density
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(graph_params, st.integers(min_value=1, max_value=64))
def test_mix_parity_on_random_graphs(params, p):
    n, density, seed = params
    g = random_graph(n, density, seed)
    Theta = jnp.asarray(
        np.random.default_rng(seed ^ 0xABCDEF).normal(size=(n, p)), jnp.float32
    )
    dense, sparse = mix_op(g, mode="dense"), mix_op(g, mode="sparse")
    np.testing.assert_allclose(
        np.asarray(dense.all(Theta)), np.asarray(sparse.all(Theta)),
        rtol=1e-5, atol=1e-5,
    )
    i = seed % n
    np.testing.assert_allclose(
        np.asarray(dense.row(Theta, i)), np.asarray(sparse.row(Theta, i)),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(
        float(dense.pairwise_smoothness(Theta)),
        float(sparse.pairwise_smoothness(Theta)),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(graph_params, st.integers(min_value=1, max_value=60))
def test_cd_trajectory_parity_on_random_graphs(params, T):
    n, density, seed = params
    g = random_graph(n, density, seed)
    rng = np.random.default_rng(seed)
    p, m = 4, 5
    targets = rng.normal(size=(n, p))
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    obj_d = make_objective(g, data, "quadratic", mu=0.4, mix_mode="dense")
    obj_s = make_objective(g.to_csr(), data, "quadratic", mu=0.4, mix_mode="sparse")
    wake = rng.integers(0, n, size=T)
    rd = run_scan(obj_d, np.zeros((n, p)), T=T, rng=rng, wake_sequence=wake)
    rs = run_scan(obj_s, np.zeros((n, p)), T=T, rng=rng, wake_sequence=wake)
    np.testing.assert_allclose(rd.Theta, rs.Theta, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(rd.objective, rs.objective, rtol=1e-4, atol=1e-5)
