"""SPMD P2P layer tests.

Single-device tests run inline; multi-device semantics (ppermute gossip vs
dense mixing vs the simulator's synchronous round) run in a subprocess with
8 XLA host devices so the main test process keeps seeing 1 device.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import P2PConfig
from repro.core import spmd
from repro.launch.mesh import use_mesh
from repro.models import build_model
from repro.models.sharding import batch_specs, cache_specs, param_specs


def make_mesh_1dev():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.slow
def test_train_step_single_device_runs_and_updates():
    mesh = make_mesh_1dev()
    cfg = get_reduced("llama3.2-1b", dtype="float32")
    m = build_model(cfg, remat=False)
    p2p = P2PConfig(agent_mode="full", dp_enabled=False, mu=0.3)
    A = spmd.num_agents(mesh, "full")
    params = jax.vmap(m.init)(jax.random.split(jax.random.PRNGKey(0), A))
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (A, 2, 17)), jnp.int32)}
    with use_mesh(mesh):
        step, _, _ = spmd.make_train_step(m, p2p, mesh, local_batch_size=2)
        p1, metrics = jax.jit(step)(params, batch, jax.random.PRNGKey(1))
        p2, m2 = jax.jit(step)(p1, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"]))
    # local descent: loss must drop over a few rounds on the same batch
    assert float(m2["loss"]) < float(metrics["loss"])


def test_dp_noise_scale_follows_theorem1():
    mesh = make_mesh_1dev()
    cfg = get_reduced("llama3.2-1b", dtype="float32")
    m = build_model(cfg, remat=False)
    p2p = P2PConfig(agent_mode="full", dp_enabled=True, eps_bar=1.0, planned_rounds=10, clip=2.0)
    with use_mesh(mesh):
        _, eps_step, noise_scale = spmd.make_train_step(m, p2p, mesh, local_batch_size=4)
    from repro.core.privacy import invert_uniform_budget

    want_eps = invert_uniform_budget(1.0, 10, p2p.delta_bar)
    assert eps_step == pytest.approx(want_eps)
    assert noise_scale == pytest.approx(2.0 * 2.0 / (want_eps * 4))


def test_param_specs_divisibility_safe():
    """No spec may shard a dim that the axis size does not divide."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("granite-moe-3b-a800m", dtype="float32")
    m = build_model(cfg, remat=False)
    params = jax.vmap(m.init)(jax.random.split(jax.random.PRNGKey(0), 1))
    # Check against the production mesh sizes without building 256 devices:
    # fake a mesh-shape lookup object.
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    specs = param_specs(params, FakeMesh(), "full", 16)
    for leaf, spec in zip(jax.tree.leaves(params), jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))):
        for dim, name in enumerate(spec):
            if name is None:
                continue
            size = {"data": 16, "model": 16, ("pod", "data"): 32}.get(name, 16)
            if isinstance(name, tuple):
                size = 32
            assert leaf.shape[dim] % size == 0 or leaf.shape[dim] == 1, (
                f"{leaf.shape} dim {dim} not divisible by {name}"
            )


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.configs.base import P2PConfig
    from repro.core import spmd
    from repro.models import build_model

    from repro.launch.mesh import make_mesh, use_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_reduced("llama3.2-1b", dtype="float32")
    m = build_model(cfg, remat=False)
    A = spmd.num_agents(mesh, "full")
    assert A == 4
    params = jax.vmap(m.init)(jax.random.split(jax.random.PRNGKey(0), A))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (A, 2, 17)), jnp.int32)}

    p2p_pp = P2PConfig(agent_mode="full", dp_enabled=False, mu=0.2,
                       neighbor_offsets=(1,), gossip_dtype=None)
    with use_mesh(mesh):
        step_pp, _, _ = spmd.make_train_step(m, p2p_pp, mesh, 2, gossip="ppermute")
        step_dn, _, _ = spmd.make_train_step(m, p2p_pp, mesh, 2, gossip="dense")
        out_pp, _ = jax.jit(step_pp)(params, batch, jax.random.PRNGKey(1))
        out_dn, _ = jax.jit(step_dn)(params, batch, jax.random.PRNGKey(1))
    for a, b in zip(jax.tree.leaves(out_pp), jax.tree.leaves(out_dn)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)

    # ppermute mixing itself equals the circulant-matrix product.
    from repro.models.sharding import param_specs
    specs = param_specs(params, mesh, "full", A)
    with use_mesh(mesh):
        mixed = jax.jit(lambda p: spmd.gossip_ppermute(p, specs, mesh, (1,), ("data",)))(params)
    W = np.zeros((A, A))
    for i in range(A):
        W[i, (i + 1) % A] = W[i, (i - 1) % A] = 0.5
    for leaf, ml in zip(jax.tree.leaves(params), jax.tree.leaves(mixed)):
        want = np.einsum("ij,j...->i...", W, np.asarray(leaf, np.float64))
        np.testing.assert_allclose(np.asarray(ml), want, rtol=2e-4, atol=2e-5)
    print("MULTIDEV_OK")
    """
)


@pytest.mark.slow
def test_gossip_ppermute_matches_dense_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_ENABLE_X64", None)
    res = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MULTIDEV_OK" in res.stdout


GOSSIP_COLLISION_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import spmd
    from repro.launch.mesh import use_mesh

    for A in (2, 3, 4, 6, 8):
        mesh = Mesh(np.asarray(jax.devices()[:A]), ("data",))
        rng = np.random.default_rng(A)
        params = {"w": jnp.asarray(rng.normal(size=(A, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(A,)), jnp.float32)}
        specs = {"w": P("data"), "b": P("data")}
        for offsets in [(1,), (2,), (1, 2), (max(A // 2, 1),), (1, A), (3,), (1, 2, 3)]:
            # The distinct target set (incl. the self-loop from offsets
            # ≡ 0 mod A) — exactly what the dense W construction stores.
            offs = sorted({s * int(o) % A for o in offsets for s in (1, -1)})
            W = np.zeros((A, A))
            for o in offsets:
                for i in range(A):
                    W[i, (i + o) % A] = 1.0
                    W[i, (i - o) % A] = 1.0
            Wn = W / W.sum(1, keepdims=True)
            idx = (np.arange(A)[:, None] + np.asarray(offs)[None, :]) % A
            wgt = np.full(idx.shape, 1.0 / len(offs), np.float32)
            with use_mesh(mesh):
                got_pp = jax.jit(
                    lambda ps: spmd.gossip_ppermute(ps, specs, mesh, offsets, ("data",))
                )(params)
            got_ga = spmd.gossip_gather(params, jnp.asarray(idx, jnp.int32), jnp.asarray(wgt))
            got_dn = spmd.gossip_dense(params, jnp.asarray(Wn, jnp.float32))
            for k in params:
                tag = f"A={A} offsets={offsets} leaf={k}"
                np.testing.assert_allclose(
                    np.asarray(got_pp[k]), np.asarray(got_ga[k]),
                    rtol=2e-5, atol=2e-6, err_msg="ppermute vs gather " + tag)
                np.testing.assert_allclose(
                    np.asarray(got_pp[k]), np.asarray(got_dn[k]),
                    rtol=2e-5, atol=2e-6, err_msg="ppermute vs dense " + tag)
    print("GOSSIP_COLLISION_OK")
    """
)


def test_gossip_ppermute_normalizes_over_distinct_targets():
    """Regression: ring offsets colliding mod A (e.g. A=4, offsets=(1, 2):
    +2 and -2 are the same neighbour) used to be double-counted by the
    ppermute path at weight 2/(2|offsets|) while the dense/sparse W stores
    a single unit entry. All three gossip paths must agree on the
    distinct-target normalization for every small-A offset combination,
    including A-dividing offsets (self-loops)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    res = subprocess.run(
        [sys.executable, "-c", GOSSIP_COLLISION_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "GOSSIP_COLLISION_OK" in res.stdout


def test_decode_step_sharded_single_device():
    mesh = make_mesh_1dev()
    cfg = get_reduced("granite-3-8b", dtype="float32")
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    caches = m.init_cache(params, 4, 32)
    with use_mesh(mesh):
        logits, new_caches = jax.jit(m.decode)(params, jnp.zeros((4, 1), jnp.int32), caches, jnp.int32(5))
    assert logits.shape == (4, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
