"""CSR graph backend: construction, invariants, and dense/sparse parity."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    AgentData,
    CSRGraph,
    as_csr,
    csr_from_coo,
    knn_cosine_graph,
    knn_graph,
    make_objective,
    mix_op,
    neighbor_counts,
    random_geometric_graph,
    ring_graph,
    run_propagation,
    run_scan,
    sparse_crossover,
    synchronous_round,
)
from repro.core.graph import dense_weights
from repro.data.synthetic import linear_classification_problem


def _quad_objectives(n=12, p=6, mu=0.5, seed=3):
    prob = linear_classification_problem(n=n, p=p, m_low=5, m_high=15, seed=seed)
    X = prob.train.X
    y = np.einsum("nmp,np->nm", X, prob.targets) * prob.train.mask
    data = AgentData(X=X, y=y, mask=prob.train.mask)
    dense = make_objective(prob.graph, data, "quadratic", mu=mu, mix_mode="dense")
    sparse = make_objective(
        prob.graph.to_csr(), data, "quadratic", mu=mu, mix_mode="sparse"
    )
    return dense, sparse


# ---------------------------------------------------------------------------
# Construction and invariants
# ---------------------------------------------------------------------------


def test_csr_roundtrip_preserves_weights():
    g = ring_graph(9, weight=1.5)
    back = g.to_csr().to_dense()
    np.testing.assert_allclose(back.weights, g.weights)


def test_csr_matches_dense_accessors():
    feats = np.random.default_rng(0).normal(size=(40, 8))
    gd = knn_cosine_graph(feats, k=4)
    gs = gd.to_csr()
    assert gs.n == gd.n
    assert gs.num_edges() == gd.num_edges()
    assert gs.max_degree() == gd.max_degree()
    np.testing.assert_allclose(gs.degrees, gd.degrees)
    np.testing.assert_array_equal(neighbor_counts(gs), neighbor_counts(gd))
    for i in range(gd.n):
        np.testing.assert_array_equal(gs.neighbors(i), gd.neighbors(i))
    assert gs.is_connected() == gd.is_connected()


def test_csr_rejects_asymmetry():
    with pytest.raises(ValueError, match="symmetric"):
        CSRGraph(
            indptr=np.array([0, 1, 1]),
            indices=np.array([1], dtype=np.int32),
            data=np.array([1.0]),
        )


def test_csr_rejects_self_loops_and_negative_weights():
    with pytest.raises(ValueError, match="diagonal"):
        CSRGraph(
            indptr=np.array([0, 1]),
            indices=np.array([0], dtype=np.int32),
            data=np.array([1.0]),
        )
    with pytest.raises(ValueError, match="non-negative"):
        csr_from_coo(2, [0, 1], [1, 0], [-1.0, -1.0])


def test_csr_from_coo_dedupes_and_symmetrizes():
    g = csr_from_coo(3, [0, 0, 1], [1, 1, 2], [0.5, 2.0, 1.0], symmetrize=True)
    np.testing.assert_allclose(
        dense_weights(g), [[0, 2.0, 0], [2.0, 0, 1.0], [0, 1.0, 0]]
    )


def test_knn_graph_matches_dense_knn():
    feats = np.random.default_rng(1).normal(size=(64, 10))
    want = knn_cosine_graph(feats, k=5).weights
    got = dense_weights(knn_graph(feats, k=5, block_rows=7))
    np.testing.assert_allclose(got, want)


def test_knn_clamps_k_to_everyone_is_a_neighbour():
    """k >= n must mean the complete graph (paper semantics), not an
    np.argpartition crash on an out-of-range kth."""
    feats = np.random.default_rng(3).normal(size=(5, 4))
    for k in (4, 5, 17):  # n - 1, n, and far beyond
        dense = knn_cosine_graph(feats, k=k)
        sparse = knn_graph(feats, k=k, block_rows=2)
        want = 1.0 - np.eye(5)
        np.testing.assert_array_equal(dense.weights, want)
        np.testing.assert_array_equal(dense_weights(sparse), want)


def test_knn_degenerate_single_agent():
    feats = np.ones((1, 3))
    assert knn_cosine_graph(feats, k=10).num_edges() == 0
    g = knn_graph(feats, k=10)
    assert g.n == 1 and g.nnz == 0


def test_random_geometric_graph_properties():
    rng = np.random.default_rng(2)
    g = random_geometric_graph(800, rng, avg_degree=10.0)
    deg = neighbor_counts(g)
    assert deg.min() >= 1  # Eq. 4 divides by D_ii
    assert 4.0 < deg.mean() < 20.0  # near the target, MC slack
    g.to_dense()  # validates symmetry/diagonal via AgentGraph checks


def test_padded_neighbors_covers_all_edges():
    g = as_csr(ring_graph(7, weight=2.0))
    idx, w = g.padded_neighbors(pad_to=5)
    assert idx.shape == (7, 5) and w.shape == (7, 5)
    np.testing.assert_allclose(w.sum(axis=1), g.degrees)  # pad weight 0
    # Pad entries point at the row itself: gathers always in-bounds.
    assert idx.min() >= 0 and idx.max() < 7


def test_sparse_crossover_env_knob(monkeypatch):
    monkeypatch.setenv("REPRO_SPARSE_CROSSOVER", "3")
    assert sparse_crossover() == 3
    g = ring_graph(5)
    assert mix_op(g, mode="auto").kind == "sparse"
    monkeypatch.setenv("REPRO_SPARSE_CROSSOVER", "1000")
    assert mix_op(g, mode="auto").kind == "dense"


def test_kernel_max_n_env_knob(monkeypatch):
    import jax

    from repro.core.mixing import kernel_max_n

    monkeypatch.setenv("REPRO_KERNEL_MAX_N", "7")
    assert kernel_max_n() == 7
    monkeypatch.setenv("REPRO_KERNEL_MAX_N", "not-a-number")
    with pytest.raises(ValueError):
        kernel_max_n()
    monkeypatch.delenv("REPRO_KERNEL_MAX_N")
    assert kernel_max_n() == 4096  # default

    # The auto-gate honours the knob (simulate a TPU backend; dtype f32).
    op = mix_op(ring_graph(16), mode="sparse")
    theta = jnp.zeros((16, 4), jnp.float32)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert op._kernel_auto(theta)
    monkeypatch.setenv("REPRO_KERNEL_MAX_N", "8")
    assert not op._kernel_auto(theta)  # n=16 now above the ceiling
    assert not op._kernel_auto(theta.astype(jnp.float64))  # dtype gate intact


# ---------------------------------------------------------------------------
# Dense/sparse parity of the operators and full algorithms
# ---------------------------------------------------------------------------


def test_mix_operator_parity():
    rng = np.random.default_rng(3)
    g = knn_cosine_graph(rng.normal(size=(50, 8)), k=6)
    Theta = jnp.asarray(rng.normal(size=(50, 17)), jnp.float32)
    dense, sparse = mix_op(g, mode="dense"), mix_op(g, mode="sparse")
    np.testing.assert_allclose(
        np.asarray(dense.all(Theta)), np.asarray(sparse.all(Theta)), atol=1e-5
    )
    for i in [0, 7, 49]:
        np.testing.assert_allclose(
            np.asarray(dense.row(Theta, i)), np.asarray(sparse.row(Theta, i)), atol=1e-5
        )
    np.testing.assert_allclose(
        float(dense.pairwise_smoothness(Theta)),
        float(sparse.pairwise_smoothness(Theta)),
        rtol=1e-6,
    )


def test_mix_gather_rows_batched_parity():
    """gather_rows (the repro.sim woken-rows path) == stacked row() calls,
    on both backends, including the interpreted kernel route."""
    rng = np.random.default_rng(8)
    g = knn_cosine_graph(rng.normal(size=(40, 8)), k=6)
    Theta = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
    rows = jnp.asarray([0, 3, 17, 39, 5])
    for mode in ("dense", "sparse"):
        op = mix_op(g, mode=mode)
        got = np.asarray(op.gather_rows(Theta, rows))
        want = np.stack([np.asarray(op.row(Theta, int(i))) for i in np.asarray(rows)])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    sparse = mix_op(g, mode="sparse")
    kern = np.asarray(sparse.gather_rows(Theta, rows, use_kernel=True))
    plain = np.asarray(sparse.gather_rows(Theta, rows, use_kernel=False))
    np.testing.assert_allclose(kern, plain, rtol=1e-5, atol=1e-5)


def test_mix_all_kernel_path_parity():
    """MixOp.all(use_kernel=True) (Pallas, interpreted on CPU) == jnp path,
    for both backends; auto stays off the kernels on a CPU backend."""
    import jax

    rng = np.random.default_rng(11)
    g = knn_cosine_graph(rng.normal(size=(48, 8)), k=5)
    Theta = jnp.asarray(rng.normal(size=(48, 130)), jnp.float32)
    for mode in ("dense", "sparse"):
        op = mix_op(g, mode=mode)
        np.testing.assert_allclose(
            np.asarray(op.all(Theta, use_kernel=True)),
            np.asarray(op.all(Theta, use_kernel=False)),
            rtol=1e-5, atol=1e-5,
        )
        if jax.default_backend() != "tpu":
            assert not op._kernel_auto(Theta)


def test_objective_value_and_grad_parity():
    obj_d, obj_s = _quad_objectives()
    rng = np.random.default_rng(4)
    Theta = jnp.asarray(rng.normal(size=(obj_d.n, obj_d.p)))
    assert abs(float(obj_d.value(Theta)) - float(obj_s.value(Theta))) < 1e-8
    np.testing.assert_allclose(
        np.asarray(obj_d.block_grad(Theta)), np.asarray(obj_s.block_grad(Theta)),
        atol=1e-8,
    )
    np.testing.assert_allclose(obj_d.solve_exact(), obj_s.solve_exact(), atol=1e-10)


def test_cd_trajectory_parity_dense_vs_sparse():
    obj_d, obj_s = _quad_objectives()
    rng = np.random.default_rng(5)
    wake = rng.integers(0, obj_d.n, size=150)
    rd = run_scan(obj_d, np.zeros((obj_d.n, obj_d.p)), T=150, rng=rng, wake_sequence=wake)
    rs = run_scan(obj_s, np.zeros((obj_s.n, obj_s.p)), T=150, rng=rng, wake_sequence=wake)
    np.testing.assert_allclose(rd.Theta, rs.Theta, atol=1e-5)
    np.testing.assert_allclose(rd.objective, rs.objective, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(rd.messages, rs.messages)


def test_synchronous_round_parity():
    obj_d, obj_s = _quad_objectives()
    rng = np.random.default_rng(6)
    Theta = jnp.asarray(rng.normal(size=(obj_d.n, obj_d.p)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(synchronous_round(obj_d, Theta)),
        np.asarray(synchronous_round(obj_s, Theta)),
        atol=1e-5,
    )


def test_model_propagation_parity():
    rng = np.random.default_rng(7)
    feats = rng.normal(size=(20, 6))
    gd = knn_cosine_graph(feats, k=4)
    theta = rng.normal(size=(20, 5))
    out_d = run_propagation(gd, theta.copy(), 0.5, np.ones(20), 60, np.random.default_rng(8))
    out_s = run_propagation(
        gd.to_csr(), theta.copy(), 0.5, np.ones(20), 60, np.random.default_rng(8)
    )
    np.testing.assert_allclose(out_d, out_s, atol=1e-12)


def test_gossip_gather_matches_gossip_dense():
    from repro.core.spmd import gossip_dense, gossip_gather

    rng = np.random.default_rng(9)
    A, K = 8, 2
    params = {"w": jnp.asarray(rng.normal(size=(A, 4, 3)), jnp.float32)}
    W = np.zeros((A, A))
    for i in range(A):
        W[i, (i + 1) % A] = W[i, (i - 1) % A] = 1.0
    mix_mat = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    idx = np.stack([(np.arange(A) + 1) % A, (np.arange(A) - 1) % A], axis=1)
    w = jnp.full((A, K), 0.5, jnp.float32)
    out_d = gossip_dense(params, mix_mat)
    out_s = gossip_gather(params, jnp.asarray(idx, jnp.int32), w)
    np.testing.assert_allclose(
        np.asarray(out_d["w"]), np.asarray(out_s["w"]), atol=1e-6
    )
