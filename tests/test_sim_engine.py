"""Batched async engine (repro.sim): cross-validation against the
sequential simulators, DP budget-stop parity, and scenario invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentData,
    DPConfig,
    knn_graph,
    make_objective,
    ring_graph,
    run,
    run_private,
)
from repro.core.coordinate_descent import _cd_step
from repro.core.model_propagation import propagation_objective
from repro.sim import (
    AsyncEngine,
    CDUpdate,
    ChurnConfig,
    DelayConfig,
    DPCDUpdate,
    PropagationUpdate,
    Scenario,
    StragglerConfig,
)


def _quad_problem(n, p=4, m=3, seed=0, mix_mode="auto", mu=0.5, graph=None):
    rng = np.random.default_rng(seed)
    if graph is None:
        graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode=mix_mode)


@pytest.fixture(scope="module")
def small_problem():
    return _quad_problem(n=24, seed=1)


# ---------------------------------------------------------------------------
# Determinism and clock statistics
# ---------------------------------------------------------------------------


def test_engine_seeded_determinism(small_problem):
    obj = small_problem
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=6.0, seed=11)
    r1 = eng.run(np.zeros((obj.n, obj.p)), slots=40)
    r2 = eng.run(np.zeros((obj.n, obj.p)), slots=40)
    np.testing.assert_array_equal(r1.Theta, r2.Theta)
    assert r1.messages == r2.messages and r1.wakes_applied == r2.wakes_applied

    r3 = AsyncEngine(CDUpdate(obj), slot_wakes=6.0, seed=12).run(
        np.zeros((obj.n, obj.p)), slots=40
    )
    assert not np.array_equal(r1.Theta, r3.Theta)


def test_thinned_wake_rate_matches_expectation(small_problem):
    obj = small_problem
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=6.0, seed=0)
    slots = 200
    res = eng.run(np.zeros((obj.n, obj.p)), slots=slots)
    mu = sum(eng.wake_probs) * slots
    sigma = np.sqrt(mu)
    assert abs(res.wakes_applied - mu) < 6 * sigma
    assert res.wakes_dropped == 0  # B = mean + 6 sigma: overflow ~impossible


def test_heterogeneous_rates_skew_wake_counts(small_problem):
    obj = small_problem
    n = obj.n
    rates = np.where(np.arange(n) < n // 2, 8.0, 0.5)
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=6.0, rates=rates, seed=3)
    state = eng.init_state(np.zeros((n, obj.p)))
    woke = np.zeros(n)
    for _ in range(60):
        prev = np.asarray(state.Theta)
        state = eng.advance(state, 1)
        woke += np.any(np.asarray(state.Theta) != prev, axis=1)
    # Fast agents (16x rate) must wake far more often than slow ones.
    assert woke[: n // 2].mean() > 3.0 * max(woke[n // 2 :].mean(), 1e-9)


def test_slot_capacity_overflow_is_counted(small_problem):
    obj = small_problem
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=4.0, batch_size=2, seed=0)
    state = eng.init_state(np.zeros((obj.n, obj.p)))
    mask = np.zeros(obj.n, dtype=bool)
    mask[:5] = True
    state = eng.step(state, mask)
    assert int(state.applied) == 2 and int(state.dropped) == 3


# ---------------------------------------------------------------------------
# Cross-validation against the sequential simulators
# ---------------------------------------------------------------------------


def test_forced_single_wakes_match_sequential_run_exactly(small_problem):
    """One agent per slot, no scenario: the engine IS the faithful simulator."""
    obj = small_problem
    rng = np.random.default_rng(5)
    wake_seq = rng.integers(0, obj.n, size=30)
    r_seq = run(obj, np.zeros((obj.n, obj.p)), T=30, rng=rng, wake_sequence=wake_seq)

    eng = AsyncEngine(CDUpdate(obj), slot_wakes=1.0, seed=0)
    state = eng.init_state(np.zeros((obj.n, obj.p)))
    for i in wake_seq:
        mask = np.zeros(obj.n, dtype=bool)
        mask[i] = True
        state = eng.step(state, mask)
    np.testing.assert_allclose(np.asarray(state.Theta), r_seq.Theta, rtol=1e-5, atol=1e-6)
    assert float(state.messages) == r_seq.messages[-1]


def test_batched_slot_equals_snapshot_updates(small_problem):
    """A multi-agent slot applies each woken agent's update from the same
    start-of-slot snapshot (bounded staleness, the recorded deviation)."""
    obj = small_problem
    rng = np.random.default_rng(6)
    Theta0 = rng.normal(size=(obj.n, obj.p))
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=4.0, seed=0, dtype=jnp.float64)
    state = eng.init_state(Theta0)
    woken = [0, 3, 9, 17]
    mask = np.zeros(obj.n, dtype=bool)
    mask[woken] = True
    state = eng.step(state, mask)

    snap = jnp.asarray(Theta0, jnp.float64)
    expected = np.array(snap)
    for i in woken:
        expected[i] = np.asarray(_cd_step(obj, snap, i))[i]
    np.testing.assert_allclose(np.asarray(state.Theta), expected, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("mix_mode", ["dense", "sparse"])
def test_engine_reaches_sequential_fixed_point_512(mix_mode):
    """Acceptance: batched engine matches the sequential CD fixed point
    within 1e-5 at n=512, dense and sparse backends."""
    obj = _quad_problem(n=512, seed=0, mix_mode=mix_mode)
    Theta_star = obj.solve_exact()
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=128.0, seed=3, dtype=jnp.float64)
    res = eng.run(np.zeros((obj.n, obj.p)), slots=700)
    assert np.abs(res.Theta - Theta_star).max() < 1e-5
    # And the sequential optimum is an engine fixed point.
    state = eng.init_state(Theta_star)
    state = eng.advance(state, 5)
    assert np.abs(np.asarray(state.Theta) - Theta_star).max() < 1e-9


def test_dense_and_sparse_backends_agree_trajectorywise():
    dense = _quad_problem(n=48, seed=2, mix_mode="dense")
    sparse = _quad_problem(n=48, seed=2, mix_mode="sparse")
    rd = AsyncEngine(CDUpdate(dense), slot_wakes=8.0, seed=4, dtype=jnp.float64).run(
        np.zeros((48, 4)), slots=60
    )
    rs = AsyncEngine(CDUpdate(sparse), slot_wakes=8.0, seed=4, dtype=jnp.float64).run(
        np.zeros((48, 4)), slots=60
    )
    np.testing.assert_allclose(rd.Theta, rs.Theta, rtol=1e-9, atol=1e-11)


# ---------------------------------------------------------------------------
# DP-CD: budget stopping parity with dp_cd.run_private
# ---------------------------------------------------------------------------


def _logistic_problem(n=8, p=3, m=4, seed=0):
    rng = np.random.default_rng(seed)
    from repro.core import erdos_renyi_graph

    graph = erdos_renyi_graph(n, 0.5, rng)
    targets = rng.normal(size=(n, p))
    X = rng.normal(size=(n, m, p))
    y = np.sign(np.einsum("nmp,np->nm", X, targets))
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "logistic", mu=0.3)


def test_dp_budget_stop_parity_with_dp_cd():
    obj = _logistic_problem()
    n = obj.n
    planned_Ti = 3
    cfg = DPConfig(eps_bar=0.8)

    # Sequential driver: round-robin wakes sized so run_private plans
    # T // n == planned_Ti and every agent wakes at least that often —
    # everyone spends exactly planned_Ti equal steps, then stops.
    wake = np.concatenate([np.tile(np.arange(n), planned_Ti), np.arange(n - 1)])
    seq = run_private(
        obj, np.zeros((n, obj.p)), T=len(wake), cfg=cfg,
        rng=np.random.default_rng(0), wake_sequence=wake, record_objective=False,
    )

    # Engine: forced all-wake slots until everyone exceeds the plan.
    upd = DPCDUpdate.plan(obj, cfg, planned_Ti=planned_Ti)
    assert upd.planned_Ti == len(wake) // n  # same plan as run_private's T//n
    eng = AsyncEngine(upd, slot_wakes=float(n), seed=0)
    state = eng.init_state(np.zeros((n, obj.p)))
    for _ in range(5):
        state = eng.step(state, np.ones(n, dtype=bool))

    counts = np.asarray(state.ustate)
    np.testing.assert_array_equal(counts, np.full(n, planned_Ti))
    eps_engine = upd.eps_spent(state.ustate)
    np.testing.assert_allclose(eps_engine, seq.eps_spent, rtol=1e-10)
    assert np.all(eps_engine <= cfg.eps_bar + 1e-9)


def test_dp_exhausted_agents_freeze():
    obj = _logistic_problem(seed=1)
    n = obj.n
    upd = DPCDUpdate.plan(obj, DPConfig(eps_bar=0.5), planned_Ti=2)
    eng = AsyncEngine(upd, slot_wakes=float(n), seed=0)
    state = eng.init_state(np.zeros((n, obj.p)))
    for _ in range(2):
        state = eng.step(state, np.ones(n, dtype=bool))
    frozen = np.asarray(state.Theta)
    msgs = float(state.messages)
    state = eng.step(state, np.ones(n, dtype=bool))  # budget spent: no-ops
    np.testing.assert_array_equal(np.asarray(state.Theta), frozen)
    assert float(state.messages) == msgs  # nothing broadcast either
    assert int(state.applied) == 2 * n


def test_dp_plan_rejects_prop2_schedule():
    obj = _logistic_problem(seed=2)
    with pytest.raises(NotImplementedError):
        DPCDUpdate.plan(obj, DPConfig(eps_bar=0.5, schedule="prop2"), planned_Ti=3)


def test_compose_uniform_vectorizes_over_agents():
    """The vectorized accounting behind DPCDUpdate.eps_spent == per-agent
    compose_kairouz. (Lives here, not test_privacy.py, which is
    hypothesis-gated and skips entirely on containers without it.)"""
    from repro.core.privacy import compose_kairouz, compose_uniform

    counts = np.array([0, 1, 5, 40])
    got = compose_uniform(0.2, counts, 1e-5)
    want = [compose_kairouz(np.full(k, 0.2), 1e-5) for k in counts]
    np.testing.assert_allclose(got, want, rtol=1e-12)
    assert got[0] == 0.0


# ---------------------------------------------------------------------------
# Scenarios: churn, delay, stragglers
# ---------------------------------------------------------------------------


def test_churn_departed_agents_params_frozen(small_problem):
    obj = small_problem
    n = obj.n
    leavers = np.zeros(n)
    leavers[[2, 5, 11]] = 1.0  # depart deterministically at slot 0
    sc = Scenario(churn=ChurnConfig(leave_prob=leavers, rejoin_prob=0.0))
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=1, scenario=sc)
    rng = np.random.default_rng(0)
    Theta0 = rng.normal(size=(n, obj.p)).astype(np.float32)  # engine dtype: exact freeze
    res = eng.run(Theta0, slots=80)
    np.testing.assert_array_equal(res.Theta[[2, 5, 11]], Theta0[[2, 5, 11]])
    assert not res.active[[2, 5, 11]].any()
    # The rest of the network kept training (and mixed the frozen models).
    others = np.setdiff1d(np.arange(n), [2, 5, 11])
    assert np.abs(res.Theta[others] - Theta0[others]).max() > 1e-3


def test_straggler_drop_prob_one_loses_everything(small_problem):
    obj = small_problem
    sc = Scenario(straggler=StragglerConfig(drop_prob=1.0))
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=1, scenario=sc)
    Theta0 = np.random.default_rng(0).normal(size=(obj.n, obj.p)).astype(np.float32)
    res = eng.run(Theta0, slots=30)
    np.testing.assert_array_equal(res.Theta, Theta0)
    assert res.wakes_applied == 0 and res.messages == 0.0


def test_delayed_messages_lag_and_arrive_in_order():
    """Per-edge delay d: a woken agent mixes neighbour state from d slots
    ago, and successive broadcasts arrive in send order (FIFO)."""
    n, p = 3, 2
    graph = ring_graph(n)
    obj = _quad_problem(n=n, p=p, m=2, seed=3, graph=graph, mix_mode="dense")
    d = 2
    sc = Scenario(delay=DelayConfig(max_delay=d, edge_delays=d))
    eng = AsyncEngine(
        CDUpdate(obj), slot_wakes=1.0, seed=0, scenario=sc, dtype=jnp.float64
    )
    rng = np.random.default_rng(4)
    Theta0 = rng.normal(size=(n, p))
    state = eng.init_state(Theta0)

    def wake(state, i):
        mask = np.zeros(n, dtype=bool)
        mask[i] = True
        return eng.step(state, mask)

    snapshots = [np.asarray(state.Theta)]  # start-of-slot states
    state = wake(state, 0)  # slot 0: theta_0 -> v1
    snapshots.append(np.asarray(state.Theta))
    state = wake(state, 0)  # slot 1: theta_0 -> v2
    snapshots.append(np.asarray(state.Theta))

    def expected_row1(state, lagged):
        """Eq. 4 for agent 1 where neighbours are read from ``lagged``."""
        view = lagged.copy()
        view[1] = np.asarray(state.Theta)[1]  # own block is always current
        return np.asarray(_cd_step(obj, jnp.asarray(view), 1))[1]

    # Slot 2: agent 1 must see theta_0 as of slot 2 - d = 0 (the initial
    # value), not v1 or v2.
    exp = expected_row1(state, snapshots[0])
    state = wake(state, 1)
    np.testing.assert_allclose(np.asarray(state.Theta)[1], exp, rtol=1e-12)

    # Slot 3: now the slot-1 snapshot (v1) arrives — the earlier broadcast
    # lands first; delayed messages are applied in send order.
    exp = expected_row1(state, snapshots[1])
    state = wake(state, 1)
    np.testing.assert_allclose(np.asarray(state.Theta)[1], exp, rtol=1e-12)


def test_zero_delay_config_matches_no_delay_engine(small_problem):
    obj = small_problem
    sc = Scenario(delay=DelayConfig(max_delay=0, edge_delays=0))
    r_delay = AsyncEngine(
        CDUpdate(obj), slot_wakes=8.0, seed=9, scenario=sc, dtype=jnp.float64
    ).run(np.zeros((obj.n, obj.p)), slots=40)
    r_plain = AsyncEngine(
        CDUpdate(obj), slot_wakes=8.0, seed=9, dtype=jnp.float64
    ).run(np.zeros((obj.n, obj.p)), slots=40)
    np.testing.assert_allclose(r_delay.Theta, r_plain.Theta, rtol=1e-9, atol=1e-11)


def test_full_scenario_still_converges(small_problem):
    """Churn + delay + stragglers: objective still heads downhill."""
    obj = small_problem
    sc = Scenario(
        churn=ChurnConfig(leave_prob=0.02, rejoin_prob=0.3),
        delay=DelayConfig(max_delay=2, edge_delays=1),
        straggler=StragglerConfig(drop_prob=0.2),
    )
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=5, scenario=sc)
    res = eng.run(np.zeros((obj.n, obj.p)), slots=150, record_every=150)
    assert res.objective[-1] < 0.75 * res.objective[0]
    assert np.isfinite(res.Theta).all()


# ---------------------------------------------------------------------------
# record_every validation
# ---------------------------------------------------------------------------


class _NoObjectiveUpdate:
    """A LocalUpdate without an objective method (delegates the rest)."""

    def __init__(self, inner):
        self._inner = inner
        self.n, self.p, self.graph, self.mix = inner.n, inner.p, inner.graph, inner.mix

    def init_state(self):
        return self._inner.init_state()

    def apply(self, *args, **kw):
        return self._inner.apply(*args, **kw)

    def apply_rows(self, *args, **kw):
        return self._inner.apply_rows(*args, **kw)


def test_record_every_without_objective_raises(small_problem):
    """Asking for an objective trace the update cannot produce must be a
    loud error, not a silently-ignored record_every."""
    obj = small_problem
    upd = _NoObjectiveUpdate(CDUpdate(obj))
    eng = AsyncEngine(upd, slot_wakes=4.0, seed=0)
    with pytest.raises(ValueError, match="record_every"):
        eng.run(np.zeros((obj.n, obj.p)), slots=4, record_every=2)
    # record_every=0 still runs fine without an objective.
    res = eng.run(np.zeros((obj.n, obj.p)), slots=4)
    assert res.objective is None and res.slots == 4


# ---------------------------------------------------------------------------
# Model propagation through the same engine
# ---------------------------------------------------------------------------


def test_propagation_update_converges_to_exact_solution():
    rng = np.random.default_rng(0)
    n, p = 20, 3
    graph = knn_graph(rng.normal(size=(n, 6)), k=5)
    theta_loc = rng.normal(size=(n, p))
    conf = np.ones(n)
    upd = PropagationUpdate(graph=graph, theta_loc=theta_loc, mu=0.5, confidences=conf)
    eng = AsyncEngine(upd, slot_wakes=5.0, seed=2, dtype=jnp.float64)
    res = eng.run(theta_loc, slots=400, record_every=200)
    _, solve = propagation_objective(graph, theta_loc, 0.5, conf)
    star = solve()
    assert np.abs(res.Theta - star).max() < 1e-6
    assert res.objective[-1] <= res.objective[0]
