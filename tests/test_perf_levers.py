"""Numerical-equivalence tests for the §Perf optimization levers: layout
changes must never change model semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod


@pytest.fixture
def cfg():
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, dtype="float32",
    )


def test_chunked_attention_matches_dense(cfg, monkeypatch):
    """The flash-style q-block path must equal the dense path exactly."""
    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 10**9)
    params = attn_mod.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2048, 64), jnp.float32)
    dense_out, _ = attn_mod.attention(params, x, cfg)
    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 1024)
    chunked_out, _ = attn_mod.attention(params, x, cfg)
    np.testing.assert_allclose(
        np.asarray(dense_out), np.asarray(chunked_out), rtol=2e-4, atol=2e-5
    )


def test_chunked_attention_matches_dense_windowed(cfg, monkeypatch):
    import dataclasses

    wcfg = dataclasses.replace(cfg, sliding_window=256)
    params = attn_mod.init_attention(jax.random.PRNGKey(0), wcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2048, 64), jnp.float32)
    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 10**9)
    dense_out, _ = attn_mod.attention(params, x, wcfg, window=256)
    monkeypatch.setattr(attn_mod, "CHUNKED_ATTN_THRESHOLD", 1024)
    chunked_out, _ = attn_mod.attention(params, x, wcfg, window=256)
    np.testing.assert_allclose(
        np.asarray(dense_out), np.asarray(chunked_out), rtol=2e-4, atol=2e-5
    )


def test_repeat_kv_cache_decode_equivalence(cfg):
    """Decode with the pre-repeated KV cache layout must produce identical
    logits to the GQA-compact layout."""
    params = attn_mod.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x_steps = jax.random.normal(jax.random.PRNGKey(2), (4, 2, 1, 64), jnp.float32)

    def run(flag):
        attn_mod.set_repeat_kv_cache(flag)
        try:
            cache = attn_mod.init_cache(cfg, 2, 16, jnp.float32)
            outs = []
            for i in range(4):
                y, cache = attn_mod.decode_attention(params, x_steps[i], cfg, cache,
                                                     jnp.int32(i))
                outs.append(np.asarray(y))
            return np.stack(outs)
        finally:
            attn_mod.set_repeat_kv_cache(False)

    np.testing.assert_allclose(run(False), run(True), rtol=1e-5, atol=1e-6)


def test_seq_axis_constraint_noop_without_mesh():
    """constrain_seq must be the identity when no TP mesh context exists."""
    from repro.models.sharding import constrain_seq, set_seq_axis

    x = jnp.ones((2, 8, 4))
    set_seq_axis("model")
    try:
        y = constrain_seq(x)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    finally:
        set_seq_axis(None)
