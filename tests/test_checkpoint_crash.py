"""Crash injection for the checkpoint layer: torn writes must never load,
and a rotation root must always fall back to the newest entry that does.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, load_checkpoint, save_checkpoint


def _tree(v=0.0, n=16):
    return {
        "w": jnp.full((n, 4), v, jnp.float32),
        "b": jnp.full((n,), v, jnp.bfloat16),
        "step_count": jnp.int32(int(v)),
    }


# -- rotation ----------------------------------------------------------------


def test_rotation_keeps_last_k_and_loads_newest(tmp_path):
    root = str(tmp_path / "rot")
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(root, _tree(step), step=step, keep_last=2)
    entries = sorted(os.listdir(root))
    assert entries == ["ckpt-000000000004", "ckpt-000000000005"]
    restored, step, _ = load_checkpoint(root, _tree())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), 5.0)


def test_missing_or_empty_root_raises_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope"), _tree())
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(empty), _tree())


def test_root_with_only_tmp_staging_raises_filenotfound(tmp_path):
    """A writer killed before its first rename leaves only ``.tmp`` —
    which must read as 'nothing was ever written', not as a candidate."""
    root = tmp_path / "rot"
    (root / "ckpt-000000000001.tmp").mkdir(parents=True)
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(root), _tree())


# -- mid-write crash ---------------------------------------------------------


def test_midwrite_crash_recovers_previous_entry(tmp_path, monkeypatch):
    """Kill the writer after its first array file: the save raises, no new
    entry appears, and the rotation still serves the previous step."""
    root = str(tmp_path / "rot")
    big = {"a": jnp.ones((256, 64)), "b": jnp.zeros((256, 64))}  # 2 files
    save_checkpoint(root, big, step=1, keep_last=3, max_shard_bytes=1 << 14)
    assert len(os.listdir(os.path.join(root, "ckpt-000000000001"))) == 3

    real_savez = np.savez
    calls = {"n": 0}

    def dying_savez(*args, **kw):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise OSError("simulated crash: disk gone mid-write")
        return real_savez(*args, **kw)

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(root, big, step=2, keep_last=3, max_shard_bytes=1 << 14)
    monkeypatch.undo()

    assert calls["n"] == 2  # it really was mid-entry, not before or after
    # The torn write left only staging debris, never a loadable entry.
    names = os.listdir(root)
    assert "ckpt-000000000002" not in names
    assert "ckpt-000000000002.tmp" in names
    restored, step, _ = load_checkpoint(root, big)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), 1.0)

    # A later successful save reclaims the stale staging dir for its step.
    save_checkpoint(root, big, step=2, keep_last=3, max_shard_bytes=1 << 14)
    _, step, _ = load_checkpoint(root, big)
    assert step == 2


def test_truncated_file_rejected_and_rotation_falls_back(tmp_path):
    root = str(tmp_path / "rot")
    save_checkpoint(root, _tree(1), step=1, keep_last=3)
    save_checkpoint(root, _tree(2), step=2, keep_last=3)
    newest = os.path.join(root, "ckpt-000000000002")
    shard = os.path.join(newest, "shard_0.npz")
    with open(shard, "rb") as f:
        blob = f.read()
    with open(shard, "wb") as f:
        f.write(blob[: len(blob) // 2])  # torn at half length

    # Loading the torn entry directly names the corruption...
    with pytest.raises(CheckpointError, match="sha256 mismatch"):
        load_checkpoint(newest, _tree())
    # ...and the rotation root silently falls back to the previous entry.
    restored, step, _ = load_checkpoint(root, _tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


def test_missing_shard_file_is_a_torn_write(tmp_path):
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, _tree(3), step=3)
    os.remove(os.path.join(ck, "shard_0.npz"))
    with pytest.raises(CheckpointError, match="missing file"):
        load_checkpoint(ck, _tree())


def test_all_entries_torn_raises_checkpoint_error(tmp_path):
    """Entries exist but none verifies: that's corruption, not absence."""
    root = str(tmp_path / "rot")
    save_checkpoint(root, _tree(1), step=1, keep_last=3)
    entry = os.path.join(root, "ckpt-000000000001")
    os.remove(os.path.join(entry, "shard_0.npz"))
    with pytest.raises(CheckpointError, match="no valid checkpoint"):
        load_checkpoint(root, _tree())


def test_manifest_without_checkpoint_kind_is_rejected(tmp_path):
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, _tree(1), step=1)
    mp = os.path.join(ck, "manifest.json")
    with open(mp) as f:
        manifest = json.load(f)
    manifest["kind"] = "mystery"
    with open(mp, "w") as f:
        json.dump(manifest, f)
    # The manifest edit is a content change too — recompute nothing: the
    # manifest itself carries no self-hash, so this exercises the kind gate.
    with pytest.raises(CheckpointError, match="not a pytree checkpoint"):
        load_checkpoint(ck, _tree())


# -- structure verification (the once-dead manifest field, now load-bearing) --


def test_structure_digest_catches_extra_and_missing_leaves(tmp_path):
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, _tree(), step=0)
    extra = dict(_tree(), junk=jnp.zeros(3))
    with pytest.raises(CheckpointError, match="structure mismatch"):
        load_checkpoint(ck, extra)
    fewer = {"w": _tree()["w"]}
    with pytest.raises(CheckpointError, match="structure mismatch"):
        load_checkpoint(ck, fewer)


def test_structure_digest_catches_dtype_change(tmp_path):
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, _tree(), step=0)
    wrong = dict(_tree(), b=jnp.zeros((16,), jnp.float32))  # bf16 -> f32
    with pytest.raises(CheckpointError, match="dtype"):
        load_checkpoint(ck, wrong)


def test_manifest_records_structure_digest_and_file_hashes(tmp_path):
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, _tree(), step=0)
    with open(os.path.join(ck, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["kind"] == "pytree" and manifest["format"] == 2
    assert len(manifest["structure"]) == 64  # sha256 hex
    npz = [n for n in os.listdir(ck) if n.endswith(".npz")]
    assert sorted(manifest["file_sha256"]) == sorted(npz)


# -- shard flush path --------------------------------------------------------


def test_single_leaf_larger_than_max_shard_bytes_gets_own_file(tmp_path):
    """The flush path: one oversized leaf may exceed ``max_shard_bytes``
    (npz files are per-leaf at minimum) but must not drag later leaves
    into its file — and the whole thing still round-trips."""
    tree = {
        "big": jnp.arange(1 << 18, dtype=jnp.float32),  # 1 MiB
        "small": jnp.full((4,), 7.0, jnp.float32),
    }
    ck = str(tmp_path / "ck")
    save_checkpoint(ck, tree, max_shard_bytes=1 << 10)
    shards = sorted(n for n in os.listdir(ck) if n.startswith("shard_"))
    assert len(shards) == 2
    sizes = [os.path.getsize(os.path.join(ck, s)) for s in shards]
    assert max(sizes) > (1 << 20) and min(sizes) < (1 << 12)
    restored, _, _ = load_checkpoint(ck, tree)
    np.testing.assert_array_equal(np.asarray(restored["big"]), np.asarray(tree["big"]))
    np.testing.assert_array_equal(np.asarray(restored["small"]), 7.0)
