"""Property-based tests on SPMD-layer invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.spmd import _ring_perm, clip_and_noise, gossip_dense
from repro.models.sharding import param_specs


@given(st.integers(min_value=2, max_value=64), st.integers(min_value=1, max_value=63))
@settings(max_examples=30, deadline=None)
def test_ring_perm_is_permutation(n, shift):
    perm = _ring_perm(n, shift % n)
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    assert sorted(srcs) == list(range(n))
    assert sorted(dsts) == list(range(n))


@given(st.floats(min_value=0.1, max_value=10.0))
@settings(max_examples=20, deadline=None)
def test_clip_and_noise_enforces_sensitivity(clip):
    """With zero noise, the output global norm never exceeds the clip."""
    tree = {
        "a": jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)) * 100, jnp.float32),
        "b": jnp.asarray(np.random.default_rng(1).normal(size=(4,)) * 100, jnp.float32),
    }
    out = clip_and_noise(tree, jax.random.PRNGKey(0), clip, 0.0)
    norm = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(out))))
    assert norm <= clip * (1 + 1e-5)


def test_clip_and_noise_preserves_small_gradients():
    tree = {"a": jnp.full((4, 4), 0.01, jnp.float32)}
    out = clip_and_noise(tree, jax.random.PRNGKey(0), clip=100.0, noise_scale=0.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.01, rtol=1e-6)


def test_gossip_dense_doubly_stochastic_fixed_point():
    """Consensus invariance: identical agents are a fixed point of mixing."""
    A = 8
    W = np.zeros((A, A))
    for i in range(A):
        W[i, (i + 1) % A] = W[i, (i - 1) % A] = 0.5
    mix = jnp.asarray(W)
    tree = {"w": jnp.broadcast_to(jnp.arange(6.0).reshape(2, 3), (A, 2, 3))}
    out = gossip_dense(tree, mix)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]), rtol=1e-6)


def test_gossip_dense_mass_conservation():
    """Row-stochastic symmetric mixing preserves the mean over agents."""
    A = 6
    W = np.zeros((A, A))
    for i in range(A):
        W[i, (i + 1) % A] = W[i, (i - 1) % A] = 0.5
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(A, 3, 2)), jnp.float32)}
    out = gossip_dense(tree, jnp.asarray(W))
    np.testing.assert_allclose(
        np.asarray(out["w"]).mean(0), np.asarray(tree["w"]).mean(0), rtol=1e-5, atol=1e-6
    )


def test_param_specs_structure_matches_params():
    from repro.configs import get_reduced
    from repro.models import build_model

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in ["llama3.2-1b", "xlstm-1.3b", "zamba2-1.2b", "seamless-m4t-medium"]:
        cfg = get_reduced(arch, dtype="float32")
        m = build_model(cfg, remat=False)
        params = jax.eval_shape(lambda: jax.vmap(m.init)(
            jax.random.split(jax.random.PRNGKey(0), 2)))
        specs = param_specs(params, FakeMesh(), "full", 16)
        assert jax.tree.structure(params) == jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
