"""Engine checkpoint/restore: crash-safe resume closures, parity-tested
back to bit-exactness.

In-process tests cover both engines at S=1: a run of 2k slots must equal
1k slots -> save -> restore into a fresh engine -> 1k more slots, bit for
bit, on Theta, the metrics counters, and the DP accountant — for CD and
DP-CD, static and dynamic topology. Multi-shard semantics (S=4 resume
parity, S=4 -> S=8 elastic restore <= 1e-12 under forced wakes, with the
no-(n,p)-materialization probe armed) run in an 8-host-device subprocess
in the ``test_sharded_engine.py`` style.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, restore, save_engine_checkpoint
from repro.core import AgentData, DPConfig, knn_graph, make_objective
from repro.sim import (
    AsyncEngine,
    CDUpdate,
    DelayConfig,
    DPCDUpdate,
    Scenario,
    ShardedAsyncEngine,
)
from repro.sim.partition import GraphPartition
from repro.sim.updates import GraphUpdate


def _quad_problem(n, p=4, m=3, seed=0, mu=0.5, clip=None):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode="sparse", clip=clip)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _resume_run(make_engine, Theta0, total, cut, tmp_path, **run_kw):
    """total slots straight through vs cut + save/restore + (total-cut)."""
    ref_eng = make_engine()
    ref = ref_eng.run(Theta0, slots=total, **run_kw)
    half_eng = make_engine()
    half = half_eng.run(Theta0, slots=cut, **run_kw)
    ck = str(tmp_path / f"ck{cut}")
    save_engine_checkpoint(half_eng, half.state, ck)
    res_eng = make_engine()
    state, step = restore(res_eng, ck)
    assert step == cut
    fin = res_eng.run(None, slots=total - cut, state=state, **run_kw)
    return ref_eng, ref, res_eng, fin


# -- AsyncEngine -------------------------------------------------------------


def test_async_static_cd_resume_bit_exact(tmp_path):
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p

    def mk():
        return AsyncEngine(
            CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64, metrics=True
        )

    _, ref, _, fin = _resume_run(mk, np.zeros((n, p)), 24, 12, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    assert fin.messages == ref.messages
    assert fin.wakes_applied == ref.wakes_applied
    assert fin.wakes_dropped == ref.wakes_dropped
    _assert_trees_equal(fin.state.metrics, ref.state.metrics)


def test_async_static_dp_resume_bit_exact_including_accountant(tmp_path):
    obj = _quad_problem(n=40, seed=1, clip=1.0)
    n, p = obj.n, obj.p
    dp = DPCDUpdate.plan(obj, DPConfig(eps_bar=1.0), planned_Ti=6)

    def mk():
        return AsyncEngine(dp, slot_wakes=8.0, seed=0, dtype=jnp.float64, metrics=True)

    _, ref, _, fin = _resume_run(mk, np.zeros((n, p)), 24, 12, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    # The DP accountant (per-agent wake counts -> eps spent) resumes exactly.
    _assert_trees_equal(fin.state.ustate, ref.state.ustate)
    np.testing.assert_array_equal(
        dp.eps_spent(fin.state.ustate), dp.eps_spent(ref.state.ustate)
    )


@pytest.mark.parametrize("cut", [6, 11, 12, 18])
def test_async_dynamic_resume_bit_exact_across_cut_points(tmp_path, cut):
    """Resume through topology refreshes: the refresh grid is absolute in
    the slot counter, so a save at any point — including exactly on a
    refresh boundary — replays the same refresh sequence."""
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p

    def mk():
        return AsyncEngine(
            CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64,
            metrics=True, graph_update=GraphUpdate(every=6),
        )

    ref_eng, ref, res_eng, fin = _resume_run(mk, np.zeros((n, p)), 24, cut, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    assert res_eng.topology_log == ref_eng.topology_log
    assert int(np.asarray(res_eng.topo.version)) == int(np.asarray(ref_eng.topo.version))
    assert res_eng.topo.capacity == ref_eng.topo.capacity
    assert res_eng._csr.digest() == ref_eng._csr.digest()


def test_async_delay_ring_resumes_bit_exact(tmp_path):
    """The staleness ring buffer (hist) is part of the resume closure."""
    obj = _quad_problem(n=32, seed=4)
    n, p = obj.n, obj.p
    scen = Scenario(delay=DelayConfig(max_delay=2))

    def mk():
        return AsyncEngine(
            CDUpdate(obj), slot_wakes=6.0, seed=2, dtype=jnp.float64, scenario=scen
        )

    _, ref, _, fin = _resume_run(mk, np.zeros((n, p)), 16, 7, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    np.testing.assert_array_equal(
        np.asarray(fin.state.hist), np.asarray(ref.state.hist)
    )


# -- ShardedAsyncEngine, S=1 in-process --------------------------------------


def test_sharded_static_resume_bit_exact_forced_wakes(tmp_path):
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p

    def mk():
        return ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0,
            dtype=jnp.float64, metrics=True,
        )

    rng = np.random.default_rng(7)
    masks = [rng.random(n) < 0.25 for _ in range(10)]
    e1 = mk()
    s1 = e1.init_state(np.zeros((n, p)))
    for m in masks:
        s1 = e1.step(s1, m)
    e2 = mk()
    s2 = e2.init_state(np.zeros((n, p)))
    for m in masks[:5]:
        s2 = e2.step(s2, m)
    ck = str(tmp_path / "ck")
    save_engine_checkpoint(e2, s2, ck)
    e3 = mk()
    st, step = restore(e3, ck)
    assert step == 5
    for m in masks[5:]:
        st = e3.step(st, m)
    # Every leaf of the sharded state — Theta tiles, churn mask, PRNG
    # keys, counters, metrics — is bit-identical to the uninterrupted run.
    _assert_trees_equal(st, s1)


def test_sharded_dp_resume_bit_exact(tmp_path):
    obj = _quad_problem(n=36, seed=2, clip=1.0)
    n, p = obj.n, obj.p
    dp = DPCDUpdate.plan(obj, DPConfig(eps_bar=1.0), planned_Ti=4)

    def mk():
        return ShardedAsyncEngine(
            dp, num_shards=1, slot_wakes=8.0, seed=0, dtype=jnp.float64, metrics=True
        )

    _, ref, res_eng, fin = _resume_run(mk, np.zeros((n, p)), 20, 10, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    _assert_trees_equal(fin.state.ustate, ref.state.ustate)


@pytest.mark.parametrize("cut", [6, 9, 12])
def test_sharded_dynamic_sampled_run_resume_bit_exact(tmp_path, cut):
    obj = _quad_problem(n=48, seed=2)
    n, p = obj.n, obj.p

    def mk():
        return ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0,
            dtype=jnp.float64, metrics=True,
            graph_update=GraphUpdate(every=6), drift_threshold=1.0,
        )

    ref_eng, ref, res_eng, fin = _resume_run(mk, np.zeros((n, p)), 24, cut, tmp_path)
    np.testing.assert_array_equal(fin.Theta, ref.Theta)
    assert res_eng.topology_log == ref_eng.topology_log


# -- Guard rails -------------------------------------------------------------


def test_fingerprint_mismatches_are_rejected(tmp_path):
    obj = _quad_problem(n=40, seed=1)
    other = _quad_problem(n=40, seed=9)  # different graph + data
    n, p = obj.n, obj.p
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0)
    res = eng.run(np.zeros((n, p)), slots=4)
    ck = str(tmp_path / "ck")
    save_engine_checkpoint(eng, res.state, ck)

    with pytest.raises(CheckpointError, match="config"):
        restore(AsyncEngine(CDUpdate(obj), slot_wakes=4.0, seed=0), ck)
    with pytest.raises(CheckpointError, match="graph"):
        restore(AsyncEngine(CDUpdate(other), slot_wakes=8.0, seed=0), ck)
    with pytest.raises(CheckpointError, match="cannot restore"):
        restore(ShardedAsyncEngine(CDUpdate(obj), num_shards=1, slot_wakes=8.0), ck)
    # And the reverse: a pytree checkpoint is not an engine checkpoint.
    from repro.checkpoint import save_checkpoint

    save_checkpoint(str(tmp_path / "plain"), {"w": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="not an engine checkpoint"):
        restore(eng, str(tmp_path / "plain"))


def test_run_checkpoint_every_writes_restorable_rotation(tmp_path):
    obj = _quad_problem(n=40, seed=1)
    n, p = obj.n, obj.p

    def mk():
        return ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0, dtype=jnp.float64
        )

    ck = str(tmp_path / "rot")
    eng = mk()
    ref = eng.run(np.zeros((n, p)), slots=12)
    eng2 = mk()
    eng2.run(
        np.zeros((n, p)), slots=12,
        checkpoint_every=4, checkpoint_dir=ck, checkpoint_keep_last=2,
    )
    entries = sorted(e for e in os.listdir(ck) if e.startswith("ckpt-"))
    assert entries == ["ckpt-000000000008", "ckpt-000000000012"]  # keep_last=2
    eng3 = mk()
    state, step = restore(eng3, ck)  # newest entry wins
    assert step == 12
    np.testing.assert_array_equal(eng3.global_theta(state), ref.Theta)

    with pytest.raises(ValueError, match="checkpoint_every and checkpoint_dir"):
        mk().run(np.zeros((n, p)), slots=4, checkpoint_every=4)
    with pytest.raises(ValueError, match="checkpoint_every and checkpoint_dir"):
        mk().run(np.zeros((n, p)), slots=4, checkpoint_dir=ck)


def test_engine_state_dict_exposes_fingerprint_and_files():
    obj = _quad_problem(n=24, seed=3)
    n, p = obj.n, obj.p
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0)
    state = eng.init_state(np.zeros((n, p)))
    files, manifest = eng.state_dict(state)
    assert manifest["kind"] == "engine" and manifest["engine"] == "sharded"
    assert manifest["fingerprint"]["n"] == n
    assert {"partition.npz", "scalars.npz", "shard_0.npz"} <= set(files)
    # Per-shard files carry original agent ids — the relabel-stable key
    # that makes the layout elastic.
    assert sorted(files["shard_0.npz"]["ids"].tolist()) == list(range(n))


class _MaterializationProbe:
    """Fails the test if the checkpoint path assembles a global (n, p)
    float array: pad_rows on an (n, >=2-D) float input, unpad_rows on
    stacked float tiles, or any global_theta call."""

    def __enter__(self):
        self._pad = GraphPartition.pad_rows
        self._unpad = GraphPartition.unpad_rows
        self._gt = ShardedAsyncEngine.global_theta
        pad, unpad = self._pad, self._unpad

        def _is_float(arr):
            dt = str(arr.dtype) if hasattr(arr, "dtype") else str(np.asarray(arr).dtype)
            return "float" in dt or dt == "bfloat16"

        def trap_pad(part, rows, *a, **k):
            if np.ndim(rows) >= 2 and np.shape(rows)[0] == part.n and _is_float(rows):
                raise AssertionError(f"pad_rows saw a global array: {np.shape(rows)}")
            return pad(part, rows, *a, **k)

        def trap_unpad(part, tiles, *a, **k):
            if np.ndim(tiles) >= 3 and _is_float(tiles):
                raise AssertionError(
                    f"unpad_rows would build a global array: {np.shape(tiles)}"
                )
            return unpad(part, tiles, *a, **k)

        def trap_gt(engine, state):
            raise AssertionError("global_theta called inside the checkpoint path")

        GraphPartition.pad_rows = trap_pad
        GraphPartition.unpad_rows = trap_unpad
        ShardedAsyncEngine.global_theta = trap_gt
        return self

    def __exit__(self, *exc):
        GraphPartition.pad_rows = self._pad
        GraphPartition.unpad_rows = self._unpad
        ShardedAsyncEngine.global_theta = self._gt
        return False


def test_sharded_checkpoint_never_materializes_global_theta(tmp_path):
    """Acceptance probe: save + restore work tile-by-tile; no (n, p)
    model matrix exists on the host at any point in either direction."""
    obj = _quad_problem(n=48, seed=5)
    n, p = obj.n, obj.p

    def mk():
        return ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0,
            dtype=jnp.float64, metrics=True,
        )

    eng = mk()
    state = eng.init_state(np.zeros((n, p)))
    rng = np.random.default_rng(3)
    for _ in range(4):
        state = eng.step(state, rng.random(n) < 0.3)
    target = mk()  # engine construction may pad data consts; that's fine
    ck = str(tmp_path / "ck")
    with _MaterializationProbe():
        save_engine_checkpoint(eng, state, ck)
        restored, step = restore(target, ck)
    _assert_trees_equal(restored, state)


# -- Multi-device subprocess matrix ------------------------------------------

_PRELUDE = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, DPConfig, knn_graph, make_objective
    from repro.sim import CDUpdate, DPCDUpdate, ShardedAsyncEngine
    from repro.sim.partition import GraphPartition
    from repro.sim.updates import GraphUpdate
    from repro.checkpoint import restore, save_engine_checkpoint

    assert len(jax.devices()) == 8

    def quad(n, p=4, m=3, seed=0, clip=None):
        rng = np.random.default_rng(seed)
        graph = knn_graph(rng.normal(size=(n, 8)), k=8)
        targets = rng.normal(size=(n, p)) / np.sqrt(p)
        X = rng.normal(size=(n, m, p)) / np.sqrt(p)
        y = np.einsum("nmp,np->nm", X, targets)
        data = AgentData(X=X, y=y, mask=np.ones((n, m)))
        return make_objective(graph, data, "quadratic", mu=0.5,
                              mix_mode="sparse", clip=clip)
    """
)

RESUME_SCRIPT = _PRELUDE + textwrap.dedent(
    """
    # S=4 forced-wake resume: bit-exact for CD and DP-CD.
    for tag, mkobj, mkupd in (
        ("CD", lambda: quad(96, seed=1), CDUpdate),
        ("DP", lambda: quad(96, seed=1, clip=1.0),
         lambda o: DPCDUpdate.plan(o, DPConfig(eps_bar=1.0), planned_Ti=4)),
    ):
        obj = mkobj()
        n, p = obj.n, obj.p
        upd = mkupd(obj)
        mk = lambda: ShardedAsyncEngine(upd, num_shards=4, slot_wakes=8.0,
                                        seed=0, dtype=jnp.float64,
                                        relabel="rcm", metrics=True)
        rng = np.random.default_rng(5)
        masks = [rng.random(n) < 0.3 for _ in range(10)]
        e1 = mk(); s1 = e1.init_state(np.zeros((n, p)))
        for m in masks: s1 = e1.step(s1, m)
        e2 = mk(); s2 = e2.init_state(np.zeros((n, p)))
        for m in masks[:5]: s2 = e2.step(s2, m)
        with tempfile.TemporaryDirectory() as td:
            ck = os.path.join(td, "ck")
            save_engine_checkpoint(e2, s2, ck)
            e3 = mk(); st, step = restore(e3, ck)
            assert step == 5, step
            for m in masks[5:]: st = e3.step(st, m)
            for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(s1)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), tag
        print(f"RESUME_{tag}_OK")

    # S=4 dynamic sampled run() resume across a refresh boundary.
    obj = quad(96, seed=2)
    n, p = obj.n, obj.p
    mk = lambda: ShardedAsyncEngine(CDUpdate(obj), num_shards=4, slot_wakes=8.0,
                                    seed=0, dtype=jnp.float64,
                                    graph_update=GraphUpdate(every=6),
                                    drift_threshold=1.0)
    refeng = mk(); ref = refeng.run(np.zeros((n, p)), slots=24)
    for cut in (6, 9):
        e2 = mk(); half = e2.run(np.zeros((n, p)), slots=cut)
        with tempfile.TemporaryDirectory() as td:
            ck = os.path.join(td, "ck")
            save_engine_checkpoint(e2, half.state, ck)
            e3 = mk(); st, step = restore(e3, ck)
            fin = e3.run(None, slots=24 - cut, state=st)
            assert np.array_equal(fin.Theta, ref.Theta), cut
            assert e3.topology_log == refeng.topology_log, cut
    print("DYN_RESUME_OK")
    """
)

ELASTIC_SCRIPT = _PRELUDE + textwrap.dedent(
    """
    # Elastic S=4 -> S=8 under forced wakes, probe armed around the
    # checkpoint round-trip: <= 1e-12 against an uninterrupted S=8 run
    # (forced S=8 from scratch is itself bit-exact to S=4 — existing
    # parity tests — so only the checkpoint may introduce error).
    obj = quad(128, seed=3)
    n, p = obj.n, obj.p
    mk = lambda S: ShardedAsyncEngine(CDUpdate(obj), num_shards=S,
                                      slot_wakes=8.0, seed=0,
                                      dtype=jnp.float64, metrics=True)
    rng = np.random.default_rng(7)
    masks = [rng.random(n) < 0.25 for _ in range(10)]
    e8 = mk(8); s8 = e8.init_state(np.zeros((n, p)))
    for m in masks: s8 = e8.step(s8, m)
    ref = e8.global_theta(s8)

    e4 = mk(4); s4 = e4.init_state(np.zeros((n, p)))
    for m in masks[:5]: s4 = e4.step(s4, m)
    e8b = mk(8)  # built before the probe: construction pads data consts

    def _is_float(arr):
        dt = str(arr.dtype) if hasattr(arr, "dtype") else str(np.asarray(arr).dtype)
        return "float" in dt or dt == "bfloat16"

    pad, unpad = GraphPartition.pad_rows, GraphPartition.unpad_rows
    gt = ShardedAsyncEngine.global_theta
    def trap_pad(part, rows, *a, **k):
        if np.ndim(rows) >= 2 and np.shape(rows)[0] == part.n and _is_float(rows):
            raise AssertionError(f"pad_rows saw a global array: {np.shape(rows)}")
        return pad(part, rows, *a, **k)
    def trap_unpad(part, tiles, *a, **k):
        if np.ndim(tiles) >= 3 and _is_float(tiles):
            raise AssertionError(f"unpad_rows: {np.shape(tiles)}")
        return unpad(part, tiles, *a, **k)
    def trap_gt(engine, state):
        raise AssertionError("global_theta inside checkpoint path")

    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        GraphPartition.pad_rows, GraphPartition.unpad_rows = trap_pad, trap_unpad
        ShardedAsyncEngine.global_theta = trap_gt
        try:
            save_engine_checkpoint(e4, s4, ck)
            st, step = restore(e8b, ck)
        finally:
            GraphPartition.pad_rows, GraphPartition.unpad_rows = pad, unpad
            ShardedAsyncEngine.global_theta = gt
        assert step == 5, step
        for m in masks[5:]: st = e8b.step(st, m)
        err = np.abs(e8b.global_theta(st) - ref).max()
        assert err <= 1e-12, err
        # Run totals survive the shard-count change (collapsed to shard 0).
        assert int(np.asarray(st.applied).sum()) == int(np.asarray(s8.applied).sum())
        assert float(np.asarray(st.messages).sum()) == float(np.asarray(s8.messages).sum())
        print(f"ELASTIC_OK err={err:.1e}")
    """
)


def _run_multidev(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )


@pytest.mark.slow
def test_sharded_multidevice_resume_bit_exact():
    res = _run_multidev(RESUME_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    for sentinel in ("RESUME_CD_OK", "RESUME_DP_OK", "DYN_RESUME_OK"):
        assert sentinel in res.stdout, res.stdout


@pytest.mark.slow
def test_sharded_elastic_restore_s4_to_s8():
    """Acceptance: a checkpoint written at S=4 restores into S=8 within
    1e-12 under forced wakes, and Theta never materializes as one (n, p)
    host array during save or load."""
    res = _run_multidev(ELASTIC_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ELASTIC_OK" in res.stdout, res.stdout
