"""Tests for Supp. C: model propagation + private warm start."""

import numpy as np
import pytest

from repro.core import (
    make_objective,
    run_scan,
    train_local_models,
    private_local_models,
    private_warm_start,
)
from repro.core.model_propagation import propagation_objective, run_propagation
from repro.data.synthetic import linear_classification_problem, eval_accuracy


@pytest.fixture(scope="module")
def problem():
    return linear_classification_problem(n=12, p=8, m_low=10, m_high=60, seed=11)


def test_propagation_converges_to_closed_form(problem):
    n, p = 12, 8
    rng = np.random.default_rng(0)
    theta_loc = rng.normal(size=(n, p))
    from repro.core.graph import confidences as conf

    c = conf(problem.train.num_examples)
    value, solve = propagation_objective(problem.graph, theta_loc, mu=0.5, confidences=c)
    star = solve()
    out = run_propagation(problem.graph, theta_loc, 0.5, c, T=2000, rng=rng)
    assert np.abs(out - star).max() < 1e-6
    assert value(out) <= value(theta_loc) + 1e-12


def test_local_models_fit_training_data(problem):
    theta_loc = train_local_models(
        problem.train, __import__("repro.core.objective", fromlist=["LOGISTIC"]).LOGISTIC,
        1.0 / np.maximum(problem.train.num_examples, 1.0),
    )
    acc = eval_accuracy(theta_loc, problem.test)
    assert acc.mean() > 0.6  # clearly better than chance


def test_private_local_models_noise_scales(problem):
    rng = np.random.default_rng(1)
    theta = np.zeros((12, 8))
    lam = 1.0 / np.maximum(problem.train.num_examples, 1.0)
    m = problem.train.num_examples
    priv = private_local_models(theta, 1.0, lam, m, eps=1e8, rng=rng)
    # Huge eps -> negligible noise.
    assert np.abs(priv).max() < 1e-4
    priv2 = private_local_models(theta, 1.0, lam, m, eps=0.1, rng=rng)
    assert np.abs(priv2).max() > np.abs(priv).max()


def test_private_warm_start_beats_constant_init(problem):
    """Fig. 2(b): warm start yields lower objective at the same tick count."""
    obj = make_objective(problem.graph, problem.train, "logistic", mu=0.3, clip=1.0)
    rng = np.random.default_rng(2)
    # n=12 agents only -> propagation averages little noise away; a clearly
    # beneficial warm start needs a larger eps_warm than the paper's n=100.
    warm = private_warm_start(obj, eps_warm=2.0, rng=rng)
    const = 2.0 * np.ones((obj.n, obj.p))
    q_warm = float(obj.value(warm.astype(np.float64)))
    q_const = float(obj.value(const))
    assert q_warm < q_const
    # And more warm-start budget helps (less noise on the local models).
    warm_hi = private_warm_start(obj, eps_warm=50.0, rng=np.random.default_rng(3))
    assert float(obj.value(warm_hi.astype(np.float64))) < q_warm
