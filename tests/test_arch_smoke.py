"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate a REDUCED variant of
the same family (2 layers, d_model <= 512, <= 4 experts), run one forward /
train step on CPU, assert output shapes and no NaNs; run one decode step
against a KV/state cache. Full configs are only exercised by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import build_model
from repro.models.encdec import enc_len

BATCH, SEQ = 2, 33  # SEQ-1 = 32 divisible by the reduced ssm/xlstm chunk (16)


def _batch_for(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)
    }
    if cfg.is_encdec:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(BATCH, enc_len(SEQ), cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch, dtype="float32")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    bundle = build_model(cfg, remat=False)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)) ** 2 for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"
    # one SGD step reduces loss on the same batch
    lr = 0.1
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(bundle.loss)(new_params, batch)
    assert float(loss2) < float(loss), f"{arch}: descent failed"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes(arch):
    cfg = get_reduced(arch, dtype="float32")
    bundle = build_model(cfg, remat=False)
    rng = np.random.default_rng(0)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    if cfg.is_encdec:
        from repro.models import encdec

        logits, _ = jax.jit(
            lambda p, b: encdec.forward(p, b["embeds"], b["tokens"][:, :-1], cfg, remat=False)
        )(params, batch)
    elif cfg.family == "hybrid":
        from repro.models import hybrid

        logits, _ = jax.jit(lambda p, b: hybrid.forward(p, b["tokens"][:, :-1], cfg, remat=False))(
            params, batch
        )
    elif cfg.family == "ssm":
        from repro.models import xlstm_stack

        logits, _ = jax.jit(
            lambda p, b: xlstm_stack.forward(p, b["tokens"][:, :-1], cfg, remat=False)
        )(params, batch)
    else:
        from repro.models import transformer

        logits, _ = jax.jit(
            lambda p, b: transformer.forward(p, b["tokens"][:, :-1], cfg, remat=False)
        )(params, batch)
    assert logits.shape == (BATCH, SEQ - 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch, dtype="float32")
    bundle = build_model(cfg, remat=False)
    params = bundle.init(jax.random.PRNGKey(0))
    caches = bundle.init_cache(params, BATCH, 64)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    decode = jax.jit(bundle.decode)
    logits, caches = decode(params, tok, caches, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # a few more steps; cache must evolve consistently
    for pos in range(1, 4):
        logits, caches = decode(params, tok, caches, jnp.int32(pos))
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    }[arch]
    got = (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected
    if arch == "granite-moe-3b-a800m":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (40, 8)
    if arch == "grok-1-314b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (8, 2)
    if arch == "zamba2-1.2b":
        assert cfg.ssm.state_dim == 64
