"""Unit tests for the objective (Eq. 2/3) and its theory constants."""

import numpy as np
import pytest

from repro.core import (
    AgentData,
    make_objective,
    ring_graph,
    complete_graph,
)
from repro.data.synthetic import linear_classification_problem


@pytest.fixture(scope="module")
def small_problem():
    return linear_classification_problem(n=12, p=8, m_low=5, m_high=20, test_points=20, seed=1)


def test_graph_constructors():
    g = ring_graph(8)
    assert g.is_connected()
    assert g.num_edges() == 8
    assert np.allclose(g.degrees, 2.0)
    gc = complete_graph(5, weight=2.0)
    assert np.allclose(gc.degrees, 8.0)


def test_block_grad_matches_finite_differences(small_problem):
    prob = small_problem
    obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3)
    rng = np.random.default_rng(0)
    Theta = rng.normal(size=(obj.n, obj.p)).astype(np.float32)
    err = obj.grad_check(Theta)
    assert err < 1e-2  # float32 fd tolerance


def test_eq4_is_scaled_block_gradient_step(small_problem):
    """Eq. 4's convex-combination form must equal Theta_i - [grad Q]_i / L_i."""
    import jax.numpy as jnp

    from repro.core.coordinate_descent import _cd_step

    prob = small_problem
    obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3)
    rng = np.random.default_rng(1)
    Theta = jnp.asarray(rng.normal(size=(obj.n, obj.p)), jnp.float32)
    L = obj.block_lipschitz()
    g = np.asarray(obj.block_grad(Theta))
    for i in [0, 3, 7]:
        stepped = np.asarray(_cd_step(obj, Theta, i))
        expected = np.asarray(Theta[i]) - g[i] / L[i]
        np.testing.assert_allclose(stepped[i], expected, rtol=2e-4, atol=2e-5)


def test_quadratic_closed_form_is_stationary(small_problem):
    prob = small_problem
    # Reuse geometry but quadratic targets: y = <x, t> + noise.
    X = prob.train.X
    y = np.einsum("nmp,np->nm", X, prob.targets) * prob.train.mask
    data = AgentData(X=X, y=y, mask=prob.train.mask)
    obj = make_objective(prob.graph, data, "quadratic", mu=0.5)
    Theta_star = obj.solve_exact()
    g = np.asarray(obj.block_grad(Theta_star.astype(np.float32)))
    assert np.abs(g).max() < 1e-3


def test_theory_constants_positive(small_problem):
    prob = small_problem
    obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3)
    assert obj.strong_convexity() > 0
    assert np.all(obj.block_lipschitz() > 0)
    assert 0 < obj.contraction() < 1
    assert np.all((obj.alphas() > 0) & (obj.alphas() <= 1))
    assert np.isfinite(obj.lipschitz_l1())


def test_clip_bounds_lipschitz(small_problem):
    prob = small_problem
    obj = make_objective(prob.graph, prob.train, "logistic", mu=0.3, clip=0.05)
    assert obj.lipschitz_l1() <= 0.05
