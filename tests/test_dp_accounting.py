"""Vectorized uniform DP accounting in dp_cd.run_private: regression
against the original O(T) per-tick accountant loop (kept here verbatim as
the reference), for both mechanisms, including agents that wake fewer
times than planned (the budget re-split branch)."""

import numpy as np
import pytest

from repro.core import AgentData, DPConfig, erdos_renyi_graph, make_objective, run_private
from repro.core.dp_cd import mechanism_scale, mechanism_scales, uniform_noise_plan
from repro.core.privacy import PrivacyAccountant, compose_kairouz, compose_uniform


def _problem(n=10, p=3, m=4, seed=0):
    rng = np.random.default_rng(seed)
    graph = erdos_renyi_graph(n, 0.5, rng)
    targets = rng.normal(size=(n, p))
    X = rng.normal(size=(n, m, p))
    y = np.sign(np.einsum("nmp,np->nm", X, targets))
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "logistic", mu=0.3)


def _reference_schedule(obj, cfg, wake, planned_Ti):
    """The pre-vectorization run_private pre-compute loop, verbatim."""
    import dataclasses

    n, T = obj.n, len(wake)
    l0 = obj.lipschitz_l1()
    m = np.maximum(obj.data.num_examples, 1.0)
    cfg = dataclasses.replace(cfg, T_total=T)
    accountants = [PrivacyAccountant(cfg.delta_bar) for _ in range(n)]
    noise_scales = np.zeros(T)
    active = np.ones(T, dtype=bool)
    wake_count = np.zeros(n, dtype=int)
    per_agent_eps = {}
    for i in range(n):
        ticks = np.nonzero(wake == i)[0][:planned_Ti]
        per_agent_eps[i] = cfg.per_step_eps(obj, ticks)
    for t in range(T):
        i = int(wake[t])
        k = wake_count[i]
        if k >= len(per_agent_eps[i]):
            active[t] = False
            continue
        eps_t = per_agent_eps[i][k]
        noise_scales[t] = mechanism_scale(cfg, l0, eps_t, m[i])
        accountants[i].spend(eps_t)
        wake_count[i] += 1
    return noise_scales, active, np.array([a.eps_bar for a in accountants])


@pytest.mark.parametrize("mechanism", ["laplace", "gaussian"])
def test_vectorized_uniform_accounting_matches_reference_loop(mechanism):
    obj = _problem()
    n = obj.n
    cfg = DPConfig(eps_bar=0.7, mechanism=mechanism)
    rng = np.random.default_rng(3)
    # Skewed wakes: some agents exceed the plan, some under-wake (re-split
    # branch), some never wake at all.
    T = 4 * n
    probs = np.concatenate([np.full(n - 2, 1.0), [0.2, 0.0]])
    wake = rng.choice(n, size=T, p=probs / probs.sum())
    planned_Ti = max(T // n, 1)
    assert (np.bincount(wake, minlength=n) < planned_Ti).any()
    assert (np.bincount(wake, minlength=n) > planned_Ti).any()

    want_scales, want_active, want_eps = _reference_schedule(obj, cfg, wake, planned_Ti)
    res = run_private(
        obj, np.zeros((n, obj.p)), T=T, cfg=cfg, rng=np.random.default_rng(0),
        wake_sequence=wake, record_objective=False,
    )
    np.testing.assert_array_equal(res.noise_scales, want_scales)
    # eps composition: k * eps vs sum of k equal terms differ by float
    # association only.
    np.testing.assert_allclose(res.eps_spent, want_eps, rtol=1e-12)
    # Inactive ticks have zero scale in both paths.
    np.testing.assert_array_equal(res.noise_scales == 0.0, ~want_active)


def test_mechanism_scales_matches_scalar_bitwise():
    obj = _problem(seed=1)
    l0 = obj.lipschitz_l1()
    m = np.maximum(obj.data.num_examples, 1.0)
    for mech in ("laplace", "gaussian"):
        cfg = DPConfig(eps_bar=1.0, mechanism=mech)
        vec = mechanism_scales(cfg, l0, 0.037, m)
        ref = np.array([mechanism_scale(cfg, l0, 0.037, mi) for mi in m])
        np.testing.assert_array_equal(vec, ref)
        eps_step, scales = uniform_noise_plan(obj, cfg, 5)
        np.testing.assert_array_equal(
            scales, [mechanism_scale(cfg, l0, eps_step, mi) for mi in m]
        )


def test_compose_uniform_accepts_per_agent_eps():
    counts = np.array([0, 1, 4, 7])
    eps = np.array([0.3, 0.5, 0.1, 0.25])
    got = compose_uniform(eps, counts, 1e-5)
    want = [compose_kairouz(np.full(k, e), 1e-5) for k, e in zip(counts, eps)]
    np.testing.assert_allclose(got, want, rtol=1e-12)
    assert got[0] == 0.0


def test_prop2_schedule_still_runs_and_spends_full_budget():
    obj = _problem(seed=2)
    n = obj.n
    cfg = DPConfig(eps_bar=0.5, schedule="prop2")
    res = run_private(
        obj, np.zeros((n, obj.p)), T=3 * n, cfg=cfg,
        rng=np.random.default_rng(1), record_objective=False,
    )
    woke = np.bincount(res.wake_sequence, minlength=n) > 0
    np.testing.assert_allclose(res.eps_spent[woke], cfg.eps_bar, rtol=1e-6)
