"""Property tests (hypothesis): CSR invariants under random edge churn.

The dynamic-topology layer edits graphs as COO batches routed through
``csr_from_coo`` (``TopologyState.apply_edge_updates``, the engines'
attach/detach paths, ``GraphUpdate``'s selection). These properties
assert that ANY random insert/delete batch round-trips into a CSR that
keeps the class invariants — sorted unique columns per row, exact
symmetry, zero diagonal, non-negative weights — and that an insert
followed by deleting the same edges returns the original edge set.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import TopologyState, csr_from_coo


def _base_graph(n: int, seed: int):
    """Random connected-ish symmetric CSR: a ring plus random chords."""
    rng = np.random.default_rng(seed)
    rows = np.arange(n, dtype=np.int64)
    cols = (rows + 1) % n
    vals = rng.uniform(0.1, 1.0, size=n)
    extra = max(n // 2, 1)
    er = rng.integers(0, n, size=extra)
    ec = rng.integers(0, n, size=extra)
    ev = rng.uniform(0.1, 1.0, size=extra)
    keep = er != ec
    return csr_from_coo(
        n,
        np.concatenate([rows, er[keep]]),
        np.concatenate([cols, ec[keep]]),
        np.concatenate([vals, ev[keep]]),
        symmetrize=True,
    )


def _edge_dict(csr):
    rows = csr.row_ids()
    return {
        (int(i), int(j)): float(v) for i, j, v in zip(rows, csr.indices, csr.data)
    }


def _assert_invariants(csr):
    n = csr.n
    assert csr.indptr[0] == 0 and csr.indptr[-1] == len(csr.indices)
    assert (np.diff(csr.indptr) >= 0).all()
    rows = csr.row_ids()
    # Sorted, unique columns within each row; no self loops; weights > 0.
    for i in range(n):
        nb = csr.indices[csr.indptr[i] : csr.indptr[i + 1]]
        assert (np.diff(nb) > 0).all(), f"row {i} columns not sorted-unique"
    assert not np.any(csr.indices == rows)
    assert (csr.data > 0.0).all()
    # Exact symmetry of the (i, j) -> w map.
    edges = _edge_dict(csr)
    for (i, j), v in edges.items():
        assert edges.get((j, i)) == v, (i, j)


churn_params = st.tuples(
    st.integers(min_value=3, max_value=20),  # n
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
    st.integers(min_value=1, max_value=12),  # batch size
)


@settings(max_examples=30, deadline=None)
@given(churn_params)
def test_random_insert_delete_batches_preserve_csr_invariants(params):
    n, seed, b = params
    csr = _base_graph(n, seed)
    _assert_invariants(csr)
    rng = np.random.default_rng(seed ^ 0x5EED)
    for _ in range(3):
        # Random insert batch (may duplicate existing edges or each other).
        ar = rng.integers(0, n, size=b)
        ac = rng.integers(0, n, size=b)
        av = rng.uniform(0.05, 2.0, size=b)
        ok = ar != ac
        rows, cols, vals = csr.row_ids(), csr.indices, csr.data
        csr = csr_from_coo(
            n,
            np.concatenate([rows, ar[ok], ac[ok]]),
            np.concatenate([cols, ac[ok], ar[ok]]),
            np.concatenate([vals, av[ok], av[ok]]),
            symmetrize=True,
            dedupe="max",
        )
        _assert_invariants(csr)
        # Random delete batch: drop some existing undirected edges.
        edges = sorted(_edge_dict(csr))
        if edges:
            picks = rng.integers(0, len(edges), size=min(b, len(edges)))
            drop = {tuple(sorted(edges[k])) for k in picks}
            rows, cols, vals = csr.row_ids(), csr.indices, csr.data
            keep = np.array(
                [tuple(sorted((int(i), int(j)))) not in drop
                 for i, j in zip(rows, cols)]
            )
            csr = csr_from_coo(n, rows[keep], cols[keep], vals[keep])
            _assert_invariants(csr)


@settings(max_examples=30, deadline=None)
@given(churn_params)
def test_topology_state_insert_then_delete_round_trips(params):
    """apply_edge_updates(add) then apply_edge_updates(remove) of the same
    novel pairs returns exactly the original edge set (weights included),
    with the version advanced by two."""
    n, seed, b = params
    csr = _base_graph(n, seed)
    before = _edge_dict(csr)
    topo = TopologyState.from_csr(csr)
    rng = np.random.default_rng(seed ^ 0xC0FFEE)
    # Pick pairs that are NOT current edges (so deletion restores exactly).
    cand_r = rng.integers(0, n, size=4 * b)
    cand_c = rng.integers(0, n, size=4 * b)
    novel, seen = [], set()
    for i, j in zip(cand_r, cand_c):
        key = tuple(sorted((int(i), int(j))))
        if i != j and key not in before and key not in seen:
            novel.append(key)
            seen.add(key)
        if len(novel) == b:
            break
    if not novel:
        return
    ar = np.array([i for i, _ in novel])
    ac = np.array([j for _, j in novel])
    grown = topo.apply_edge_updates(
        add_rows=ar, add_cols=ac, add_vals=rng.uniform(0.1, 1.0, size=len(novel))
    )
    _assert_invariants(grown.to_csr())
    assert grown.to_csr().num_edges() == csr.num_edges() + len(novel)
    shrunk = grown.apply_edge_updates(remove_rows=ar, remove_cols=ac)
    after = _edge_dict(shrunk.to_csr())
    assert after == before
    assert int(np.asarray(shrunk.version)) == 2
