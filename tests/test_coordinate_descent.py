"""Convergence tests for the non-private algorithm (Sec. 2.3, Prop. 1)."""

import numpy as np
import pytest

from repro.core import (
    AgentData,
    make_objective,
    proposition1_bound,
    run,
    run_scan,
    synchronous_round,
)
from repro.data.synthetic import linear_classification_problem


@pytest.fixture(scope="module")
def quad_problem():
    prob = linear_classification_problem(n=10, p=6, m_low=5, m_high=15, seed=3)
    X = prob.train.X
    y = np.einsum("nmp,np->nm", X, prob.targets) * prob.train.mask
    data = AgentData(X=X, y=y, mask=prob.train.mask)
    obj = make_objective(prob.graph, data, "quadratic", mu=0.5)
    return obj


def test_cd_converges_to_exact_optimum(quad_problem):
    obj = quad_problem
    Theta_star = obj.solve_exact()
    q_star = float(obj.value(Theta_star))
    rng = np.random.default_rng(0)
    res = run_scan(obj, np.zeros((obj.n, obj.p)), T=1500, rng=rng)
    assert res.objective[-1] - q_star < 1e-4 * max(1.0, abs(q_star))
    assert np.abs(res.Theta - Theta_star).max() < 1e-2


def test_cd_monotone_descent_in_objective(quad_problem):
    """Each exact block-CD step with 1/L_i step size cannot increase Q."""
    obj = quad_problem
    rng = np.random.default_rng(1)
    res = run_scan(obj, np.zeros((obj.n, obj.p)), T=300, rng=rng)
    diffs = np.diff(res.objective)
    assert np.all(diffs <= 1e-6)


def test_python_and_scan_paths_agree(quad_problem):
    obj = quad_problem
    rng = np.random.default_rng(2)
    wake = rng.integers(0, obj.n, size=50)
    r1 = run(obj, np.zeros((obj.n, obj.p)), T=50, rng=rng, wake_sequence=wake)
    r2 = run_scan(obj, np.zeros((obj.n, obj.p)), T=50, rng=rng, wake_sequence=wake)
    np.testing.assert_allclose(r1.Theta, r2.Theta, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(r1.objective, r2.objective, rtol=1e-5, atol=1e-6)


def test_proposition1_bound_holds_in_expectation(quad_problem):
    """Averaged over wake sequences, the suboptimality gap must respect
    Prop. 1's linear rate (up to Monte-Carlo slack)."""
    obj = quad_problem
    q_star = float(obj.value(obj.solve_exact()))
    T = 400
    gaps = []
    for s in range(5):
        rng = np.random.default_rng(100 + s)
        res = run_scan(obj, np.zeros((obj.n, obj.p)), T=T, rng=rng)
        gaps.append(res.objective - q_star)
    mean_gap = np.mean(gaps, axis=0)
    bound = proposition1_bound(obj, mean_gap[0], T)
    # The bound must hold (with slack for MC noise) and be non-trivial.
    assert np.all(mean_gap <= bound * 1.5 + 1e-8)
    assert mean_gap[-1] < mean_gap[0] * 0.05


def test_synchronous_round_reaches_same_fixed_point(quad_problem):
    """DESIGN §4.2: the SPMD synchronous-round variant optimizes the same Q."""
    import jax.numpy as jnp

    obj = quad_problem
    Theta_star = obj.solve_exact()
    Theta = jnp.zeros((obj.n, obj.p))
    for _ in range(400):
        Theta = synchronous_round(obj, Theta)
    assert np.abs(np.asarray(Theta) - Theta_star).max() < 1e-3
    # And the optimum is a fixed point.
    stepped = synchronous_round(obj, jnp.asarray(Theta_star))
    np.testing.assert_allclose(np.asarray(stepped), Theta_star, rtol=1e-6, atol=1e-7)


def test_message_accounting(quad_problem):
    obj = quad_problem
    rng = np.random.default_rng(5)
    wake = np.array([0, 1, 2])
    res = run(obj, np.zeros((obj.n, obj.p)), T=3, rng=rng, wake_sequence=wake)
    expected = sum(len(obj.graph.neighbors(i)) for i in wake)
    assert res.messages[-1] == expected
