"""Agent-block partitioner: halo-map round-trips and tile correctness.

Pure-numpy property tests: for random graphs and shard counts, the
partition's owned/halo/border maps must reconstruct exactly the rows each
shard reads, and the per-shard padded tiles must reproduce the global
neighbour-sum operator bit-for-bit — the invariant the sharded engine's
forced-wake parity rests on. Also covers the locality relabel passes
(RCM / Morton SFC / explicit permutations), the point-to-point exchange
plan, and the acceptance halo-fraction drop on a shuffled random
geometric graph."""

import numpy as np
import pytest

from repro.core import (
    as_csr,
    erdos_renyi_graph,
    knn_graph,
    random_geometric_graph,
    ring_graph,
)
from repro.core.mixing import sharded_mix_op
from repro.sim import (
    hilbert_order,
    partition_graph,
    point_to_point_plan,
    rcm_order,
    sfc_order,
)


def _graphs():
    rng = np.random.default_rng(0)
    yield "knn", knn_graph(rng.normal(size=(57, 6)), k=5)
    yield "er", as_csr(erdos_renyi_graph(40, 0.15, rng))
    yield "ring", as_csr(ring_graph(12, weight=0.5))


def _simulate_exchange(part, Theta):
    """Numpy re-enactment of ShardedMixOp.exchange_halo: publish border
    rows, all-gather the pool, gather halo rows per shard."""
    S, Bmax = part.border.shape
    blocks = part.pad_rows(Theta)
    pool = np.stack([blocks[s][part.border[s]] for s in range(S)])
    pool = pool.reshape((S * Bmax,) + Theta.shape[1:])
    return [np.concatenate([blocks[s], pool[part.halo_src[s]]], axis=0) for s in range(S)]


def _simulate_p2p(part, Theta):
    """Numpy re-enactment of the point-to-point path: one ring shift per
    offset, receivers scatter buffer rows into their halo slots."""
    S, Hmax = part.halo.shape
    blocks = part.pad_rows(Theta)
    offsets, sends, dsts = part.p2p_plan
    ext = []
    for s in range(S):
        halo = np.zeros((Hmax,) + Theta.shape[1:], Theta.dtype)
        for off, snd, dst in zip(offsets, sends, dsts):
            t = (s - off) % S  # the shard whose buffer lands here
            recv = blocks[t][snd[t]]
            keep = dst[s] < Hmax  # sentinel Hmax = padding, dropped
            halo[dst[s][keep]] = recv[keep]
        ext.append(np.concatenate([blocks[s], halo], axis=0))
    return ext


@pytest.mark.parametrize("mode", ["contiguous", "degree"])
def test_halo_maps_round_trip(mode):
    rng = np.random.default_rng(1)
    for name, g in _graphs():
        for S in (1, 2, 3, min(8, g.n)):
            part = partition_graph(g, S, mode=mode)
            x = rng.normal(size=(g.n, 3))
            # pad/unpad is the identity on per-agent arrays.
            np.testing.assert_array_equal(part.unpad_rows(part.pad_rows(x)), x)
            ext = _simulate_exchange(part, x)
            for s in range(S):
                # The exchanged halo rows are exactly Theta at the halo ids.
                h = part.halo_sizes[s]
                R = part.rows_per_shard
                np.testing.assert_array_equal(
                    ext[s][R : R + h], x[part.halo[s, :h]], f"{name} S={S} shard {s}"
                )


@pytest.mark.parametrize("mode", ["contiguous", "degree"])
def test_shard_tiles_reproduce_global_mix_exactly(mode):
    rng = np.random.default_rng(2)
    for name, g in _graphs():
        W = g.to_dense().weights
        Theta = rng.normal(size=(g.n, 4))
        want = W @ Theta
        for S in (1, 2, 5):
            part = partition_graph(g, S, mode=mode)
            ext = _simulate_exchange(part, Theta)
            for s in range(S):
                got = np.einsum("rk,rkp->rp", part.w[s], ext[s][part.idx[s]])
                lo, hi = part.bounds[s], part.bounds[s + 1]
                np.testing.assert_allclose(
                    got[: hi - lo], want[lo:hi], rtol=1e-13, atol=1e-13,
                    err_msg=f"{name} S={S} shard {s}",
                )


def test_degree_mode_balances_nnz():
    # Heavily skewed degrees: the first agents are hubs.
    rng = np.random.default_rng(3)
    n = 60
    rows, cols = [], []
    for i in range(4):  # 4 hubs touching everyone
        rows += [i] * (n - 1 - i)
        cols += [j for j in range(i + 1, n)]
    from repro.core import csr_from_coo

    g = csr_from_coo(n, rows, cols, np.ones(len(rows)), symmetrize=True)
    S = 4
    contig = partition_graph(g, S, mode="contiguous")
    deg = partition_graph(g, S, mode="degree")
    nnz_of = lambda part: np.array(
        [
            g.indptr[part.bounds[s + 1]] - g.indptr[part.bounds[s]]
            for s in range(S)
        ]
    )
    # Degree-balanced boundaries must spread the hub mass better than
    # equal-count blocks on this skew.
    assert nnz_of(deg).max() < nnz_of(contig).max()
    assert (np.diff(deg.bounds) >= 1).all()


def test_partition_validation_and_edges():
    g = as_csr(ring_graph(6))
    with pytest.raises(ValueError):
        partition_graph(g, 7)  # more shards than agents
    with pytest.raises(ValueError):
        partition_graph(g, 2, mode="spectral")
    with pytest.raises(ValueError):
        partition_graph(g, 2, tile_width=1)  # below max degree
    # One shard: no halo, no border traffic.
    p1 = partition_graph(g, 1)
    assert p1.halo_sizes.sum() == 0 and p1.border_sizes.sum() == 0
    assert p1.halo_fraction() == 0.0
    # n shards: every agent its own block; ring halo = both neighbours.
    pn = partition_graph(g, 6, mode="contiguous")
    assert (pn.sizes == 1).all()
    assert (pn.halo_sizes == 2).all()
    # Wider tiles are allowed and keep weights in the padded region zero.
    pw = partition_graph(g, 2, tile_width=5)
    assert pw.tile_width == 5
    assert (pw.w[..., 2:] == 0).all()


def test_sharded_mix_op_carries_partition_arrays():
    g = knn_graph(np.random.default_rng(4).normal(size=(30, 5)), k=4)
    part = partition_graph(g, 3)
    smix = sharded_mix_op(part)
    assert smix.n == 30 and smix.num_shards == 3
    assert smix.rows_per_shard == part.rows_per_shard
    np.testing.assert_array_equal(smix.idx, part.idx)
    np.testing.assert_array_equal(smix.border, part.border)


# ---------------------------------------------------------------------------
# Locality relabeling + point-to-point exchange
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["contiguous", "degree"])
def test_relabeled_tiles_reproduce_global_mix_exactly(mode):
    """Under any relabel, owned ids stay original and the tiles (which
    keep the original CSR neighbour order per row) still reproduce the
    global operator bit-for-bit — the 'bit-exact under any relabeling'
    guarantee at the numpy layer."""
    rng = np.random.default_rng(5)
    for name, g in _graphs():
        W = g.to_dense().weights
        Theta = rng.normal(size=(g.n, 4))
        want = W @ Theta
        shuffle = rng.permutation(g.n)
        for relabel in ("rcm", shuffle):
            for S in (1, 2, 5):
                part = partition_graph(g, S, mode=mode, relabel=relabel)
                assert np.array_equal(np.sort(part.order), np.arange(g.n))
                np.testing.assert_array_equal(part.unpad_rows(part.pad_rows(Theta)), Theta)
                for ext in (_simulate_exchange(part, Theta), _simulate_p2p(part, Theta)):
                    for s in range(S):
                        size = int(part.sizes[s])
                        got = np.einsum("rk,rkp->rp", part.w[s], ext[s][part.idx[s]])
                        np.testing.assert_allclose(
                            got[:size],
                            want[part.owned[s, :size]],
                            rtol=1e-13,
                            atol=1e-13,
                            err_msg=f"{name} S={S} shard {s}",
                        )


def test_p2p_plan_round_trips_halo_rows():
    """The ppermute plan delivers exactly the halo rows the all-gather
    pool does, for relabeled and unrelabeled partitions alike."""
    rng = np.random.default_rng(6)
    for name, g in _graphs():
        x = rng.normal(size=(g.n, 3))
        for relabel in (None, "rcm"):
            for S in (1, 2, 4):
                part = partition_graph(g, S, relabel=relabel)
                ext = _simulate_p2p(part, x)
                for s in range(S):
                    h = int(part.halo_sizes[s])
                    R = part.rows_per_shard
                    np.testing.assert_array_equal(
                        ext[s][R : R + h],
                        x[part.halo[s, :h]],
                        err_msg=f"{name} relabel={relabel} S={S} shard {s}",
                    )
                offsets, sends, dsts = point_to_point_plan(part)
                assert part.exchange_rows("p2p") == S * sum(b.shape[1] for b in sends)


def test_neighbor_shards_and_halo_owner_agree():
    g = knn_graph(np.random.default_rng(7).normal(size=(60, 5)), k=6)
    part = partition_graph(g, 4, relabel="rcm")
    nbrs = part.neighbor_shards()
    for s in range(4):
        h = int(part.halo_sizes[s])
        want = np.unique(part.shard_of[part.halo[s, :h]])
        np.testing.assert_array_equal(nbrs[s], want)
        assert s not in nbrs[s]
        assert (part.halo_owner[s, h:] == 4).all()


def test_rcm_relabel_drops_halo_fraction_on_shuffled_rgg():
    """Acceptance: on a (label-shuffled by construction) random geometric
    graph with n >= 4096 and S = 4, contiguous index blocks read ~75%
    remote rows; the RCM relabel pass brings that to <= 0.3 (the Morton
    curve over the true coordinates does even better), and the
    point-to-point plan ships fewer rows than the all-gather pool."""
    rng = np.random.default_rng(0)
    g, pos = random_geometric_graph(4096, rng, avg_degree=16.0, return_pos=True)
    base = partition_graph(g, 4)
    rcm = partition_graph(g, 4, relabel="rcm")
    sfc = partition_graph(g, 4, relabel="sfc", coords=pos)
    assert base.halo_fraction() > 0.6
    assert rcm.halo_fraction() <= 0.3
    assert sfc.halo_fraction() <= 0.3
    for part in (rcm, sfc):
        assert part.exchange_rows("p2p") < part.exchange_rows("all_gather")
        assert sharded_mix_op(part).method == "p2p"
    assert sharded_mix_op(base).method == "all_gather"  # dense cut: fused collective


def test_relabel_validation_and_orders():
    g = as_csr(ring_graph(8))
    with pytest.raises(ValueError, match="coords"):
        partition_graph(g, 2, relabel="sfc")
    with pytest.raises(ValueError, match="coords"):
        partition_graph(g, 2, relabel="hilbert")
    with pytest.raises(ValueError, match="relabel"):
        partition_graph(g, 2, relabel="metis")
    with pytest.raises(ValueError, match="permutation"):
        partition_graph(g, 2, relabel=np.zeros(8, dtype=np.int64))
    with pytest.raises(ValueError, match="coords"):
        sfc_order(np.zeros((8, 3)))
    # RCM on a ring yields a bandwidth-2 ordering: every neighbour within
    # 2 positions, so a 2-shard cut has a 2-row halo per shard.
    order = rcm_order(g)
    rank = np.empty(8, dtype=np.int64)
    rank[order] = np.arange(8)
    for i in range(8):
        for j in g.neighbors(i):
            assert abs(rank[i] - rank[int(j)]) <= 2
    # Morton order on a line of points is the line order.
    coords = np.stack([np.linspace(0, 1, 8), np.zeros(8)], axis=1)
    np.testing.assert_array_equal(sfc_order(coords), np.arange(8))


def test_hilbert_order_walks_unit_steps_on_full_grid():
    """Defining Hilbert property: consecutive curve positions are grid
    neighbours (L1 step exactly 1 on a full 2^k x 2^k grid). The Morton
    curve jumps — up to a full grid side — which is exactly the diagonal
    discontinuity the Hilbert relabel removes."""
    k = 16
    xs, ys = np.meshgrid(np.arange(k), np.arange(k), indexing="ij")
    coords = np.stack([xs.ravel(), ys.ravel()], axis=1).astype(float)
    order = hilbert_order(coords, bits=4)
    np.testing.assert_array_equal(np.sort(order), np.arange(k * k))  # permutation
    steps = np.abs(np.diff(coords[order], axis=0)).sum(axis=1)
    assert (steps == 1).all()
    morton_steps = np.abs(np.diff(coords[sfc_order(coords)], axis=0)).sum(axis=1)
    assert morton_steps.max() > 1  # Morton demonstrably jumps
    with pytest.raises(ValueError, match="coords"):
        hilbert_order(np.zeros((8, 3)))


def test_hilbert_relabel_beats_morton_at_s16():
    """Acceptance (PR-6 satellite): at S=16 on a shuffled random geometric
    graph the Hilbert relabel's halo fraction is no worse than the Morton
    SFC's — and its point-to-point plan ships strictly fewer rows — while
    both stay far below the unrelabeled cut."""
    rng = np.random.default_rng(0)
    g, pos = random_geometric_graph(4096, rng, avg_degree=16.0, return_pos=True)
    base = partition_graph(g, 16)
    sfc = partition_graph(g, 16, relabel="sfc", coords=pos)
    hil = partition_graph(g, 16, relabel="hilbert", coords=pos)
    assert base.halo_fraction() > 0.6
    assert hil.halo_fraction() <= 0.35
    assert hil.halo_fraction() <= sfc.halo_fraction() + 1e-9
    assert hil.exchange_rows("p2p") < sfc.exchange_rows("p2p")
    assert sharded_mix_op(hil).method == "p2p"
