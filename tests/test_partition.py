"""Agent-block partitioner: halo-map round-trips and tile correctness.

Pure-numpy property tests: for random graphs and shard counts, the
partition's owned/halo/border maps must reconstruct exactly the rows each
shard reads, and the per-shard padded tiles must reproduce the global
neighbour-sum operator bit-for-bit — the invariant the sharded engine's
forced-wake parity rests on."""

import numpy as np
import pytest

from repro.core import as_csr, erdos_renyi_graph, knn_graph, ring_graph
from repro.core.mixing import sharded_mix_op
from repro.sim import partition_graph


def _graphs():
    rng = np.random.default_rng(0)
    yield "knn", knn_graph(rng.normal(size=(57, 6)), k=5)
    yield "er", as_csr(erdos_renyi_graph(40, 0.15, rng))
    yield "ring", as_csr(ring_graph(12, weight=0.5))


def _simulate_exchange(part, Theta):
    """Numpy re-enactment of ShardedMixOp.exchange_halo: publish border
    rows, all-gather the pool, gather halo rows per shard."""
    S, Bmax = part.border.shape
    blocks = part.pad_rows(Theta)
    pool = np.stack([blocks[s][part.border[s]] for s in range(S)])
    pool = pool.reshape((S * Bmax,) + Theta.shape[1:])
    return [np.concatenate([blocks[s], pool[part.halo_src[s]]], axis=0) for s in range(S)]


@pytest.mark.parametrize("mode", ["contiguous", "degree"])
def test_halo_maps_round_trip(mode):
    rng = np.random.default_rng(1)
    for name, g in _graphs():
        for S in (1, 2, 3, min(8, g.n)):
            part = partition_graph(g, S, mode=mode)
            x = rng.normal(size=(g.n, 3))
            # pad/unpad is the identity on per-agent arrays.
            np.testing.assert_array_equal(part.unpad_rows(part.pad_rows(x)), x)
            ext = _simulate_exchange(part, x)
            for s in range(S):
                # The exchanged halo rows are exactly Theta at the halo ids.
                h = part.halo_sizes[s]
                R = part.rows_per_shard
                np.testing.assert_array_equal(
                    ext[s][R : R + h], x[part.halo[s, :h]], f"{name} S={S} shard {s}"
                )


@pytest.mark.parametrize("mode", ["contiguous", "degree"])
def test_shard_tiles_reproduce_global_mix_exactly(mode):
    rng = np.random.default_rng(2)
    for name, g in _graphs():
        W = g.to_dense().weights
        Theta = rng.normal(size=(g.n, 4))
        want = W @ Theta
        for S in (1, 2, 5):
            part = partition_graph(g, S, mode=mode)
            ext = _simulate_exchange(part, Theta)
            for s in range(S):
                got = np.einsum("rk,rkp->rp", part.w[s], ext[s][part.idx[s]])
                lo, hi = part.bounds[s], part.bounds[s + 1]
                np.testing.assert_allclose(
                    got[: hi - lo], want[lo:hi], rtol=1e-13, atol=1e-13,
                    err_msg=f"{name} S={S} shard {s}",
                )


def test_degree_mode_balances_nnz():
    # Heavily skewed degrees: the first agents are hubs.
    rng = np.random.default_rng(3)
    n = 60
    rows, cols = [], []
    for i in range(4):  # 4 hubs touching everyone
        rows += [i] * (n - 1 - i)
        cols += [j for j in range(i + 1, n)]
    from repro.core import csr_from_coo

    g = csr_from_coo(n, rows, cols, np.ones(len(rows)), symmetrize=True)
    S = 4
    contig = partition_graph(g, S, mode="contiguous")
    deg = partition_graph(g, S, mode="degree")
    nnz_of = lambda part: np.array(
        [
            g.indptr[part.bounds[s + 1]] - g.indptr[part.bounds[s]]
            for s in range(S)
        ]
    )
    # Degree-balanced boundaries must spread the hub mass better than
    # equal-count blocks on this skew.
    assert nnz_of(deg).max() < nnz_of(contig).max()
    assert (np.diff(deg.bounds) >= 1).all()


def test_partition_validation_and_edges():
    g = as_csr(ring_graph(6))
    with pytest.raises(ValueError):
        partition_graph(g, 7)  # more shards than agents
    with pytest.raises(ValueError):
        partition_graph(g, 2, mode="spectral")
    with pytest.raises(ValueError):
        partition_graph(g, 2, tile_width=1)  # below max degree
    # One shard: no halo, no border traffic.
    p1 = partition_graph(g, 1)
    assert p1.halo_sizes.sum() == 0 and p1.border_sizes.sum() == 0
    assert p1.halo_fraction() == 0.0
    # n shards: every agent its own block; ring halo = both neighbours.
    pn = partition_graph(g, 6, mode="contiguous")
    assert (pn.sizes == 1).all()
    assert (pn.halo_sizes == 2).all()
    # Wider tiles are allowed and keep weights in the padded region zero.
    pw = partition_graph(g, 2, tile_width=5)
    assert pw.tile_width == 5
    assert (pw.w[..., 2:] == 0).all()


def test_sharded_mix_op_carries_partition_arrays():
    g = knn_graph(np.random.default_rng(4).normal(size=(30, 5)), k=4)
    part = partition_graph(g, 3)
    smix = sharded_mix_op(part)
    assert smix.n == 30 and smix.num_shards == 3
    assert smix.rows_per_shard == part.rows_per_shard
    np.testing.assert_array_equal(smix.idx, part.idx)
    np.testing.assert_array_equal(smix.border, part.border)
