"""The online serving tier: snapshot consistency, routing, cold start.

In-process tests cover the single-device engine and the degenerate
S=1 sharded mesh (with the no-``(n, p)``-materialization probe armed on
the serve path); real multi-shard routing (S=4 on 8 XLA host devices)
runs in a subprocess in the ``test_engine_checkpoint.py`` style.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, save_engine_checkpoint
from repro.checkpoint.checkpoint import CheckpointError
from repro.core import AgentData, knn_graph, make_objective
from repro.serve import ServeHandle, ServeSpec, serve_from_checkpoint
from repro.sim import (
    ArrivalConfig,
    AsyncEngine,
    CDUpdate,
    Scenario,
    ShardedAsyncEngine,
)
from repro.sim.engine import ShardedSimState
from repro.sim.partition import GraphPartition


def _quad_problem(n, p=4, m=3, seed=0, mu=0.5):
    rng = np.random.default_rng(seed)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    data = AgentData(X=X, y=y, mask=np.ones((n, m)))
    return make_objective(graph, data, "quadratic", mu=mu, mix_mode="sparse")


def _engines(obj):
    return (
        AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64),
        ShardedAsyncEngine(
            CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0, dtype=jnp.float64
        ),
    )


# -- spec / run-driver contract ----------------------------------------------


def test_serve_spec_coerce_and_validation():
    assert ServeSpec.coerce(None) == ServeSpec()
    spec = ServeSpec(buffers=3, neighbors={9: (0, 1)})
    assert ServeSpec.coerce(spec) is spec
    with pytest.raises(TypeError, match="ServeSpec"):
        ServeSpec.coerce("double")  # bare strings never configure serving
    with pytest.raises(ValueError, match="buffers"):
        ServeSpec(buffers=1)
    with pytest.raises(ValueError, match="at least one neighbour"):
        ServeSpec(neighbors={3: ()})


def test_run_driver_error_messages_identical_across_engines():
    """The shared run-driver raises the same message from either engine:
    metrics off, the checkpoint pairing, and the snapshot pairing."""
    obj = _quad_problem(n=32, seed=1)
    Theta0 = np.zeros((obj.n, obj.p))
    messages = {"metrics": set(), "checkpoint": set(), "snapshot": set()}
    for eng in _engines(obj):
        with pytest.raises(ValueError) as ei:
            eng.run(Theta0, 2, metrics_every=1)  # engine built metrics-off
        messages["metrics"].add(str(ei.value))
        for kwargs in (dict(checkpoint_every=2), dict(checkpoint_dir="ck")):
            with pytest.raises(ValueError) as ei:
                eng.run(Theta0, 2, **kwargs)
            messages["checkpoint"].add(str(ei.value))
        handle = ServeHandle.for_engine(eng)
        for kwargs in (dict(snapshot_every=2), dict(serve=handle)):
            with pytest.raises(ValueError) as ei:
                eng.run(Theta0, 2, **kwargs)
            messages["snapshot"].add(str(ei.value))
    assert messages["metrics"] == {
        "metrics_every requires metrics collection on; construct the "
        "engine with EngineConfig(metrics=True) (or a MetricsSpec)"
    }
    assert messages["checkpoint"] == {
        "checkpoint_every and checkpoint_dir come together: pass both "
        "(periodic checkpoints) or neither"
    }
    assert messages["snapshot"] == {
        "snapshot_every and serve come together: pass both (a "
        "repro.serve.ServeHandle receiving the published snapshots) "
        "or neither"
    }


# -- snapshot consistency ----------------------------------------------------


@pytest.mark.parametrize("sharded", [False, True])
def test_snapshot_version_bit_exact_and_immutable(sharded):
    """A version read mid-training is bit-exact vs the engine's Theta at
    its publication slot — and stays so after training moves on."""
    obj = _quad_problem(n=48, seed=2)
    n, p = obj.n, obj.p
    eng = _engines(obj)[int(sharded)]
    handle = ServeHandle.for_engine(eng)
    ids = np.arange(n)

    half = eng.run(np.zeros((n, p)), 3, snapshot_every=3, serve=handle)
    assert handle.version == 3 == half.slots
    pinned = handle.snapshot()  # version 3, held across further training
    served3 = handle.rows(ids, at=pinned)
    assert np.array_equal(served3.values, half.Theta[ids].astype(np.float32))

    final = eng.run(None, 3, state=half.state, snapshot_every=3, serve=handle)
    assert handle.version == 6 == final.slots
    served6 = handle.rows(ids)
    assert np.array_equal(served6.values, final.Theta[ids].astype(np.float32))
    # the pinned version is immutable: identical to its publication slot
    again3 = handle.rows(ids, at=pinned)
    assert np.array_equal(again3.values, served3.values)
    assert not np.array_equal(served6.values, served3.values)

    # a one-hot feature makes the whole predict path exactly one Theta
    # entry — full-pipeline bit-exactness, no dot-product tolerance
    onehot = np.eye(p)[[1] * n]
    pr = handle.predict(ids, onehot)
    assert np.array_equal(pr.values, final.Theta[:, 1].astype(np.float32))


def test_sharded_serve_path_never_materializes_global_theta():
    """The probe from the checkpoint suite, aimed at serving: publish,
    route, gather, predict — none may assemble an (n, p) float array."""
    obj = _quad_problem(n=40, seed=3)
    n, p = obj.n, obj.p
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=8.0, seed=0, dtype=jnp.float64
    )
    handle = ServeHandle.for_engine(eng)
    state = eng.init_state(np.zeros((n, p)))
    state = eng.advance(state, 2)

    def _is_float(arr):
        dt = str(arr.dtype) if hasattr(arr, "dtype") else str(np.asarray(arr).dtype)
        return "float" in dt or dt == "bfloat16"

    pad, unpad = GraphPartition.pad_rows, GraphPartition.unpad_rows
    gt = ShardedAsyncEngine.global_theta

    def trap_pad(part, rows, *a, **k):
        if np.ndim(rows) >= 2 and np.shape(rows)[0] == part.n and _is_float(rows):
            raise AssertionError(f"pad_rows saw a global array: {np.shape(rows)}")
        return pad(part, rows, *a, **k)

    def trap_unpad(part, tiles, *a, **k):
        if np.ndim(tiles) >= 3 and _is_float(tiles):
            raise AssertionError(f"unpad_rows: {np.shape(tiles)}")
        return unpad(part, tiles, *a, **k)

    def trap_gt(engine, s):
        raise AssertionError("global_theta on the serve path")

    GraphPartition.pad_rows, GraphPartition.unpad_rows = trap_pad, trap_unpad
    ShardedAsyncEngine.global_theta = trap_gt
    try:
        handle.publish(state)
        r = handle.rows([0, 7, n - 1])
        handle.predict([0, 7, n - 1], np.ones((3, p)))
        handle.predict([n + 5], np.ones((1, p)), neighbors={n + 5: (0, 7)})
    finally:
        GraphPartition.pad_rows, GraphPartition.unpad_rows = pad, unpad
        ShardedAsyncEngine.global_theta = gt
    assert np.array_equal(
        r.values, np.asarray(state.Theta)[0, [0, 7, n - 1]].astype(np.float32)
    )
    assert handle.snapshot().tiles.shape == (1, eng.part.rows_per_shard, p)


# -- cold start --------------------------------------------------------------


def test_cold_start_matches_hand_computed_eq16_average():
    """A cold row is the Eq. 16 confidence-zero neighbour average, i.e.
    the uniform mean of the attachment neighbours' served rows."""
    obj = _quad_problem(n=32, seed=4)
    n, p = obj.n, obj.p
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    handle = ServeHandle.for_engine(eng)
    res = eng.run(np.zeros((n, p)), 4, snapshot_every=4, serve=handle)

    nbrs = (0, 2, 5)
    want_row = res.Theta[list(nbrs)].astype(np.float32).mean(axis=0)
    got = handle.rows([n + 100], neighbors={n + 100: nbrs})
    assert bool(got.cold[0])
    np.testing.assert_allclose(got.values[0], want_row, rtol=1e-6)

    x = np.linspace(-1, 1, p)
    pr = handle.predict([n + 100], x[None], neighbors={n + 100: nbrs})
    np.testing.assert_allclose(
        pr.values[0], want_row @ x.astype(np.float32), rtol=1e-5
    )
    # warm ids in the same batch keep their own exact rows
    mixed = handle.rows([3, n + 100], neighbors={n + 100: nbrs})
    assert np.array_equal(mixed.values[0], res.Theta[3].astype(np.float32))
    assert list(mixed.cold) == [False, True]

    with pytest.raises(ValueError, match="no attachment neighbours"):
        handle.rows([n + 5])


def test_pending_arrivals_served_cold_from_their_attach_map():
    """A scheduled-but-not-yet-admitted arrival is cold, and
    ``for_engine`` defaults its neighbours from the arrival attach map;
    pending ids are rejected as neighbours."""
    obj = _quad_problem(n=24, seed=5)
    n, p = obj.n, obj.p
    late = 7
    arrival = ArrivalConfig(schedule=((1000, (late,)),), attach={late: (1, 4)})
    eng = AsyncEngine(
        CDUpdate(obj),
        slot_wakes=6.0,
        seed=0,
        dtype=jnp.float64,
        scenario=Scenario(arrival=arrival),
    )
    handle = ServeHandle.for_engine(eng)
    assert handle.spec.neighbors == {late: (1, 4)}
    res = eng.run(np.zeros((n, p)), 3, snapshot_every=3, serve=handle)

    got = handle.rows([late])
    assert bool(got.cold[0])  # scheduled far in the future: still pending
    want = res.Theta[[1, 4]].astype(np.float32).mean(axis=0)
    np.testing.assert_allclose(got.values[0], want, rtol=1e-6)
    with pytest.raises(ValueError, match="not established"):
        handle.rows([n + 1], neighbors={n + 1: (late, 1)})


# -- checkpoint serving ------------------------------------------------------


@pytest.mark.parametrize("sharded", [False, True])
def test_serve_from_checkpoint_round_trip(sharded, tmp_path):
    obj = _quad_problem(n=40, seed=6)
    n, p = obj.n, obj.p
    eng = _engines(obj)[int(sharded)]
    ck = str(tmp_path / "ck")
    res = eng.run(np.zeros((n, p)), 4, checkpoint_every=2, checkpoint_dir=ck)

    handle = serve_from_checkpoint(ck)
    assert (handle.n, handle.p, handle.version) == (n, p, 4)
    ids = np.arange(n)
    assert np.array_equal(
        handle.rows(ids).values, res.Theta[ids].astype(np.float32)
    )
    want = res.Theta[[0, 3]].astype(np.float32).mean(axis=0)
    cold = handle.rows([n + 9], neighbors={n + 9: (0, 3)})
    np.testing.assert_allclose(cold.values[0], want, rtol=1e-6)
    with pytest.raises(RuntimeError, match="not bound to a live engine"):
        handle.publish(res.state)


def test_serve_from_checkpoint_fingerprint_rejection_matrix(tmp_path):
    obj = _quad_problem(n=32, seed=7)
    n, p = obj.n, obj.p
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    res = eng.run(np.zeros((n, p)), 2)
    ck = str(tmp_path / "ck")
    save_engine_checkpoint(eng, res.state, ck)

    # every expected-fingerprint key must match exactly, and the error
    # names the offending key
    for key, bogus in (("n", n + 1), ("dtype", "float32"), ("engine", "sharded")):
        with pytest.raises(CheckpointError, match=f"mismatch on '{key}'"):
            serve_from_checkpoint(ck, expect_fingerprint={key: bogus})
    # a matching subset serves fine
    handle = serve_from_checkpoint(
        ck, expect_fingerprint={"n": n, "engine": "async", "dynamic": False}
    )
    assert handle.version == 2

    # non-engine checkpoints are rejected by kind
    plain = str(tmp_path / "plain")
    save_checkpoint(plain, {"theta": np.zeros((4, 2))})
    with pytest.raises(CheckpointError, match="not an engine checkpoint"):
        serve_from_checkpoint(plain)

    # a tampered entry fails sha256 verification before serving
    npzs = sorted(
        os.path.join(root, f)
        for root, _dirs, files in os.walk(ck)
        for f in files
        if f.endswith(".npz")
    )
    with open(npzs[0], "r+b") as f:
        f.seek(60)
        f.write(b"\xde\xad")
    with pytest.raises(CheckpointError):
        serve_from_checkpoint(ck)


# -- counters / obs ----------------------------------------------------------


def test_serve_counters_and_version_lag():
    from repro.obs import SERVE_COUNTERS, serve_counters_init

    assert "serve_version_lag" in SERVE_COUNTERS
    assert serve_counters_init()["serve_version_lag"] == 0

    obj = _quad_problem(n=32, seed=8)
    n, p = obj.n, obj.p
    eng = AsyncEngine(CDUpdate(obj), slot_wakes=8.0, seed=0, dtype=jnp.float64)
    handle = ServeHandle.for_engine(eng)
    half = eng.run(np.zeros((n, p)), 2, snapshot_every=2, serve=handle)
    stale = handle.snapshot()  # version 2
    eng.run(None, 4, state=half.state, snapshot_every=2, serve=handle)

    handle.predict([1, 2, 3], np.ones((3, p)))  # current: lag 0
    c = handle.counters()
    assert c["serve_version_lag"] == 0
    handle.predict([1], np.ones((1, p)), at=stale)  # 4 slots behind
    c = handle.counters()
    assert c["serve_version_lag"] == 4
    assert c["serve_version_lag_max"] == 4
    assert c["serve_requests"] == 2
    assert c["serve_predictions"] == 4
    assert c["serve_batch_rows_max"] == 3
    assert set(c) == set(SERVE_COUNTERS)


def test_deprecated_launch_serve_stub_forwards():
    import repro.launch.serve as old

    with pytest.warns(DeprecationWarning, match="repro.serve"):
        with pytest.raises(SystemExit):  # unknown flag dies in the new CLI
            old.main(["--definitely-not-a-flag"])


# -- multi-shard routing (subprocess, 8 host devices) ------------------------

SERVE_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import AgentData, knn_graph, make_objective
    from repro.serve import ServeHandle, serve_from_checkpoint
    from repro.sim import CDUpdate, ShardedAsyncEngine
    from repro.sim.partition import GraphPartition
    from repro.checkpoint import save_engine_checkpoint

    assert len(jax.devices()) == 8

    n, p, m = 96, 4, 3
    rng = np.random.default_rng(11)
    graph = knn_graph(rng.normal(size=(n, 8)), k=8)
    targets = rng.normal(size=(n, p)) / np.sqrt(p)
    X = rng.normal(size=(n, m, p)) / np.sqrt(p)
    y = np.einsum("nmp,np->nm", X, targets)
    obj = make_objective(graph, AgentData(X=X, y=y, mask=np.ones((n, m))),
                         "quadratic", mu=0.5, mix_mode="sparse")
    eng = ShardedAsyncEngine(CDUpdate(obj), num_shards=4, slot_wakes=8.0,
                             seed=0, dtype=jnp.float64, relabel="rcm")
    handle = ServeHandle.for_engine(eng)

    half = eng.run(np.zeros((n, p)), 3, snapshot_every=3, serve=handle)
    pinned = handle.snapshot()
    assert pinned.version == 3 == half.slots
    final = eng.run(None, 3, state=half.state, snapshot_every=3, serve=handle)
    assert handle.version == 6 == final.slots

    ids = np.arange(n)
    # Mid-training version pinned across further training: bit-exact vs
    # the engine's Theta at its publication slot.
    assert np.array_equal(handle.rows(ids, at=pinned).values,
                          half.Theta[ids].astype(np.float32))
    assert np.array_equal(handle.rows(ids).values,
                          final.Theta[ids].astype(np.float32))
    # One-hot predict: the full batched path returns exact Theta entries
    # routed through shard_of/local_of.
    pr = handle.predict(ids, np.eye(p)[np.full(n, 2)])
    assert np.array_equal(pr.values, final.Theta[:, 2].astype(np.float32))
    print("SERVE_CONSISTENCY_OK")

    # Probe: serving (live publish/predict AND checkpoint-serve) never
    # assembles a global (n, p) float array.
    def _is_float(arr):
        dt = str(arr.dtype) if hasattr(arr, "dtype") else str(np.asarray(arr).dtype)
        return "float" in dt or dt == "bfloat16"
    pad, unpad = GraphPartition.pad_rows, GraphPartition.unpad_rows
    gt = ShardedAsyncEngine.global_theta
    def trap_pad(part, rows, *a, **k):
        if np.ndim(rows) >= 2 and np.shape(rows)[0] == part.n and _is_float(rows):
            raise AssertionError(f"pad_rows saw a global array: {np.shape(rows)}")
        return pad(part, rows, *a, **k)
    def trap_unpad(part, tiles, *a, **k):
        if np.ndim(tiles) >= 3 and _is_float(tiles):
            raise AssertionError(f"unpad_rows: {np.shape(tiles)}")
        return unpad(part, tiles, *a, **k)
    def trap_gt(engine, s):
        raise AssertionError("global_theta on the serve path")

    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        save_engine_checkpoint(eng, final.state, ck)
        GraphPartition.pad_rows, GraphPartition.unpad_rows = trap_pad, trap_unpad
        ShardedAsyncEngine.global_theta = trap_gt
        try:
            handle.publish(final.state)
            live_rows = handle.rows(ids).values
            offline = serve_from_checkpoint(ck)
            off_rows = offline.rows(ids).values
            cold = offline.rows([n + 1], neighbors={n + 1: (0, 9)}).values
        finally:
            GraphPartition.pad_rows, GraphPartition.unpad_rows = pad, unpad
            ShardedAsyncEngine.global_theta = gt
    assert np.array_equal(live_rows, final.Theta[ids].astype(np.float32))
    assert np.array_equal(off_rows, final.Theta[ids].astype(np.float32))
    assert np.allclose(cold[0], final.Theta[[0, 9]].astype(np.float32).mean(0),
                       rtol=1e-6)
    assert offline.version == 6
    print("SERVE_PROBE_OK")
    """
)


def _run_multidev(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("JAX_ENABLE_X64", None)
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )


@pytest.mark.slow
def test_sharded_multidevice_serve_consistency_and_probe():
    """S=4 on 8 host devices: mid-training versions bit-exact at their
    publication slot, one-hot predicts exact through the shard routing,
    and neither live nor checkpoint serving materializes (n, p)."""
    res = _run_multidev(SERVE_SCRIPT)
    assert res.returncode == 0, res.stderr[-3000:]
    for sentinel in ("SERVE_CONSISTENCY_OK", "SERVE_PROBE_OK"):
        assert sentinel in res.stdout, res.stdout


# keep the import exercised: ShardedSimState is the published tile type
def test_published_tiles_are_the_engines_own_state():
    obj = _quad_problem(n=24, seed=9)
    eng = ShardedAsyncEngine(
        CDUpdate(obj), num_shards=1, slot_wakes=6.0, seed=0, dtype=jnp.float64
    )
    handle = ServeHandle.for_engine(eng)
    state = eng.init_state(np.zeros((obj.n, obj.p)))
    assert isinstance(state, ShardedSimState)
    handle.publish(state)
    # zero-copy: the snapshot holds the engine's own immutable buffer
    assert handle.snapshot().tiles is state.Theta
