"""Property-based round-trip tests for the checkpoint layer (hypothesis).

Any pytree of arrays over the supported dtype zoo — including bf16 (which
ships as a uint16 view), empty arrays, and 0-d scalars — must survive
save -> load bit-for-bit, at any ``max_shard_bytes`` grouping.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint

_DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint16, jnp.bool_]
_SHAPES = [(), (0,), (1,), (3, 2), (2, 0, 4), (5,)]


def _leaf(draw_i, shape, dtype):
    rng = np.random.default_rng(draw_i)
    if dtype == jnp.bool_:
        return jnp.asarray(rng.random(shape) < 0.5)
    if dtype in (jnp.int32, jnp.uint16):
        return jnp.asarray(rng.integers(0, 1000, size=shape), dtype)
    return jnp.asarray(rng.normal(size=shape), dtype)


leaves = st.builds(
    _leaf,
    st.integers(min_value=0, max_value=2**31 - 1),
    st.sampled_from(_SHAPES),
    st.sampled_from(_DTYPES),
)

trees = st.recursive(
    leaves,
    lambda children: st.one_of(
        st.dictionaries(
            st.sampled_from(list("abcdef")), children, min_size=1, max_size=3
        ),
        st.lists(children, min_size=1, max_size=3),
    ),
    max_leaves=6,
)


def _assert_same(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        assert np.shape(x) == np.shape(y)
        np.testing.assert_array_equal(
            np.asarray(x, np.float64), np.asarray(y, np.float64)
        )


@given(tree=trees, step=st.integers(min_value=0, max_value=10**9))
@settings(max_examples=25, deadline=None)
def test_roundtrip_preserves_values_dtypes_and_step(tree, step):
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        save_checkpoint(ck, tree, step=step, extra={"tag": "prop"})
        like = jax.tree.map(jnp.zeros_like, tree)
        restored, got_step, extra = load_checkpoint(ck, like)
        assert got_step == step and extra["tag"] == "prop"
        _assert_same(tree, restored)


@given(tree=trees, max_shard_bytes=st.sampled_from([1, 128, 1 << 10, 1 << 30]))
@settings(max_examples=25, deadline=None)
def test_roundtrip_invariant_to_shard_grouping(tree, max_shard_bytes):
    """The on-disk grouping of leaves into npz files is a pure layout
    choice — it must never change what loads back."""
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        save_checkpoint(ck, tree, max_shard_bytes=max_shard_bytes)
        restored, _, _ = load_checkpoint(ck, jax.tree.map(jnp.zeros_like, tree))
        _assert_same(tree, restored)


@given(dtype=st.sampled_from(_DTYPES), shape=st.sampled_from(_SHAPES))
@settings(max_examples=30, deadline=None)
def test_every_dtype_shape_cell_roundtrips(dtype, shape):
    """The full dtype x shape matrix, one leaf at a time — includes the
    bf16 uint16-view codec on empty and 0-d arrays."""
    leaf = _leaf(7, shape, dtype)
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ck")
        save_checkpoint(ck, {"x": leaf})
        restored, _, _ = load_checkpoint(ck, {"x": jnp.zeros_like(leaf)})
        assert restored["x"].dtype == leaf.dtype
        assert np.shape(restored["x"]) == shape
        np.testing.assert_array_equal(
            np.asarray(restored["x"], np.float64), np.asarray(leaf, np.float64)
        )
