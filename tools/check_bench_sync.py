"""Check that the two BENCH_summary.json copies agree.

``benchmarks/run.py`` writes the perf trajectory once under
``results/BENCH_summary.json`` and copies it byte-identical to the repo
root, where the perf-history tooling looks. This guard fails when the
copies drift — e.g. someone hand-edits one, or a tool writes only one of
them — comparing parsed JSON so formatting-only differences (which the
copy step makes impossible anyway) do not mask a real divergence.
Run from the repo root:

    python tools/check_bench_sync.py
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT_COPY = Path("BENCH_summary.json")
RESULTS_COPY = Path("results/BENCH_summary.json")


def check(root_copy: Path = ROOT_COPY, results_copy: Path = RESULTS_COPY) -> list[str]:
    """Return human-readable errors; empty when the copies are in sync."""
    present = [p for p in (root_copy, results_copy) if p.exists()]
    if not present:
        # A fresh checkout before any bench ran has neither copy; nothing
        # to compare, nothing to flag.
        return []
    if len(present) == 1:
        return [f"{present[0]} exists but its counterpart does not"]
    try:
        a = json.loads(root_copy.read_text())
        b = json.loads(results_copy.read_text())
    except json.JSONDecodeError as e:
        return [f"unparseable BENCH_summary.json: {e}"]
    if a == b:
        return []
    ka, kb = set(a), set(b)
    errors = []
    for name in sorted(ka ^ kb):
        where = root_copy if name in ka else results_copy
        errors.append(f"entry {name!r} only in {where}")
    for name in sorted(ka & kb):
        if a[name] != b[name]:
            errors.append(
                f"entry {name!r} differs: {root_copy}={a[name]!r} "
                f"{results_copy}={b[name]!r}"
            )
    return errors


def main() -> int:
    errors = check()
    for err in errors:
        print(f"bench-sync: {err}", file=sys.stderr)
    if errors:
        print(
            "bench-sync: BENCH_summary.json and results/BENCH_summary.json have "
            "drifted; re-run `python -m benchmarks.run` (it writes once and "
            "copies) or copy the authoritative file over the stale one.",
            file=sys.stderr,
        )
        return 1
    print("bench-sync: BENCH_summary.json copies in sync")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
