"""Check that relative markdown links in README.md, ROADMAP.md and docs/ resolve.

Scans ``[text](target)`` links (and reference-style ``[text]: target``
definitions), skips absolute URLs / anchors / mailto, resolves each
target against the file it appears in, and fails if any target is
missing on disk. Module/function paths written as ``path#anchor`` are
checked for the file part only. Run from the repo root:

    python tools/check_docs_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REFDEF = re.compile(r"^\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path, root: Path) -> list[str]:
    """Return human-readable errors for the dangling links in ``md``."""
    text = md.read_text(encoding="utf-8")
    errors = []
    for match in list(LINK.finditer(text)) + list(REFDEF.finditer(text)):
        target = match.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        # Repo-relative badge-style links like ../../actions/... point at
        # the GitHub UI, not the tree; skip anything that escapes the repo.
        resolved = (md.parent / path_part).resolve()
        try:
            resolved.relative_to(root)
        except ValueError:
            continue
        if not resolved.exists():
            errors.append(f"{md.relative_to(root)}: dangling link -> {target}")
    return errors


def main() -> int:
    """Check every tracked markdown file; return a process exit code."""
    root = Path(__file__).resolve().parent.parent
    files = [
        root / "README.md",
        root / "ROADMAP.md",
        *sorted((root / "docs").glob("*.md")),
    ]
    errors = []
    checked = 0
    for md in files:
        if not md.exists():
            errors.append(f"missing expected doc file: {md.relative_to(root)}")
            continue
        checked += 1
        errors.extend(check_file(md, root))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {checked} files, {len(errors)} dangling links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
